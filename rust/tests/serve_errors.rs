//! Error-path coverage for the structured `ServeError` taxonomy: every
//! variant is constructible through a *real* bad request driven down the
//! serving path (the router — the same code `Server::run_trace` uses per
//! batch), and tests assert the VARIANT, not message text — the point of
//! replacing `anyhow` in the public coordinator API.

use shira::adapter::sparse::SparseDelta;
use shira::adapter::{LoraAdapter, LoraTensor, ShiraAdapter};
use shira::coordinator::engine::Router;
use shira::coordinator::error::ServeError;
use shira::coordinator::selection::Selection;
use shira::coordinator::store::AdapterStore;
use shira::model::tensor::Tensor2;
use shira::model::weights::WeightStore;
use shira::util::rng::Rng;

const DIM: usize = 16;

fn base_weights() -> WeightStore {
    WeightStore::init(&[("wq".into(), vec![DIM, DIM])], 3)
}

fn shira(name: &str, target: &str, dim: usize) -> ShiraAdapter {
    let mut rng = Rng::new(7);
    let idx = rng.sample_indices(dim * dim, 8);
    let mut d = vec![0.0; 8];
    rng.fill_normal(&mut d, 0.0, 0.5);
    ShiraAdapter {
        name: name.into(),
        strategy: "rand".into(),
        tensors: vec![(target.into(), SparseDelta::new(dim, dim, idx, d))],
    }
}

fn lora(name: &str) -> LoraAdapter {
    let mut rng = Rng::new(9);
    let mut a = Tensor2::zeros(DIM, 2);
    let mut b = Tensor2::zeros(2, DIM);
    rng.fill_normal(&mut a.data, 0.0, 0.1);
    rng.fill_normal(&mut b.data, 0.0, 0.1);
    LoraAdapter {
        name: name.into(),
        scale: 1.0,
        tensors: vec![LoraTensor { target: "wq".into(), a, b }],
    }
}

fn setup() -> (AdapterStore, Router) {
    let mut store = AdapterStore::new(1 << 20);
    store.add_shira(&shira("good", "wq", DIM));
    store.add_shira(&shira("good2", "wq", DIM));
    store.add_lora(&lora("lowrank"));
    (store, Router::new(base_weights(), None, false))
}

#[test]
fn unknown_adapter_single_and_set() {
    let (mut store, mut router) = setup();
    assert!(matches!(
        router.apply(&mut store, &Selection::single("ghost")),
        Err(ServeError::UnknownAdapter(n)) if n == "ghost"
    ));
    assert!(matches!(
        router.apply(
            &mut store,
            &Selection::set(&[("good", 1.0), ("ghost", 1.0)])
        ),
        Err(ServeError::UnknownAdapter(n)) if n == "ghost"
    ));
    // The router stays serviceable after an error.
    router.apply(&mut store, &Selection::single("good")).unwrap();
}

#[test]
fn lora_in_a_fused_set_is_not_shira() {
    let (mut store, mut router) = setup();
    assert!(matches!(
        router.apply(
            &mut store,
            &Selection::set(&[("good", 1.0), ("lowrank", 0.5)])
        ),
        Err(ServeError::NotShira(n)) if n == "lowrank"
    ));
    // LoRA is fine as a single (dense fuse) — only fused sets are
    // SHiRA-only.
    router
        .apply(&mut store, &Selection::single("lowrank"))
        .unwrap();
}

#[test]
fn malformed_specs_are_invalid_selection() {
    for spec in ["a++b", "a@x", "@1", "a@", "+"] {
        assert!(
            matches!(
                Selection::parse(spec),
                Err(ServeError::InvalidSelection { .. })
            ),
            "{spec:?}"
        );
    }
    // Hand-built selections with metacharacter names are rejected on the
    // request path too (the fused-roster guard).
    let (mut store, mut router) = setup();
    assert!(matches!(
        router.apply(&mut store, &Selection::single("a+b")),
        Err(ServeError::InvalidSelection { .. })
    ));
    assert!(matches!(
        router.apply(&mut store, &Selection::Set { members: vec![] }),
        Err(ServeError::InvalidSelection { .. })
    ));
}

#[test]
fn duplicate_members_are_their_own_variant() {
    assert!(matches!(
        Selection::parse("a+a@2"),
        Err(ServeError::DuplicateMember(n)) if n == "a"
    ));
    let (mut store, mut router) = setup();
    assert!(matches!(
        router.apply(
            &mut store,
            &Selection::Set {
                members: vec![("good".into(), 1.0), ("good".into(), 2.0)]
            }
        ),
        Err(ServeError::DuplicateMember(n)) if n == "good"
    ));
}

#[test]
fn shape_mismatch_surfaces_structured() {
    // An adapter whose delta shape disagrees with the resident tensor:
    // the fused-mode activation reports target + both shapes.
    let (mut store, mut router) = setup();
    store.add_shira(&shira("tiny", "wq", DIM / 2));
    match router.apply(&mut store, &Selection::set(&[("tiny", 1.0)])) {
        Err(ServeError::ShapeMismatch { target, expect, got }) => {
            assert_eq!(target, "wq");
            assert_eq!(expect, (DIM / 2, DIM / 2)); // the plan's shape
            assert_eq!(got, (DIM, DIM)); // the resident tensor
        }
        other => panic!("expected ShapeMismatch, got {other:?}"),
    }
}

#[test]
fn missing_target_rides_the_fusion_variant() {
    let (mut store, mut router) = setup();
    store.add_shira(&shira("offtarget", "nope", DIM));
    assert!(matches!(
        router.apply(&mut store, &Selection::set(&[("offtarget", 1.0)])),
        Err(ServeError::Fusion(
            shira::coordinator::fusion::FusionError::MissingTarget(t)
        )) if t == "nope"
    ));
}

#[test]
fn failed_set_apply_does_not_leave_a_stale_active_key() {
    // Regression: a Set apply that reverts the live single and THEN
    // fails (unknown member) must not leave the router believing the
    // single is still resident — the next request for that single has
    // to actually re-apply it, not no-op against base weights.
    let (mut store, mut router) = setup();
    let base = base_weights();
    router.apply(&mut store, &Selection::single("good")).unwrap();
    let applied = router.weights().clone();
    assert!(applied.max_abs_diff(&base) > 0.0, "single visibly applied");
    assert!(matches!(
        router.apply(
            &mut store,
            &Selection::set(&[("good", 1.0), ("ghost", 1.0)])
        ),
        Err(ServeError::UnknownAdapter(_))
    ));
    // The failed set reverted the single; the router must know that.
    assert!(router.weights().bit_equal(&base));
    let again = router.apply(&mut store, &Selection::single("good")).unwrap();
    assert!(again.switched, "stale active key suppressed the re-apply");
    assert!(router.weights().bit_equal(&applied));
}

#[test]
fn error_paths_release_every_pin() {
    // Pin-leak audit (DESIGN.md §13): after ANY failed apply the store
    // must hold pins only for what is actually resident — the active
    // single, or nothing — and never an in-flight transition plan.
    // Each case drives one ServeError variant down the router and
    // checks the pinned counts return to the live-single baseline.
    use shira::coordinator::fault::FaultPlan;
    let (mut store, mut router) = setup();
    store.add_shira(&shira("tiny", "wq", DIM / 2));
    store.add_shira(&shira("offtarget", "nope", DIM));
    store.add_encoded("junk", vec![0xAB; 64]);
    router.apply(&mut store, &Selection::single("good")).unwrap();
    assert_eq!(store.pinned_count(), 1, "baseline: the active single");
    let cases: Vec<(&str, Selection)> = vec![
        ("unknown-adapter", Selection::single("ghost")),
        (
            "unknown-adapter",
            Selection::set(&[("good", 1.0), ("ghost", 1.0)]),
        ),
        (
            "not-shira",
            Selection::set(&[("good", 1.0), ("lowrank", 1.0)]),
        ),
        ("invalid-selection", Selection::single("a+b")),
        (
            "duplicate-member",
            Selection::Set {
                members: vec![("good".into(), 1.0), ("good".into(), 2.0)],
            },
        ),
        ("shape-mismatch", Selection::set(&[("tiny", 1.0)])),
        ("fusion", Selection::set(&[("offtarget", 1.0)])),
        ("io", Selection::single("junk")),
    ];
    for (kind, sel) in &cases {
        let err = router.apply(&mut store, sel).unwrap_err();
        assert_eq!(err.kind(), *kind, "case drives the intended variant");
        assert!(
            store.pinned_count() <= 1,
            "{kind}: error path leaked pins ({} pinned)",
            store.pinned_count()
        );
        assert_eq!(
            store.pinned_plan_count(),
            0,
            "{kind}: error path leaked a transition-plan pin"
        );
        // Re-establish the live single; the count must come back to the
        // baseline exactly (a leak would grow it monotonically).
        router.apply(&mut store, &Selection::single("good")).unwrap();
        assert_eq!(store.pinned_count(), 1, "{kind}: baseline restored");
        assert!(store.is_pinned("good"));
    }
    // MutationRolledBack: a rolled-back apply pins nothing at all.
    router.set_fault(FaultPlan::new().panic_wave_at(1).injector());
    let err = router
        .apply(&mut store, &Selection::single("good2"))
        .unwrap_err();
    assert_eq!(err.kind(), "mutation-rolled-back");
    assert_eq!(store.pinned_count(), 0, "rollback releases every pin");
    assert_eq!(store.pinned_plan_count(), 0);
    router.apply(&mut store, &Selection::single("good")).unwrap();
    assert_eq!(store.pinned_count(), 1);
}

#[test]
fn adapter_quarantine_ttl_lifecycle_through_the_router() {
    // End-to-end adapter-quarantine lifecycle (DESIGN.md §13.3) driven
    // down the REAL serving path — the router, not store unit calls:
    // a terminal fetch failure quarantines the adapter; while the TTL
    // runs the store refuses with a retry_in_ms hint and never touches
    // flash; after expiry one re-probe goes through — a failed probe
    // re-quarantines, a clean probe fully recovers the adapter.
    use shira::coordinator::fault::FaultPlan;
    use shira::coordinator::store::StoreConfig;
    use std::time::Duration;
    const TTL_MS: u64 = 40;
    let mut store = AdapterStore::with_config(
        StoreConfig {
            cache_bytes: 1 << 20,
            prefetch_depth: 0,
            retry_max: 0, // every injected fetch failure is terminal
            retry_backoff_us: 0,
            quarantine_threshold: 1,
            quarantine_ttl_ms: TTL_MS,
            ..StoreConfig::default()
        },
        None,
    );
    store.add_shira(&shira("flaky", "wq", DIM));
    store.add_shira(&shira("good", "wq", DIM));
    let mut router = Router::new(base_weights(), None, false);
    // Flash-read ordinals: 1 = flaky's first fetch (fails, quarantines),
    // 2 = good's fetch (clean), 3 = flaky's first re-probe (fails,
    // re-quarantines), 4 = flaky's second re-probe (clean).  Refused
    // fetches never reach flash, so they consume no ordinal.
    store.set_fault(FaultPlan::new().fail_fetch_at(1).fail_fetch_at(3).injector());

    // 1) Terminal failure trips the quarantine at threshold 1.
    match router.apply(&mut store, &Selection::single("flaky")) {
        Err(ServeError::Quarantined { name, failures, retry_in_ms }) => {
            assert_eq!(name, "flaky");
            assert_eq!(failures, 1);
            assert!(retry_in_ms <= TTL_MS);
        }
        other => panic!("expected Quarantined, got {other:?}"),
    }
    assert!(store.is_quarantined("flaky"));
    assert_eq!(store.stats().quarantines, 1);

    // 2) While the TTL runs: refused with a hint, flash untouched.
    match router.apply(&mut store, &Selection::single("flaky")) {
        Err(ServeError::Quarantined { failures, retry_in_ms, .. }) => {
            assert_eq!(failures, 1, "a refused fetch is not a new failure");
            assert!(retry_in_ms <= TTL_MS);
        }
        other => panic!("expected Quarantined refusal, got {other:?}"),
    }
    // The router stays serviceable for everything else meanwhile.
    router.apply(&mut store, &Selection::single("good")).unwrap();

    // 3) TTL expiry lets one probe through — and this probe fails, so
    // the adapter is re-quarantined with a grown failure streak.
    std::thread::sleep(Duration::from_millis(TTL_MS + 15));
    match router.apply(&mut store, &Selection::single("flaky")) {
        Err(ServeError::Quarantined { failures, .. }) => {
            assert_eq!(failures, 2, "failed probe re-quarantines");
        }
        other => panic!("expected re-quarantine, got {other:?}"),
    }
    assert!(store.is_quarantined("flaky"));
    assert_eq!(store.stats().quarantines, 2);

    // 4) Second expiry, clean probe: the adapter fully recovers and the
    // apply lands bit-identically to a never-quarantined serve.
    std::thread::sleep(Duration::from_millis(TTL_MS + 15));
    let res = router.apply(&mut store, &Selection::single("flaky"));
    assert!(res.is_ok(), "clean probe must recover: {res:?}");
    assert!(!store.is_quarantined("flaky"));
    let mut reference = base_weights();
    for (t, d) in &shira("flaky", "wq", DIM).tensors {
        d.apply(reference.get_mut(t), 1.0);
    }
    assert!(router.weights().bit_equal(&reference));
    // Fully healthy again: the next switch needs no probe at all.
    router.apply(&mut store, &Selection::single("good")).unwrap();
    router.apply(&mut store, &Selection::single("flaky")).unwrap();
    assert_eq!(store.stats().quarantines, 2, "no further trips");
}

#[test]
fn corrupt_flash_bytes_are_io() {
    let (mut store, mut router) = setup();
    store.add_encoded("junk", vec![0xAB; 64]);
    assert!(matches!(
        router.apply(&mut store, &Selection::single("junk")),
        Err(ServeError::Io(_))
    ));
}

#[test]
fn every_error_kind_has_a_stable_label() {
    // kind() gives callers a stable log/counter key per variant.
    assert_eq!(ServeError::UnknownAdapter("x".into()).kind(), "unknown-adapter");
    assert_eq!(ServeError::NotShira("x".into()).kind(), "not-shira");
    assert_eq!(
        ServeError::InvalidSelection { spec: "a@".into(), reason: "w".into() }.kind(),
        "invalid-selection"
    );
    assert_eq!(ServeError::Runtime("x".into()).kind(), "runtime");
    assert_eq!(
        ServeError::Overloaded {
            selection: "a".into(),
            replicas: 2,
            queue_depth: 4
        }
        .kind(),
        "overloaded"
    );
}

/// Artifact-gated: builder-level UnknownModel through the real Server.
#[test]
fn unknown_model_from_the_builder() {
    use shira::coordinator::server::Server;
    use shira::runtime::manifest::Manifest;
    use shira::runtime::Runtime;
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let base = WeightStore::new();
    assert!(matches!(
        Server::builder(&rt, base).model("nonexistent").build(),
        Err(ServeError::UnknownModel(n)) if n == "nonexistent"
    ));
}
