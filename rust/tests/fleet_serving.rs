//! Fleet acceptance tests (DESIGN.md §14): a seeded bursty 10k-user
//! trace served by 2- and 8-replica fleets must produce per-request
//! outcomes AND final resident weights bit-identical to the
//! single-replica serial reference — verified three ways:
//!
//! * the fleet's own bit-identity oracle (every replica checked against
//!   a fault-free serial [`Router`] after every apply) stays green;
//! * the per-request terminal-disposition record (`FleetReport.actions`)
//!   is equal across replica counts;
//! * each replica's final resident weights are re-derived here from an
//!   independent serial router and compared byte-for-byte.
//!
//! Any failing configuration replays its exact interleaving from
//! `(trace seed, schedule seed)` alone — asserted by the replay test.
//!
//! The CI replica-matrix job runs this file once per replica count via
//! `FLEET_REPLICAS` (see .github/workflows/ci.yml).

use shira::coordinator::engine::Router;
use shira::coordinator::fleet::{Fleet, FleetReport};
use shira::coordinator::selection::Selection;
use shira::coordinator::store::{AdapterStore, StoreConfig};
use shira::data::synth::{adapter_names, fleet_trace, toy_base, toy_shira_zoo};
use shira::data::trace::{mixed_selections, Request};

const DIM: usize = 32;
const NNZ: usize = 80;
const ZOO: usize = 6;
const TRACE_SEED: u64 = 0xF1EE7;
const SCHEDULE_SEED: u64 = 0x5EED;

fn store_cfg() -> StoreConfig {
    StoreConfig {
        cache_bytes: 64 << 20,
        prefetch_depth: 0,
        plan_cache_bytes: 0,
        ..StoreConfig::default()
    }
}

fn fleet(replicas: usize) -> Fleet {
    let names = adapter_names(ZOO);
    Fleet::builder(toy_base(DIM, TRACE_SEED))
        .replicas(replicas)
        .queue_depth(256)
        .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, TRACE_SEED))
        .store_config(store_cfg())
        .build()
}

fn trace(n: usize, burst: usize) -> Vec<Request> {
    let sels = mixed_selections(&adapter_names(ZOO));
    fleet_trace(&sels, n, burst, TRACE_SEED)
}

/// Run the trace at `replicas` and return the report plus each
/// replica's final active key.
fn run(replicas: usize, trace: &[Request]) -> (FleetReport, Vec<Option<String>>) {
    let mut f = fleet(replicas);
    let report = f.run_trace(trace, SCHEDULE_SEED).unwrap();
    assert!(
        report.oracle_failures.is_empty(),
        "replicas={replicas}: {:?}",
        report.oracle_failures
    );
    let finals = f
        .routers()
        .map(|r| r.active_key().map(str::to_string))
        .collect();
    (report, finals)
}

/// Independently re-derive the reference weights for `key` with a
/// fresh serial router (no fleet machinery at all) and assert `got`
/// matches byte-for-byte.
fn assert_final_weights(replica: usize, key: Option<&str>, got: &shira::model::weights::WeightStore) {
    let names = adapter_names(ZOO);
    let mut store = AdapterStore::with_config(store_cfg(), None);
    for a in &toy_shira_zoo(DIM, &names, NNZ, TRACE_SEED) {
        store.add_shira(a);
    }
    let mut router = Router::new(toy_base(DIM, TRACE_SEED), None, false);
    let sel = match key {
        None | Some("") => Selection::Base,
        Some(k) => Selection::parse(k).unwrap(),
    };
    router.apply(&mut store, &sel).unwrap();
    assert!(
        got.bit_equal(router.weights()),
        "replica {replica}: final resident weights diverge from the serial \
         reference for key {key:?}"
    );
}

#[test]
fn multi_replica_outcomes_match_serial_reference() {
    // The acceptance criterion: 2- and 8-replica fleets on the seeded
    // bursty trace land the same per-request outcomes as the 1-replica
    // serial reference, and every replica's final weights re-derive
    // bit-identically from a standalone serial router.
    let t = trace(300, 8);
    let mut serial_fleet = fleet(1);
    let serial = serial_fleet.run_trace(&t, SCHEDULE_SEED).unwrap();
    assert!(serial.oracle_failures.is_empty(), "{:?}", serial.oracle_failures);
    assert_eq!(serial.served, 300, "serial reference must serve everything");
    assert!(serial.actions.values().all(|&a| a == "served"));
    for (id, r) in serial_fleet.routers().enumerate() {
        assert_final_weights(id, r.active_key(), r.weights());
    }
    for replicas in [2usize, 8] {
        let mut f = fleet(replicas);
        let report = f.run_trace(&t, SCHEDULE_SEED).unwrap();
        assert!(
            report.oracle_failures.is_empty(),
            "replicas={replicas}: {:?}",
            report.oracle_failures
        );
        assert_eq!(
            report.actions, serial.actions,
            "per-request outcomes diverge from the serial reference at \
             {replicas} replicas"
        );
        assert_eq!(report.served, serial.served);
        assert!(report.oracle_checks > 0);
        for (id, r) in f.routers().enumerate() {
            assert_final_weights(id, r.active_key(), r.weights());
        }
        // Work actually spread: with a bursty multi-selection trace at
        // least two replicas must have served something.
        assert!(
            report.per_replica_served.iter().filter(|&&s| s > 0).count() >= 2,
            "affinity router starved all but one replica: {:?}",
            report.per_replica_served
        );
    }
}

#[test]
fn failing_seed_replays_exact_interleaving() {
    // Determinism harness: the same (trace seed, schedule seed) pair
    // reproduces the run bit-for-bit — actions, placement, summary and
    // final weights — so any red configuration replays from its seeds.
    let t = trace(160, 4);
    let (a, fa) = run(2, &t);
    let (b, fb) = run(2, &t);
    assert_eq!(a.actions, b.actions);
    assert_eq!(a.per_replica_served, b.per_replica_served);
    assert_eq!(a.summary, b.summary);
    assert_eq!(fa, fb);
}

#[test]
fn concurrent_mode_matches_serial_outcomes() {
    // Real threads, OS scheduling: placement is nondeterministic but
    // with headroom every request is served, outcomes match the serial
    // reference, the oracle stays green, and final weights re-derive.
    let t = trace(200, 6);
    let (serial, _) = run(1, &t);
    for replicas in [2usize, 8] {
        let mut f = fleet(replicas);
        let report = f.run_trace_concurrent(&t).unwrap();
        assert!(
            report.oracle_failures.is_empty(),
            "replicas={replicas}: {:?}",
            report.oracle_failures
        );
        assert_eq!(report.actions, serial.actions);
        assert_eq!(report.served, serial.served);
        for (id, r) in f.routers().enumerate() {
            assert_final_weights(id, r.active_key(), r.weights());
        }
    }
}

#[test]
fn replica_matrix_from_env() {
    // CI matrix hook: FLEET_REPLICAS picks one fleet size; unset runs a
    // small default sweep so the test is meaningful locally too.
    let counts: Vec<usize> = match std::env::var("FLEET_REPLICAS") {
        Ok(s) => vec![s.parse().expect("FLEET_REPLICAS must be an integer")],
        Err(_) => vec![1, 2, 8],
    };
    let t = trace(120, 4);
    let (serial, _) = run(1, &t);
    for replicas in counts {
        let (report, finals) = run(replicas, &t);
        assert_eq!(report.actions, serial.actions, "replicas={replicas}");
        assert_eq!(report.requests, 120);
        let mut f = fleet(replicas);
        f.run_trace(&t, SCHEDULE_SEED).unwrap();
        for ((id, r), key) in f.routers().enumerate().zip(&finals) {
            assert_eq!(r.active_key().map(str::to_string), *key);
            assert_final_weights(id, r.active_key(), r.weights());
        }
    }
}
