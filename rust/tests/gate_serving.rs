//! Gated-serving acceptance tests (DESIGN.md §17): a seeded all-`Auto`
//! trace served through the learned top-k gate must be **bit-identical
//! and placement-identical** to replaying the same trace with the
//! gate's emitted `Selection::Set`s named explicitly — at 1 and 4
//! worker threads and at 2 and 8 replicas.  Gating happens up front on
//! the ingest thread, so a gated trace is indistinguishable downstream
//! from an explicit one and the whole fleet determinism story carries
//! over unchanged.
//!
//! Also covered here: replay determinism of gated runs from
//! `(trace, schedule, gate)` seeds alone, expert retire-under-traffic
//! never evicting a pinned roster member, and gate faults degrading to
//! the configured `FailurePolicy`.
//!
//! The CI gating job runs this file once per (threads, replicas) cell
//! via `GATE_THREADS` / `GATE_REPLICAS` (see .github/workflows/ci.yml).

use std::sync::Arc;

use shira::coordinator::fault::FaultPlan;
use shira::coordinator::fleet::{Fleet, FleetReport};
use shira::coordinator::pool::{lock_pool, ExpertPool, RetireDisposition, SharedExpertPool};
use shira::coordinator::selection::Selection;
use shira::coordinator::server::FailurePolicy;
use shira::coordinator::store::StoreConfig;
use shira::data::synth::{adapter_names, fleet_trace, toy_base, toy_shira_zoo};
use shira::data::trace::Request;
use shira::train::gate::train_gate;
use shira::util::threadpool::ThreadPool;

const DIM: usize = 32;
const NNZ: usize = 80;
const ZOO: usize = 6;
const TRACE_SEED: u64 = 0x6A7E;
const SCHEDULE_SEED: u64 = 0x5EED;
const GATE_SEED: u64 = 0x9A7E;

fn store_cfg() -> StoreConfig {
    StoreConfig {
        cache_bytes: 64 << 20,
        prefetch_depth: 0,
        plan_cache_bytes: 0,
        ..StoreConfig::default()
    }
}

fn expert_pool() -> SharedExpertPool {
    let pool = ExpertPool::shared(0);
    for n in &adapter_names(ZOO) {
        lock_pool(&pool).register(n).unwrap();
    }
    pool
}

/// A fleet with the trained gate attached.  `threads == 0` means no
/// worker pool (serial scatter); otherwise an N-thread pool.
fn gated_fleet(replicas: usize, threads: usize) -> (Fleet, SharedExpertPool) {
    let names = adapter_names(ZOO);
    let trained = train_gate(&names, 2, 800, GATE_SEED);
    let pool = expert_pool();
    let mut b = Fleet::builder(toy_base(DIM, TRACE_SEED))
        .replicas(replicas)
        .queue_depth(256)
        .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, TRACE_SEED))
        .store_config(store_cfg())
        .gate(Arc::new(trained.gate))
        .expert_pool(Arc::clone(&pool));
    if threads > 0 {
        b = b.pool(Arc::new(ThreadPool::new(threads)));
    }
    (b.build(), pool)
}

/// The same fleet shape with no gate at all — what the explicit replay
/// runs on, so bit-identity cannot come from shared gate state.
fn plain_fleet(replicas: usize, threads: usize) -> Fleet {
    let names = adapter_names(ZOO);
    let mut b = Fleet::builder(toy_base(DIM, TRACE_SEED))
        .replicas(replicas)
        .queue_depth(256)
        .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, TRACE_SEED))
        .store_config(store_cfg());
    if threads > 0 {
        b = b.pool(Arc::new(ThreadPool::new(threads)));
    }
    b.build()
}

fn auto_trace(n: usize) -> Vec<Request> {
    fleet_trace(&[Selection::Auto], n, 4, TRACE_SEED)
}

/// CI matrix hook: GATE_REPLICAS / GATE_THREADS pin one cell; unset
/// runs the full acceptance sweep locally.
fn matrix() -> (Vec<usize>, Vec<usize>) {
    let replicas = match std::env::var("GATE_REPLICAS") {
        Ok(s) => vec![s.parse().expect("GATE_REPLICAS must be an integer")],
        Err(_) => vec![2, 8],
    };
    let threads = match std::env::var("GATE_THREADS") {
        Ok(s) => vec![s.parse().expect("GATE_THREADS must be an integer")],
        Err(_) => vec![1, 4],
    };
    (replicas, threads)
}

#[test]
fn gated_autos_replay_bit_identically_as_explicit_sets() {
    let t = auto_trace(160);
    let (replica_counts, thread_counts) = matrix();
    // Capture the gate's rewrite once: every auto becomes an explicit
    // weighted set.
    let (mut resolver, _) = gated_fleet(2, 0);
    let explicit = resolver.resolve_trace(&t).unwrap();
    assert_eq!(explicit.len(), t.len());
    assert!(explicit
        .iter()
        .all(|q| matches!(q.selection, Selection::Set { .. })));
    for &replicas in &replica_counts {
        // Collected per thread count; everything must agree across
        // thread counts too (the pool parallelizes scatter arithmetic,
        // never scheduling decisions).
        let mut per_thread: Vec<(Vec<u64>, Vec<Option<String>>)> = Vec::new();
        for &threads in &thread_counts {
            let (mut auto_fleet, _) = gated_fleet(replicas, threads);
            let a = auto_fleet.run_trace(&t, SCHEDULE_SEED).unwrap();
            assert!(
                a.oracle_failures.is_empty(),
                "replicas={replicas} threads={threads}: {:?}",
                a.oracle_failures
            );
            assert_eq!(a.gated, 160, "replicas={replicas} threads={threads}");
            assert_eq!(a.served, 160);
            // Explicit replay on a gateless fleet of the same shape.
            let mut exp_fleet = plain_fleet(replicas, threads);
            let r = exp_fleet.run_trace(&explicit, SCHEDULE_SEED).unwrap();
            assert_eq!(r.gated, 0);
            assert_eq!(
                a.actions, r.actions,
                "replicas={replicas} threads={threads}: gated outcomes \
                 diverge from the explicit replay"
            );
            assert_eq!(
                a.per_replica_served, r.per_replica_served,
                "replicas={replicas} threads={threads}: gated placement \
                 diverges from the explicit replay"
            );
            let mut finals: Vec<Option<String>> = Vec::new();
            for (ra, rb) in auto_fleet.routers().zip(exp_fleet.routers()) {
                assert_eq!(ra.active_key(), rb.active_key());
                assert!(
                    ra.weights().bit_equal(rb.weights()),
                    "replicas={replicas} threads={threads}: resident weights \
                     diverge between gated and explicit serving"
                );
                finals.push(ra.active_key().map(str::to_string));
            }
            per_thread.push((a.per_replica_served.clone(), finals));
        }
        for w in per_thread.windows(2) {
            assert_eq!(
                w[0], w[1],
                "replicas={replicas}: thread count changed placement or \
                 final residency"
            );
        }
    }
}

#[test]
fn gated_replay_is_bit_and_placement_identical() {
    // Same (trace, schedule, gate) seeds → the same run, byte for byte:
    // actions, placement, summary, utilization and final weights.
    let t = auto_trace(120);
    let run = || {
        let (mut f, _) = gated_fleet(2, 2);
        let rep: FleetReport = f.run_trace(&t, SCHEDULE_SEED).unwrap();
        let finals: Vec<Option<String>> = f
            .routers()
            .map(|r| r.active_key().map(str::to_string))
            .collect();
        (rep, finals)
    };
    let (a, fa) = run();
    let (b, fb) = run();
    assert_eq!(a.actions, b.actions);
    assert_eq!(a.per_replica_served, b.per_replica_served);
    assert_eq!(a.summary, b.summary);
    assert_eq!(a.expert_utilization, b.expert_utilization);
    assert_eq!(fa, fb);
    assert!(a.summary.contains("gate: gated=120"), "{}", a.summary);
}

#[test]
fn retire_under_traffic_never_evicts_pinned_roster_members() {
    // Serve a gated burst, then retire an expert while a replica still
    // pins its weights: the pool defers eviction (the store keeps the
    // bytes resident and pinned), the roster shrinks immediately, and
    // later gated traffic never selects the retiree.
    let t = auto_trace(60);
    let (mut f, pool) = gated_fleet(2, 0);
    let rep = f.run_trace(&t, SCHEDULE_SEED).unwrap();
    assert_eq!(rep.gated, 60);
    let store = f.store();
    let guard = store.lock().unwrap();
    // Final active selections keep their members pinned: find one.
    let pinned: Vec<String> = adapter_names(ZOO)
        .into_iter()
        .filter(|n| guard.is_pinned(n))
        .collect();
    assert!(!pinned.is_empty(), "end-of-run fleet should hold pins");
    let retiree = &pinned[0];
    let disp = lock_pool(&pool).retire(retiree, &guard).unwrap();
    assert_eq!(disp, RetireDisposition::DeferredPinned);
    // Never evicted: still pinned, still resident, exactly because the
    // retire path has no eviction authority over pinned weights.
    assert!(guard.is_pinned(retiree));
    assert!(guard.is_resident(retiree));
    drop(guard);
    // The roster shrank immediately: future gated selections exclude
    // the retiree even while its bytes remain resident.
    assert!(!lock_pool(&pool).roster().contains(retiree));
    let explicit = f.resolve_trace(&t).unwrap();
    assert!(explicit
        .iter()
        .all(|q| !q.selection.names().contains(&retiree.as_str())));
    // An unpinned retiree is evictable — and still not evicted by the
    // pool itself (disposition only; the store decides under pressure).
    let unpinned: Vec<String> = adapter_names(ZOO)
        .into_iter()
        .filter(|n| !pinned.contains(n))
        .collect();
    if let Some(name) = unpinned.first() {
        let guard = store.lock().unwrap();
        let disp = lock_pool(&pool).retire(name, &guard).unwrap();
        assert_eq!(disp, RetireDisposition::Evictable);
    }
}

#[test]
fn gate_faults_follow_the_failure_policy() {
    let t = auto_trace(40);
    let build = |policy: FailurePolicy| {
        let names = adapter_names(ZOO);
        let trained = train_gate(&names, 2, 800, GATE_SEED);
        Fleet::builder(toy_base(DIM, TRACE_SEED))
            .replicas(2)
            .queue_depth(256)
            .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, TRACE_SEED))
            .store_config(store_cfg())
            .gate(Arc::new(trained.gate))
            .expert_pool(expert_pool())
            .failure_policy(policy)
            .fault_plan(FaultPlan::new().fail_gate_at(2))
            .build()
    };
    // FailFast: the structured gate error surfaces before anything is
    // queued or placed.
    let err = build(FailurePolicy::FailFast)
        .run_trace(&t, SCHEDULE_SEED)
        .unwrap_err();
    assert_eq!(err.kind(), "gate");
    assert!(err.to_string().contains("injected fault"), "{err}");
    // DegradeToBase: the faulted request rides base weights; every
    // request stays terminally accounted.
    let rep = build(FailurePolicy::DegradeToBase)
        .run_trace(&t, SCHEDULE_SEED)
        .unwrap();
    assert_eq!((rep.gated, rep.degraded), (39, 1));
    assert_eq!(rep.served, 40);
    assert_eq!(rep.actions.len(), 40);
    assert!(rep
        .outcomes
        .iter()
        .any(|o| o.action == "gate-degraded-to-base"
            && o.replica.is_none()
            && o.selection == "@auto"));
    // SkipRequest: dropped, but never silently lost.
    let rep = build(FailurePolicy::SkipRequest)
        .run_trace(&t, SCHEDULE_SEED)
        .unwrap();
    assert_eq!((rep.gated, rep.skipped, rep.served), (39, 1, 39));
    assert_eq!(rep.actions.len(), 40);
    assert!(rep.actions.values().any(|&a| a == "gate-skipped"));
}
