//! Chaos property test (DESIGN.md §13): random mixed selection traces
//! driven through the [`Router`] with a seeded deterministic
//! [`FaultPlan`], at 1 and 4 threads.  Whatever faults fire — injected
//! I/O errors, decode corruption, mid-wave panics, latency stalls — the
//! resident weights must stay bit-identical to a fault-free reference:
//!
//! * a successful apply lands on the same bytes as serving that
//!   selection from base on a fault-free router;
//! * a rolled-back mutation lands on base bytes exactly (the zoo is
//!   pure SHiRA, so rollback is bit-exact);
//! * a pre-dispatch store error leaves either the pre-apply bytes or
//!   base (set applies legally revert the outgoing single before the
//!   fallible roster build);
//! * transition-plan pins never outlive an apply, and the router keeps
//!   serving after every failure.
//!
//! The CI chaos job runs this file under a fixed seed matrix via
//! `CHAOS_SEED` (see .github/workflows/ci.yml).

use std::sync::Arc;

use shira::adapter::sparse::SparseDelta;
use shira::adapter::ShiraAdapter;
use shira::coordinator::engine::Router;
use shira::coordinator::error::ServeError;
use shira::coordinator::fault::FaultPlan;
use shira::coordinator::fleet::Fleet;
use shira::coordinator::fusion::fuse_shira;
use shira::coordinator::selection::Selection;
use shira::coordinator::server::FailurePolicy;
use shira::coordinator::store::{AdapterStore, StoreConfig};
use shira::data::synth::{adapter_names, fleet_trace, toy_base, toy_shira_zoo};
use shira::data::trace::mixed_selections;
use shira::model::weights::WeightStore;
use shira::util::rng::Rng;
use shira::util::threadpool::ThreadPool;

const DIM: usize = 64;
/// Crosses the engines' parallel threshold so pooled runs really wave.
const NNZ: usize = 3000;

fn base_weights(seed: u64) -> WeightStore {
    WeightStore::init(
        &[("wq".into(), vec![DIM, DIM]), ("wk".into(), vec![DIM, DIM])],
        seed,
    )
}

fn make_adapter(rng: &mut Rng, name: &str, k: usize) -> ShiraAdapter {
    let mk = |rng: &mut Rng| {
        let idx = rng.sample_indices(DIM * DIM, k);
        let mut d = vec![0.0; k];
        rng.fill_normal(&mut d, 0.0, 0.5);
        SparseDelta::new(DIM, DIM, idx, d)
    };
    ShiraAdapter {
        name: name.into(),
        strategy: "rand".into(),
        tensors: vec![("wq".into(), mk(rng)), ("wk".into(), mk(rng))],
    }
}

fn store_with(zoo: &[ShiraAdapter]) -> AdapterStore {
    // No store pool and no prefetch: fetch/decode fault ordinals then
    // depend only on the apply sequence, so the 1- and 4-thread runs
    // claim identical fault schedules.
    let mut store = AdapterStore::with_config(
        StoreConfig {
            cache_bytes: 64 << 20,
            prefetch_depth: 0,
            ..StoreConfig::default()
        },
        None,
    );
    for a in zoo {
        store.add_shira(a);
    }
    store
}

/// Fault-free reference: what serving `sel` from base makes resident.
fn reference_weights(base: &WeightStore, zoo: &[ShiraAdapter], sel: &Selection) -> WeightStore {
    let by_name = |n: &str| zoo.iter().find(|a| a.name == n).expect("known adapter");
    let scaled = |a: &ShiraAdapter, w: f32| ShiraAdapter {
        name: a.name.clone(),
        strategy: a.strategy.clone(),
        tensors: a
            .tensors
            .iter()
            .map(|(t, d)| (t.clone(), d.scaled(w)))
            .collect(),
    };
    match sel {
        Selection::Base => base.clone(),
        Selection::Single { name, alpha } => {
            let mut w = base.clone();
            for (t, d) in &by_name(name).tensors {
                d.apply(w.get_mut(t), *alpha);
            }
            w
        }
        Selection::Set { members } => {
            let mut sorted = members.clone();
            sorted.sort_by(|a, b| a.0.cmp(&b.0));
            let scaled_members: Vec<ShiraAdapter> = sorted
                .iter()
                .map(|(n, w)| scaled(by_name(n), *w))
                .collect();
            let refs: Vec<&ShiraAdapter> = scaled_members.iter().collect();
            let fused = fuse_shira(&refs, "reference").expect("same target sets");
            let mut w = base.clone();
            for (t, d) in &fused.tensors {
                d.apply(w.get_mut(t), 1.0);
            }
            w
        }
    }
}

/// Deterministic random trace over the 3-adapter zoo.
fn make_trace(seed: u64) -> Vec<Selection> {
    let mut r = Rng::new(seed);
    (0..6 + r.below(6))
        .map(|_| {
            let (i, j) = (r.below(3), r.below(3));
            let (na, nb) = (format!("ad{i}"), format!("ad{j}"));
            let (wa, wb) = (
                -1.5 + 3.0 * r.uniform_f32(),
                -1.5 + 3.0 * r.uniform_f32(),
            );
            match r.below(4) {
                0 => Selection::Base,
                1 | 2 => Selection::single_at(&na, wa),
                _ => {
                    if i == j {
                        Selection::set(&[(na.as_str(), wa)])
                    } else {
                        Selection::set(&[(na.as_str(), wa), (nb.as_str(), wb)])
                    }
                }
            }
        })
        .collect()
}

/// Drive one trace against a fault-armed router and check every
/// invariant after every apply.  Returns (rollbacks, store retries).
fn run_chaos(seed: u64, plan: FaultPlan, threads: usize) -> (u64, u64) {
    let mut rng = Rng::new(seed ^ 0x5EED);
    let zoo: Vec<ShiraAdapter> = (0..3)
        .map(|i| make_adapter(&mut rng, &format!("ad{i}"), NNZ))
        .collect();
    let base = base_weights(seed);
    let pool = (threads > 1).then(|| Arc::new(ThreadPool::new(threads)));
    let mut store = store_with(&zoo);
    let mut router = Router::new(base.clone(), pool, false);
    let injector = plan.injector();
    store.set_fault(Arc::clone(&injector));
    router.set_fault(injector);

    let mut pre_apply = base.clone();
    for (step, sel) in make_trace(seed).iter().enumerate() {
        match router.apply(&mut store, sel) {
            Ok(_) => {
                assert!(
                    router.weights().bit_equal(&reference_weights(&base, &zoo, sel)),
                    "seed {seed:#x} step {step} ({sel}) diverged from the \
                     fault-free reference (threads={threads})"
                );
            }
            Err(ServeError::MutationRolledBack { .. }) => {
                assert!(
                    router.weights().bit_equal(&base),
                    "seed {seed:#x} step {step}: rollback not bit-exact \
                     (threads={threads})"
                );
            }
            Err(_) => {
                // Pre-dispatch failure: nothing mutated beyond the legal
                // outgoing revert — bytes are the pre-apply state or base.
                let w = router.weights();
                assert!(
                    w.bit_equal(&pre_apply) || w.bit_equal(&base),
                    "seed {seed:#x} step {step}: pre-dispatch error left \
                     torn bytes (threads={threads})"
                );
            }
        }
        assert_eq!(
            store.pinned_plan_count(),
            0,
            "seed {seed:#x} step {step}: transition-plan pin outlived apply"
        );
        pre_apply = router.weights().clone();
    }
    router.revert_all(&mut store);
    assert!(
        router.weights().bit_equal(&base),
        "seed {seed:#x}: final revert_all not bit-exact (threads={threads})"
    );
    assert_eq!(store.pinned_count(), 0, "seed {seed:#x}: pins leaked");
    (router.rollbacks(), store.stats().retries)
}

#[test]
fn seeded_fault_plans_never_tear_the_weights() {
    // Fixed seed matrix, extendable from the environment (the CI chaos
    // job runs one seed per matrix entry via CHAOS_SEED).
    let mut seeds: Vec<u64> = vec![0xC0A51, 0xC0A52, 0xC0A53];
    if let Ok(s) = std::env::var("CHAOS_SEED") {
        if let Ok(n) = s.trim().parse::<u64>() {
            seeds.push(n);
        }
    }
    for seed in seeds {
        for threads in [1usize, 4] {
            run_chaos(seed, FaultPlan::seeded(seed, 6, 20), threads);
        }
    }
}

// ---------------------------------------------------------------------
// Fleet chaos (DESIGN.md §14): the same seeded fault plans armed across
// an N-replica fleet — one shared injector, fleet-global ordinals.

/// Build a chaos fleet: seeded zoo, shared store without prefetch (so
/// fault ordinals depend only on the apply sequence), policy under test.
fn chaos_fleet(replicas: usize, seed: u64, policy: FailurePolicy, faults: u64) -> Fleet {
    let names = adapter_names(4);
    Fleet::builder(toy_base(32, seed))
        .replicas(replicas)
        .queue_depth(64)
        .shira_adapters(&toy_shira_zoo(32, &names, 80, seed))
        .store_config(StoreConfig {
            cache_bytes: 64 << 20,
            prefetch_depth: 0,
            plan_cache_bytes: 0,
            ..StoreConfig::default()
        })
        .failure_policy(policy)
        .fault_plan(FaultPlan::seeded(seed, faults, 20))
        .build()
}

fn chaos_trace(seed: u64) -> Vec<shira::data::trace::Request> {
    let sels = mixed_selections(&adapter_names(4));
    fleet_trace(&sels, 160, 4, seed)
}

#[test]
fn fleet_chaos_isolates_faults_between_replicas() {
    // Satellite: at 2 and 8 replicas, seeded fault plans fire inside
    // replica workers.  The fleet oracle checks EVERY replica after
    // every apply and after every handled failure — so a green oracle
    // IS the rollback-isolation assertion: a fault on one replica never
    // perturbed another replica's resident bytes.  Afterwards the
    // fleet-wide pin audit must come back clean.
    let mut seeds: Vec<u64> = vec![0xF1EE1, 0xF1EE2];
    if let Ok(s) = std::env::var("CHAOS_SEED") {
        if let Ok(n) = s.trim().parse::<u64>() {
            seeds.push(n);
        }
    }
    for seed in seeds {
        for replicas in [2usize, 8] {
            for policy in [FailurePolicy::DegradeToBase, FailurePolicy::SkipRequest] {
                let trace = chaos_trace(seed);
                let mut fleet = chaos_fleet(replicas, seed, policy, 6);
                let report = fleet.run_trace(&trace, seed ^ 0xD5).unwrap();
                assert!(
                    report.oracle_failures.is_empty(),
                    "seed {seed:#x} replicas={replicas} {policy:?}: \
                     {:?}",
                    report.oracle_failures
                );
                // Every request reached exactly one terminal action.
                assert_eq!(
                    report.actions.len(),
                    trace.len(),
                    "seed {seed:#x} replicas={replicas}: requests lost"
                );
                // Fleet-wide pin-leak audit.
                fleet.revert_all();
                let store = fleet.store();
                let guard = store.lock().unwrap();
                assert_eq!(guard.pinned_count(), 0, "seed {seed:#x}: pins leaked");
                assert_eq!(
                    guard.pinned_plan_count(),
                    0,
                    "seed {seed:#x}: plan pins leaked"
                );
            }
        }
    }
}

#[test]
fn fleet_chaos_replays_identically_and_faults_really_fire() {
    // The determinism harness holds under injected faults: one shared
    // injector means fleet-global ordinals, so the same (trace seed,
    // schedule seed, fault seed) triple replays the exact interleaving
    // — failures, quarantines and all.
    let seed = 0xF1EE3;
    let run = || {
        let trace = chaos_trace(seed);
        let mut fleet = chaos_fleet(4, seed, FailurePolicy::DegradeToBase, 8);
        fleet.run_trace(&trace, seed ^ 0xD5).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.actions, b.actions);
    assert_eq!(a.per_replica_served, b.per_replica_served);
    assert_eq!(a.summary, b.summary);
    assert!(a.oracle_failures.is_empty(), "{:?}", a.oracle_failures);
    // The plan must have actually perturbed the run somewhere: handled
    // failures, rollbacks or store retries.
    assert!(
        !a.outcomes.is_empty() || a.rollbacks > 0 || a.store.retries > 0,
        "seeded fault plan never fired: {}",
        a.summary
    );
}

#[test]
fn fleet_chaos_concurrent_workers_stay_isolated() {
    // Same fault plans through real worker threads: the oracle checks
    // each replica after its own applies and sweeps the whole fleet
    // after the workers join.
    for replicas in [2usize, 8] {
        let seed = 0xF1EE4 + replicas as u64;
        let trace = chaos_trace(seed);
        let mut fleet = chaos_fleet(replicas, seed, FailurePolicy::SkipRequest, 6);
        let report = fleet.run_trace_concurrent(&trace).unwrap();
        assert!(
            report.oracle_failures.is_empty(),
            "replicas={replicas}: {:?}",
            report.oracle_failures
        );
        assert_eq!(report.actions.len(), trace.len());
        fleet.revert_all();
        let store = fleet.store();
        let guard = store.lock().unwrap();
        assert_eq!(guard.pinned_count(), 0);
        assert_eq!(guard.pinned_plan_count(), 0);
    }
}

// ---------------------------------------------------------------------
// Replica recovery (DESIGN.md §16): crash every replica's first apply
// and require the full quarantine -> drain -> probe -> recover cycle.

/// Build a fleet whose fault plan crashes the FIRST apply on every
/// replica: each one must trip quarantine, drain its queue into the
/// requeue path, pass the recovery bit-gate and end Healthy.
fn recovery_fleet(replicas: usize, seed: u64, ttl_us: u64) -> Fleet {
    let names = adapter_names(4);
    let mut plan = FaultPlan::new();
    for r in 0..replicas {
        plan = plan.crash_replica_at(r, 1);
    }
    Fleet::builder(toy_base(32, seed))
        .replicas(replicas)
        .queue_depth(64)
        .shira_adapters(&toy_shira_zoo(32, &names, 80, seed))
        .store_config(StoreConfig {
            cache_bytes: 64 << 20,
            prefetch_depth: 0,
            plan_cache_bytes: 0,
            ..StoreConfig::default()
        })
        .failure_policy(FailurePolicy::DegradeToBase)
        .quarantine_after(1)
        .replica_quarantine_ttl_us(ttl_us)
        .retry_backoff_us(50)
        .fault_plan(plan)
        .build()
}

#[test]
fn every_replica_recovers_through_quarantine_deterministic() {
    // Tentpole gate: at 2 and 8 replicas every replica is quarantined at
    // least once, every drained request is re-dispatched or terminally
    // accounted (nothing silently lost), every recovered replica passes
    // the bit-identity gate, and the run ends all-Healthy — replay-
    // identical from the same (trace, schedule, fault) seeds.  The CI
    // chaos job re-runs this under its replica-recovery seed matrix via
    // CHAOS_SEED.
    let mut seeds: Vec<u64> = vec![0x5E1F];
    if let Ok(s) = std::env::var("CHAOS_SEED") {
        if let Ok(n) = s.trim().parse::<u64>() {
            seeds.push(n);
        }
    }
    for seed in seeds {
        for replicas in [2usize, 8] {
            let trace = chaos_trace(seed);
            let run = || {
                let mut fleet = recovery_fleet(replicas, seed, 50_000);
                fleet.run_trace(&trace, seed ^ 0xD5).unwrap()
            };
            let a = run();
            assert!(
                a.quarantine_trips >= replicas as u64,
                "seed {seed:#x} replicas={replicas}: only {} trips\n{}",
                a.quarantine_trips,
                a.summary
            );
            assert!(a.probes >= replicas as u64, "{}", a.summary);
            assert!(a.recoveries >= replicas as u64, "{}", a.summary);
            assert!(
                a.replica_health.iter().all(|&h| h == "healthy"),
                "seed {seed:#x} replicas={replicas}: end states {:?}",
                a.replica_health
            );
            assert_eq!(a.quarantined_replicas, 0);
            // Drain accounting: every request reached a terminal action
            // and the dispositions add back up to the trace.
            assert_eq!(a.actions.len(), trace.len());
            assert_eq!(
                a.served + a.shed + a.skipped + a.deadline_exceeded,
                trace.len() as u64
            );
            assert!(a.requeues >= replicas as u64, "{}", a.summary);
            // Recovery bit-gate stayed green across every re-admission.
            assert!(
                a.oracle_failures.is_empty(),
                "seed {seed:#x} replicas={replicas}: {:?}",
                a.oracle_failures
            );
            let b = run();
            assert_eq!(a.actions, b.actions);
            assert_eq!(a.summary, b.summary);
            assert_eq!(a.per_replica_served, b.per_replica_served);
        }
    }
}

#[test]
fn every_replica_recovers_through_quarantine_concurrent() {
    // Same crash-on-first-apply plan through real worker threads and
    // wall-clock TTLs.  Quarantines cascade: while earlier replicas sit
    // out their TTL, traffic lands on the next healthy replica and trips
    // its planned crash too — so every replica really cycles through
    // quarantine -> probe -> probation before the run settles.
    for replicas in [2usize, 8] {
        let seed = 0x5E2F + replicas as u64;
        let trace = chaos_trace(seed);
        let mut fleet = recovery_fleet(replicas, seed, 20_000);
        let report = fleet.run_trace_concurrent(&trace).unwrap();
        assert!(
            report.quarantine_trips >= replicas as u64,
            "replicas={replicas}: only {} trips\n{}",
            report.quarantine_trips,
            report.summary
        );
        assert!(report.probes >= replicas as u64, "{}", report.summary);
        assert!(
            report.replica_health.iter().all(|&h| h == "healthy"),
            "replicas={replicas}: end states {:?}",
            report.replica_health
        );
        assert_eq!(report.actions.len(), trace.len(), "requests lost");
        assert_eq!(
            report.served + report.shed + report.skipped + report.deadline_exceeded,
            trace.len() as u64
        );
        assert!(
            report.oracle_failures.is_empty(),
            "replicas={replicas}: {:?}",
            report.oracle_failures
        );
        fleet.revert_all();
        let store = fleet.store();
        let guard = store.lock().unwrap();
        assert_eq!(guard.pinned_count(), 0);
        assert_eq!(guard.pinned_plan_count(), 0);
    }
}

#[test]
fn slow_fetch_stalls_are_bounded_by_the_fetch_deadline() {
    // Satellite: an injected SlowFetch stall far past the fetch deadline
    // must trip the timeout path (bounded wall time), surface as a
    // transient fault and ride the store's retry — not inflate latency
    // unobserved.
    let mut rng = Rng::new(0x51_0F);
    let zoo: Vec<ShiraAdapter> = (0..3)
        .map(|i| make_adapter(&mut rng, &format!("ad{i}"), NNZ))
        .collect();
    let mut store = AdapterStore::with_config(
        StoreConfig {
            cache_bytes: 64 << 20,
            prefetch_depth: 0,
            fetch_deadline_us: 500,
            retry_backoff_us: 0,
            ..StoreConfig::default()
        },
        None,
    );
    for a in &zoo {
        store.add_shira(a);
    }
    // A 5-second stall against a 500us deadline: without the bound this
    // test would take seconds; with it the stall is clipped and retried.
    let plan = FaultPlan::new().slow_fetch_at(1).slow_us(5_000_000);
    store.set_fault(plan.injector());
    let started = std::time::Instant::now();
    store.fetch("ad0").expect("retry absorbs the timed-out stall");
    assert!(
        started.elapsed() < std::time::Duration::from_secs(2),
        "stall was not clipped by the fetch deadline ({:?})",
        started.elapsed()
    );
    let stats = store.stats();
    assert!(stats.fetch_timeouts >= 1, "timeout never recorded");
    assert!(stats.retries >= 1, "timed-out fetch never retried");
}

#[test]
fn planned_faults_hit_every_resilience_counter() {
    // One deterministic scenario per counter: a transient fetch error is
    // absorbed by the store's retry, and a wave panic rolls back.
    for threads in [1usize, 4] {
        let plan = FaultPlan::new()
            .fail_fetch_at(1)
            .corrupt_decode_at(2)
            .panic_wave_at(2)
            .slow_fetch_at(3)
            .slow_us(50);
        let (rollbacks, retries) = run_chaos(0xFA117, plan, threads);
        assert!(rollbacks >= 1, "planned wave panic never rolled back");
        assert!(retries >= 1, "planned fetch fault never retried");
    }
}
