//! Acceptance tests for the adapter lifecycle subsystem: serving is
//! bit-identical no matter how an adapter reaches the engine (cold miss,
//! cache hit, prefetch) and no matter which on-flash format stored it
//! (v1, v2 or v2-f16 — the latter both f32- and f16-resident).  Runs
//! entirely at the engine level, so no compiled artifacts are needed.

use std::sync::Arc;

use shira::adapter::io::Format;
use shira::adapter::sparse::SparseDelta;
use shira::adapter::ShiraAdapter;
use shira::coordinator::fusion_engine::{FusionEngine, FusionPlan};
use shira::coordinator::store::{AdapterStore, AnyAdapter, StoreConfig};
use shira::coordinator::switch::{SwitchEngine, SwitchPath};
use shira::model::weights::WeightStore;
use shira::util::rng::Rng;
use shira::util::threadpool::ThreadPool;

const DIM: usize = 96;

fn base_weights(seed: u64) -> WeightStore {
    WeightStore::init(
        &[("l0.wq".into(), vec![DIM, DIM]), ("l0.wk".into(), vec![DIM, DIM])],
        seed,
    )
}

fn make_adapter(rng: &mut Rng, name: &str, k: usize) -> ShiraAdapter {
    let mk = |rng: &mut Rng| {
        let idx = rng.sample_indices(DIM * DIM, k);
        let mut d = vec![0.0; k];
        rng.fill_normal(&mut d, 0.0, 0.5);
        SparseDelta::new(DIM, DIM, idx, d)
    };
    ShiraAdapter {
        name: name.into(),
        strategy: "rand".into(),
        tensors: vec![("l0.wq".into(), mk(rng)), ("l0.wk".into(), mk(rng))],
    }
}

fn adapters() -> Vec<ShiraAdapter> {
    // 2 tensors × 3000 nnz crosses the parallel cutoff, so pooled runs
    // exercise the store-built shard plans on the parallel dispatch path.
    let mut rng = Rng::new(0xBEEF);
    (0..4)
        .map(|i| make_adapter(&mut rng, &format!("ad{i}"), 3000))
        .collect()
}

/// The switch sequence a bursty trace would produce.
fn switch_sequence() -> Vec<usize> {
    vec![0, 1, 0, 2, 3, 1, 2, 0, 3, 2]
}

/// Reference: eagerly-decoded adapters through a serial engine, recording
/// the weight bytes after every switch.
fn reference_states(adapters: &[ShiraAdapter]) -> (Vec<WeightStore>, WeightStore) {
    let base = base_weights(7);
    let mut w = base.clone();
    let mut eng = SwitchEngine::new();
    let mut states = Vec::new();
    for &i in &switch_sequence() {
        eng.switch_to_shira(&mut w, &adapters[i], 1.0);
        states.push(w.clone());
    }
    eng.revert(&mut w);
    assert!(w.bit_equal(&base));
    (states, base)
}

fn run_through_store(
    adapters: &[ShiraAdapter],
    format: Format,
    cache_bytes: usize,
    prefetch: bool,
    threads: usize,
    f16_resident: bool,
) -> (Vec<WeightStore>, WeightStore, AdapterStore) {
    let pool = Arc::new(ThreadPool::new(threads));
    let mut store = AdapterStore::with_config(
        StoreConfig {
            cache_bytes,
            format,
            prefetch_depth: if prefetch { 2 } else { 0 },
            f16_resident,
            ..StoreConfig::default()
        },
        Some(Arc::clone(&pool)),
    );
    for a in adapters {
        store.add_shira(a);
    }
    let mut w = base_weights(7);
    let mut eng = SwitchEngine::with_pool(Some(pool));
    let seq = switch_sequence();
    let mut states = Vec::new();
    for (step, &i) in seq.iter().enumerate() {
        if prefetch {
            // trace lookahead: stage the next adapters in the background
            let ahead: Vec<String> = seq[step + 1..]
                .iter()
                .take(2)
                .map(|&j| adapters[j].name.clone())
                .collect();
            store.prefetch(&ahead);
        }
        let h = store.fetch(&adapters[i].name).unwrap();
        match &h.adapter {
            AnyAdapter::Shira(a) => {
                eng.switch_to_shira_planned(
                    &mut w,
                    Arc::clone(a),
                    Some(Arc::clone(&h.plans)),
                    1.0,
                );
            }
            AnyAdapter::ShiraF16(a) => {
                eng.switch_to_shira_f16(
                    &mut w,
                    Arc::clone(a),
                    Some(Arc::clone(&h.plans)),
                    1.0,
                );
            }
            AnyAdapter::Lora(_) => panic!("family"),
        }
        states.push(w.clone());
    }
    eng.revert(&mut w);
    (states, w, store)
}

#[test]
fn serving_bit_identical_across_formats_and_fetch_paths() {
    let adapters = adapters();
    let (want, base) = reference_states(&adapters);
    let one_adapter = adapters[0].nbytes() + 1;
    // (format, cache budget, prefetch): cold-miss heavy (evicting cache),
    // all-hits (big cache), and prefetch-driven — for both formats.
    let cases = [
        (Format::V1, 64 << 20, false),
        (Format::V1, one_adapter, false),
        (Format::V2, 64 << 20, false),
        (Format::V2, one_adapter, false),
        (Format::V2, one_adapter, true),
        (Format::V2, 64 << 20, true),
    ];
    for &(format, cache_bytes, prefetch) in &cases {
        for threads in [1usize, 4] {
            let (got, final_w, store) =
                run_through_store(&adapters, format, cache_bytes, prefetch, threads, false);
            for (step, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    g.bit_equal(w),
                    "weights diverged at step {step} (format={} cache={cache_bytes} \
                     prefetch={prefetch} threads={threads})",
                    format.name()
                );
            }
            assert!(final_w.bit_equal(&base), "revert not exact");
            let stats = store.stats();
            if cache_bytes > 1 << 20 {
                assert!(stats.hits > 0, "big cache should hit");
            } else {
                assert!(stats.evictions > 0, "small cache should evict");
            }
            if prefetch {
                assert!(stats.prefetch_issued > 0);
            }
        }
    }
}

#[test]
fn f16_resident_serving_bit_identical_to_f32_of_same_flash() {
    // v2-f16 flash is lossy at encode time (f32 → binary16 RNE), so the
    // reference here is f32-resident serving of the SAME flash file — and
    // f16-resident serving (values kept as u16 bits, widened lane-wise in
    // the kernels at apply time, DESIGN.md §15.4) must match it bit for
    // bit at 1 and 4 threads, across cold-miss and prefetch-driven paths.
    let adapters = adapters();
    let base = base_weights(7);
    let one_adapter = adapters[0].nbytes() + 1;
    let (want, final_ref, _s) =
        run_through_store(&adapters, Format::V2F16, 64 << 20, false, 1, false);
    assert!(final_ref.bit_equal(&base), "f32 reference revert not exact");
    let cases = [(64usize << 20, false), (one_adapter, true)];
    for &(cache_bytes, prefetch) in &cases {
        for threads in [1usize, 4] {
            let (got, final_w, store) = run_through_store(
                &adapters,
                Format::V2F16,
                cache_bytes,
                prefetch,
                threads,
                true,
            );
            for (step, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    g.bit_equal(w),
                    "f16-resident serving diverged at step {step} \
                     (cache={cache_bytes} prefetch={prefetch} threads={threads})"
                );
            }
            assert!(final_w.bit_equal(&base), "f16-resident revert not exact");
            let stats = store.stats();
            assert!(stats.f16_resident_bytes > 0, "f16 residency never engaged");
        }
    }
}

#[test]
fn v2_flash_is_smaller_for_paper_sparsity() {
    // 400 nnz over 96×96 ≈ 4.3% here; also check a 1–2% sparse adapter.
    let mut rng = Rng::new(3);
    let sparse = make_adapter(&mut rng, "sp", (DIM * DIM) / 64);
    for a in adapters().iter().chain(std::iter::once(&sparse)) {
        let mut v1 = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 1 << 20,
                format: Format::V1,
                prefetch_depth: 0,
                ..StoreConfig::default()
            },
            None,
        );
        let mut v2 = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 1 << 20,
                format: Format::V2,
                prefetch_depth: 0,
                ..StoreConfig::default()
            },
            None,
        );
        v1.add_shira(a);
        v2.add_shira(a);
        assert!(
            v2.encoded_len(&a.name).unwrap() < v1.encoded_len(&a.name).unwrap(),
            "{}",
            a.name
        );
    }
}

#[test]
fn fusion_bit_identical_for_v1_and_v2_store_handles() {
    // Fused-mode serving over Arc handles fetched from the store: v1 and
    // v2 flash produce identical fused weights through identical
    // apply_set sequences, and both match a serial rebuild.
    let adapters = adapters();
    let sets: Vec<Vec<(String, f32)>> = vec![
        vec![("ad0".into(), 1.0), ("ad1".into(), 0.5)],
        vec![("ad1".into(), 0.5), ("ad2".into(), 2.0)],
        vec![("ad0".into(), 1.0), ("ad2".into(), 2.0), ("ad3".into(), 1.0)],
        vec![("ad3".into(), 0.25)],
    ];
    let mut results: Vec<WeightStore> = Vec::new();
    for format in [Format::V1, Format::V2] {
        let pool = Arc::new(ThreadPool::new(3));
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                format,
                prefetch_depth: 0,
                ..StoreConfig::default()
            },
            Some(Arc::clone(&pool)),
        );
        for a in &adapters {
            store.add_shira(a);
        }
        let mut roster = Vec::new();
        for a in &adapters {
            match &store.fetch(&a.name).unwrap().adapter {
                AnyAdapter::Shira(s) => roster.push(Arc::clone(s)),
                _ => panic!("family"),
            }
            assert!(store.pin(&a.name), "roster member must pin after fetch");
        }
        let base = base_weights(11);
        let mut weights = base.clone();
        let plan = FusionPlan::build(roster).unwrap();
        let mut eng = FusionEngine::with_pool(plan, Some(pool));
        eng.activate(&mut weights).unwrap();
        let mut final_states = Vec::new();
        for set in &sets {
            eng.apply_set(&mut weights, set).unwrap();
            let reference = eng.rebuild_reference(&base).expect("active engine");
            assert!(
                weights.bit_equal(&reference),
                "incremental state != rebuild ({})",
                format.name()
            );
            final_states.push(weights.clone());
        }
        eng.deactivate(&mut weights);
        assert!(weights.bit_equal(&base), "deactivate not exact");
        results.push(final_states.pop().unwrap());
    }
    assert!(
        results[0].bit_equal(&results[1]),
        "v1-backed and v2-backed fusion diverged"
    );
}

#[test]
fn pinned_roster_survives_cache_pressure_from_switch_traffic() {
    // The invariant behind fused-mode serving: roster members stay
    // resident (pinned) while unrelated switch traffic thrashes the cache.
    let adapters = adapters();
    let one_adapter = adapters[0].nbytes() + 1;
    let mut store = AdapterStore::with_config(
        StoreConfig {
            cache_bytes: 2 * one_adapter,
            format: Format::V2,
            prefetch_depth: 0,
            ..StoreConfig::default()
        },
        None,
    );
    for a in &adapters {
        store.add_shira(a);
    }
    store.fetch("ad0").unwrap();
    assert!(store.pin("ad0"));
    for _ in 0..3 {
        for name in ["ad1", "ad2", "ad3"] {
            store.fetch(name).unwrap();
        }
    }
    let stats = store.stats();
    assert!(stats.evictions > 0);
    assert!(store.is_pinned("ad0"));
    let before_hits = store.stats().hits;
    store.fetch("ad0").unwrap();
    assert_eq!(store.stats().hits, before_hits + 1, "pinned member decoded again");
}

#[test]
fn direct_transitions_bit_identical_through_the_store() {
    // The PR acceptance path at the lifecycle level: the same switch
    // sequence served through the store is bit-identical whether every
    // hot pair takes the one-pass direct transition (plans prefetched in
    // the background) or every switch falls back to revert+apply (the
    // reference_states engine) — at 1 and 4 threads.
    let adapters = adapters();
    let (want, base) = reference_states(&adapters);
    for threads in [1usize, 4] {
        let pool = Arc::new(ThreadPool::new(threads));
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                format: Format::V2,
                prefetch_depth: 4,
                ..StoreConfig::default()
            },
            Some(Arc::clone(&pool)),
        );
        for a in &adapters {
            store.add_shira(a);
        }
        // Decode everything up front so every pair is plannable.
        for a in &adapters {
            store.fetch(&a.name).unwrap();
        }
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(Arc::clone(&pool)));
        let seq = switch_sequence();
        let mut transitions = 0u64;
        for (step, &i) in seq.iter().enumerate() {
            let name = adapters[i].name.clone();
            let prev = eng.active_name().map(|s| s.to_string());
            if let Some(prev) = prev.as_deref() {
                // Background plan build; joined so the test is
                // deterministic (serving just falls back when it loses
                // the race — same bytes either way).
                store.prefetch_transitions(prev, std::slice::from_ref(&name));
                pool.join();
            }
            let h = store.fetch(&name).unwrap();
            let AnyAdapter::Shira(a) = &h.adapter else { panic!("family") };
            match prev.as_deref().and_then(|p| store.begin_transition(p, &name)) {
                Some(tp) => {
                    let (_t, path) = eng.transition_to(
                        &mut w,
                        Arc::clone(a),
                        Some(Arc::clone(&h.plans)),
                        &tp,
                        1.0,
                    );
                    store.end_transition(prev.as_deref().unwrap(), &name);
                    assert_eq!(path, SwitchPath::Transition, "step {step}");
                    transitions += 1;
                }
                None => {
                    eng.switch_to_shira_planned(
                        &mut w,
                        Arc::clone(a),
                        Some(Arc::clone(&h.plans)),
                        1.0,
                    );
                }
            }
            assert!(
                w.bit_equal(&want[step]),
                "transition-path weights diverged at step {step} (threads={threads})"
            );
        }
        assert_eq!(
            transitions,
            (seq.len() - 1) as u64,
            "every non-first switch should have transitioned"
        );
        assert!(store.stats().plan_hits >= transitions);
        eng.revert(&mut w);
        assert!(w.bit_equal(&base), "revert after transitions not exact");
    }
}
