//! Integration tests: cross-layer flows over the real AOT artifacts.
//!
//! Every test is skipped gracefully when `artifacts/` has not been built
//! (run `make artifacts` first); CI runs them after the AOT step.

use shira::adapter::io;
use shira::adapter::mask::MaskStrategy;
use shira::config::RunConfig;
use shira::coordinator::fusion;
use shira::coordinator::selection::Selection;
use shira::coordinator::server::Server;
use shira::coordinator::switch::SwitchEngine;
use shira::data::style::{Style, StyleDataset, StyleWorld};
use shira::data::tasks::Task;
use shira::data::trace::{generate_trace, TracePattern};
use shira::model::weights::WeightStore;
use shira::runtime::manifest::Manifest;
use shira::runtime::{HostValue, Runtime};
use shira::train::eval::{eval_style, eval_task};
use shira::train::schedule::Schedule;
use shira::train::{Trainer, TrainKind};
use shira::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Runtime::new(&dir).expect("runtime"))
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn sd_world() -> StyleWorld {
    StyleWorld::new(16, 48, 5)
}

/// L1-in-artifact vs native L3: the pallas fuse_lora kernel must agree with
/// the rust `add_outer_product` baseline.
#[test]
fn fuse_lora_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let d = rt.manifest.pallas_dim;
    let r = rt.manifest.adapter.lora_rank;
    let mut rng = Rng::new(1);
    let mut w = vec![0.0f32; d * d];
    rng.fill_normal(&mut w, 0.0, 1.0);
    let mut a = vec![0.0f32; d * r];
    let mut b = vec![0.0f32; r * d];
    rng.fill_normal(&mut a, 0.0, 0.1);
    rng.fill_normal(&mut b, 0.0, 0.1);
    let scale = 1.7f32;
    let out = rt
        .run(
            "fuse_lora",
            &[
                HostValue::f32(w.clone(), vec![d, d]),
                HostValue::f32(a.clone(), vec![d, r]),
                HostValue::f32(b.clone(), vec![r, d]),
                HostValue::f32(vec![scale], vec![1, 1]),
            ],
        )
        .unwrap();
    let got = out[0].as_f32();

    let mut wt = shira::model::tensor::Tensor2::from_vec(d, d, w);
    let at = shira::model::tensor::Tensor2::from_vec(d, r, a);
    let bt = shira::model::tensor::Tensor2::from_vec(r, d, b);
    wt.add_outer_product(&at, &bt, scale);
    let mut max_diff = 0.0f32;
    for (x, y) in got.iter().zip(wt.data.iter()) {
        max_diff = max_diff.max((x - y).abs());
    }
    assert!(max_diff < 1e-3, "pallas vs native fuse diff {max_diff}");
}

/// L1 masked_grad artifact agrees with a trivial elementwise reference.
#[test]
fn masked_grad_artifact_is_hadamard() {
    let Some(rt) = runtime() else { return };
    let d = rt.manifest.pallas_dim;
    let mut rng = Rng::new(2);
    let mut g = vec![0.0f32; d * d];
    rng.fill_normal(&mut g, 0.0, 1.0);
    let mask: Vec<f32> = (0..d * d)
        .map(|i| if i % 53 == 0 { 1.0 } else { 0.0 })
        .collect();
    let out = rt
        .run(
            "masked_grad_op",
            &[
                HostValue::f32(g.clone(), vec![d, d]),
                HostValue::f32(mask.clone(), vec![d, d]),
            ],
        )
        .unwrap();
    for ((got, g), m) in out[0].as_f32().iter().zip(g.iter()).zip(mask.iter()) {
        assert_eq!(*got, g * m);
    }
}

/// Full lifecycle: train on sd → export → save/load file → switch → SPS
/// improves over base; revert restores base bit-exactly.
#[test]
fn sd_full_lifecycle_improves_style_score() {
    let Some(rt) = runtime() else { return };
    let world = sd_world();
    let meta = rt.manifest.model("sd").unwrap().clone();
    let batch = meta.dim("batch");

    // quick base pretrain so the generator produces content
    let base0 = WeightStore::init(&meta.params, 11);
    let mut trainer = Trainer::new(&rt, "sd", base0).unwrap();
    let w2 = world.clone();
    let mut pre = move |_s: usize, rng: &mut Rng| {
        let mut zs = Vec::new();
        let mut imgs = Vec::new();
        for _ in 0..batch {
            let z = w2.sample_z(rng.below(9), rng);
            let img = w2.base_image(&z);
            zs.extend_from_slice(&z);
            imgs.extend_from_slice(&img);
        }
        vec![
            HostValue::f32(zs, vec![batch, w2.d_z]),
            HostValue::f32(imgs, vec![batch, w2.d_img]),
        ]
    };
    let out = trainer
        .train(TrainKind::Full, 80, Schedule::Cosine { lr: 5e-3 }, &mut pre, 1)
        .unwrap();
    trainer.absorb_full_theta(&out.theta);
    let base = trainer.base.clone();

    // style finetune
    let ds = StyleDataset::new(world.clone(), Style::Bluefire, 3);
    let dz = world.d_z;
    let dimg = world.d_img;
    let mut data = move |_s: usize, rng: &mut Rng| {
        let (z, t) = ds.train_batch(batch, rng);
        vec![
            HostValue::f32(z, vec![batch, dz]),
            HostValue::f32(t, vec![batch, dimg]),
        ]
    };
    let trainer = Trainer::new(&rt, "sd", base.clone()).unwrap();
    let out = trainer
        .train(
            TrainKind::Shira(MaskStrategy::Snip),
            60,
            Schedule::Cosine { lr: 5e-3 },
            &mut data,
            2,
        )
        .unwrap();
    assert!(out.last_loss() < out.first_loss());
    let adapter = trainer.export_shira(&out, "bf", MaskStrategy::Snip);

    // file roundtrip
    let path = std::env::temp_dir().join("integration.shira");
    io::save_shira(&path, &adapter).unwrap();
    let loaded = io::load_shira(&path).unwrap();
    assert_eq!(loaded, adapter);

    // switch + eval
    let base_sps = eval_style(&rt, &base, &world, Style::Bluefire, 1.0, 2, false, 7).unwrap();
    let mut weights = base.clone();
    let mut engine = SwitchEngine::new();
    engine.switch_to_shira(&mut weights, &loaded, 1.0);
    let adapted_sps =
        eval_style(&rt, &weights, &world, Style::Bluefire, 1.0, 2, false, 7).unwrap();
    assert!(
        adapted_sps > base_sps + 1.0,
        "style adapter should raise SPS: {base_sps:.1} -> {adapted_sps:.1}"
    );
    engine.revert(&mut weights);
    assert!(weights.bit_equal(&base));
}

/// Training the same config twice is bit-deterministic (theta identical).
#[test]
fn training_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let world = sd_world();
    let meta = rt.manifest.model("sd").unwrap().clone();
    let batch = meta.dim("batch");
    let base = WeightStore::init(&meta.params, 21);
    let run = || {
        let trainer = Trainer::new(&rt, "sd", base.clone()).unwrap();
        let ds = StyleDataset::new(world.clone(), Style::Paintings, 4);
        let dz = world.d_z;
        let dimg = world.d_img;
        let mut data = move |_s: usize, rng: &mut Rng| {
            let (z, t) = ds.train_batch(batch, rng);
            vec![
                HostValue::f32(z, vec![batch, dz]),
                HostValue::f32(t, vec![batch, dimg]),
            ]
        };
        trainer
            .train(
                TrainKind::Shira(MaskStrategy::Rand),
                10,
                Schedule::Const(3e-3),
                &mut data,
                9,
            )
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.theta, b.theta);
    assert_eq!(a.idx, b.idx);
    assert_eq!(a.losses, b.losses);
}

/// The llama grad-probe + mask calibration path yields Grad/SNIP masks that
/// differ from WM and drive a working train step.
#[test]
fn llama_grad_calibrated_masks_work() {
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest.model("llama").unwrap().clone();
    let (b, t) = (meta.dim("batch"), meta.dim("seq_len"));
    let base = WeightStore::init(&meta.params, 31);
    let trainer = Trainer::new(&rt, "llama", base).unwrap();
    let mut data = move |_s: usize, rng: &mut Rng| {
        let batch = shira::data::tasks::mixture_batch(
            &[Task::ArcEasy],
            b,
            t,
            5,
            rng,
        );
        vec![
            HostValue::i32(batch.x, vec![b, t]),
            HostValue::i32(batch.y, vec![b, t]),
            HostValue::f32(batch.mask, vec![b, t]),
        ]
    };
    let out = trainer
        .train(
            TrainKind::Shira(MaskStrategy::Snip),
            6,
            Schedule::Const(2e-3),
            &mut data,
            3,
        )
        .unwrap();
    assert!(out.losses.iter().all(|l| l.is_finite()));
    // SNIP mask should differ from a pure-WM mask
    let mut rng = Rng::new(3);
    let wm = trainer.build_masks(MaskStrategy::WeightMagnitude, None, &mut rng);
    assert_ne!(out.idx, wm);
}

/// Serving the same single-adapter trace over a SHiRA zoo and a LoRA zoo
/// completes both and orders switch costs: scatter far below dense fuse.
/// (Same builder-built server either way — the adapter family picks the
/// path per-request, not a construction-time policy.)
#[test]
fn serving_family_switch_costs_ordered() {
    let Some(rt) = runtime() else { return };
    let meta = rt.manifest.model("llama").unwrap().clone();
    let names: Vec<String> = (0..3).map(|i| format!("z{i}")).collect();
    let trace = generate_trace(
        &Selection::singles(&names),
        30,
        TracePattern::RoundRobin,
        1e4,
        5,
    );

    let mut mean_switch = std::collections::HashMap::new();
    for family in ["shira", "lora"] {
        let base = WeightStore::init(&meta.params, 9);
        let mut server = Server::builder(&rt, base)
            .model("llama")
            .cache_bytes(8 << 20)
            .build()
            .unwrap();
        let mut rng = Rng::new(77);
        for name in &names {
            if family == "shira" {
                let tensors = meta
                    .shira
                    .iter()
                    .map(|seg| {
                        let idx = rng.sample_indices(seg.numel(), seg.k);
                        let mut d = vec![0.0f32; seg.k];
                        rng.fill_normal(&mut d, 0.0, 0.01);
                        (
                            seg.name.clone(),
                            shira::adapter::sparse::SparseDelta::new(
                                seg.shape.0,
                                seg.shape.1,
                                idx,
                                d,
                            ),
                        )
                    })
                    .collect();
                server.store.add_shira(&shira::adapter::ShiraAdapter {
                    name: name.clone(),
                    strategy: "rand".into(),
                    tensors,
                });
            } else {
                let tensors = meta
                    .lora
                    .iter()
                    .map(|seg| {
                        let mut a =
                            shira::model::tensor::Tensor2::zeros(seg.shape.0, seg.rank);
                        let mut bb =
                            shira::model::tensor::Tensor2::zeros(seg.rank, seg.shape.1);
                        rng.fill_normal(&mut a.data, 0.0, 0.01);
                        rng.fill_normal(&mut bb.data, 0.0, 0.01);
                        shira::adapter::LoraTensor {
                            target: seg.name.clone(),
                            a,
                            b: bb,
                        }
                    })
                    .collect();
                server.store.add_lora(&shira::adapter::LoraAdapter {
                    name: name.clone(),
                    scale: 2.0,
                    tensors,
                });
            }
        }
        let rep = server.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 30);
        mean_switch.insert(family, rep.mean_switch_us);
    }
    let shira_us = mean_switch["shira"];
    let lora_us = mean_switch["lora"];
    assert!(
        shira_us < lora_us,
        "shira switch {shira_us:.1}us should beat lora fuse {lora_us:.1}us"
    );
}

/// Fusing trained adapters preserves each adapter's deltas where supports
/// don't collide (cross checks fusion + trainer export).
#[test]
fn fusion_of_trained_adapters_is_conservative() {
    let Some(rt) = runtime() else { return };
    let world = sd_world();
    let meta = rt.manifest.model("sd").unwrap().clone();
    let batch = meta.dim("batch");
    let base = WeightStore::init(&meta.params, 41);
    let mut adapters = Vec::new();
    for (i, style) in [Style::Bluefire, Style::Paintings].into_iter().enumerate() {
        let trainer = Trainer::new(&rt, "sd", base.clone()).unwrap();
        let ds = StyleDataset::new(world.clone(), style, 6);
        let dz = world.d_z;
        let dimg = world.d_img;
        let mut data = move |_s: usize, rng: &mut Rng| {
            let (z, t) = ds.train_batch(batch, rng);
            vec![
                HostValue::f32(z, vec![batch, dz]),
                HostValue::f32(t, vec![batch, dimg]),
            ]
        };
        let out = trainer
            .train(
                TrainKind::Shira(MaskStrategy::Rand),
                8,
                Schedule::Const(3e-3),
                &mut data,
                100 + i as u64,
            )
            .unwrap();
        adapters.push(trainer.export_shira(&out, style.name(), MaskStrategy::Rand));
    }
    let refs: Vec<&shira::adapter::ShiraAdapter> = adapters.iter().collect();
    let fused = fusion::fuse_shira(&refs, "both").expect("adapters share target sets");
    let report = fusion::analyze_shira(&refs);
    // different random masks at ~2%: overlap must be tiny
    assert!(report.mean_overlap < 0.2, "{report:?}");
    // fused support covers both adapters
    for a in &adapters {
        for (tname, d) in &a.tensors {
            let fd = fused.find(tname).unwrap();
            for &i in &d.idx {
                assert!(fd.idx.binary_search(&i).is_ok());
            }
        }
    }
}

/// The llama accuracy pipeline detects a trained (full-FT) improvement —
/// eval plumbing end-to-end.
#[test]
fn full_ft_lifts_task_accuracy() {
    let Some(rt) = runtime() else { return };
    let cfg = RunConfig {
        pretrain_steps: 120,
        ..RunConfig::fast()
    };
    let base = shira::repro::ensure_llama_base(&rt, &cfg, "llama_a").unwrap();
    // the pretrained base should beat a random-init model on at least the
    // easy arithmetic task (it has seen the task FORMAT during pretraining)
    let meta = rt.manifest.model("llama").unwrap();
    let random = WeightStore::init(&meta.params, 999);
    let acc_base = eval_task(&rt, &base, Task::ArcEasy, 64, 3).unwrap();
    let acc_rand = eval_task(&rt, &random, Task::ArcEasy, 64, 3).unwrap();
    assert!(
        acc_base >= acc_rand - 0.05,
        "pretrained {acc_base} vs random {acc_rand}"
    );
}
