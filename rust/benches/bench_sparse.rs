//! Micro-benchmarks of the sparse hot path (supports the §Perf iteration
//! log): scatter apply/revert/gather/snapshot, sorted vs unsorted index
//! order, density sweep, and adapter (de)serialization.
//!
//! Run: `cargo bench --bench bench_sparse`.

use shira::adapter::io;
use shira::adapter::sparse::SparseDelta;
use shira::adapter::ShiraAdapter;
use shira::model::tensor::Tensor2;
use shira::util::benchlib::{black_box, Bencher};
use shira::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(0x5BA6);
    let dim = 2048;
    let mut w = Tensor2::zeros(dim, dim);
    rng.fill_normal(&mut w.data, 0.0, 1.0);

    b.group("sparse/density-sweep(dim2048)");
    for frac in [0.005f64, 0.01, 0.02, 0.05] {
        let k = ((dim * dim) as f64 * frac) as usize;
        let idx = rng.sample_indices(dim * dim, k);
        let mut d = vec![0.0f32; k];
        rng.fill_normal(&mut d, 0.0, 0.1);
        let sd = SparseDelta::new(dim, dim, idx, d);
        b.bench(&format!("apply_frac{frac}"), || {
            sd.apply(&mut w, 1.0);
            black_box(&w.data[0]);
        });
    }

    b.group("sparse/order-sensitivity(dim2048,2%)");
    let k = ((dim * dim) as f64 * 0.02) as usize;
    let sorted_idx = rng.sample_indices(dim * dim, k);
    let mut unsorted = sorted_idx.clone();
    rng.shuffle(&mut unsorted);
    let mut d = vec![0.0f32; k];
    rng.fill_normal(&mut d, 0.0, 0.1);
    let sd_sorted = SparseDelta::new(dim, dim, sorted_idx.clone(), d.clone());
    b.bench("apply_sorted_indices", || {
        sd_sorted.apply(&mut w, 1.0);
        black_box(&w.data[0]);
    });
    // unsorted apply: emulate with a raw loop (SparseDelta requires sorted)
    b.bench("apply_unsorted_indices(raw)", || {
        for (j, &i) in unsorted.iter().enumerate() {
            w.data[i as usize] += d[j];
        }
        black_box(&w.data[0]);
    });

    b.group("sparse/stages(dim2048,2%)");
    b.bench("snapshot", || {
        black_box(sd_sorted.snapshot(&w).len());
    });
    let snap = sd_sorted.snapshot(&w);
    b.bench("restore", || {
        sd_sorted.restore(&mut w, &snap);
        black_box(&w.data[0]);
    });
    b.bench("gather", || {
        black_box(sd_sorted.gather(&w).len());
    });

    b.group("sparse/io");
    let adapter = ShiraAdapter {
        name: "io".into(),
        strategy: "rand".into(),
        tensors: vec![("w".into(), sd_sorted.clone())],
    };
    let bytes = io::encode_shira(&adapter);
    b.bench("encode", || {
        black_box(io::encode_shira(&adapter).len());
    });
    b.bench("decode", || {
        black_box(io::decode_shira(&bytes).unwrap().param_count());
    });

    b.write_results("bench_sparse");
}
