//! Adapter lifecycle bench: format v1/v2 encode/decode cost and size, and
//! the three store fetch paths (cold miss / cache hit / prefetch hit) in
//! front of a switch cycle.
//!
//! Correctness gates run before any timing:
//!   * v1 and v2 decode bit-identically to the source adapter;
//!   * v2 files are smaller than v1 (and v2-f16 smaller still) at the
//!     paper's 1–2% sparsity.
//!
//! The fetch-path table is the tentpole claim in numbers: a prefetch-hit
//! fetch+switch excludes decode cost (≈ the cache-hit line), while a cold
//! miss pays decode on the request path.
//!
//! Run: `cargo bench --bench bench_store`.  Flags:
//!   --check           compare against the committed rust/BENCH_store.json
//!   --tolerance 0.5   fractional slowdown allowed by --check (default 0.5)
//!   --save-baseline   rewrite rust/BENCH_store.json from this run
//! `SHIRA_BENCH_FAST=1` shrinks the protocol and dims for CI smoke runs.

use std::sync::Arc;
use std::time::Instant;

use shira::adapter::io::{self, Format};
use shira::adapter::sparse::SparseDelta;
use shira::adapter::ShiraAdapter;
use shira::coordinator::store::{AdapterStore, AnyAdapter, StoreConfig};
use shira::coordinator::switch::SwitchEngine;
use shira::model::tensor::Tensor2;
use shira::model::weights::WeightStore;
use shira::util::benchlib::{
    black_box, finish_bench, results_to_entries, BaselineEntry, Bencher,
};
use shira::util::rng::Rng;
use shira::util::stats::Sample;
use shira::util::threadpool::ThreadPool;

fn random_shira(rng: &mut Rng, name: &str, dim: usize, frac: f64) -> ShiraAdapter {
    let k = ((dim * dim) as f64 * frac) as usize;
    let idx = rng.sample_indices(dim * dim, k);
    let mut delta = vec![0.0f32; k];
    rng.fill_normal(&mut delta, 0.0, 0.1);
    ShiraAdapter {
        name: name.into(),
        strategy: "rand".into(),
        tensors: vec![("w".into(), SparseDelta::new(dim, dim, idx, delta))],
    }
}

/// Collect `reps` samples from `f`, which does its own per-rep setup and
/// returns only the nanoseconds of the part it timed (used for the fetch
/// paths, where prefetch must complete *outside* the timed window).
fn timed_entry(name: &str, reps: usize, mut f: impl FnMut() -> f64) -> BaselineEntry {
    let mut sample = Sample::new();
    for _ in 0..reps {
        sample.push(f());
    }
    let entry = BaselineEntry {
        name: name.to_string(),
        mean_ns: sample.mean(),
        p50_ns: sample.percentile(50.0),
        p99_ns: sample.percentile(99.0),
    };
    println!(
        "  {:48} {:>12.1} us/op (p50 {:>10.1} us, {} reps)",
        entry.name,
        entry.mean_ns / 1e3,
        entry.p50_ns / 1e3,
        reps
    );
    entry
}

fn main() {
    let fast = std::env::var("SHIRA_BENCH_FAST").is_ok();
    let mut b = Bencher::new();
    let mut rng = Rng::new(0x570E);
    let frac = 0.02;

    // -- correctness + size gates (before any timing) ---------------------
    let gate = random_shira(&mut rng, "gate", 256, frac);
    let v1 = io::encode_shira(&gate);
    let v2 = io::encode_shira_as(&gate, Format::V2);
    let v2f16 = io::encode_shira_as(&gate, Format::V2F16);
    assert_eq!(
        io::decode_shira(&v1).unwrap(),
        gate,
        "v1 decode not bit-identical"
    );
    assert_eq!(
        io::decode_shira(&v2).unwrap(),
        gate,
        "v2 decode not bit-identical"
    );
    assert!(v2.len() < v1.len(), "v2 ({}) not smaller than v1 ({})", v2.len(), v1.len());
    assert!(v2f16.len() < v2.len());
    println!("format gate: v1/v2 decode bit-identical; sizes verified\n");
    println!("== on-flash size (dim 256, {:.0}% sparse) ==", frac * 100.0);
    println!("| format | bytes | vs v1 |");
    println!("|---|---|---|");
    for (name, len) in [("v1", v1.len()), ("v2", v2.len()), ("v2-f16", v2f16.len())] {
        println!("| {name} | {len} | {:.2}x |", v1.len() as f64 / len as f64);
    }

    // -- format encode/decode cost ---------------------------------------
    let dims: &[usize] = if fast { &[512] } else { &[512, 2048] };
    for &dim in dims {
        b.group(&format!("format/dim{dim}"));
        let a = random_shira(&mut rng, "fmt", dim, frac);
        let enc_v1 = io::encode_shira(&a);
        let enc_v2 = io::encode_shira_as(&a, Format::V2);
        b.bench("encode_v1", || {
            black_box(io::encode_shira_as(&a, Format::V1).len());
        });
        b.bench("encode_v2", || {
            black_box(io::encode_shira_as(&a, Format::V2).len());
        });
        b.bench("decode_v1", || {
            black_box(io::decode_shira(&enc_v1).unwrap().param_count());
        });
        b.bench("decode_v2", || {
            black_box(io::decode_shira(&enc_v2).unwrap().param_count());
        });
    }

    // -- fetch paths in front of a switch cycle ---------------------------
    // Two adapters + a one-adapter cache budget: every alternating fetch
    // is a cold miss unless staged by prefetch.
    let dim = if fast { 512 } else { 2048 };
    let reps = if fast { 30 } else { 200 };
    let a0 = random_shira(&mut rng, "a0", dim, frac);
    let a1 = random_shira(&mut rng, "a1", dim, frac);
    let one_slot = a0.nbytes() + a1.nbytes() / 2; // holds one, not both
    let pool = Arc::new(ThreadPool::host_sized());
    let mut base = WeightStore::new();
    let mut w = Tensor2::zeros(dim, dim);
    rng.fill_normal(&mut w.data, 0.0, 1.0);
    base.insert("w", w);

    println!("\n== fetch paths (dim {dim}, one-adapter cache) ==");
    let mut extra: Vec<BaselineEntry> = Vec::new();
    {
        // cache hit: generous budget, adapter resident after warmup.
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                format: Format::V2,
                prefetch_depth: 0,
                ..StoreConfig::default()
            },
            Some(Arc::clone(&pool)),
        );
        store.add_shira(&a0);
        store.fetch("a0").unwrap();
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(Arc::clone(&pool)));
        extra.push(timed_entry("store/fetch_cache_hit_switch", reps, || {
            let t0 = Instant::now();
            let h = store.fetch("a0").unwrap();
            if let AnyAdapter::Shira(a) = &h.adapter {
                eng.switch_to_shira_planned(&mut w, Arc::clone(a), Some(Arc::clone(&h.plans)), 1.0);
            }
            t0.elapsed().as_nanos() as f64
        }));
        eng.revert(&mut w);
    }
    {
        // cold miss: alternating pair, one-slot budget → decode every time.
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: one_slot,
                format: Format::V2,
                prefetch_depth: 0,
                ..StoreConfig::default()
            },
            Some(Arc::clone(&pool)),
        );
        store.add_shira(&a0);
        store.add_shira(&a1);
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(Arc::clone(&pool)));
        let mut flip = 0usize;
        extra.push(timed_entry("store/fetch_cold_miss_switch", reps, || {
            flip += 1;
            let name = if flip % 2 == 0 { "a0" } else { "a1" };
            let t0 = Instant::now();
            let h = store.fetch(name).unwrap();
            if let AnyAdapter::Shira(a) = &h.adapter {
                eng.switch_to_shira_planned(&mut w, Arc::clone(a), Some(Arc::clone(&h.plans)), 1.0);
            }
            t0.elapsed().as_nanos() as f64
        }));
        let stats = store.stats();
        assert!(stats.evictions > 0, "cold-miss setup failed to evict");
        eng.revert(&mut w);
    }
    {
        // prefetch hit: same evicting pair, but the next adapter is decoded
        // in the background (and joined) before the timed fetch — the
        // switch path pays no decode.
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: one_slot,
                format: Format::V2,
                prefetch_depth: 1,
                ..StoreConfig::default()
            },
            Some(Arc::clone(&pool)),
        );
        store.add_shira(&a0);
        store.add_shira(&a1);
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(Arc::clone(&pool)));
        let mut flip = 0usize;
        let pool_ref = Arc::clone(&pool);
        extra.push(timed_entry("store/fetch_prefetch_hit_switch", reps, || {
            flip += 1;
            let next = if flip % 2 == 0 { "a0" } else { "a1" }.to_string();
            store.prefetch(std::slice::from_ref(&next));
            pool_ref.join(); // decode completes off the timed path
            let t0 = Instant::now();
            let h = store.fetch(&next).unwrap();
            if let AnyAdapter::Shira(a) = &h.adapter {
                eng.switch_to_shira_planned(&mut w, Arc::clone(a), Some(Arc::clone(&h.plans)), 1.0);
            }
            t0.elapsed().as_nanos() as f64
        }));
        let stats = store.stats();
        assert!(stats.prefetch_hits > 0, "prefetch never hit");
        eng.revert(&mut w);
    }
    println!(
        "interpretation: prefetch_hit ≈ cache_hit (decode excluded); \
         cold_miss adds the decode cost"
    );

    b.write_results("bench_store");
    let mut entries = results_to_entries(b.results());
    entries.extend(extra);
    let ok = finish_bench("store", &entries);
    if !ok {
        std::process::exit(1);
    }
}
