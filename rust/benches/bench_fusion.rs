//! §3.2 orthogonality + fusion-cost benchmark, and the incremental
//! fused-mode engine: interference diagnostics across sparsity levels,
//! the cost of the naive serial merge, and — the headline — that
//! `fuse_into`/`unfuse_one`/`reweight_one` cost scales with the *touched*
//! adapter's nnz while a `fuse_shira` rebuild scales with the fused set's
//! total nnz.
//!
//! Run: `cargo bench --bench bench_fusion`.  Flags:
//!   --check           compare against the committed rust/BENCH_fusion.json
//!   --tolerance 0.5   fractional slowdown allowed by --check (default 0.5)
//!   --save-baseline   rewrite rust/BENCH_fusion.json from this run
//! `SHIRA_BENCH_FAST=1` shrinks the protocol and dims for CI smoke runs.

use std::sync::Arc;

use shira::adapter::sparse::SparseDelta;
use shira::adapter::ShiraAdapter;
use shira::coordinator::fusion;
use shira::coordinator::fusion_engine::{FusionEngine, FusionPlan};
use shira::model::tensor::Tensor2;
use shira::model::weights::WeightStore;
use shira::util::benchlib::{black_box, finish_bench, results_to_entries, Bencher};
use shira::util::rng::Rng;

fn adapter(seed: u64, name: &str, n: usize, frac: f64) -> ShiraAdapter {
    let mut rng = Rng::new(seed);
    let k = (((n * n) as f64) * frac).max(1.0) as usize;
    let idx = rng.sample_indices(n * n, k);
    let mut d = vec![0.0f32; k];
    rng.fill_normal(&mut d, 0.0, 0.1);
    ShiraAdapter {
        name: name.into(),
        strategy: "rand".into(),
        tensors: vec![("w".into(), SparseDelta::new(n, n, idx, d))],
    }
}

fn main() {
    let fast = std::env::var("SHIRA_BENCH_FAST").is_ok();
    let mut b = Bencher::new();

    println!("== §3.2 orthogonality: interference vs sparsity (dim 512) ==");
    println!("| frac | mean overlap | A1ᵀA2 density | collisions |");
    println!("|---|---|---|---|");
    for frac in [0.005, 0.01, 0.02, 0.05, 0.10] {
        let a1 = adapter(1, "a1", 512, frac);
        let a2 = adapter(2, "a2", 512, frac);
        let rep = fusion::analyze_shira(&[&a1, &a2]);
        println!(
            "| {frac:.3} | {:.5} | {:.5} | {} |",
            rep.mean_overlap, rep.mean_ata_density, rep.collisions
        );
    }
    println!("| LoRA (dense) | 1.00000 | 1.00000 | all |");

    b.group("fusion/merge-cost");
    for n in [256usize, 1024, 4096] {
        let a1 = adapter(3, "a1", n, 0.02);
        let a2 = adapter(4, "a2", n, 0.02);
        let (d1, d2) = (&a1.tensors[0].1, &a2.tensors[0].1);
        b.bench(&format!("sparse_merge_dim{n}"), || {
            black_box(d1.merge(d2).nnz());
        });
        b.bench(&format!("overlap_dim{n}"), || {
            black_box(d1.overlap(d2));
        });
    }

    b.group("fusion/analysis-cost");
    let a1 = adapter(5, "a1", 1024, 0.02);
    let a2 = adapter(6, "a2", 1024, 0.02);
    b.bench("ata_nnz_dim1024", || {
        black_box(a1.tensors[0].1.ata_nnz(&a2.tensors[0].1).0);
    });

    // -- incremental engine: touched-nnz vs total-nnz scaling -------------
    //
    // One SMALL adapter rides in fused sets of growing total nnz.  If the
    // incremental claim holds, reweighting/unfusing the small adapter
    // costs roughly the same at every set size, while the serial
    // fuse_shira rebuild grows linearly with the set.
    let dim = if fast { 512 } else { 2048 };
    let small_frac = 0.002;
    let large_frac = 0.02;
    let set_sizes: &[usize] = if fast { &[2, 4] } else { &[2, 4, 8, 16] };
    let mut summary = Vec::new();
    for &n_large in set_sizes {
        let mut roster: Vec<Arc<ShiraAdapter>> =
            vec![Arc::new(adapter(100, "small", dim, small_frac))];
        for i in 0..n_large {
            roster.push(Arc::new(adapter(
                200 + i as u64,
                &format!("large{i}"),
                dim,
                large_frac,
            )));
        }
        let small_nnz = roster[0].param_count();
        let total_nnz: usize = roster.iter().map(|a| a.param_count()).sum();
        let plan = FusionPlan::build(roster.clone()).expect("uniform roster");

        let mut store = WeightStore::new();
        store.insert("w", {
            let mut rng = Rng::new(7);
            let mut w = Tensor2::zeros(dim, dim);
            rng.fill_normal(&mut w.data, 0.0, 1.0);
            w
        });
        let base = store.clone();
        let mut eng = FusionEngine::new(plan);
        eng.activate(&mut store).expect("store matches plan");
        for a in &roster {
            eng.fuse_into(&mut store, &a.name, 1.0).expect("member");
        }
        // Correctness gate before any timing: the incremental state must
        // equal the serial fuse_shira rebuild, bit for bit.
        let reference = eng.rebuild_reference(&base).expect("set nonempty");
        assert!(
            store.bit_equal(&reference),
            "incremental != rebuild at set={n_large}"
        );

        b.group(&format!("fusion/incremental/set{n_large}"));
        let mut flip = false;
        let reweight = b.bench("reweight_small", || {
            flip = !flip;
            let w = if flip { 0.5 } else { 1.0 };
            eng.reweight_one(&mut store, "small", w).expect("member");
            black_box(&store.get("w").data[0]);
        });
        b.bench("unfuse_fuse_small", || {
            eng.unfuse_one(&mut store, "small").expect("member");
            eng.fuse_into(&mut store, "small", 1.0).expect("member");
            black_box(&store.get("w").data[0]);
        });
        let refs: Vec<&ShiraAdapter> = roster.iter().map(|a| a.as_ref()).collect();
        let rebuild = b.bench("rebuild_fuse_shira", || {
            black_box(
                fusion::fuse_shira(&refs, "rebuilt")
                    .expect("uniform roster")
                    .param_count(),
            );
        });
        summary.push((n_large, small_nnz, total_nnz, reweight.mean_ns, rebuild.mean_ns));
    }

    println!("\n== incremental scaling (small adapter nnz fixed, set grows) ==");
    println!("| set | small nnz | total nnz | reweight_small | rebuild | rebuild/reweight |");
    println!("|---|---|---|---|---|---|");
    for (n_large, small_nnz, total_nnz, reweight_ns, rebuild_ns) in &summary {
        println!(
            "| {n_large} | {small_nnz} | {total_nnz} | {:.1} us | {:.1} us | {:.1}x |",
            reweight_ns / 1e3,
            rebuild_ns / 1e3,
            rebuild_ns / reweight_ns
        );
    }
    println!("expected shape: reweight_small stays ~flat (O(touched nnz));");
    println!("rebuild grows with the set's total nnz — the incremental win.");

    b.write_results("bench_fusion");
    let ok = finish_bench("fusion", &results_to_entries(b.results()));
    if !ok {
        std::process::exit(1);
    }
}
