//! §3.2 orthogonality + Fig. 3b fusion-cost benchmark: interference
//! diagnostics (support overlap, A1ᵀA2 density) across sparsity levels, and
//! the cost of the naive sparse merge itself.
//!
//! Run: `cargo bench --bench bench_fusion`.

use shira::adapter::sparse::SparseDelta;
use shira::adapter::ShiraAdapter;
use shira::coordinator::fusion;
use shira::util::benchlib::{black_box, Bencher};
use shira::util::rng::Rng;

fn adapter(seed: u64, n: usize, frac: f64) -> ShiraAdapter {
    let mut rng = Rng::new(seed);
    let k = (((n * n) as f64) * frac).max(1.0) as usize;
    let idx = rng.sample_indices(n * n, k);
    let mut d = vec![0.0f32; k];
    rng.fill_normal(&mut d, 0.0, 0.1);
    ShiraAdapter {
        name: format!("a{seed}"),
        strategy: "rand".into(),
        tensors: vec![("w".into(), SparseDelta::new(n, n, idx, d))],
    }
}

fn main() {
    let mut b = Bencher::new();

    println!("== §3.2 orthogonality: interference vs sparsity (dim 512) ==");
    println!("| frac | mean overlap | A1ᵀA2 density | collisions |");
    println!("|---|---|---|---|");
    for frac in [0.005, 0.01, 0.02, 0.05, 0.10] {
        let a1 = adapter(1, 512, frac);
        let a2 = adapter(2, 512, frac);
        let rep = fusion::analyze_shira(&[&a1, &a2]);
        println!(
            "| {frac:.3} | {:.5} | {:.5} | {} |",
            rep.mean_overlap, rep.mean_ata_density, rep.collisions
        );
    }
    println!("| LoRA (dense) | 1.00000 | 1.00000 | all |");

    b.group("fusion/merge-cost");
    for n in [256usize, 1024, 4096] {
        let a1 = adapter(3, n, 0.02);
        let a2 = adapter(4, n, 0.02);
        let (d1, d2) = (&a1.tensors[0].1, &a2.tensors[0].1);
        b.bench(&format!("sparse_merge_dim{n}"), || {
            black_box(d1.merge(d2).nnz());
        });
        b.bench(&format!("overlap_dim{n}"), || {
            black_box(d1.overlap(d2));
        });
    }

    b.group("fusion/analysis-cost");
    let a1 = adapter(5, 1024, 0.02);
    let a2 = adapter(6, 1024, 0.02);
    b.bench("ata_nnz_dim1024", || {
        black_box(a1.tensors[0].1.ata_nnz(&a2.tensors[0].1).0);
    });

    println!("\npaper shape: at 1-2% sparsity A1ᵀA2 is >95% zeros; the naive");
    println!("merge is linear in nnz (microseconds), i.e. fusion itself is free.");
    b.write_results("bench_fusion");
}
