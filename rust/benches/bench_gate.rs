//! Gating benchmark (DESIGN.md §17): what learned top-k selection
//! costs.  Gate training throughput, per-request resolution latency,
//! and end-to-end gated serving vs the same trace with the emitted
//! sets spelled explicitly (the gating overhead), at 2 and 8 replicas.
//!
//! Run: `cargo bench --bench bench_gate`.  Artifact-free: everything
//! drives the `Fleet` determinism harness, so it runs anywhere.
//! Flags: `--check` compares against the committed
//! `rust/BENCH_gate.json`; `--save-baseline` rewrites it.
//! `SHIRA_BENCH_FAST=1` shrinks the grid for CI smoke runs.
//!
//! ## Determinism gate
//!
//! Before any timing, every grid cell serves the seeded all-`Auto`
//! trace twice with the oracle ON and once more with the gate's
//! rewrite spelled explicitly on a gateless fleet: both gated runs
//! must be byte-identical to each other, and the explicit replay must
//! match their outcomes, placement and final resident weights.
//! Timings below are only meaningful because gating provably changes
//! nothing downstream.

use std::sync::Arc;
use std::time::Instant;

use shira::coordinator::fleet::Fleet;
use shira::coordinator::gate::{request_features, Gate, LinearGate};
use shira::coordinator::pool::{lock_pool, ExpertPool, SharedExpertPool};
use shira::coordinator::selection::Selection;
use shira::coordinator::store::StoreConfig;
use shira::data::synth::{adapter_names, fleet_trace, toy_base, toy_shira_zoo};
use shira::train::gate::train_gate;
use shira::util::benchlib::{black_box, finish_bench, BaselineEntry};

const DIM: usize = 48;
const NNZ: usize = 200;
const ZOO: usize = 6;
const TOP_K: usize = 2;
const SEED: u64 = 0x6A7E;

fn store_cfg() -> StoreConfig {
    StoreConfig {
        cache_bytes: 64 << 20,
        prefetch_depth: 0,
        plan_cache_bytes: 0,
        ..StoreConfig::default()
    }
}

fn expert_pool() -> SharedExpertPool {
    let pool = ExpertPool::shared(0);
    for n in &adapter_names(ZOO) {
        lock_pool(&pool).register(n).unwrap();
    }
    pool
}

/// One grid cell's fleet; `gate` None builds the gateless explicit-
/// replay twin of the same shape.
fn build(replicas: usize, oracle: bool, gate: Option<LinearGate>) -> Fleet {
    let names = adapter_names(ZOO);
    let mut b = Fleet::builder(toy_base(DIM, SEED))
        .replicas(replicas)
        .queue_depth(512)
        .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, SEED))
        .store_config(store_cfg())
        .oracle(oracle);
    if let Some(g) = gate {
        b = b.gate(Arc::new(g)).expert_pool(expert_pool());
    }
    b.build()
}

fn main() {
    let fast = std::env::var("SHIRA_BENCH_FAST").is_ok();
    let (grid, n_requests, train_steps): (&[usize], usize, usize) = if fast {
        (&[2], 120, 400)
    } else {
        (&[2, 8], 400, 2000)
    };
    let names = adapter_names(ZOO);

    // Train once; the same parameters serve every cell.
    let t_train = Instant::now();
    let trained = train_gate(&names, TOP_K, train_steps, SEED);
    let train_wall = t_train.elapsed();
    println!(
        "trained gate: {} steps in {:.1}ms, held-out accuracy {:.3}, \
         final loss {:.3}",
        trained.steps,
        train_wall.as_secs_f64() * 1e3,
        trained.accuracy,
        trained.final_loss
    );

    // Determinism gate first (module docs).
    let trace = fleet_trace(&[Selection::Auto], n_requests, 4, SEED);
    for &r in grid {
        let mut a_fleet = build(r, true, Some(trained.gate.clone()));
        let a = a_fleet.run_trace(&trace, SEED).unwrap();
        let mut b_fleet = build(r, true, Some(trained.gate.clone()));
        let b = b_fleet.run_trace(&trace, SEED).unwrap();
        assert!(
            a.oracle_failures.is_empty(),
            "gate determinism (replicas {r}): {:?}",
            a.oracle_failures
        );
        assert_eq!(a.gated, n_requests as u64, "gate determinism (replicas {r})");
        assert!(
            a.actions == b.actions && a.summary == b.summary,
            "gate determinism (replicas {r}): gated replay diverged"
        );
        let explicit = build(r, true, Some(trained.gate.clone()))
            .resolve_trace(&trace)
            .unwrap();
        let mut e_fleet = build(r, true, None);
        let e = e_fleet.run_trace(&explicit, SEED).unwrap();
        assert!(
            a.actions == e.actions && a.per_replica_served == e.per_replica_served,
            "gate determinism (replicas {r}): explicit replay diverged"
        );
        for (ra, re) in a_fleet.routers().zip(e_fleet.routers()) {
            assert!(
                ra.active_key() == re.active_key()
                    && ra.weights().bit_equal(re.weights()),
                "gate determinism (replicas {r}): resident weights diverged"
            );
        }
    }
    println!(
        "determinism gate: gated runs byte-identical across replays, and \
         bit/placement-identical to the explicit-set replay on every cell"
    );

    println!(
        "\n== gating: resolution cost and gated-vs-explicit serving \
         ({n_requests} requests, {ZOO} experts, top-{TOP_K}, zipf 10k \
         users) =="
    );
    println!("| replicas | scenario | served | gated | req/s (wall) | p99 wait (us) |");
    println!("|---|---|---|---|---|---|");
    let mut entries: Vec<BaselineEntry> = Vec::new();

    // Pure resolution latency: features + top-k select, no serving.
    let roster = adapter_names(ZOO);
    let resolve_iters = if fast { 2_000u64 } else { 20_000 };
    let t0 = Instant::now();
    for i in 0..resolve_iters {
        let f = request_features(SEED ^ i);
        black_box(trained.gate.select(&f, &roster).unwrap());
    }
    let resolve_wall = t0.elapsed();
    entries.push(BaselineEntry {
        name: "gate/resolve".to_string(),
        mean_ns: resolve_wall.as_nanos() as f64 / resolve_iters as f64,
        p50_ns: 0.0,
        p99_ns: 0.0,
    });
    println!(
        "| - | resolve-only | - | {resolve_iters} | {:.0} | - |",
        resolve_iters as f64 / resolve_wall.as_secs_f64()
    );

    for &r in grid {
        for (scenario, gated) in [("explicit", false), ("gated", true)] {
            let run_trace = if gated {
                trace.clone()
            } else {
                build(r, false, Some(trained.gate.clone()))
                    .resolve_trace(&trace)
                    .unwrap()
            };
            let gate = gated.then(|| trained.gate.clone());
            let mut fleet = build(r, false, gate);
            let t0 = Instant::now();
            let rep = fleet.run_trace(&run_trace, SEED).unwrap();
            let wall = t0.elapsed();
            let rps = n_requests as f64 / wall.as_secs_f64();
            println!(
                "| {r} | {scenario} | {} | {} | {rps:.0} | {:.1} |",
                rep.served, rep.gated, rep.p99_wait_us
            );
            entries.push(BaselineEntry {
                name: format!("gate/r{r}/{scenario}"),
                mean_ns: wall.as_nanos() as f64 / n_requests as f64,
                p50_ns: rep.p50_wait_us * 1e3,
                p99_ns: rep.p99_wait_us * 1e3,
            });
        }
    }
    println!(
        "\npaper shape: resolution is a few hundred nanoseconds of linear \
         algebra per request, so gated serving tracks the explicit-set run \
         — the adapter scatter dominates, exactly as SHiRA's rapid-switch \
         claim needs."
    );
    if !finish_bench("gate", &entries) {
        std::process::exit(1);
    }
}
