//! Resilience benchmark (DESIGN.md §16): what self-healing costs.
//! Failover latency (drain-and-requeue after a planned replica crash)
//! and recovery time (quarantine TTL -> recovery pass -> probation ->
//! Healthy) at 2 and 8 replicas, against the fault-free run of the
//! same trace as the control.
//!
//! Run: `cargo bench --bench bench_resilience`.  Artifact-free: the
//! whole bench drives the `Fleet` determinism harness, so it runs
//! anywhere.  Flags: `--check` compares against the committed
//! `rust/BENCH_resilience.json`; `--save-baseline` rewrites it.
//! `SHIRA_BENCH_FAST=1` shrinks the grid for CI smoke runs.
//!
//! ## Bit-identity gate
//!
//! Before any timing, every grid cell runs with the oracle ON and a
//! fault plan that crashes the first apply on EVERY replica: each
//! replica must trip quarantine, re-admit through the recovery pass
//! bit-identical to the fault-free reference, and end Healthy with
//! every request terminally accounted.  Timings below are only
//! meaningful because recovery provably restores the bytes.

use std::time::Instant;

use shira::coordinator::fault::FaultPlan;
use shira::coordinator::fleet::Fleet;
use shira::coordinator::server::FailurePolicy;
use shira::coordinator::store::StoreConfig;
use shira::data::synth::{adapter_names, fleet_trace, toy_base, toy_shira_zoo};
use shira::data::trace::mixed_selections;
use shira::util::benchlib::{finish_bench, BaselineEntry};

const DIM: usize = 48;
const NNZ: usize = 200;
const SEED: u64 = 0x5E1F;
/// Base replica-quarantine TTL for the crash cells (virtual time).
const TTL_US: u64 = 50_000;

/// Build one grid cell's fleet.  `crash_every` plans the first apply on
/// every replica to crash — the canonical every-replica-recovers
/// scenario the chaos tests gate on.
fn build(replicas: usize, oracle: bool, crash_every: bool) -> Fleet {
    let names = adapter_names(6);
    let mut plan = FaultPlan::new();
    if crash_every {
        for r in 0..replicas {
            plan = plan.crash_replica_at(r, 1);
        }
    }
    Fleet::builder(toy_base(DIM, SEED))
        .replicas(replicas)
        .queue_depth(512)
        .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, SEED))
        .store_config(StoreConfig {
            cache_bytes: 64 << 20,
            prefetch_depth: 0,
            plan_cache_bytes: 0,
            ..StoreConfig::default()
        })
        .failure_policy(FailurePolicy::DegradeToBase)
        .quarantine_after(1)
        .replica_quarantine_ttl_us(TTL_US)
        .retry_backoff_us(50)
        .fault_plan(plan)
        .oracle(oracle)
        .build()
}

fn main() {
    let fast = std::env::var("SHIRA_BENCH_FAST").is_ok();
    let (grid, n_requests): (&[usize], usize) = if fast {
        (&[2], 120)
    } else {
        (&[2, 8], 400)
    };
    let names = adapter_names(6);
    let sels = mixed_selections(&names);

    // Bit-identity gate first (module docs).
    for &r in grid {
        let trace = fleet_trace(&sels, n_requests, 4, SEED);
        let mut fleet = build(r, true, true);
        let rep = fleet.run_trace(&trace, SEED).unwrap();
        assert!(
            rep.oracle_failures.is_empty(),
            "resilience gate (replicas {r}): {:?}",
            rep.oracle_failures
        );
        assert!(
            rep.quarantine_trips >= r as u64,
            "resilience gate (replicas {r}): only {} quarantine trips\n{}",
            rep.quarantine_trips,
            rep.summary
        );
        assert!(
            rep.replica_health.iter().all(|&h| h == "healthy"),
            "resilience gate (replicas {r}): end states {:?}",
            rep.replica_health
        );
        assert_eq!(
            rep.actions.len(),
            trace.len(),
            "resilience gate (replicas {r}): requests lost on drain"
        );
    }
    println!(
        "resilience gate: every replica quarantined >= once, recovered \
         bit-identical, run ends all-Healthy, every request accounted"
    );

    println!(
        "\n== resilience: fault-free control vs crash-every-replica \
         ({n_requests} requests, 6 adapters, zipf 10k users, ttl {TTL_US}us) =="
    );
    println!(
        "| replicas | scenario | served | degraded | requeues | trips | \
         probes | recoveries | req/s (wall) | makespan (virtual us) | \
         p99 wait (us) |"
    );
    println!("|---|---|---|---|---|---|---|---|---|---|---|");
    let mut entries: Vec<BaselineEntry> = Vec::new();
    for &r in grid {
        let trace = fleet_trace(&sels, n_requests, 4, SEED);
        for (scenario, crash) in [("clean", false), ("failover", true)] {
            let mut fleet = build(r, false, crash);
            let t0 = Instant::now();
            let rep = fleet.run_trace(&trace, SEED).unwrap();
            let wall = t0.elapsed();
            let rps = n_requests as f64 / wall.as_secs_f64();
            println!(
                "| {r} | {scenario} | {} | {} | {} | {} | {} | {} | \
                 {rps:.0} | {} | {:.1} |",
                rep.served,
                rep.degraded,
                rep.requeues,
                rep.quarantine_trips,
                rep.probes,
                rep.recoveries,
                rep.makespan_us,
                rep.p99_wait_us
            );
            // Wall mean per request; deterministic virtual-time tails —
            // the failover/clean delta IS the self-healing overhead.
            entries.push(BaselineEntry {
                name: format!("resilience/r{r}/{scenario}"),
                mean_ns: wall.as_nanos() as f64 / n_requests as f64,
                p50_ns: rep.p50_wait_us * 1e3,
                p99_ns: rep.p99_wait_us * 1e3,
            });
        }
    }
    println!(
        "\npaper shape: failover adds drain+requeue latency bounded by the \
         retry backoff, and recovery time is dominated by the quarantine \
         TTL — the bytes after re-admission are gate-checked identical."
    );
    if !finish_bench("resilience", &entries) {
        std::process::exit(1);
    }
}
