//! Fig. 5 reproduction: LoRA-fuse vs SHiRA-scatter time per weight tensor
//! across dimensions (the paper's headline systems result — up to ~10×
//! faster switching at dim 4096 on CPU).
//!
//! Protocol matches the paper: per dimension, 10 randomly initialized
//! weights; fuse time = `W += s·A@B` (rank 32); scatter time = sparse
//! overwrite of 2% of entries.  Run: `cargo bench --bench bench_switch`.

use shira::adapter::sparse::SparseDelta;
use shira::model::tensor::Tensor2;
use shira::util::benchlib::{black_box, Bencher};
use shira::util::rng::Rng;

fn random_weight(rng: &mut Rng, dim: usize) -> Tensor2 {
    let mut w = Tensor2::zeros(dim, dim);
    rng.fill_normal(&mut w.data, 0.0, 1.0);
    w
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(0xF165);
    let frac = 0.02;
    let rank = 32;

    let mut speedups = Vec::new();
    for dim in [512usize, 1024, 2048, 4096] {
        b.group(&format!("fig5/dim{dim}"));
        let k = ((dim * dim) as f64 * frac) as usize;
        let mut w = random_weight(&mut rng, dim);
        let idx = rng.sample_indices(dim * dim, k);
        let mut delta = vec![0.0f32; k];
        rng.fill_normal(&mut delta, 0.0, 0.1);
        let sd = SparseDelta::new(dim, dim, idx, delta);
        let mut a = Tensor2::zeros(dim, rank);
        let mut bb = Tensor2::zeros(rank, dim);
        rng.fill_normal(&mut a.data, 0.0, 0.1);
        rng.fill_normal(&mut bb.data, 0.0, 0.1);

        let scatter = b.bench("shira_scatter", || {
            sd.apply(&mut w, 1.0);
            black_box(&w.data[0]);
        });
        let fuse = b.bench("lora_fuse", || {
            w.add_outer_product(&a, &bb, 1.0);
            black_box(&w.data[0]);
        });
        // revert path (the other half of a switch)
        let snap = sd.snapshot(&w);
        b.bench("shira_revert", || {
            sd.restore(&mut w, &snap);
            black_box(&w.data[0]);
        });
        b.bench("lora_unfuse", || {
            w.sub_outer_product(&a, &bb, 1.0);
            black_box(&w.data[0]);
        });
        let speedup = fuse.mean_ns / scatter.mean_ns;
        speedups.push((dim, speedup));
    }

    println!("\n== Fig. 5 summary (fuse / scatter) ==");
    println!("| dim | speedup |");
    println!("|---|---|");
    for (dim, s) in &speedups {
        println!("| {dim} | {s:.1}x |");
    }
    println!("paper shape: speedup grows with dim, ~10x at 4096");
    b.write_results("bench_switch");
}
