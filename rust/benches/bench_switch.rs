//! Fig. 5 reproduction + parallel switch-engine sweep.
//!
//! Part 1 (serial, the paper's headline systems result): LoRA-fuse vs
//! SHiRA-scatter time per weight tensor across dimensions — up to ~10×
//! faster switching at dim 4096 on CPU.
//!
//! Part 2 (this repo's scaling claim): the shard-parallel scatter/restore
//! paths and the parallel LoRA fuse baseline across thread counts, after
//! verifying each parallel path is bit-identical to its serial twin.
//!
//! Part 3 (direct transitions): `SwitchEngine::transition_to` (one pass
//! over the A∪B support union, one dispatch wave) vs revert+apply, across
//! support-overlap ratios (0% / 50% / 95%) and nnz scales — gated on
//! bit-identity before timing.  These stages land in their own baseline
//! document, `rust/BENCH_transition.json`.
//!
//! Part 4 (kernel dispatch, DESIGN.md §15): the SIMD span kernels vs the
//! scalar ladder across run-length distributions (fully-contiguous block /
//! ~1.5% uniform singletons / 16-wide clusters), plus f16-resident vs
//! f32-resident serving under SIMD — every (dispatch × residency ×
//! pooling) combination gated on bit-identity before timing.  These
//! stages land in `rust/BENCH_kernel.json`.
//!
//! Run: `cargo bench --bench bench_switch`.  Flags:
//!   --check           compare against the committed rust/BENCH_switch.json
//!                     AND rust/BENCH_transition.json + rust/BENCH_kernel.json
//!   --tolerance 0.5   fractional slowdown allowed by --check (default 0.5)
//!   --save-baseline   rewrite the committed baselines from this run
//!   --require-entries fail instead of trivially passing on empty baselines
//!   --baseline-dir D  read/write baselines under D instead of the repo
//! `SHIRA_BENCH_FAST=1` shrinks the protocol and dims for CI smoke runs.

use std::sync::Arc;

use shira::adapter::kernel::{self, KernelDispatch};
use shira::adapter::sparse::{SparseDelta, SparseDeltaF16};
use shira::adapter::{AdapterTransition, ShiraAdapter, ShiraF16Adapter};
use shira::coordinator::switch::{SwitchEngine, SwitchPath};
use shira::model::tensor::Tensor2;
use shira::model::weights::WeightStore;
use shira::util::benchlib::{black_box, finish_bench, results_to_entries, Bencher};
use shira::util::rng::Rng;
use shira::util::threadpool::ThreadPool;

fn random_weight(rng: &mut Rng, dim: usize) -> Tensor2 {
    let mut w = Tensor2::zeros(dim, dim);
    rng.fill_normal(&mut w.data, 0.0, 1.0);
    w
}

fn random_sparse(rng: &mut Rng, dim: usize, frac: f64) -> SparseDelta {
    let k = ((dim * dim) as f64 * frac) as usize;
    let idx = rng.sample_indices(dim * dim, k);
    let mut delta = vec![0.0f32; k];
    rng.fill_normal(&mut delta, 0.0, 0.1);
    SparseDelta::new(dim, dim, idx, delta)
}

/// A delta sharing ~`overlap` of `base`'s support (rest resampled), same
/// nnz — the knob of the Part-3 transition table.
fn overlapping_sparse(rng: &mut Rng, base: &SparseDelta, overlap: f64) -> SparseDelta {
    use std::collections::HashSet;
    let k = base.nnz();
    let shared = (k as f64 * overlap) as usize;
    let mut seen: HashSet<u32> = base.idx[..shared].iter().copied().collect();
    while seen.len() < k {
        seen.insert(rng.below(base.numel()) as u32);
    }
    let mut idx: Vec<u32> = seen.into_iter().collect();
    idx.sort_unstable();
    let mut delta = vec![0.0f32; k];
    rng.fill_normal(&mut delta, 0.0, 0.1);
    SparseDelta::new(base.rows, base.cols, idx, delta)
}

/// A fully-contiguous block of `k` flat indices — one maximal row run per
/// row crossed, the kernel layer's best case.
fn contiguous_sparse(rng: &mut Rng, dim: usize, k: usize) -> SparseDelta {
    let start = rng.below(dim * dim - k);
    let idx: Vec<u32> = (start as u32..(start + k) as u32).collect();
    let mut delta = vec![0.0f32; k];
    rng.fill_normal(&mut delta, 0.0, 0.1);
    SparseDelta::new(dim, dim, idx, delta)
}

/// `k` indices in contiguous 16-wide clusters — short runs, the middle of
/// the run-length spectrum between a single block and uniform singletons.
fn clustered_sparse(rng: &mut Rng, dim: usize, k: usize) -> SparseDelta {
    use std::collections::HashSet;
    const CLUSTER: usize = 16;
    let mut seen: HashSet<u32> = HashSet::with_capacity(k + CLUSTER);
    while seen.len() < k {
        let start = rng.below(dim * dim - CLUSTER) as u32;
        for o in 0..CLUSTER as u32 {
            seen.insert(start + o);
        }
    }
    let mut idx: Vec<u32> = seen.into_iter().collect();
    idx.sort_unstable();
    idx.truncate(k);
    let mut delta = vec![0.0f32; k];
    rng.fill_normal(&mut delta, 0.0, 0.1);
    SparseDelta::new(dim, dim, idx, delta)
}

fn shira_of(name: &str, delta: SparseDelta) -> ShiraAdapter {
    ShiraAdapter {
        name: name.into(),
        strategy: "rand".into(),
        tensors: vec![("w".into(), delta)],
    }
}

fn main() {
    let fast = std::env::var("SHIRA_BENCH_FAST").is_ok();
    let mut b = Bencher::new();
    let mut rng = Rng::new(0xF165);
    let frac = 0.02;
    let rank = 32;

    // -- Part 1: the serial Fig. 5 sweep ---------------------------------
    let dims: &[usize] = if fast {
        &[512, 1024]
    } else {
        &[512, 1024, 2048, 4096]
    };
    let mut speedups = Vec::new();
    for &dim in dims {
        b.group(&format!("fig5/dim{dim}"));
        let mut w = random_weight(&mut rng, dim);
        let sd = random_sparse(&mut rng, dim, frac);
        let mut a = Tensor2::zeros(dim, rank);
        let mut bb = Tensor2::zeros(rank, dim);
        rng.fill_normal(&mut a.data, 0.0, 0.1);
        rng.fill_normal(&mut bb.data, 0.0, 0.1);

        let scatter = b.bench("shira_scatter", || {
            sd.apply(&mut w, 1.0);
            black_box(&w.data[0]);
        });
        let fuse = b.bench("lora_fuse", || {
            w.add_outer_product(&a, &bb, 1.0);
            black_box(&w.data[0]);
        });
        // revert path (the other half of a switch)
        let snap = sd.snapshot(&w);
        b.bench("shira_revert", || {
            sd.restore(&mut w, &snap);
            black_box(&w.data[0]);
        });
        b.bench("lora_unfuse", || {
            w.sub_outer_product(&a, &bb, 1.0);
            black_box(&w.data[0]);
        });
        let speedup = fuse.mean_ns / scatter.mean_ns;
        speedups.push((dim, speedup));
    }

    // -- Part 2: the shard-parallel engine across thread counts ----------
    let par_dim = if fast { 1024 } else { 4096 };
    let threads_sweep: &[usize] = if fast { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let sd = random_sparse(&mut rng, par_dim, frac);
    let w0 = random_weight(&mut rng, par_dim);
    let mut la = Tensor2::zeros(par_dim, rank);
    let mut lb = Tensor2::zeros(rank, par_dim);
    rng.fill_normal(&mut la.data, 0.0, 0.1);
    rng.fill_normal(&mut lb.data, 0.0, 0.1);

    // Correctness gate before any timing: parallel == serial, bit for bit.
    {
        let pool = ThreadPool::new(4);
        let plan = sd.shard(8);
        let mut ws = w0.clone();
        sd.apply(&mut ws, 1.0);
        let mut wp = w0.clone();
        let mut snap = vec![0.0f32; sd.nnz()];
        sd.snapshot_apply_parallel(&mut wp, 1.0, &mut snap, &pool, &plan);
        assert_eq!(ws.data, wp.data, "parallel apply != serial apply");
        sd.restore_parallel(&mut wp, &snap, &pool, &plan);
        assert_eq!(wp.data, w0.data, "parallel restore != snapshot");
        let mut ls = w0.clone();
        ls.add_outer_product(&la, &lb, 1.0);
        let mut lp = w0.clone();
        lp.add_outer_product_par(&la, &lb, 1.0, &pool);
        assert_eq!(ls.data, lp.data, "parallel fuse != serial fuse");
        println!("parallel paths verified bit-identical to serial (dim {par_dim})");
    }

    let mut par_scatter = Vec::new();
    for &threads in threads_sweep {
        b.group(&format!("par/dim{par_dim}/t{threads}"));
        let pool = ThreadPool::new(threads);
        let plan = sd.shard(threads * 2);
        let mut w = w0.clone();
        let mut snap = vec![0.0f32; sd.nnz()];
        let scatter = b.bench("scatter_apply", || {
            sd.snapshot_apply_parallel(&mut w, 1.0, &mut snap, &pool, &plan);
            black_box(&w.data[0]);
        });
        let restore = b.bench("restore", || {
            sd.restore_parallel(&mut w, &snap, &pool, &plan);
            black_box(&w.data[0]);
        });
        b.bench("lora_fuse_par", || {
            w.add_outer_product_par(&la, &lb, 1.0, &pool);
            black_box(&w.data[0]);
        });
        par_scatter.push((threads, scatter.mean_ns + restore.mean_ns));
    }

    // Engine-level: full switch+revert cycles through the snapshot arena
    // (zero allocation in steady state), serial vs pooled.
    let adapter = ShiraAdapter {
        name: "bench".into(),
        strategy: "rand".into(),
        tensors: vec![("w".into(), sd.clone())],
    };
    let mut store = WeightStore::new();
    store.insert("w", w0.clone());
    b.group(&format!("engine/dim{par_dim}"));
    let shared = Arc::new(adapter.clone());
    {
        // Same Arc-shared entry point as the pooled runs, so serial vs
        // parallel differ only in dispatch — not in adapter cloning.
        let mut w = store.clone();
        let mut eng = SwitchEngine::new();
        b.bench("switch_cycle_serial", || {
            eng.switch_to_shira_shared(&mut w, Arc::clone(&shared), 1.0);
            eng.revert(&mut w);
        });
    }
    for &threads in threads_sweep {
        let pool = Arc::new(ThreadPool::new(threads));
        let mut w = store.clone();
        let mut eng = SwitchEngine::with_pool(Some(pool));
        b.bench(&format!("switch_cycle_t{threads}"), || {
            eng.switch_to_shira_shared(&mut w, Arc::clone(&shared), 1.0);
            eng.revert(&mut w);
        });
    }

    // -- Part 3: direct transitions vs revert+apply -----------------------
    // One engine cycles A→B→A via transition_to (one union pass, one
    // dispatch wave per switch); the reference cycles the same pair via
    // switch_to (revert + apply, two passes, two waves).  Bit-identity is
    // asserted before any timing.  The transition should win at EVERY
    // overlap ratio: at 0% the union walk equals revert+apply's total
    // work but saves a dispatch wave; overlap shrinks the union further.
    let t_threads = 4usize;
    let nnz_scales: &[usize] = if fast { &[8_000] } else { &[8_000, 80_000] };
    let overlaps = [0.0f64, 0.5, 0.95];
    let t_dim = if fast { 1024 } else { 2048 };
    let mut transition_rows = Vec::new();
    for &nnz in nnz_scales {
        let frac = nnz as f64 / (t_dim * t_dim) as f64;
        let da = random_sparse(&mut rng, t_dim, frac);
        let w0 = random_weight(&mut rng, t_dim);
        for &ov in &overlaps {
            b.group(&format!("transition/nnz{nnz}/ov{}", (ov * 100.0) as usize));
            let db = overlapping_sparse(&mut rng, &da, ov);
            let a = Arc::new(shira_of("a", da.clone()));
            let bb = Arc::new(shira_of("b", db));
            let tp_ab = AdapterTransition::build(&a, &bb, t_threads).unwrap();
            let tp_ba = AdapterTransition::build(&bb, &a, t_threads).unwrap();
            let mut store = WeightStore::new();
            store.insert("w", w0.clone());

            // Bit-identity gate: transition == revert+apply, and both
            // engines revert to base exactly.
            {
                let pool = Arc::new(ThreadPool::new(t_threads));
                let mut wd = store.clone();
                let mut wr = store.clone();
                let mut direct = SwitchEngine::with_pool(Some(Arc::clone(&pool)));
                let mut reference = SwitchEngine::with_pool(Some(pool));
                direct.switch_to_shira_shared(&mut wd, Arc::clone(&a), 1.0);
                reference.switch_to_shira_shared(&mut wr, Arc::clone(&a), 1.0);
                for (next, tp) in [(&bb, &tp_ab), (&a, &tp_ba), (&bb, &tp_ab)] {
                    let (_t, path) =
                        direct.transition_to(&mut wd, Arc::clone(next), None, tp, 1.0);
                    assert_eq!(path, SwitchPath::Transition, "plan rejected");
                    reference.switch_to_shira_shared(&mut wr, Arc::clone(next), 1.0);
                    assert!(
                        wd.bit_equal(&wr),
                        "transition != revert+apply (nnz {nnz}, overlap {ov})"
                    );
                }
                direct.revert(&mut wd);
                reference.revert(&mut wr);
                assert!(wd.bit_equal(&store));
                assert!(wr.bit_equal(&store));
            }

            let pool = Arc::new(ThreadPool::new(t_threads));
            let mut wd = store.clone();
            let mut direct = SwitchEngine::with_pool(Some(Arc::clone(&pool)));
            direct.switch_to_shira_shared(&mut wd, Arc::clone(&a), 1.0);
            let mut flip = false;
            let tr = b.bench("transition_cycle", || {
                // alternate A→B / B→A so steady state stays a transition
                let (next, tp) = if flip { (&a, &tp_ba) } else { (&bb, &tp_ab) };
                flip = !flip;
                direct.transition_to(&mut wd, Arc::clone(next), None, tp, 1.0);
                black_box(&wd.get("w").data[0]);
            });
            let mut wr = store.clone();
            let mut reference = SwitchEngine::with_pool(Some(pool));
            reference.switch_to_shira_shared(&mut wr, Arc::clone(&a), 1.0);
            let mut flip = false;
            let ra = b.bench("revert_apply_cycle", || {
                let next = if flip { &a } else { &bb };
                flip = !flip;
                reference.switch_to_shira_shared(&mut wr, Arc::clone(next), 1.0);
                black_box(&wr.get("w").data[0]);
            });
            transition_rows.push((nnz, ov, tr.mean_ns, ra.mean_ns));
        }
    }

    // -- Part 4: kernel dispatch (scalar vs simd) across run shapes -------
    // The tentpole claim in numbers (DESIGN.md §15): the SIMD span kernels
    // against the scalar ladder across run-length distributions — one
    // maximal contiguous block / ~1.5% uniform singletons / 16-wide
    // clusters — plus f16-resident vs f32-resident serving under SIMD.
    // Every (dispatch × residency × pooling) combination is asserted
    // bit-identical to the scalar-serial f32 reference before any timing.
    // Serial one-shot paths read the process-global dispatch at call time,
    // so the override here is `force_dispatch` (safe: this binary is
    // single-threaded outside the pools it builds itself); engines are
    // constructed after each force so their wave paths capture it too.
    let entry_dispatch = kernel::active_dispatch();
    let k_dim = if fast { 1024 } else { 2048 };
    let k_frac = 0.015;
    let kk = ((k_dim * k_dim) as f64 * k_frac) as usize;
    let k_threads = 4usize;
    let dists: Vec<(&str, SparseDelta)> = vec![
        ("contig", contiguous_sparse(&mut rng, k_dim, kk)),
        ("uniform", random_sparse(&mut rng, k_dim, k_frac)),
        ("clustered", clustered_sparse(&mut rng, k_dim, kk)),
    ];
    let kw0 = random_weight(&mut rng, k_dim);
    let mut kernel_rows = Vec::new();
    for (dist, d) in &dists {
        b.group(&format!("kernel/{dist}"));
        let adapter = Arc::new(shira_of("k", d.clone()));
        let f16 = Arc::new(ShiraF16Adapter {
            name: "k16".into(),
            strategy: "rand".into(),
            tensors: vec![("w".into(), SparseDeltaF16::from_f32(d))],
        });
        let mut kstore = WeightStore::new();
        kstore.insert("w", kw0.clone());

        // Bit-identity gates: every dispatch × pooling combination lands
        // on the scalar-serial bytes; f16-resident lands on the bytes of
        // an f32 apply of the widened values; every revert is exact.
        {
            kernel::force_dispatch(KernelDispatch::Scalar);
            let mut w_ref = kstore.clone();
            let mut eng_ref = SwitchEngine::new();
            eng_ref.switch_to_shira_shared(&mut w_ref, Arc::clone(&adapter), 1.0);
            let decoded = Arc::new(f16.to_shira());
            let mut w16_ref = kstore.clone();
            let mut eng16_ref = SwitchEngine::new();
            eng16_ref.switch_to_shira_shared(&mut w16_ref, decoded, 1.0);
            for dispatch in [KernelDispatch::Scalar, KernelDispatch::Simd] {
                kernel::force_dispatch(dispatch);
                for pooled in [false, true] {
                    let pool = if pooled {
                        Some(Arc::new(ThreadPool::new(k_threads)))
                    } else {
                        None
                    };
                    let mut eng = SwitchEngine::with_pool(pool.clone());
                    let mut w = kstore.clone();
                    eng.switch_to_shira_shared(&mut w, Arc::clone(&adapter), 1.0);
                    assert!(
                        w.bit_equal(&w_ref),
                        "kernel/{dist}: {} pooled={pooled} != scalar serial",
                        dispatch.name()
                    );
                    eng.revert(&mut w);
                    assert!(w.bit_equal(&kstore), "kernel/{dist}: revert not exact");
                    let mut eng = SwitchEngine::with_pool(pool);
                    let mut w = kstore.clone();
                    eng.switch_to_shira_f16(&mut w, Arc::clone(&f16), None, 1.0);
                    assert!(
                        w.bit_equal(&w16_ref),
                        "kernel/{dist}: f16 {} pooled={pooled} != widened f32",
                        dispatch.name()
                    );
                    eng.revert(&mut w);
                    assert!(w.bit_equal(&kstore), "kernel/{dist}: f16 revert not exact");
                }
            }
        }

        // Timed switch+revert cycles at 4 threads, dispatch forced per run.
        let mut cell = [0.0f64; 3];
        for (ci, dispatch) in [KernelDispatch::Scalar, KernelDispatch::Simd]
            .into_iter()
            .enumerate()
        {
            kernel::force_dispatch(dispatch);
            let pool = Arc::new(ThreadPool::new(k_threads));
            let mut eng = SwitchEngine::with_pool(Some(pool));
            let mut w = kstore.clone();
            let r = b.bench(&format!("cycle_f32_{}", dispatch.name()), || {
                eng.switch_to_shira_shared(&mut w, Arc::clone(&adapter), 1.0);
                eng.revert(&mut w);
                black_box(&w.get("w").data[0]);
            });
            cell[ci] = r.mean_ns;
        }
        {
            kernel::force_dispatch(KernelDispatch::Simd);
            let pool = Arc::new(ThreadPool::new(k_threads));
            let mut eng = SwitchEngine::with_pool(Some(pool));
            let mut w = kstore.clone();
            let r = b.bench("cycle_f16_simd", || {
                eng.switch_to_shira_f16(&mut w, Arc::clone(&f16), None, 1.0);
                eng.revert(&mut w);
                black_box(&w.get("w").data[0]);
            });
            cell[2] = r.mean_ns;
        }
        kernel_rows.push((*dist, cell[0], cell[1], cell[2]));
    }
    // Hand the process back to whatever the env/default probe selected, so
    // the forced runs above don't leak into anything after us.
    kernel::force_dispatch(entry_dispatch);
    println!("kernel gates: scalar/simd × serial/pooled × f32/f16 all bit-identical");

    // -- summaries --------------------------------------------------------
    println!("\n== Fig. 5 summary (fuse / scatter) ==");
    println!("| dim | speedup |");
    println!("|---|---|");
    for (dim, s) in &speedups {
        println!("| {dim} | {s:.1}x |");
    }
    println!("paper shape: speedup grows with dim, ~10x at 4096");

    println!("\n== parallel scaling (scatter_apply + restore, dim {par_dim}) ==");
    println!("| threads | total (ms) | speedup vs t1 |");
    println!("|---|---|---|");
    if let Some(&(_, t1)) = par_scatter.first() {
        for (threads, total) in &par_scatter {
            println!("| {threads} | {:.2} | {:.2}x |", total / 1e6, t1 / total);
        }
    }

    println!("\n== direct transition vs revert+apply (dim {t_dim}, t{t_threads}) ==");
    println!("| nnz | overlap | transition (us) | revert+apply (us) | speedup |");
    println!("|---|---|---|---|---|");
    for (nnz, ov, tr, ra) in &transition_rows {
        println!(
            "| {nnz} | {:.0}% | {:.1} | {:.1} | {:.2}x |",
            ov * 100.0,
            tr / 1e3,
            ra / 1e3,
            ra / tr
        );
    }
    println!("expectation: transition wins at every overlap ratio (one union \
              pass + one dispatch wave vs two passes + two waves)");

    println!("\n== kernel dispatch (dim {k_dim}, t{k_threads}, switch+revert cycle) ==");
    println!("| distribution | scalar (us) | simd (us) | speedup | f16 simd (us) |");
    println!("|---|---|---|---|---|");
    for (dist, sc, si, f16ns) in &kernel_rows {
        println!(
            "| {dist} | {:.1} | {:.1} | {:.2}x | {:.1} |",
            sc / 1e3,
            si / 1e3,
            sc / si,
            f16ns / 1e3
        );
    }
    println!("expectation: simd wins most on the contiguous block (long runs), \
              least on singleton-dominated uniform supports");

    b.write_results("bench_switch");
    // Part-3 and Part-4 stages gate against their own committed baselines
    // so each table can be regenerated independently of the Fig. 5 sweep.
    let mut switch_entries = Vec::new();
    let mut transition_entries = Vec::new();
    let mut kernel_entries = Vec::new();
    for e in results_to_entries(b.results()) {
        if e.name.starts_with("transition/") {
            transition_entries.push(e);
        } else if e.name.starts_with("kernel/") {
            kernel_entries.push(e);
        } else {
            switch_entries.push(e);
        }
    }
    let ok_switch = finish_bench("switch", &switch_entries);
    let ok_transition = finish_bench("transition", &transition_entries);
    let ok_kernel = finish_bench("kernel", &kernel_entries);
    if !(ok_switch && ok_transition && ok_kernel) {
        std::process::exit(1);
    }
}
