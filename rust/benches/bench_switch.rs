//! Fig. 5 reproduction + parallel switch-engine sweep.
//!
//! Part 1 (serial, the paper's headline systems result): LoRA-fuse vs
//! SHiRA-scatter time per weight tensor across dimensions — up to ~10×
//! faster switching at dim 4096 on CPU.
//!
//! Part 2 (this repo's scaling claim): the shard-parallel scatter/restore
//! paths and the parallel LoRA fuse baseline across thread counts, after
//! verifying each parallel path is bit-identical to its serial twin.
//!
//! Run: `cargo bench --bench bench_switch`.  Flags:
//!   --check           compare against the committed rust/BENCH_switch.json
//!   --tolerance 0.5   fractional slowdown allowed by --check (default 0.5)
//!   --save-baseline   rewrite rust/BENCH_switch.json from this run
//! `SHIRA_BENCH_FAST=1` shrinks the protocol and dims for CI smoke runs.

use std::sync::Arc;

use shira::adapter::sparse::SparseDelta;
use shira::adapter::ShiraAdapter;
use shira::coordinator::switch::SwitchEngine;
use shira::model::tensor::Tensor2;
use shira::model::weights::WeightStore;
use shira::util::benchlib::{black_box, finish_bench, results_to_entries, Bencher};
use shira::util::rng::Rng;
use shira::util::threadpool::ThreadPool;

fn random_weight(rng: &mut Rng, dim: usize) -> Tensor2 {
    let mut w = Tensor2::zeros(dim, dim);
    rng.fill_normal(&mut w.data, 0.0, 1.0);
    w
}

fn random_sparse(rng: &mut Rng, dim: usize, frac: f64) -> SparseDelta {
    let k = ((dim * dim) as f64 * frac) as usize;
    let idx = rng.sample_indices(dim * dim, k);
    let mut delta = vec![0.0f32; k];
    rng.fill_normal(&mut delta, 0.0, 0.1);
    SparseDelta::new(dim, dim, idx, delta)
}

fn main() {
    let fast = std::env::var("SHIRA_BENCH_FAST").is_ok();
    let mut b = Bencher::new();
    let mut rng = Rng::new(0xF165);
    let frac = 0.02;
    let rank = 32;

    // -- Part 1: the serial Fig. 5 sweep ---------------------------------
    let dims: &[usize] = if fast {
        &[512, 1024]
    } else {
        &[512, 1024, 2048, 4096]
    };
    let mut speedups = Vec::new();
    for &dim in dims {
        b.group(&format!("fig5/dim{dim}"));
        let mut w = random_weight(&mut rng, dim);
        let sd = random_sparse(&mut rng, dim, frac);
        let mut a = Tensor2::zeros(dim, rank);
        let mut bb = Tensor2::zeros(rank, dim);
        rng.fill_normal(&mut a.data, 0.0, 0.1);
        rng.fill_normal(&mut bb.data, 0.0, 0.1);

        let scatter = b.bench("shira_scatter", || {
            sd.apply(&mut w, 1.0);
            black_box(&w.data[0]);
        });
        let fuse = b.bench("lora_fuse", || {
            w.add_outer_product(&a, &bb, 1.0);
            black_box(&w.data[0]);
        });
        // revert path (the other half of a switch)
        let snap = sd.snapshot(&w);
        b.bench("shira_revert", || {
            sd.restore(&mut w, &snap);
            black_box(&w.data[0]);
        });
        b.bench("lora_unfuse", || {
            w.sub_outer_product(&a, &bb, 1.0);
            black_box(&w.data[0]);
        });
        let speedup = fuse.mean_ns / scatter.mean_ns;
        speedups.push((dim, speedup));
    }

    // -- Part 2: the shard-parallel engine across thread counts ----------
    let par_dim = if fast { 1024 } else { 4096 };
    let threads_sweep: &[usize] = if fast { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let sd = random_sparse(&mut rng, par_dim, frac);
    let w0 = random_weight(&mut rng, par_dim);
    let mut la = Tensor2::zeros(par_dim, rank);
    let mut lb = Tensor2::zeros(rank, par_dim);
    rng.fill_normal(&mut la.data, 0.0, 0.1);
    rng.fill_normal(&mut lb.data, 0.0, 0.1);

    // Correctness gate before any timing: parallel == serial, bit for bit.
    {
        let pool = ThreadPool::new(4);
        let plan = sd.shard(8);
        let mut ws = w0.clone();
        sd.apply(&mut ws, 1.0);
        let mut wp = w0.clone();
        let mut snap = vec![0.0f32; sd.nnz()];
        sd.snapshot_apply_parallel(&mut wp, 1.0, &mut snap, &pool, &plan);
        assert_eq!(ws.data, wp.data, "parallel apply != serial apply");
        sd.restore_parallel(&mut wp, &snap, &pool, &plan);
        assert_eq!(wp.data, w0.data, "parallel restore != snapshot");
        let mut ls = w0.clone();
        ls.add_outer_product(&la, &lb, 1.0);
        let mut lp = w0.clone();
        lp.add_outer_product_par(&la, &lb, 1.0, &pool);
        assert_eq!(ls.data, lp.data, "parallel fuse != serial fuse");
        println!("parallel paths verified bit-identical to serial (dim {par_dim})");
    }

    let mut par_scatter = Vec::new();
    for &threads in threads_sweep {
        b.group(&format!("par/dim{par_dim}/t{threads}"));
        let pool = ThreadPool::new(threads);
        let plan = sd.shard(threads * 2);
        let mut w = w0.clone();
        let mut snap = vec![0.0f32; sd.nnz()];
        let scatter = b.bench("scatter_apply", || {
            sd.snapshot_apply_parallel(&mut w, 1.0, &mut snap, &pool, &plan);
            black_box(&w.data[0]);
        });
        let restore = b.bench("restore", || {
            sd.restore_parallel(&mut w, &snap, &pool, &plan);
            black_box(&w.data[0]);
        });
        b.bench("lora_fuse_par", || {
            w.add_outer_product_par(&la, &lb, 1.0, &pool);
            black_box(&w.data[0]);
        });
        par_scatter.push((threads, scatter.mean_ns + restore.mean_ns));
    }

    // Engine-level: full switch+revert cycles through the snapshot arena
    // (zero allocation in steady state), serial vs pooled.
    let adapter = ShiraAdapter {
        name: "bench".into(),
        strategy: "rand".into(),
        tensors: vec![("w".into(), sd.clone())],
    };
    let mut store = WeightStore::new();
    store.insert("w", w0.clone());
    b.group(&format!("engine/dim{par_dim}"));
    let shared = Arc::new(adapter.clone());
    {
        // Same Arc-shared entry point as the pooled runs, so serial vs
        // parallel differ only in dispatch — not in adapter cloning.
        let mut eng = SwitchEngine::new(store.clone());
        b.bench("switch_cycle_serial", || {
            eng.switch_to_shira_shared(Arc::clone(&shared), 1.0);
            eng.revert();
        });
    }
    for &threads in threads_sweep {
        let pool = Arc::new(ThreadPool::new(threads));
        let mut eng = SwitchEngine::with_pool(store.clone(), Some(pool));
        b.bench(&format!("switch_cycle_t{threads}"), || {
            eng.switch_to_shira_shared(Arc::clone(&shared), 1.0);
            eng.revert();
        });
    }

    // -- summaries --------------------------------------------------------
    println!("\n== Fig. 5 summary (fuse / scatter) ==");
    println!("| dim | speedup |");
    println!("|---|---|");
    for (dim, s) in &speedups {
        println!("| {dim} | {s:.1}x |");
    }
    println!("paper shape: speedup grows with dim, ~10x at 4096");

    println!("\n== parallel scaling (scatter_apply + restore, dim {par_dim}) ==");
    println!("| threads | total (ms) | speedup vs t1 |");
    println!("|---|---|---|");
    if let Some(&(_, t1)) = par_scatter.first() {
        for (threads, total) in &par_scatter {
            println!("| {threads} | {:.2} | {:.2}x |", total / 1e6, t1 / total);
        }
    }

    b.write_results("bench_switch");
    let ok = finish_bench("switch", &results_to_entries(b.results()));
    if !ok {
        std::process::exit(1);
    }
}
