//! Table 5 reproduction: per-stage latency of the HF-style adapter
//! pipeline (load / fuse / unfuse / unload) for SHiRA vs LoRA over a
//! whole model's target set, plus an SDXL-shaped large-tensor variant.
//!
//! Run: `cargo bench --bench bench_pipeline`.

use shira::adapter::io;
use shira::adapter::sparse::SparseDelta;
use shira::adapter::{LoraAdapter, LoraTensor, ShiraAdapter};
use shira::coordinator::switch::SwitchEngine;
use shira::model::tensor::Tensor2;
use shira::model::weights::WeightStore;
use shira::util::benchlib::Bencher;
use shira::util::rng::Rng;

/// Build a synthetic model + adapters over the given target shapes.
fn build(
    shapes: &[(usize, usize)],
    frac: f64,
    rank: usize,
    seed: u64,
) -> (WeightStore, ShiraAdapter, LoraAdapter) {
    let mut rng = Rng::new(seed);
    let specs: Vec<(String, Vec<usize>)> = shapes
        .iter()
        .enumerate()
        .map(|(i, &(n, m))| (format!("t{i}"), vec![n, m]))
        .collect();
    let weights = WeightStore::init(&specs, seed);
    let shira_tensors = shapes
        .iter()
        .enumerate()
        .map(|(i, &(n, m))| {
            let k = ((n * m) as f64 * frac).max(1.0) as usize;
            let idx = rng.sample_indices(n * m, k);
            let mut d = vec![0.0f32; k];
            rng.fill_normal(&mut d, 0.0, 0.1);
            (format!("t{i}"), SparseDelta::new(n, m, idx, d))
        })
        .collect();
    let lora_tensors = shapes
        .iter()
        .enumerate()
        .map(|(i, &(n, m))| {
            let mut a = Tensor2::zeros(n, rank);
            let mut b = Tensor2::zeros(rank, m);
            rng.fill_normal(&mut a.data, 0.0, 0.1);
            rng.fill_normal(&mut b.data, 0.0, 0.1);
            LoraTensor {
                target: format!("t{i}"),
                a,
                b,
            }
        })
        .collect();
    (
        weights,
        ShiraAdapter {
            name: "s".into(),
            strategy: "rand".into(),
            tensors: shira_tensors,
        },
        LoraAdapter {
            name: "l".into(),
            scale: 2.0,
            tensors: lora_tensors,
        },
    )
}

fn bench_stage_set(b: &mut Bencher, label: &str, shapes: &[(usize, usize)]) {
    let (mut weights, shira, lora) = build(shapes, 0.02, 32, 7);
    let shira_bytes = io::encode_shira(&shira);
    let lora_bytes = io::encode_lora(&lora);
    let mut engine = SwitchEngine::new();

    b.group(&format!("table5/{label}/shira"));
    b.bench("load(decode)", || {
        let a = io::decode_shira(&shira_bytes).unwrap();
        std::hint::black_box(a.param_count());
    });
    b.bench("fuse(apply)", || {
        engine.switch_to_shira(&mut weights, &shira, 1.0);
    });
    b.bench("unfuse(revert)", || {
        engine.switch_to_shira(&mut weights, &shira, 1.0);
        engine.revert(&mut weights);
    });
    b.bench("full_pipeline", || {
        let t = engine.hf_pipeline_shira(&mut weights, &shira_bytes, 1.0);
        std::hint::black_box(t.total_us());
    });

    b.group(&format!("table5/{label}/lora"));
    b.bench("load(decode)", || {
        let a = io::decode_lora(&lora_bytes).unwrap();
        std::hint::black_box(a.param_count());
    });
    b.bench("fuse", || {
        engine.switch_to_lora(&mut weights, &lora);
    });
    b.bench("unfuse", || {
        engine.switch_to_lora(&mut weights, &lora);
        engine.revert(&mut weights);
    });
    b.bench("full_pipeline", || {
        let t = engine.hf_pipeline_lora(&mut weights, &lora_bytes);
        std::hint::black_box(t.total_us());
    });
    engine.revert(&mut weights);
}

fn main() {
    let mut b = Bencher::new();
    // nanollama-shaped target set (15 small matrices)
    let llama_shapes: Vec<(usize, usize)> = (0..3)
        .flat_map(|_| {
            vec![(128, 128), (128, 128), (128, 128), (128, 256), (256, 128)]
        })
        .collect();
    bench_stage_set(&mut b, "nanollama", &llama_shapes);

    // SDXL-ish large tensors (the paper's Table 5 measures SDXL): a few
    // big attention/MLP blocks.
    let sdxl_shapes = vec![(1024, 1024), (1024, 1024), (1024, 4096), (4096, 1024)];
    bench_stage_set(&mut b, "sdxl-shaped", &sdxl_shapes);

    println!("\npaper shape (Table 5 CPU column): LoRA fuse/unfuse dominate;");
    println!("SHiRA apply/revert are a small fraction of LoRA's stages.");
    b.write_results("bench_pipeline");
}
