//! End-to-end serving benchmark: throughput / latency / switch overhead of
//! the three policies (SHiRA-scatter vs LoRA-fuse vs LoRA-unfused) across
//! trace patterns — the quantitative version of the paper's Appendix A
//! deployment argument.
//!
//! Run: `cargo bench --bench bench_serving` (requires `make artifacts`).
//! Flags: `--check` compares stage timings against the committed
//! `rust/BENCH_serving.json`; `--save-baseline` rewrites it.

use shira::adapter::sparse::SparseDelta;
use shira::adapter::{LoraAdapter, LoraTensor, ShiraAdapter};
use shira::coordinator::server::Server;
use shira::coordinator::switch::Policy;
use shira::data::trace::{generate_trace, switch_count, TracePattern};
use shira::model::tensor::Tensor2;
use shira::model::weights::WeightStore;
use shira::runtime::Runtime;
use shira::util::benchlib::{finish_bench, BaselineEntry};
use shira::util::rng::Rng;

fn main() {
    let rt = match Runtime::with_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_serving (no artifacts): {e}");
            return;
        }
    };
    let meta = rt.manifest.model("llama").unwrap().clone();
    let n_adapters = 6;
    let n_requests = 96;
    let mut rng = Rng::new(0x5E21);
    let names: Vec<String> = (0..n_adapters).map(|i| format!("a{i}")).collect();

    println!("== serving: policy x pattern ({n_requests} requests, {n_adapters} adapters) ==");
    println!("| policy | pattern | trace switches | engine switches | mean switch (us) | mean exec (us) | p99 lat (us) | req/s |");
    println!("|---|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    let mut entries: Vec<BaselineEntry> = Vec::new();
    for policy in [Policy::ShiraScatter, Policy::LoraFuse, Policy::LoraUnfused] {
        for (pname, pattern) in [
            ("bursty", TracePattern::Bursty { burst: 8 }),
            ("uniform", TracePattern::UniformMix),
            ("roundrobin", TracePattern::RoundRobin),
        ] {
            let base = WeightStore::init(&meta.params, 3);
            let mut server = Server::new(&rt, base, policy, "llama", 8 << 20).unwrap();
            for (i, name) in names.iter().enumerate() {
                match policy {
                    Policy::ShiraScatter => {
                        let tensors = meta
                            .shira
                            .iter()
                            .map(|seg| {
                                let idx = rng.sample_indices(seg.numel(), seg.k);
                                let mut d = vec![0.0f32; seg.k];
                                rng.fill_normal(&mut d, 0.0, 0.01);
                                (
                                    seg.name.clone(),
                                    SparseDelta::new(seg.shape.0, seg.shape.1, idx, d),
                                )
                            })
                            .collect();
                        server.store.add_shira(&ShiraAdapter {
                            name: name.clone(),
                            strategy: "rand".into(),
                            tensors,
                        });
                    }
                    _ => {
                        let tensors = meta
                            .lora
                            .iter()
                            .map(|seg| {
                                let mut a = Tensor2::zeros(seg.shape.0, seg.rank);
                                let mut b = Tensor2::zeros(seg.rank, seg.shape.1);
                                rng.fill_normal(&mut a.data, 0.0, 0.01);
                                rng.fill_normal(&mut b.data, 0.0, 0.01);
                                LoraTensor {
                                    target: seg.name.clone(),
                                    a,
                                    b,
                                }
                            })
                            .collect();
                        server.store.add_lora(&LoraAdapter {
                            name: name.clone(),
                            scale: rt.manifest.adapter.lora_scale as f32,
                            tensors,
                        });
                    }
                }
                let _ = i;
            }
            let trace = generate_trace(&names, n_requests, pattern, 1e4, 11);
            let ts = switch_count(&trace);
            let rep = server.run_trace(&trace).unwrap();
            println!(
                "| {} | {pname} | {ts} | {} | {:.1} | {:.1} | {:.0} | {:.1} |",
                policy.name(),
                rep.switches,
                rep.mean_switch_us,
                rep.mean_exec_us,
                rep.p99_latency_us,
                rep.throughput_rps
            );
            rows.push(format!(
                "{{\"name\":\"serving/{}/{}\",\"switches\":{},\"mean_switch_us\":{:.1},\"mean_exec_us\":{:.1},\"rps\":{:.2}}}",
                policy.name(),
                pname,
                rep.switches,
                rep.mean_switch_us,
                rep.mean_exec_us,
                rep.throughput_rps
            ));
            // Per-stage mean/p50/p99 for the regression harness (ns).
            entries.push(BaselineEntry {
                name: format!("serving/{}/{}/switch", policy.name(), pname),
                mean_ns: rep.mean_switch_us * 1e3,
                p50_ns: rep.p50_switch_us * 1e3,
                p99_ns: rep.p99_switch_us * 1e3,
            });
            entries.push(BaselineEntry {
                name: format!("serving/{}/{}/exec", policy.name(), pname),
                mean_ns: rep.mean_exec_us * 1e3,
                p50_ns: rep.p50_exec_us * 1e3,
                p99_ns: rep.p99_exec_us * 1e3,
            });
        }
    }
    println!("\npaper shape: shira-scatter's switch cost ≪ lora-fuse's; lora-unfused");
    println!("avoids switch cost but pays it on every forward (higher exec time).");
    let _ = std::fs::create_dir_all("target/bench-results");
    let _ = std::fs::write(
        "target/bench-results/bench_serving.jsonl",
        rows.join("\n") + "\n",
    );
    if !finish_bench("serving", &entries) {
        std::process::exit(1);
    }
}
