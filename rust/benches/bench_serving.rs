//! End-to-end serving benchmark: throughput / latency / switch overhead
//! across selection mixes (SHiRA singles vs LoRA-fuse vs LoRA-unfused vs
//! a mixed base/single/set trace) and trace patterns — the quantitative
//! version of the paper's Appendix A deployment argument on the unified
//! `Selection` routing API.
//!
//! Run: `cargo bench --bench bench_serving` (tables require
//! `make artifacts`; the bit-identity gate below runs regardless).
//! Flags: `--check` compares stage timings against the committed
//! `rust/BENCH_serving.json`; `--save-baseline` rewrites it.
//!
//! ## Bit-identity gate
//!
//! Before any timing, a mixed base/single/set selection sequence is
//! driven through the `Router` (the serving request path) and asserted
//! bit-identical to the old per-policy engines serving each selection
//! from base — a scatter apply for singles, a serial `fuse_shira`
//! rebuild for sets — at 1 and 4 threads.  This is the acceptance gate
//! for per-request routing: timings below are only meaningful because
//! the bytes are provably unchanged.

use std::sync::Arc;

use shira::adapter::sparse::SparseDelta;
use shira::adapter::{LoraAdapter, LoraTensor, ShiraAdapter};
use shira::coordinator::engine::Router;
use shira::coordinator::fusion::fuse_shira;
use shira::coordinator::selection::Selection;
use shira::coordinator::server::Server;
use shira::coordinator::store::AdapterStore;
use shira::coordinator::switch::SwitchEngine;
use shira::data::trace::{generate_trace, mixed_selections, switch_count, TracePattern};
use shira::model::tensor::Tensor2;
use shira::model::weights::WeightStore;
use shira::runtime::Runtime;
use shira::util::benchlib::{finish_bench, BaselineEntry};
use shira::util::rng::Rng;
use shira::util::threadpool::ThreadPool;

/// Engine-level mixed-selection gate (no artifacts needed): Router bytes
/// == per-policy reference bytes for every step of a base/single/set
/// sequence, at 1 and 4 threads, with an exact base restore at the end.
fn mixed_selection_gate() {
    const DIM: usize = 64;
    let base = WeightStore::init(
        &[("wq".into(), vec![DIM, DIM]), ("wk".into(), vec![DIM, DIM])],
        41,
    );
    let mut rng = Rng::new(0x6A7E);
    let zoo: Vec<ShiraAdapter> = (0..3)
        .map(|i| {
            let mk = |rng: &mut Rng| {
                let idx = rng.sample_indices(DIM * DIM, 200);
                let mut d = vec![0.0; 200];
                rng.fill_normal(&mut d, 0.0, 0.3);
                SparseDelta::new(DIM, DIM, idx, d)
            };
            ShiraAdapter {
                name: format!("g{i}"),
                strategy: "rand".into(),
                tensors: vec![("wq".into(), mk(&mut rng)), ("wk".into(), mk(&mut rng))],
            }
        })
        .collect();
    let seq = vec![
        Selection::single("g0"),
        Selection::set(&[("g0", 1.0), ("g1", 0.5)]),
        Selection::single_at("g2", 0.9),
        Selection::Base,
        Selection::set(&[("g1", 2.0), ("g2", 1.0)]),
        Selection::single_at("g0", 0.5),
        Selection::set(&[("g0", 1.0), ("g1", 1.0), ("g2", 1.0)]),
    ];
    let reference = |sel: &Selection| -> WeightStore {
        let by_name = |n: &str| zoo.iter().find(|a| a.name == n).unwrap();
        match sel {
            Selection::Base => base.clone(),
            Selection::Single { name, alpha } => {
                let mut w = base.clone();
                SwitchEngine::new().switch_to_shira(&mut w, by_name(name), *alpha);
                w
            }
            Selection::Set { members } => {
                let mut sorted = members.clone();
                sorted.sort_by(|a, b| a.0.cmp(&b.0));
                let scaled: Vec<ShiraAdapter> = sorted
                    .iter()
                    .map(|(n, wt)| {
                        let a = by_name(n);
                        ShiraAdapter {
                            name: a.name.clone(),
                            strategy: a.strategy.clone(),
                            tensors: a
                                .tensors
                                .iter()
                                .map(|(t, d)| (t.clone(), d.scaled(*wt)))
                                .collect(),
                        }
                    })
                    .collect();
                let refs: Vec<&ShiraAdapter> = scaled.iter().collect();
                let fused = fuse_shira(&refs, "gate").unwrap();
                let mut w = base.clone();
                SwitchEngine::new().switch_to_shira(&mut w, &fused, 1.0);
                w
            }
        }
    };
    for threads in [1usize, 4] {
        let pool = Arc::new(ThreadPool::new(threads));
        let mut store = AdapterStore::with_config(
            shira::coordinator::store::StoreConfig::default(),
            Some(Arc::clone(&pool)),
        );
        for a in &zoo {
            store.add_shira(a);
        }
        let mut router = Router::new(base.clone(), Some(pool), false);
        for (step, sel) in seq.iter().enumerate() {
            router.apply(&mut store, sel).unwrap();
            assert!(
                router.weights().bit_equal(&reference(sel)),
                "mixed routing diverged at step {step} ({sel}) threads={threads}"
            );
        }
        router.revert_all(&mut store);
        assert!(router.weights().bit_equal(&base), "base restore not exact");
    }
    println!(
        "mixed-selection gate: router bytes == per-policy engine bytes \
         (base/single/set, 1 and 4 threads)"
    );
}

/// One serving scenario: which zoo it needs and which selections it
/// serves.
enum Scenario {
    ShiraSingles,
    LoraFuse,
    LoraUnfused,
    Mixed,
}

impl Scenario {
    fn name(&self) -> &'static str {
        match self {
            Scenario::ShiraSingles => "shira-scatter",
            Scenario::LoraFuse => "lora-fuse",
            Scenario::LoraUnfused => "lora-unfused",
            Scenario::Mixed => "mixed",
        }
    }

    fn lora_zoo(&self) -> bool {
        matches!(self, Scenario::LoraFuse | Scenario::LoraUnfused)
    }

    fn selections(&self, names: &[String]) -> Vec<Selection> {
        match self {
            Scenario::Mixed => mixed_selections(names),
            _ => Selection::singles(names),
        }
    }
}

fn main() {
    // Correctness gate first — runs with or without artifacts.
    mixed_selection_gate();

    let rt = match Runtime::with_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_serving tables (no artifacts): {e}");
            // The gate ran; an empty entry set still exercises --check.
            if !finish_bench("serving", &[]) {
                std::process::exit(1);
            }
            return;
        }
    };
    let meta = rt.manifest.model("llama").unwrap().clone();
    let n_adapters = 6;
    let n_requests = 96;
    let mut rng = Rng::new(0x5E21);
    let names: Vec<String> = (0..n_adapters).map(|i| format!("a{i}")).collect();

    println!("== serving: scenario x pattern ({n_requests} requests, {n_adapters} adapters) ==");
    println!("| scenario | pattern | trace switches | engine switches | transition/fallback/fused | mean switch (us) | mean exec (us) | p99 lat (us) | req/s |");
    println!("|---|---|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    let mut entries: Vec<BaselineEntry> = Vec::new();
    for scenario in [
        Scenario::ShiraSingles,
        Scenario::LoraFuse,
        Scenario::LoraUnfused,
        Scenario::Mixed,
    ] {
        for (pname, pattern) in [
            ("bursty", TracePattern::Bursty { burst: 8 }),
            ("uniform", TracePattern::UniformMix),
            ("roundrobin", TracePattern::RoundRobin),
        ] {
            let base = WeightStore::init(&meta.params, 3);
            let mut server = Server::builder(&rt, base)
                .model("llama")
                .cache_bytes(8 << 20)
                .unfused_lora(matches!(scenario, Scenario::LoraUnfused))
                .build()
                .unwrap();
            for name in names.iter() {
                if scenario.lora_zoo() {
                    let tensors = meta
                        .lora
                        .iter()
                        .map(|seg| {
                            let mut a = Tensor2::zeros(seg.shape.0, seg.rank);
                            let mut b = Tensor2::zeros(seg.rank, seg.shape.1);
                            rng.fill_normal(&mut a.data, 0.0, 0.01);
                            rng.fill_normal(&mut b.data, 0.0, 0.01);
                            LoraTensor {
                                target: seg.name.clone(),
                                a,
                                b,
                            }
                        })
                        .collect();
                    server.store.add_lora(&LoraAdapter {
                        name: name.clone(),
                        scale: rt.manifest.adapter.lora_scale as f32,
                        tensors,
                    });
                } else {
                    let tensors = meta
                        .shira
                        .iter()
                        .map(|seg| {
                            let idx = rng.sample_indices(seg.numel(), seg.k);
                            let mut d = vec![0.0f32; seg.k];
                            rng.fill_normal(&mut d, 0.0, 0.01);
                            (
                                seg.name.clone(),
                                SparseDelta::new(seg.shape.0, seg.shape.1, idx, d),
                            )
                        })
                        .collect();
                    server.store.add_shira(&ShiraAdapter {
                        name: name.clone(),
                        strategy: "rand".into(),
                        tensors,
                    });
                }
            }
            let sels = scenario.selections(&names);
            let trace = generate_trace(&sels, n_requests, pattern, 1e4, 11);
            let ts = switch_count(&trace);
            let rep = server.run_trace(&trace).unwrap();
            println!(
                "| {} | {pname} | {ts} | {} | {}/{}/{} | {:.1} | {:.1} | {:.0} | {:.1} |",
                scenario.name(),
                rep.switches,
                rep.transitions,
                rep.fallbacks,
                rep.fused_switches,
                rep.mean_switch_us,
                rep.mean_exec_us,
                rep.p99_latency_us,
                rep.throughput_rps
            );
            rows.push(format!(
                "{{\"name\":\"serving/{}/{}\",\"switches\":{},\"mean_switch_us\":{:.1},\"mean_exec_us\":{:.1},\"rps\":{:.2}}}",
                scenario.name(),
                pname,
                rep.switches,
                rep.mean_switch_us,
                rep.mean_exec_us,
                rep.throughput_rps
            ));
            // Per-stage mean/p50/p99 for the regression harness (ns).
            entries.push(BaselineEntry {
                name: format!("serving/{}/{}/switch", scenario.name(), pname),
                mean_ns: rep.mean_switch_us * 1e3,
                p50_ns: rep.p50_switch_us * 1e3,
                p99_ns: rep.p99_switch_us * 1e3,
            });
            entries.push(BaselineEntry {
                name: format!("serving/{}/{}/exec", scenario.name(), pname),
                mean_ns: rep.mean_exec_us * 1e3,
                p50_ns: rep.p50_exec_us * 1e3,
                p99_ns: rep.p99_exec_us * 1e3,
            });
        }
    }
    println!("\npaper shape: shira singles' switch cost ≪ lora-fuse's; lora-unfused");
    println!("avoids switch cost but pays it on every forward (higher exec time);");
    println!("the mixed trace routes all three selection kinds through one server.");
    let _ = std::fs::create_dir_all("target/bench-results");
    let _ = std::fs::write(
        "target/bench-results/bench_serving.jsonl",
        rows.join("\n") + "\n",
    );
    if !finish_bench("serving", &entries) {
        std::process::exit(1);
    }
}
