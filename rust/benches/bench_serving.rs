//! End-to-end serving benchmark: throughput / latency / switch overhead
//! across selection mixes (SHiRA singles vs LoRA-fuse vs LoRA-unfused vs
//! a mixed base/single/set trace) and trace patterns — the quantitative
//! version of the paper's Appendix A deployment argument on the unified
//! `Selection` routing API.
//!
//! Run: `cargo bench --bench bench_serving` (tables require
//! `make artifacts`; the bit-identity gates and the artifact-free fleet
//! scenario below run regardless).  Flags: `--check` compares stage
//! timings against the committed `rust/BENCH_serving.json` and
//! `rust/BENCH_fleet.json`; `--save-baseline` rewrites them.
//! `SHIRA_BENCH_FAST=1` shrinks the fleet grid for CI smoke runs.
//!
//! ## Fleet scenario (DESIGN.md §14)
//!
//! A replicas x burstiness grid over the canonical seeded 10k-user
//! Zipf trace from `data::synth` — throughput plus p50/p99 queueing
//! tails — gated on bit-identity against the 1-replica serial
//! reference before any timing.
//!
//! ## Bit-identity gate
//!
//! Before any timing, a mixed base/single/set selection sequence is
//! driven through the `Router` (the serving request path) and asserted
//! bit-identical to the old per-policy engines serving each selection
//! from base — a scatter apply for singles, a serial `fuse_shira`
//! rebuild for sets — at 1 and 4 threads.  This is the acceptance gate
//! for per-request routing: timings below are only meaningful because
//! the bytes are provably unchanged.

use std::sync::Arc;
use std::time::Instant;

use shira::adapter::sparse::SparseDelta;
use shira::adapter::ShiraAdapter;
use shira::coordinator::engine::Router;
use shira::coordinator::fleet::Fleet;
use shira::coordinator::fusion::fuse_shira;
use shira::coordinator::selection::Selection;
use shira::coordinator::server::Server;
use shira::coordinator::store::{AdapterStore, StoreConfig};
use shira::coordinator::switch::SwitchEngine;
use shira::data::synth::{
    adapter_names, fleet_trace, synth_lora_adapter, synth_shira_adapter, toy_base, toy_shira_zoo,
};
use shira::data::trace::{generate_trace, mixed_selections, switch_count, TracePattern};
use shira::model::weights::WeightStore;
use shira::runtime::Runtime;
use shira::util::benchlib::{finish_bench, BaselineEntry};
use shira::util::rng::Rng;
use shira::util::threadpool::ThreadPool;

/// Engine-level mixed-selection gate (no artifacts needed): Router bytes
/// == per-policy reference bytes for every step of a base/single/set
/// sequence, at 1 and 4 threads, with an exact base restore at the end.
fn mixed_selection_gate() {
    const DIM: usize = 64;
    let base = WeightStore::init(
        &[("wq".into(), vec![DIM, DIM]), ("wk".into(), vec![DIM, DIM])],
        41,
    );
    let mut rng = Rng::new(0x6A7E);
    let zoo: Vec<ShiraAdapter> = (0..3)
        .map(|i| {
            let mk = |rng: &mut Rng| {
                let idx = rng.sample_indices(DIM * DIM, 200);
                let mut d = vec![0.0; 200];
                rng.fill_normal(&mut d, 0.0, 0.3);
                SparseDelta::new(DIM, DIM, idx, d)
            };
            ShiraAdapter {
                name: format!("g{i}"),
                strategy: "rand".into(),
                tensors: vec![("wq".into(), mk(&mut rng)), ("wk".into(), mk(&mut rng))],
            }
        })
        .collect();
    let seq = vec![
        Selection::single("g0"),
        Selection::set(&[("g0", 1.0), ("g1", 0.5)]),
        Selection::single_at("g2", 0.9),
        Selection::Base,
        Selection::set(&[("g1", 2.0), ("g2", 1.0)]),
        Selection::single_at("g0", 0.5),
        Selection::set(&[("g0", 1.0), ("g1", 1.0), ("g2", 1.0)]),
    ];
    let reference = |sel: &Selection| -> WeightStore {
        let by_name = |n: &str| zoo.iter().find(|a| a.name == n).unwrap();
        match sel {
            Selection::Base => base.clone(),
            Selection::Single { name, alpha } => {
                let mut w = base.clone();
                SwitchEngine::new().switch_to_shira(&mut w, by_name(name), *alpha);
                w
            }
            Selection::Set { members } => {
                let mut sorted = members.clone();
                sorted.sort_by(|a, b| a.0.cmp(&b.0));
                let scaled: Vec<ShiraAdapter> = sorted
                    .iter()
                    .map(|(n, wt)| {
                        let a = by_name(n);
                        ShiraAdapter {
                            name: a.name.clone(),
                            strategy: a.strategy.clone(),
                            tensors: a
                                .tensors
                                .iter()
                                .map(|(t, d)| (t.clone(), d.scaled(*wt)))
                                .collect(),
                        }
                    })
                    .collect();
                let refs: Vec<&ShiraAdapter> = scaled.iter().collect();
                let fused = fuse_shira(&refs, "gate").unwrap();
                let mut w = base.clone();
                SwitchEngine::new().switch_to_shira(&mut w, &fused, 1.0);
                w
            }
        }
    };
    for threads in [1usize, 4] {
        let pool = Arc::new(ThreadPool::new(threads));
        let mut store = AdapterStore::with_config(
            shira::coordinator::store::StoreConfig::default(),
            Some(Arc::clone(&pool)),
        );
        for a in &zoo {
            store.add_shira(a);
        }
        let mut router = Router::new(base.clone(), Some(pool), false);
        for (step, sel) in seq.iter().enumerate() {
            router.apply(&mut store, sel).unwrap();
            assert!(
                router.weights().bit_equal(&reference(sel)),
                "mixed routing diverged at step {step} ({sel}) threads={threads}"
            );
        }
        router.revert_all(&mut store);
        assert!(router.weights().bit_equal(&base), "base restore not exact");
    }
    println!(
        "mixed-selection gate: router bytes == per-policy engine bytes \
         (base/single/set, 1 and 4 threads)"
    );
}

/// Fleet scenario (DESIGN.md §14): replicas x burstiness grid over the
/// canonical seeded 10k-user Zipf trace — artifact-free, so it always
/// runs.  Before anything is timed, EVERY grid cell is gated on
/// bit-identity: the oracle must stay green and per-request outcomes
/// must equal the 1-replica serial reference.  Timed runs then disable
/// the oracle.  Returns the `--check` verdict against
/// `rust/BENCH_fleet.json`.
fn fleet_bench() -> bool {
    const DIM: usize = 48;
    const NNZ: usize = 200;
    const SEED: u64 = 0xF1EE7;
    let fast = std::env::var("SHIRA_BENCH_FAST").is_ok();
    let (grid, bursts, n_requests): (&[usize], &[usize], usize) = if fast {
        (&[1, 2], &[4], 120)
    } else {
        (&[1, 2, 4, 8], &[2, 16], 400)
    };
    let names = adapter_names(6);
    let sels = mixed_selections(&names);
    let cfg = StoreConfig {
        cache_bytes: 64 << 20,
        prefetch_depth: 0,
        plan_cache_bytes: 0,
        ..StoreConfig::default()
    };
    let build = |replicas: usize, oracle: bool| {
        Fleet::builder(toy_base(DIM, SEED))
            .replicas(replicas)
            .queue_depth(512)
            .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, SEED))
            .store_config(cfg.clone())
            .oracle(oracle)
            .build()
    };
    // Bit-identity gate first: timings below are only meaningful
    // because the outcomes and bytes are provably unchanged.
    for &burst in bursts {
        let trace = fleet_trace(&sels, n_requests, burst, SEED);
        let mut serial_fleet = build(1, true);
        let serial = serial_fleet.run_trace(&trace, SEED).unwrap();
        assert!(
            serial.oracle_failures.is_empty(),
            "fleet gate (serial, burst {burst}): {:?}",
            serial.oracle_failures
        );
        for &r in grid {
            let mut fleet = build(r, true);
            let rep = fleet.run_trace(&trace, SEED).unwrap();
            assert!(
                rep.oracle_failures.is_empty(),
                "fleet gate (replicas {r}, burst {burst}): {:?}",
                rep.oracle_failures
            );
            assert_eq!(
                rep.actions, serial.actions,
                "fleet gate: outcomes at {r} replicas diverge from the \
                 serial reference (burst {burst})"
            );
        }
    }
    println!(
        "fleet gate: outcomes and resident bytes bit-identical to the \
         serial reference at every replica count"
    );

    println!("\n== fleet: replicas x burstiness ({n_requests} requests, 6 adapters, zipf 10k users) ==");
    println!("| replicas | burst | served | switches | req/s (wall) | p50 wait (us) | p99 wait (us) |");
    println!("|---|---|---|---|---|---|---|");
    let mut entries: Vec<BaselineEntry> = Vec::new();
    for &r in grid {
        for &burst in bursts {
            let trace = fleet_trace(&sels, n_requests, burst, SEED);
            let mut fleet = build(r, false);
            let t0 = Instant::now();
            let rep = fleet.run_trace(&trace, SEED).unwrap();
            let wall = t0.elapsed();
            let rps = n_requests as f64 / wall.as_secs_f64();
            println!(
                "| {r} | {burst} | {} | {} | {rps:.0} | {:.1} | {:.1} |",
                rep.served, rep.switches, rep.p50_wait_us, rep.p99_wait_us
            );
            // Wall mean per request; deterministic virtual-time tails.
            entries.push(BaselineEntry {
                name: format!("fleet/r{r}/b{burst}"),
                mean_ns: wall.as_nanos() as f64 / n_requests as f64,
                p50_ns: rep.p50_wait_us * 1e3,
                p99_ns: rep.p99_wait_us * 1e3,
            });
        }
    }
    finish_bench("fleet", &entries)
}

/// One serving scenario: which zoo it needs and which selections it
/// serves.
enum Scenario {
    ShiraSingles,
    LoraFuse,
    LoraUnfused,
    Mixed,
}

impl Scenario {
    fn name(&self) -> &'static str {
        match self {
            Scenario::ShiraSingles => "shira-scatter",
            Scenario::LoraFuse => "lora-fuse",
            Scenario::LoraUnfused => "lora-unfused",
            Scenario::Mixed => "mixed",
        }
    }

    fn lora_zoo(&self) -> bool {
        matches!(self, Scenario::LoraFuse | Scenario::LoraUnfused)
    }

    fn selections(&self, names: &[String]) -> Vec<Selection> {
        match self {
            Scenario::Mixed => mixed_selections(names),
            _ => Selection::singles(names),
        }
    }
}

fn main() {
    // Correctness gates first — both run with or without artifacts.
    mixed_selection_gate();
    let fleet_ok = fleet_bench();

    let rt = match Runtime::with_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_serving tables (no artifacts): {e}");
            // The gates ran; an empty entry set still exercises --check.
            if !finish_bench("serving", &[]) || !fleet_ok {
                std::process::exit(1);
            }
            return;
        }
    };
    let meta = rt.manifest.model("llama").unwrap().clone();
    let n_adapters = 6;
    let n_requests = 96;
    let names = adapter_names(n_adapters);

    println!("== serving: scenario x pattern ({n_requests} requests, {n_adapters} adapters) ==");
    println!("| scenario | pattern | trace switches | engine switches | transition/fallback/fused | mean switch (us) | mean exec (us) | p99 lat (us) | req/s |");
    println!("|---|---|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    let mut entries: Vec<BaselineEntry> = Vec::new();
    for scenario in [
        Scenario::ShiraSingles,
        Scenario::LoraFuse,
        Scenario::LoraUnfused,
        Scenario::Mixed,
    ] {
        for (pname, pattern) in [
            ("bursty", TracePattern::Bursty { burst: 8 }),
            ("uniform", TracePattern::UniformMix),
            ("roundrobin", TracePattern::RoundRobin),
        ] {
            let base = WeightStore::init(&meta.params, 3);
            let mut server = Server::builder(&rt, base)
                .model("llama")
                .cache_bytes(8 << 20)
                .unfused_lora(matches!(scenario, Scenario::LoraUnfused))
                .build()
                .unwrap();
            // Seeded zoo shared with `shira serve` and the fleet tests
            // (data::synth): same (seed, name) pair, same adapter.
            for name in names.iter() {
                if scenario.lora_zoo() {
                    server.store.add_lora(&synth_lora_adapter(
                        &meta,
                        name,
                        rt.manifest.adapter.lora_scale as f32,
                        0x5E21,
                    ));
                } else {
                    server.store.add_shira(&synth_shira_adapter(&meta, name, 0x5E21));
                }
            }
            let sels = scenario.selections(&names);
            let trace = generate_trace(&sels, n_requests, pattern, 1e4, 11);
            let ts = switch_count(&trace);
            let rep = server.run_trace(&trace).unwrap();
            println!(
                "| {} | {pname} | {ts} | {} | {}/{}/{} | {:.1} | {:.1} | {:.0} | {:.1} |",
                scenario.name(),
                rep.switches,
                rep.transitions,
                rep.fallbacks,
                rep.fused_switches,
                rep.mean_switch_us,
                rep.mean_exec_us,
                rep.p99_latency_us,
                rep.throughput_rps
            );
            rows.push(format!(
                "{{\"name\":\"serving/{}/{}\",\"switches\":{},\"mean_switch_us\":{:.1},\"mean_exec_us\":{:.1},\"rps\":{:.2}}}",
                scenario.name(),
                pname,
                rep.switches,
                rep.mean_switch_us,
                rep.mean_exec_us,
                rep.throughput_rps
            ));
            // Per-stage mean/p50/p99 for the regression harness (ns).
            entries.push(BaselineEntry {
                name: format!("serving/{}/{}/switch", scenario.name(), pname),
                mean_ns: rep.mean_switch_us * 1e3,
                p50_ns: rep.p50_switch_us * 1e3,
                p99_ns: rep.p99_switch_us * 1e3,
            });
            entries.push(BaselineEntry {
                name: format!("serving/{}/{}/exec", scenario.name(), pname),
                mean_ns: rep.mean_exec_us * 1e3,
                p50_ns: rep.p50_exec_us * 1e3,
                p99_ns: rep.p99_exec_us * 1e3,
            });
        }
    }
    println!("\npaper shape: shira singles' switch cost ≪ lora-fuse's; lora-unfused");
    println!("avoids switch cost but pays it on every forward (higher exec time);");
    println!("the mixed trace routes all three selection kinds through one server.");
    let _ = std::fs::create_dir_all("target/bench-results");
    let _ = std::fs::write(
        "target/bench-results/bench_serving.jsonl",
        rows.join("\n") + "\n",
    );
    if !finish_bench("serving", &entries) || !fleet_ok {
        std::process::exit(1);
    }
}
