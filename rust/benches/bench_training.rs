//! Table 6 + Appendix C/D reproduction: training steps/s and peak logical
//! memory per adapter implementation (LoRA / DoRA / SHiRA-sparse /
//! SHiRA-dense-grad-hook / full FT), driven through the real AOT train-step
//! executables.
//!
//! Run: `cargo bench --bench bench_training` (requires `make artifacts`).

use shira::adapter::mask::MaskStrategy;
use shira::config::RunConfig;
use shira::data::tasks::ALL_TASKS;
use shira::runtime::{HostValue, Runtime};
use shira::train::schedule::Schedule;
use shira::train::{Trainer, TrainKind};
use shira::util::alloc::fmt_bytes;
use shira::util::rng::Rng;

fn main() {
    let rt = match Runtime::with_default_artifacts() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping bench_training (no artifacts): {e}");
            return;
        }
    };
    let cfg = RunConfig::fast();
    let meta = rt.manifest.model("llama").unwrap().clone();
    let base = shira::model::weights::WeightStore::init(&meta.params, cfg.seed);
    let trainer = Trainer::new(&rt, "llama", base).unwrap();
    let (bsz, t) = (meta.dim("batch"), meta.dim("seq_len"));
    let steps = 12;

    let kinds: Vec<(&str, TrainKind)> = vec![
        ("lora", TrainKind::Lora),
        ("dora", TrainKind::Dora),
        ("shira_sparse(AppD)", TrainKind::Shira(MaskStrategy::WeightMagnitude)),
        (
            "shira_dense(AppC)",
            TrainKind::ShiraDense(MaskStrategy::WeightMagnitude),
        ),
        ("full_ft", TrainKind::Full),
    ];
    println!("== Table 6: training speed & memory ({steps} steps each) ==");
    println!("| adapter | trainable | steps/s | Δsteps vs lora | peak mem | Δmem vs lora |");
    println!("|---|---|---|---|---|---|");
    let mut lora_ref: Option<(f64, usize)> = None;
    let mut rows = Vec::new();
    for (i, (label, kind)) in kinds.iter().enumerate() {
        let seed = cfg.seed;
        let mut data = move |_s: usize, rng: &mut Rng| {
            let batch = shira::data::tasks::mixture_batch(&ALL_TASKS, bsz, t, seed, rng);
            vec![
                HostValue::i32(batch.x, vec![bsz, t]),
                HostValue::i32(batch.y, vec![bsz, t]),
                HostValue::f32(batch.mask, vec![bsz, t]),
            ]
        };
        let out = trainer
            .train(*kind, steps, Schedule::Const(1e-3), &mut data, seed ^ i as u64)
            .unwrap();
        let (ds, dm) = match lora_ref {
            Some((s0, m0)) => (
                format!("{:+.1}%", 100.0 * (out.steps_per_sec - s0) / s0),
                format!(
                    "{:+.1}%",
                    100.0 * (out.peak_bytes as f64 - m0 as f64) / m0 as f64
                ),
            ),
            None => {
                lora_ref = Some((out.steps_per_sec, out.peak_bytes));
                ("+0%".into(), "+0%".into())
            }
        };
        println!(
            "| {label} | {} | {:.2} | {ds} | {} | {dm} |",
            out.trainable_params,
            out.steps_per_sec,
            fmt_bytes(out.peak_bytes)
        );
        rows.push(format!(
            "{{\"name\":\"table6/{label}\",\"steps_per_sec\":{:.3},\"peak_bytes\":{},\"trainable\":{}}}",
            out.steps_per_sec, out.peak_bytes, out.trainable_params
        ));
    }
    println!("\npaper shape: SHiRA-sparse peak mem < LoRA < DoRA; SHiRA ~ LoRA speed;");
    println!("DoRA clearly slower; dense grad-hook variant shows the memory cost App. D removes.");
    let _ = std::fs::create_dir_all("target/bench-results");
    let _ = std::fs::write(
        "target/bench-results/bench_training.jsonl",
        rows.join("\n") + "\n",
    );
}
