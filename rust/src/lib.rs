//! # SHiRA: Sparse High Rank Adapters
//!
//! A rapid-switching adapter serving + finetuning framework reproducing
//! Bhardwaj et al., *"Rapid Switching and Multi-Adapter Fusion via Sparse
//! High Rank Adapters"* (ICML 2024 W-FMW).
//!
//! Three layers (DESIGN.md §2):
//! * **L1** Pallas kernels + **L2** JAX models live in `python/compile/` and
//!   are AOT-lowered to HLO text at build time (`make artifacts`).
//! * **L3** (this crate) owns everything at run time: the PJRT [`runtime`],
//!   the [`adapter`] algebra (masks, sparse deltas, file format), the
//!   [`train`] orchestrator, the synthetic [`data`] suites, and the serving
//!   [`coordinator`] (router → batcher → switch engine → executor).
//!
//! See `rust/README.md` for the architecture map and DESIGN.md for the
//! per-subsystem invariants.

// Every public item in the crate is documented (the config/data/repro/
// runtime/train pass deferred since PR 2 landed with the Selection
// routing redesign); CI denies rustdoc warnings to keep it that way.
#![warn(missing_docs)]

pub mod adapter;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod repro;
pub mod runtime;
pub mod train;
pub mod util;
