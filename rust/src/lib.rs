//! # SHiRA: Sparse High Rank Adapters
//!
//! A rapid-switching adapter serving + finetuning framework reproducing
//! Bhardwaj et al., *"Rapid Switching and Multi-Adapter Fusion via Sparse
//! High Rank Adapters"* (ICML 2024 W-FMW).
//!
//! Three layers (DESIGN.md §2):
//! * **L1** Pallas kernels + **L2** JAX models live in `python/compile/` and
//!   are AOT-lowered to HLO text at build time (`make artifacts`).
//! * **L3** (this crate) owns everything at run time: the PJRT [`runtime`],
//!   the [`adapter`] algebra (masks, sparse deltas, file format), the
//!   [`train`] orchestrator, the synthetic [`data`] suites, and the serving
//!   [`coordinator`] (router → batcher → switch engine → executor).
//!
//! See `rust/README.md` for the architecture map and DESIGN.md for the
//! per-subsystem invariants.

// Every public item in the serving core (adapter, coordinator, model) and
// the substrate it leans on (benchlib, threadpool, rng, stats, json) is
// documented; modules still carrying `allow(missing_docs)` below are
// tracked for a follow-up docs pass.
#![warn(missing_docs)]

pub mod adapter;
#[allow(missing_docs)]
pub mod config;
pub mod coordinator;
#[allow(missing_docs)]
pub mod data;
pub mod model;
#[allow(missing_docs)]
pub mod repro;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod train;
pub mod util;
