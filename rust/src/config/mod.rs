//! Run configuration: JSON files + CLI overrides, with validation.
//!
//! Every experiment is fully described by a `RunConfig`; the repro drivers
//! serialize the exact config they ran into their report header so results
//! are reproducible from the report alone.

use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// One experiment's full configuration: seeds, step counts, learning
/// rates, serving-trace shape, and the report directory.
///
/// # Examples
///
/// ```
/// use shira::config::RunConfig;
///
/// let fast = RunConfig::fast();
/// assert!(fast.adapter_steps < RunConfig::default().adapter_steps);
/// fast.validate().unwrap();
/// // JSON roundtrips exactly.
/// assert_eq!(RunConfig::from_json(&fast.to_json()).unwrap(), fast);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Root seed — every stochastic stream derives from it.
    pub seed: u64,
    /// Steps for adapter finetuning runs.
    pub adapter_steps: usize,
    /// Steps for base-model pretraining.
    pub pretrain_steps: usize,
    /// Eval examples per task.
    pub eval_examples: usize,
    /// Eval batches per style measurement.
    pub style_eval_batches: usize,
    /// SHiRA adapter learning rate (paper Table 8: 5e-4 SHiRA LLM).
    pub lr_shira: f64,
    /// LoRA/DoRA adapter learning rate (paper Table 8: 2e-4 LLM).
    pub lr_lora: f64,
    /// Serving: requests per synthesized trace.
    pub trace_len: usize,
    /// Serving: decoded-adapter cache budget in bytes.
    pub cache_bytes: usize,
    /// Output directory for reports.
    pub report_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            seed: 42,
            adapter_steps: 2000,
            pretrain_steps: 1500,
            eval_examples: 128,
            style_eval_batches: 4,
            lr_shira: 5e-3,
            lr_lora: 2e-3,
            trace_len: 96,
            cache_bytes: 8 << 20,
            report_dir: "reports".into(),
        }
    }
}

impl RunConfig {
    /// Small config for smoke tests / --fast runs.
    pub fn fast() -> Self {
        RunConfig {
            adapter_steps: 60,
            pretrain_steps: 120,
            eval_examples: 48,
            style_eval_batches: 2,
            trace_len: 32,
            ..Default::default()
        }
    }

    /// Build a config from parsed JSON, keeping defaults for absent keys
    /// and validating the result.
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut c = RunConfig::default();
        let get_usize = |key: &str, dst: &mut usize| {
            if let Some(v) = j.get(key) {
                *dst = v.as_usize().ok_or(format!("{key}: expected integer"))?;
            }
            Ok::<(), String>(())
        };
        if let Some(v) = j.get("seed") {
            c.seed = v.as_f64().ok_or("seed: expected number")? as u64;
        }
        get_usize("adapter_steps", &mut c.adapter_steps)?;
        get_usize("pretrain_steps", &mut c.pretrain_steps)?;
        get_usize("eval_examples", &mut c.eval_examples)?;
        get_usize("style_eval_batches", &mut c.style_eval_batches)?;
        get_usize("trace_len", &mut c.trace_len)?;
        get_usize("cache_bytes", &mut c.cache_bytes)?;
        if let Some(v) = j.get("lr_shira") {
            c.lr_shira = v.as_f64().ok_or("lr_shira: expected number")?;
        }
        if let Some(v) = j.get("lr_lora") {
            c.lr_lora = v.as_f64().ok_or("lr_lora: expected number")?;
        }
        if let Some(v) = j.get("report_dir") {
            c.report_dir = v.as_str().ok_or("report_dir: expected string")?.to_string();
        }
        c.validate()?;
        Ok(c)
    }

    /// Load and validate a JSON config file.
    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let j = json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    /// Apply CLI overrides (`--seed`, `--steps`, `--fast`, `--config`).
    pub fn from_args(args: &Args) -> Result<Self, String> {
        let mut c = if let Some(path) = args.get("config") {
            Self::load(path)?
        } else if args.has("fast") {
            Self::fast()
        } else {
            Self::default()
        };
        c.seed = args.get_u64("seed", c.seed).map_err(|e| e.to_string())?;
        c.adapter_steps = args
            .get_usize("steps", c.adapter_steps)
            .map_err(|e| e.to_string())?;
        c.pretrain_steps = args
            .get_usize("pretrain-steps", c.pretrain_steps)
            .map_err(|e| e.to_string())?;
        c.eval_examples = args
            .get_usize("eval-examples", c.eval_examples)
            .map_err(|e| e.to_string())?;
        c.trace_len = args
            .get_usize("trace-len", c.trace_len)
            .map_err(|e| e.to_string())?;
        c.cache_bytes = args
            .get_usize("cache-bytes", c.cache_bytes)
            .map_err(|e| e.to_string())?;
        if let Some(dir) = args.get("report-dir") {
            c.report_dir = dir.to_string();
        }
        c.validate()?;
        Ok(c)
    }

    /// Reject configs that cannot run (zero steps/examples, non-positive
    /// learning rates).
    pub fn validate(&self) -> Result<(), String> {
        if self.adapter_steps == 0 {
            return Err("adapter_steps must be > 0".into());
        }
        if self.eval_examples == 0 {
            return Err("eval_examples must be > 0".into());
        }
        if !(self.lr_shira > 0.0 && self.lr_lora > 0.0) {
            return Err("learning rates must be positive".into());
        }
        Ok(())
    }

    /// Serialize to JSON (the exact form repro reports embed in their
    /// headers, so results are reproducible from the report alone).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("adapter_steps", Json::num(self.adapter_steps as f64)),
            ("pretrain_steps", Json::num(self.pretrain_steps as f64)),
            ("eval_examples", Json::num(self.eval_examples as f64)),
            (
                "style_eval_batches",
                Json::num(self.style_eval_batches as f64),
            ),
            ("lr_shira", Json::num(self.lr_shira)),
            ("lr_lora", Json::num(self.lr_lora)),
            ("trace_len", Json::num(self.trace_len as f64)),
            ("cache_bytes", Json::num(self.cache_bytes as f64)),
            ("report_dir", Json::str(&self.report_dir)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        RunConfig::default().validate().unwrap();
        RunConfig::fast().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let c = RunConfig::default();
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn overrides_from_args() {
        let argv: Vec<String> =
            ["--seed", "7", "--steps", "10", "--fast", "--cache-bytes", "4096"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        let args = Args::parse(&argv, &[]).unwrap();
        let c = RunConfig::from_args(&args).unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.adapter_steps, 10);
        assert_eq!(c.cache_bytes, 4096);
        assert_eq!(c.pretrain_steps, RunConfig::fast().pretrain_steps);
    }

    #[test]
    fn invalid_rejected() {
        let j = json::parse(r#"{"adapter_steps": 0}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let j = json::parse(r#"{"seed": 9}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.seed, 9);
        assert_eq!(c.adapter_steps, RunConfig::default().adapter_steps);
    }
}
