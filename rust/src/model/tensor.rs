//! Dense f32 tensor substrate for the serving-side weight memory.
//!
//! This is NOT a general autodiff tensor — the compute graphs live in the
//! AOT-compiled XLA artifacts.  What lives here is what the paper's
//! switching benchmarks exercise: contiguous weight storage, the dense
//! `W += scale * A@B` LoRA fuse (kept deliberately fast — the Fig. 5
//! baseline must not be a strawman), and elementwise utilities.
//!
//! The fuse has both a serial and a row-sharded parallel form; both run
//! the *same* per-row kernel ([`Tensor2::add_outer_product`] delegates to
//! it over the full row range), so when the switch engine goes parallel
//! the LoRA baseline parallelizes identically and the Fig. 5 comparison
//! stays fair.

use crate::util::threadpool::{SendPtr, ThreadPool};

/// A dense row-major f32 matrix (1-D tensors are stored as 1×n).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor2 {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major contiguous storage, `rows * cols` long.
    pub data: Vec<f32>,
}

impl Tensor2 {
    /// All-zeros tensor of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor2 {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Wrap an existing row-major buffer (length must match the shape).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Tensor2 { rows, cols, data }
    }

    /// Build element-wise from `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Tensor2 { rows, cols, data }
    }

    /// Number of elements (rows × cols).
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Storage size in bytes (f32 per element).
    pub fn nbytes(&self) -> usize {
        self.numel() * 4
    }

    /// Element at (i, j).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Overwrite element (i, j).
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// `self += scale * a @ b` — the LoRA fuse baseline (paper Fig. 5).
    ///
    /// Rank `r = a.cols` is small (4-64), so the optimal loop order is the
    /// rank-1 update: for each row i and each k < r, do one vectorizable
    /// axpy over the contiguous output row.  LLVM autovectorizes the inner
    /// loop to FMA lanes; no blocking needed because each output row is
    /// touched exactly once (streaming, cache-friendly).
    pub fn add_outer_product(&mut self, a: &Tensor2, b: &Tensor2, scale: f32) {
        assert_eq!(a.rows, self.rows);
        assert_eq!(b.cols, self.cols);
        assert_eq!(a.cols, b.rows);
        let rows = self.rows;
        Self::outer_rows(&mut self.data, a, b, scale, 0, rows);
    }

    /// Row-sharded parallel form of [`Self::add_outer_product`].
    ///
    /// Rows are split into contiguous chunks, one per task; each output
    /// row is owned by exactly one task and the per-row arithmetic is the
    /// same kernel as the serial path, so results are bit-identical for
    /// any thread count (the baseline stays fair, per the Fig. 5
    /// strawman note).
    pub fn add_outer_product_par(
        &mut self,
        a: &Tensor2,
        b: &Tensor2,
        scale: f32,
        pool: &ThreadPool,
    ) {
        assert_eq!(a.rows, self.rows);
        assert_eq!(b.cols, self.cols);
        assert_eq!(a.cols, b.rows);
        let rows = self.rows;
        let n_tasks = pool.threads().min(rows).max(1);
        if n_tasks <= 1 {
            Self::outer_rows(&mut self.data, a, b, scale, 0, rows);
            return;
        }
        let m = self.cols;
        let wp = SendPtr::new(self.data.as_mut_ptr());
        pool.scoped_for(n_tasks, move |t| {
            let lo = rows * t / n_tasks;
            let hi = rows * (t + 1) / n_tasks;
            // SAFETY: tasks own disjoint row ranges [lo, hi) of the output.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(wp.get().add(lo * m), (hi - lo) * m)
            };
            Self::outer_rows(dst, a, b, scale, lo, hi);
        });
    }

    /// The shared per-row fuse kernel: `dst` holds rows `[lo, hi)` of W.
    fn outer_rows(dst: &mut [f32], a: &Tensor2, b: &Tensor2, scale: f32, lo: usize, hi: usize) {
        let r = a.cols;
        let m = b.cols;
        for i in lo..hi {
            let w_row = &mut dst[(i - lo) * m..(i - lo + 1) * m];
            let a_row = &a.data[i * r..(i + 1) * r];
            for (k, &aik) in a_row.iter().enumerate() {
                let s = scale * aik;
                if s == 0.0 {
                    continue;
                }
                let b_row = &b.data[k * m..(k + 1) * m];
                for (w, &bv) in w_row.iter_mut().zip(b_row.iter()) {
                    *w += s * bv;
                }
            }
        }
    }

    /// `self -= scale * a @ b` — LoRA unfuse (the HF pipeline's 4th stage).
    pub fn sub_outer_product(&mut self, a: &Tensor2, b: &Tensor2, scale: f32) {
        self.add_outer_product(a, b, -scale);
    }

    /// Parallel unfuse (see [`Self::add_outer_product_par`]).
    pub fn sub_outer_product_par(
        &mut self,
        a: &Tensor2,
        b: &Tensor2,
        scale: f32,
        pool: &ThreadPool,
    ) {
        self.add_outer_product_par(a, b, -scale, pool);
    }

    /// Dense matmul (used by tests and the unfused-mode model): C = A @ B.
    pub fn matmul(a: &Tensor2, b: &Tensor2) -> Tensor2 {
        assert_eq!(a.cols, b.rows);
        let mut c = Tensor2::zeros(a.rows, b.cols);
        let m = b.cols;
        for i in 0..a.rows {
            let c_row = &mut c.data[i * m..(i + 1) * m];
            for k in 0..a.cols {
                let aik = a.data[i * a.cols + k];
                if aik == 0.0 {
                    continue;
                }
                let b_row = &b.data[k * m..(k + 1) * m];
                for (cv, &bv) in c_row.iter_mut().zip(b_row.iter()) {
                    *cv += aik * bv;
                }
            }
        }
        c
    }

    /// Largest absolute element-wise difference to `other`.
    pub fn max_abs_diff(&self, other: &Tensor2) -> f32 {
        assert_eq!(self.numel(), other.numel());
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Frobenius norm (√Σx²).
    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, n: usize, m: usize) -> Tensor2 {
        let mut t = Tensor2::zeros(n, m);
        rng.fill_normal(&mut t.data, 0.0, 1.0);
        t
    }

    #[test]
    fn matmul_identity() {
        let i3 = Tensor2::from_fn(3, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        let mut r = Rng::new(1);
        let a = random(&mut r, 3, 3);
        assert_eq!(Tensor2::matmul(&i3, &a), a);
        assert_eq!(Tensor2::matmul(&a, &i3), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor2::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = Tensor2::matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn outer_product_matches_matmul() {
        let mut r = Rng::new(2);
        let (n, rank, m) = (16, 4, 24);
        let a = random(&mut r, n, rank);
        let b = random(&mut r, rank, m);
        let w0 = random(&mut r, n, m);
        let mut w = w0.clone();
        w.add_outer_product(&a, &b, 0.7);
        let ab = Tensor2::matmul(&a, &b);
        let want = Tensor2::from_fn(n, m, |i, j| w0.at(i, j) + 0.7 * ab.at(i, j));
        assert!(w.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn fuse_then_unfuse_is_near_identity() {
        // The float drift measured by the switch-drift ablation; tiny but
        // nonzero — SHiRA's snapshot-revert is exact instead.
        let mut r = Rng::new(3);
        let a = random(&mut r, 32, 4);
        let b = random(&mut r, 4, 32);
        let w0 = random(&mut r, 32, 32);
        let mut w = w0.clone();
        w.add_outer_product(&a, &b, 2.0);
        w.sub_outer_product(&a, &b, 2.0);
        assert!(w.max_abs_diff(&w0) < 1e-4);
    }

    #[test]
    fn parallel_outer_product_bit_identical_for_any_thread_count() {
        let mut rng = Rng::new(21);
        let (n, rank, m) = (37, 8, 53); // deliberately non-divisible sizes
        let a = random(&mut rng, n, rank);
        let b = random(&mut rng, rank, m);
        let w0 = random(&mut rng, n, m);
        let mut serial = w0.clone();
        serial.add_outer_product(&a, &b, 1.3);
        for threads in [1usize, 2, 4, 9] {
            let pool = ThreadPool::new(threads);
            let mut par = w0.clone();
            par.add_outer_product_par(&a, &b, 1.3, &pool);
            assert_eq!(par.data, serial.data, "threads={threads}");
            par.sub_outer_product_par(&a, &b, 1.3, &pool);
            let mut serial_rt = w0.clone();
            serial_rt.add_outer_product(&a, &b, 1.3);
            serial_rt.sub_outer_product(&a, &b, 1.3);
            assert_eq!(par.data, serial_rt.data, "roundtrip threads={threads}");
        }
    }

    #[test]
    fn zero_scale_is_noop() {
        let mut r = Rng::new(4);
        let a = random(&mut r, 8, 2);
        let b = random(&mut r, 2, 8);
        let w0 = random(&mut r, 8, 8);
        let mut w = w0.clone();
        w.add_outer_product(&a, &b, 0.0);
        assert_eq!(w, w0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor2::zeros(2, 3);
        let b = Tensor2::zeros(2, 3);
        Tensor2::matmul(&a, &b);
    }

    #[test]
    fn from_fn_layout_row_major() {
        let t = Tensor2::from_fn(2, 3, |i, j| (i * 10 + j) as f32);
        assert_eq!(t.data, vec![0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        assert_eq!(t.at(1, 2), 12.0);
    }
}
