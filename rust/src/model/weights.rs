//! Named weight store — the serving-side resident copy of the base model,
//! the object the switch engine mutates in place.

use std::collections::HashMap;

use super::tensor::Tensor2;
use crate::util::rng::Rng;

/// Ordered, named collection of weight tensors (1-D tensors are stored as
/// 1×n).  Order matches the AOT manifest's param order so the store can be
/// marshalled straight into executable inputs.
#[derive(Clone, Debug)]
pub struct WeightStore {
    names: Vec<String>,
    index: HashMap<String, usize>,
    tensors: Vec<Tensor2>,
}

impl WeightStore {
    /// Empty store.
    pub fn new() -> Self {
        WeightStore {
            names: Vec::new(),
            index: HashMap::new(),
            tensors: Vec::new(),
        }
    }

    /// Initialize from (name, shape) specs with 1/sqrt(fan_in) gaussians for
    /// matrices and ones for 1-D gains — matching python/compile/params.py.
    pub fn init(specs: &[(String, Vec<usize>)], seed: u64) -> Self {
        let rng = Rng::new(seed);
        let mut store = WeightStore::new();
        for (name, shape) in specs {
            let t = match shape.len() {
                1 => Tensor2::from_vec(1, shape[0], vec![1.0; shape[0]]),
                2 => {
                    let mut t = Tensor2::zeros(shape[0], shape[1]);
                    let std = 1.0 / (shape[0] as f32).sqrt();
                    let mut stream = rng.stream(name);
                    stream.fill_normal(&mut t.data, 0.0, std);
                    t
                }
                _ => panic!("unsupported rank for {name}"),
            };
            store.insert(name, t);
        }
        store
    }

    /// Append a named tensor (names must be unique).
    pub fn insert(&mut self, name: &str, t: Tensor2) {
        assert!(
            !self.index.contains_key(name),
            "duplicate weight name {name}"
        );
        self.index.insert(name.to_string(), self.tensors.len());
        self.names.push(name.to_string());
        self.tensors.push(t);
    }

    /// The tensor named `name` (panics on unknown names — weight names
    /// come from the manifest, so a miss is a programming error).
    pub fn get(&self, name: &str) -> &Tensor2 {
        &self.tensors[*self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("unknown weight {name}"))]
    }

    /// Mutable access to the tensor named `name` (same contract as
    /// [`Self::get`]).
    pub fn get_mut(&mut self, name: &str) -> &mut Tensor2 {
        let i = *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("unknown weight {name}"));
        &mut self.tensors[i]
    }

    /// Tensor names in insertion (= manifest) order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// True when the store holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Iterate (name, tensor) pairs in insertion order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor2)> {
        self.names.iter().zip(self.tensors.iter())
    }

    /// Total elements across all tensors.
    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Total storage bytes (f32 per element).
    pub fn nbytes(&self) -> usize {
        self.total_params() * 4
    }

    /// Values at flat indices `idx` of tensor `name` — the sparse capture
    /// half of the transactional switch guard (DESIGN.md §13.1): the
    /// router gathers a selection's support before any mutation wave so a
    /// mid-wave failure can be rolled back bit-exactly.
    pub fn gather(&self, name: &str, idx: &[u32]) -> Vec<f32> {
        let t = self.get(name);
        idx.iter().map(|&i| t.data[i as usize]).collect()
    }

    /// Write `vals[j]` to flat index `idx[j]` of tensor `name` — the
    /// sparse restore half of the transactional switch guard.  `idx` and
    /// `vals` must be the same length (as produced by [`Self::gather`]).
    pub fn scatter(&mut self, name: &str, idx: &[u32], vals: &[f32]) {
        assert_eq!(idx.len(), vals.len(), "scatter idx/vals length mismatch");
        let t = self.get_mut(name);
        for (&i, &v) in idx.iter().zip(vals.iter()) {
            t.data[i as usize] = v;
        }
    }

    /// Bit-exact equality — the serving invariant check after revert.
    pub fn bit_equal(&self, other: &WeightStore) -> bool {
        self.names == other.names
            && self
                .tensors
                .iter()
                .zip(other.tensors.iter())
                .all(|(a, b)| a.data == b.data)
    }

    /// Largest absolute element-wise difference to `other`.
    pub fn max_abs_diff(&self, other: &WeightStore) -> f32 {
        self.tensors
            .iter()
            .zip(other.tensors.iter())
            .map(|(a, b)| a.max_abs_diff(b))
            .fold(0.0, f32::max)
    }
}

impl Default for WeightStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<(String, Vec<usize>)> {
        vec![
            ("embed".into(), vec![32, 8]),
            ("l0.ln1".into(), vec![8]),
            ("l0.wq".into(), vec![8, 8]),
        ]
    }

    #[test]
    fn init_shapes_and_order() {
        let s = WeightStore::init(&specs(), 1);
        assert_eq!(s.len(), 3);
        assert_eq!(s.names(), &["embed", "l0.ln1", "l0.wq"]);
        assert_eq!(s.get("embed").rows, 32);
        assert_eq!(s.get("l0.ln1").rows, 1);
        assert_eq!(s.get("l0.ln1").data, vec![1.0; 8]);
        assert_eq!(s.total_params(), 32 * 8 + 8 + 64);
    }

    #[test]
    fn init_is_seed_deterministic_per_name() {
        let a = WeightStore::init(&specs(), 7);
        let b = WeightStore::init(&specs(), 7);
        let c = WeightStore::init(&specs(), 8);
        assert!(a.bit_equal(&b));
        assert!(!a.bit_equal(&c));
    }

    #[test]
    fn mutation_via_get_mut() {
        let mut s = WeightStore::init(&specs(), 1);
        s.get_mut("l0.wq").data[0] = 42.0;
        assert_eq!(s.get("l0.wq").data[0], 42.0);
    }

    #[test]
    fn gather_scatter_round_trips_bit_exactly() {
        let base = WeightStore::init(&specs(), 3);
        let mut w = base.clone();
        let idx = [0u32, 5, 17, 63];
        let pre = w.gather("l0.wq", &idx);
        for &i in &idx {
            w.get_mut("l0.wq").data[i as usize] = f32::NAN;
        }
        assert!(!w.bit_equal(&base));
        w.scatter("l0.wq", &idx, &pre);
        assert!(w.bit_equal(&base), "scatter restores gathered bytes");
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn scatter_rejects_mismatched_lengths() {
        let mut s = WeightStore::init(&specs(), 1);
        s.scatter("l0.wq", &[0, 1], &[0.0]);
    }

    #[test]
    #[should_panic(expected = "unknown weight")]
    fn unknown_name_panics() {
        let s = WeightStore::init(&specs(), 1);
        s.get("nope");
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_name_panics() {
        let mut s = WeightStore::new();
        s.insert("a", Tensor2::zeros(1, 1));
        s.insert("a", Tensor2::zeros(1, 1));
    }
}
