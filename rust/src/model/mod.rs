//! Dense tensor + weight-store substrate for the serving-side weight memory.

pub mod tensor;
pub mod weights;
