//! Vision experiments: Table 1 (HPSv2-proxy per mask), Fig. 4 (mask
//! comparison, single + multi), Fig. 6 (α sweep), Fig. 7 (unseen-concept
//! multi-adapter generations).

use anyhow::Result;

use super::{ensure_sd_base, style_world, Report};
use crate::adapter::mask::MaskStrategy;
use crate::adapter::{LoraAdapter, ShiraAdapter};
use crate::config::RunConfig;
use crate::coordinator::fusion;
use crate::coordinator::switch::SwitchEngine;
use crate::data::style::{Style, StyleDataset, StyleWorld, ALL_STYLES};
use crate::model::weights::WeightStore;
use crate::runtime::{HostValue, Runtime};
use crate::train::eval::{eval_style, eval_style_multi};
use crate::train::schedule::Schedule;
use crate::train::{Trainer, TrainKind, TrainOutcome};
use crate::util::rng::Rng;

/// All adapters of one style, trained with every method in Table 1.
pub struct StyleAdapters {
    /// The style the zoo was trained for.
    pub style: Style,
    /// The LoRA baseline adapter.
    pub lora: LoraAdapter,
    /// The LoRA baseline's training outcome.
    pub lora_outcome: TrainOutcome,
    /// One SHiRA adapter (and outcome) per mask strategy.
    pub shira: Vec<(MaskStrategy, ShiraAdapter, TrainOutcome)>,
}

/// A fresh copy of the base with a SHiRA adapter applied at `alpha`.
fn applied_shira(base: &WeightStore, a: &ShiraAdapter, alpha: f32) -> WeightStore {
    let mut w = base.clone();
    SwitchEngine::new().switch_to_shira(&mut w, a, alpha);
    w
}

/// A fresh copy of the base with a LoRA adapter fused in.
fn applied_lora(base: &WeightStore, a: &LoraAdapter) -> WeightStore {
    let mut w = base.clone();
    SwitchEngine::new().switch_to_lora(&mut w, a);
    w
}

fn sd_data<'a>(
    ds: &'a StyleDataset,
    batch: usize,
) -> impl FnMut(usize, &mut Rng) -> Vec<HostValue> + 'a {
    let dz = ds.world.d_z;
    let dimg = ds.world.d_img;
    move |_step, rng| {
        let (z, t) = ds.train_batch(batch, rng);
        vec![
            HostValue::f32(z, vec![batch, dz]),
            HostValue::f32(t, vec![batch, dimg]),
        ]
    }
}

/// Train the full Table-1 adapter zoo for one style.
pub fn train_style_adapters(
    rt: &Runtime,
    cfg: &RunConfig,
    base: &WeightStore,
    world: &StyleWorld,
    style: Style,
) -> Result<StyleAdapters> {
    let trainer = Trainer::new(rt, "sd", base.clone())?;
    let batch = trainer.model.dim("batch");
    let ds = StyleDataset::new(world.clone(), style, cfg.seed);
    let steps = cfg.adapter_steps;

    let mut data = sd_data(&ds, batch);
    let lora_out = trainer.train(
        TrainKind::Lora,
        steps,
        Schedule::Cosine { lr: cfg.lr_lora as f32 },
        &mut data,
        cfg.seed ^ 1,
    )?;
    let lora = trainer.export_lora(&lora_out, &format!("{}-lora", style.name()));

    let mut shira = Vec::new();
    for strategy in MaskStrategy::all() {
        let mut data = sd_data(&ds, batch);
        let out = trainer.train(
            TrainKind::Shira(strategy),
            steps,
            Schedule::Cosine { lr: cfg.lr_shira as f32 },
            &mut data,
            cfg.seed ^ (2 + strategy as u64),
        )?;
        let adapter = trainer.export_shira(
            &out,
            &format!("{}-shira-{}", style.name(), strategy.name()),
            strategy,
        );
        shira.push((strategy, adapter, out));
    }
    Ok(StyleAdapters {
        style,
        lora,
        lora_outcome: lora_out,
        shira,
    })
}

fn pct_params(trainable: usize, total: usize) -> f64 {
    100.0 * trainable as f64 / total as f64
}

/// Evaluate one applied adapter state at strength alpha (seen + unseen mix).
fn sps_at(
    rt: &Runtime,
    weights: &WeightStore,
    world: &StyleWorld,
    style: Style,
    alpha: f32,
    cfg: &RunConfig,
) -> Result<f64> {
    let seen = eval_style(rt, weights, world, style, alpha,
                          cfg.style_eval_batches, false, cfg.seed)?;
    let unseen = eval_style(rt, weights, world, style, alpha,
                            cfg.style_eval_batches, true, cfg.seed)?;
    Ok(0.5 * (seen + unseen))
}

/// Table 1: SPS for LoRA vs the five SHiRA masks, both styles, α ∈ {1, 0.5}.
pub fn table1(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let world = style_world(rt, cfg);
    let base = ensure_sd_base(rt, cfg, &world)?;
    let total = base.total_params();
    let mut rep = Report::new(
        "table1",
        "SPS (HPSv2 proxy) — LoRA vs SHiRA masks, α ∈ {1.0, 0.5}",
    );
    rep.line("| Style | Method | %Params | SPS α=1 | SPS α=0.5 |");
    rep.line("|---|---|---|---|---|");
    for style in ALL_STYLES {
        let zoo = train_style_adapters(rt, cfg, &base, &world, style)?;
        // LoRA row (α scaling: rescale the fused product)
        {
            let pct = pct_params(zoo.lora_outcome.trainable_params, total);
            let mut scores = Vec::new();
            for &alpha in &[1.0f32, 0.5] {
                let mut scaled = zoo.lora.clone();
                scaled.scale *= alpha;
                let w = applied_lora(&base, &scaled);
                scores.push(sps_at(rt, &w, &world, style, alpha, cfg)?);
            }
            rep.line(format!(
                "| {} | LoRA | {pct:.2} | {:.1} | {:.1} |",
                style.name(),
                scores[0],
                scores[1]
            ));
        }
        for (strategy, adapter, out) in &zoo.shira {
            let mut scores = Vec::new();
            for &alpha in &[1.0f32, 0.5] {
                let w = applied_shira(&base, adapter, alpha);
                scores.push(sps_at(rt, &w, &world, style, alpha, cfg)?);
            }
            rep.line(format!(
                "| {} | SHiRA-{} | {:.2} | {:.1} | {:.1} |",
                style.name(),
                strategy.name(),
                pct_params(out.trainable_params, total),
                scores[0],
                scores[1]
            ));
        }
    }
    rep.line("");
    rep.line("Paper shape: all SHiRA variants ≥ LoRA, gap larger at α=1.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

/// Fig. 4: per-mask single-adapter and multi-adapter quality.
pub fn fig4(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let world = style_world(rt, cfg);
    let base = ensure_sd_base(rt, cfg, &world)?;
    let bf = train_style_adapters(rt, cfg, &base, &world, Style::Bluefire)?;
    let pt = train_style_adapters(rt, cfg, &base, &world, Style::Paintings)?;
    let mut rep = Report::new(
        "fig4",
        "Mask comparison: single-adapter SPS and naive multi-adapter SPS",
    );
    rep.line("| Method | bluefire (single) | paintings (single) | multi (both) |");
    rep.line("|---|---|---|---|");

    // LoRA: multi = fuse both AB products into the base (half strength each,
    // the standard multi-LoRA recipe).
    {
        let w_bf = applied_lora(&base, &bf.lora);
        let s_bf = sps_at(rt, &w_bf, &world, Style::Bluefire, 1.0, cfg)?;
        let w_pt = applied_lora(&base, &pt.lora);
        let s_pt = sps_at(rt, &w_pt, &world, Style::Paintings, 1.0, cfg)?;
        let mut both = base.clone();
        for l in [&bf.lora, &pt.lora] {
            for t in &l.tensors {
                both.get_mut(&t.target)
                    .add_outer_product(&t.a, &t.b, 0.5 * l.scale);
            }
        }
        let s_multi = eval_style_multi(rt, &both, &world, cfg.style_eval_batches, cfg.seed)?;
        rep.line(format!(
            "| LoRA | {s_bf:.1} | {s_pt:.1} | {s_multi:.1} |"
        ));
    }
    for (i, strategy) in MaskStrategy::all().into_iter().enumerate() {
        let (_, a_bf, _) = &bf.shira[i];
        let (_, a_pt, _) = &pt.shira[i];
        let w_bf = applied_shira(&base, a_bf, 1.0);
        let s_bf = sps_at(rt, &w_bf, &world, Style::Bluefire, 1.0, cfg)?;
        let w_pt = applied_shira(&base, a_pt, 1.0);
        let s_pt = sps_at(rt, &w_pt, &world, Style::Paintings, 1.0, cfg)?;
        // naive multi-adapter fusion at half strength each
        let fused = fusion::fuse_shira(&[a_bf, a_pt], "both")?;
        let w_multi = applied_shira(&base, &fused, 0.5);
        let s_multi =
            eval_style_multi(rt, &w_multi, &world, cfg.style_eval_batches, cfg.seed)?;
        rep.line(format!(
            "| SHiRA-{} | {s_bf:.1} | {s_pt:.1} | {s_multi:.1} |",
            strategy.name()
        ));
    }
    rep.line("");
    rep.line("Paper shape: SHiRA multi-adapter > LoRA multi-adapter (concept loss).");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

/// Fig. 6: effect of α on SHiRA generation quality (bluefire).
pub fn fig6(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let world = style_world(rt, cfg);
    let base = ensure_sd_base(rt, cfg, &world)?;
    let trainer = Trainer::new(rt, "sd", base.clone())?;
    let batch = trainer.model.dim("batch");
    let ds = StyleDataset::new(world.clone(), Style::Bluefire, cfg.seed);
    let mut data = sd_data(&ds, batch);
    let out = trainer.train(
        TrainKind::Shira(MaskStrategy::Snip),
        cfg.adapter_steps,
        Schedule::Cosine { lr: cfg.lr_shira as f32 },
        &mut data,
        cfg.seed ^ 6,
    )?;
    let adapter = trainer.export_shira(&out, "bf-snip", MaskStrategy::Snip);
    let mut rep = Report::new("fig6", "Effect of α on SHiRA (bluefire, SNIP mask)");
    rep.line("| α | SPS vs α-target | SPS vs base (α=0 target) |");
    rep.line("|---|---|---|");
    for alpha in [0.0f32, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0] {
        let w = applied_shira(&base, &adapter, alpha);
        let vs_target = eval_style(
            rt, &w, &world, Style::Bluefire, alpha,
            cfg.style_eval_batches, false, cfg.seed,
        )?;
        let vs_base = eval_style(
            rt, &w, &world, Style::Bluefire, 0.0,
            cfg.style_eval_batches, false, cfg.seed,
        )?;
        rep.line(format!("| {alpha:.2} | {vs_target:.1} | {vs_base:.1} |"));
    }
    rep.line("");
    rep.line("Paper shape: α=0 reproduces the base model; style strength rises with α;");
    rep.line("over-amplified α drifts off the α-target curve.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

/// Fig. 7 / Fig. 1: unseen-concept (koala) quality, single vs multi.
pub fn fig7(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let world = style_world(rt, cfg);
    let base = ensure_sd_base(rt, cfg, &world)?;
    let bf = train_style_adapters(rt, cfg, &base, &world, Style::Bluefire)?;
    let pt = train_style_adapters(rt, cfg, &base, &world, Style::Paintings)?;
    let mut rep = Report::new(
        "fig7",
        "Unseen-concept generations (the koala test): single and fused",
    );
    rep.line("| Method | bluefire unseen | paintings unseen | multi unseen |");
    rep.line("|---|---|---|---|");
    {
        let w_bf = applied_lora(&base, &bf.lora);
        let s1 = eval_style(rt, &w_bf, &world, Style::Bluefire, 1.0,
                            cfg.style_eval_batches, true, cfg.seed)?;
        let w_pt = applied_lora(&base, &pt.lora);
        let s2 = eval_style(rt, &w_pt, &world, Style::Paintings, 1.0,
                            cfg.style_eval_batches, true, cfg.seed)?;
        let mut both = base.clone();
        for l in [&bf.lora, &pt.lora] {
            for t in &l.tensors {
                both.get_mut(&t.target)
                    .add_outer_product(&t.a, &t.b, 0.5 * l.scale);
            }
        }
        let s3 = eval_style_multi(rt, &both, &world, cfg.style_eval_batches, cfg.seed)?;
        rep.line(format!("| LoRA | {s1:.1} | {s2:.1} | {s3:.1} |"));
    }
    // best SHiRA masks per the paper: Struct and SNIP
    for strategy in [MaskStrategy::Struct, MaskStrategy::Snip] {
        let i = MaskStrategy::all().iter().position(|s| *s == strategy).unwrap();
        let (_, a_bf, _) = &bf.shira[i];
        let (_, a_pt, _) = &pt.shira[i];
        let w_bf = applied_shira(&base, a_bf, 1.0);
        let s1 = eval_style(rt, &w_bf, &world, Style::Bluefire, 1.0,
                            cfg.style_eval_batches, true, cfg.seed)?;
        let w_pt = applied_shira(&base, a_pt, 1.0);
        let s2 = eval_style(rt, &w_pt, &world, Style::Paintings, 1.0,
                            cfg.style_eval_batches, true, cfg.seed)?;
        let fused = fusion::fuse_shira(&[a_bf, a_pt], "both")?;
        let w_multi = applied_shira(&base, &fused, 0.5);
        let s3 = eval_style_multi(rt, &w_multi, &world, cfg.style_eval_batches, cfg.seed)?;
        rep.line(format!(
            "| SHiRA-{} | {s1:.1} | {s2:.1} | {s3:.1} |",
            strategy.name()
        ));
    }
    rep.line("");
    rep.line("Paper shape: on unseen concepts LoRA's fused generations degrade most;");
    rep.line("SHiRA retains both the concept and the styles.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pct_params_sane() {
        assert!((pct_params(1, 100) - 1.0).abs() < 1e-12);
    }

    // Full vision-experiment integration is exercised by
    // examples/style_transfer and the repro CLI; unit coverage for the
    // pieces lives in train/, adapter/ and data/style tests.
    #[test]
    fn report_render_includes_header() {
        let mut r = Report::new("x", "t");
        r.line("| a |");
        let cfg = RunConfig::fast();
        let s = r.render(&cfg);
        assert!(s.contains("# x — t"));
        assert!(s.contains("| a |"));
    }
}
