//! Language experiments: Table 2 (LLaMA-7B proxy, all adapter kinds),
//! Table 3 (LLaMA2-7B proxy), Table 4 (multi-adapter fusion + %Drop).

use anyhow::Result;

use super::{ensure_llama_base, Report};
use crate::adapter::mask::MaskStrategy;
use crate::config::RunConfig;
use crate::coordinator::fusion;
use crate::coordinator::switch::SwitchEngine;
use crate::data::tasks::{self, Task, ALL_TASKS};
use crate::model::weights::WeightStore;
use crate::runtime::{HostValue, Runtime};
use crate::train::eval::eval_tasks;
use crate::train::schedule::Schedule;
use crate::train::{Trainer, TrainKind, TrainOutcome};
use crate::util::rng::Rng;

fn llama_data<'a>(
    tasks_list: &'a [Task],
    b: usize,
    t: usize,
    table_seed: u64,
) -> impl FnMut(usize, &mut Rng) -> Vec<HostValue> + 'a {
    move |_step, rng| {
        let batch = tasks::mixture_batch(tasks_list, b, t, table_seed, rng);
        vec![
            HostValue::i32(batch.x, vec![b, t]),
            HostValue::i32(batch.y, vec![b, t]),
            HostValue::f32(batch.mask, vec![b, t]),
        ]
    }
}

/// Train one adapter kind on a task mixture; returns outcome + the fused
/// weights (base with adapter applied) ready for evaluation.
pub fn train_and_apply(
    rt: &Runtime,
    cfg: &RunConfig,
    base: &WeightStore,
    kind: TrainKind,
    tasks_list: &[Task],
    seed: u64,
) -> Result<(TrainOutcome, WeightStore)> {
    let trainer = Trainer::new(rt, "llama", base.clone())?;
    let (b, t) = (trainer.model.dim("batch"), trainer.model.dim("seq_len"));
    let lr = match kind {
        TrainKind::Lora | TrainKind::Dora => cfg.lr_lora as f32,
        _ => cfg.lr_shira as f32,
    };
    let mut data = llama_data(tasks_list, b, t, cfg.seed);
    let out = trainer.train(
        kind,
        cfg.adapter_steps,
        Schedule::Linear { lr, floor_frac: 0.1 },
        &mut data,
        seed,
    )?;
    // Apply the trained adapter in FUSED form for evaluation.
    let weights = apply_outcome(&trainer, kind, &out)?;
    Ok((out, weights))
}

/// Apply a trained theta to a copy of the base (fused inference weights).
pub fn apply_outcome(
    trainer: &Trainer,
    kind: TrainKind,
    out: &TrainOutcome,
) -> Result<WeightStore> {
    let mut w = trainer.base.clone();
    match kind {
        TrainKind::Shira(s) => {
            let adapter = trainer.export_shira(out, "tmp", s);
            let mut engine = SwitchEngine::new();
            engine.switch_to_shira(&mut w, &adapter, 1.0);
        }
        TrainKind::Lora => {
            let adapter = trainer.export_lora(out, "tmp");
            let mut engine = SwitchEngine::new();
            engine.switch_to_lora(&mut w, &adapter);
        }
        TrainKind::Dora => {
            // W' = mag ⊙_col (W + s·AB)/||W + s·AB||_col
            let scale = trainer.rt.manifest.adapter.lora_scale as f32;
            for seg in &trainer.model.dora {
                let (n, m) = seg.shape;
                let target = w.get_mut(&seg.name);
                // dense AB
                let a = &out.theta[seg.a_off..seg.a_off + seg.a_len];
                let bmat = &out.theta[seg.b_off..seg.b_off + seg.b_len];
                let mag =
                    &out.theta[seg.mag_off.unwrap()..seg.mag_off.unwrap() + m];
                let r = seg.rank;
                let mut dir = target.data.clone();
                for i in 0..n {
                    for k in 0..r {
                        let aik = scale * a[i * r + k];
                        if aik == 0.0 {
                            continue;
                        }
                        for j in 0..m {
                            dir[i * m + j] += aik * bmat[k * m + j];
                        }
                    }
                }
                // column norms
                for j in 0..m {
                    let mut norm = 0.0f32;
                    for i in 0..n {
                        norm += dir[i * m + j] * dir[i * m + j];
                    }
                    let norm = (norm + 1e-6).sqrt();
                    for i in 0..n {
                        target.data[i * m + j] = mag[j] * dir[i * m + j] / norm;
                    }
                }
            }
        }
        TrainKind::ShiraDora(_) => {
            for seg in &trainer.model.shira_dora {
                let (n, m) = seg.shape;
                let target = w.get_mut(&seg.name);
                let mag =
                    &out.theta[seg.mag_off.unwrap()..seg.mag_off.unwrap() + m];
                let mut dir = target.data.clone();
                for j in 0..seg.k {
                    let local = out.idx[seg.off + j] as usize;
                    dir[local] = out.theta[seg.off + j];
                }
                for jm in 0..m {
                    let mut norm = 0.0f32;
                    for i in 0..n {
                        norm += dir[i * m + jm] * dir[i * m + jm];
                    }
                    let norm = (norm + 1e-6).sqrt();
                    for i in 0..n {
                        target.data[i * m + jm] = mag[jm] * dir[i * m + jm] / norm;
                    }
                }
            }
        }
        TrainKind::ShiraDense(_) => {
            for seg in &trainer.model.probe {
                let target = w.get_mut(&seg.name);
                target
                    .data
                    .copy_from_slice(&out.theta[seg.off..seg.off + seg.len]);
            }
        }
        TrainKind::Full => {
            let mut off = 0;
            for (name, shape) in trainer.model.params.clone() {
                let numel: usize = shape.iter().product();
                w.get_mut(&name)
                    .data
                    .copy_from_slice(&out.theta[off..off + numel]);
                off += numel;
            }
        }
    }
    Ok(w)
}

/// %C — fraction of base-model parameters changed in fused mode.
fn pct_changed(rt: &Runtime, kind: TrainKind, out: &TrainOutcome, total: usize) -> f64 {
    let meta = rt.manifest.model("llama").expect("meta");
    match kind {
        TrainKind::Shira(_) => 100.0 * out.trainable_params as f64 / total as f64,
        TrainKind::ShiraDora(_) => {
            // sparse values + column magnitudes
            100.0 * out.trainable_params as f64 / total as f64
        }
        TrainKind::Lora | TrainKind::Dora | TrainKind::ShiraDense(_) => {
            let changed: usize = meta.probe.iter().map(|s| s.len).sum();
            100.0 * changed as f64 / total as f64
        }
        TrainKind::Full => 100.0,
    }
}

fn table_header(rep: &mut Report) {
    let mut h = String::from("| Method | %Params | %C |");
    for t in ALL_TASKS {
        h.push_str(&format!(" {}(↑) |", t.name()));
    }
    h.push_str(" Avg(↑) |");
    rep.line(h);
    let mut sep = String::from("|---|---|---|");
    for _ in ALL_TASKS {
        sep.push_str("---|");
    }
    sep.push_str("---|");
    rep.line(sep);
}

fn result_row(
    rep: &mut Report,
    label: &str,
    pct_p: f64,
    pct_c: f64,
    per: &[(Task, f64)],
    avg: f64,
    baseline_avg: Option<f64>,
) {
    let mut row = format!("| {label} | {pct_p:.2} | {pct_c:.2} |");
    for (_, acc) in per {
        row.push_str(&format!(" {acc:.1} |"));
    }
    match baseline_avg {
        Some(b) => row.push_str(&format!(" {avg:.1} ({:+.1}%) |", avg - b)),
        None => row.push_str(&format!(" {avg:.1} (+0%) |")),
    }
    rep.line(row);
}

/// Table 2: LLaMA-7B proxy — LoRA vs SHiRA-{Grad,WM,SNIP} vs DoRA vs
/// SHiRA-WM-DoRA on the combined commonsense mixture.
pub fn table2(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let base = ensure_llama_base(rt, cfg, "llama_a")?;
    let total = base.total_params();
    let mut rep = Report::new(
        "table2",
        "Commonsense reasoning (nanollama-A): LoRA vs SHiRA vs DoRA",
    );
    table_header(&mut rep);
    let kinds: Vec<(&str, TrainKind)> = vec![
        ("LoRA", TrainKind::Lora),
        ("SHiRA-Grad", TrainKind::Shira(MaskStrategy::Grad)),
        ("SHiRA-WM", TrainKind::Shira(MaskStrategy::WeightMagnitude)),
        ("SHiRA-SNIP", TrainKind::Shira(MaskStrategy::Snip)),
        ("DoRA", TrainKind::Dora),
        (
            "SHiRA-WM-DoRA",
            TrainKind::ShiraDora(MaskStrategy::WeightMagnitude),
        ),
    ];
    let mut lora_avg = None;
    let mut dora_avg = None;
    for (i, (label, kind)) in kinds.iter().enumerate() {
        let (out, weights) = train_and_apply(
            rt, cfg, &base, *kind, &ALL_TASKS, cfg.seed ^ (10 + i as u64),
        )?;
        let (per, avg) = eval_tasks(rt, &weights, &ALL_TASKS, cfg.eval_examples, cfg.seed)?;
        let baseline = match kind {
            TrainKind::Lora => {
                lora_avg = Some(avg);
                None
            }
            TrainKind::Dora => {
                dora_avg = Some(avg);
                None
            }
            TrainKind::ShiraDora(_) => dora_avg,
            _ => lora_avg,
        };
        result_row(
            &mut rep,
            label,
            100.0 * out.trainable_params as f64 / total as f64,
            pct_changed(rt, *kind, &out, total),
            &per,
            avg,
            baseline,
        );
        crate::log_info!(
            "table2 {label}: loss {:.3}->{:.3}, avg acc {avg:.1}%",
            out.first_loss(),
            out.last_loss()
        );
    }
    rep.line("");
    rep.line("Paper shape: SHiRA variants ≥ LoRA at %C≈SHiRA-frac vs ≈66% for LoRA;");
    rep.line("SHiRA-WM-DoRA within a few tenths of DoRA.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

/// Table 3: second base model (LLaMA2-7B proxy) — LoRA vs DoRA vs SHiRA-SNIP.
pub fn table3(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let base = ensure_llama_base(rt, cfg, "llama_b")?;
    let total = base.total_params();
    let mut rep = Report::new(
        "table3",
        "Commonsense reasoning (nanollama-B): LoRA vs DoRA vs SHiRA-SNIP",
    );
    table_header(&mut rep);
    let kinds: Vec<(&str, TrainKind)> = vec![
        ("LoRA", TrainKind::Lora),
        ("DoRA", TrainKind::Dora),
        ("SHiRA-SNIP", TrainKind::Shira(MaskStrategy::Snip)),
    ];
    let mut lora_avg = None;
    for (i, (label, kind)) in kinds.iter().enumerate() {
        let (out, weights) = train_and_apply(
            rt, cfg, &base, *kind, &ALL_TASKS, cfg.seed ^ (30 + i as u64),
        )?;
        let (per, avg) = eval_tasks(rt, &weights, &ALL_TASKS, cfg.eval_examples, cfg.seed)?;
        let baseline = if matches!(kind, TrainKind::Lora) {
            lora_avg = Some(avg);
            None
        } else {
            lora_avg
        };
        result_row(
            &mut rep,
            label,
            100.0 * out.trainable_params as f64 / total as f64,
            pct_changed(rt, *kind, &out, total),
            &per,
            avg,
            baseline,
        );
    }
    rep.line("");
    rep.line("Paper shape: SHiRA-SNIP beats LoRA and lands near DoRA.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

/// Table 4: independently trained per-task adapters, naive multi-adapter
/// fusion, accuracy drop.
pub fn table4(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let base = ensure_llama_base(rt, cfg, "llama_b")?;
    let fusion_tasks = [Task::BoolQ, Task::Piqa, Task::ArcEasy];
    let mut rep = Report::new(
        "table4",
        "Multi-adapter fusion of per-task adapters (BoolQ, PIQA, Arc-e)",
    );
    rep.line("| Method | single boolq | single piqa | single arc_e | single avg | multi boolq | multi piqa | multi arc_e | multi avg | %Drop(↓) |");
    rep.line("|---|---|---|---|---|---|---|---|---|---|");

    // ---- LoRA -----------------------------------------------------------
    {
        let mut single = Vec::new();
        let mut adapters = Vec::new();
        for (i, &task) in fusion_tasks.iter().enumerate() {
            let trainer = Trainer::new(rt, "llama", base.clone())?;
            let (b, t) = (trainer.model.dim("batch"), trainer.model.dim("seq_len"));
            let mut data = llama_data(std::slice::from_ref(&task), b, t, cfg.seed);
            let out = trainer.train(
                TrainKind::Lora,
                cfg.adapter_steps,
                Schedule::Linear { lr: cfg.lr_lora as f32, floor_frac: 0.1 },
                &mut data,
                cfg.seed ^ (50 + i as u64),
            )?;
            let adapter = trainer.export_lora(&out, task.name());
            let mut w = base.clone();
            SwitchEngine::new().switch_to_lora(&mut w, &adapter);
            let acc =
                100.0 * crate::train::eval::eval_task(rt, &w, task,
                                                      cfg.eval_examples, cfg.seed)?;
            single.push(acc);
            adapters.push(adapter);
        }
        // naive multi-LoRA: fuse all three (1/n strength — standard recipe)
        let mut fused = base.clone();
        for a in &adapters {
            for t in &a.tensors {
                fused
                    .get_mut(&t.target)
                    .add_outer_product(&t.a, &t.b, a.scale / adapters.len() as f32);
            }
        }
        let mut multi = Vec::new();
        for &task in &fusion_tasks {
            multi.push(100.0 * crate::train::eval::eval_task(
                rt, &fused, task, cfg.eval_examples, cfg.seed,
            )?);
        }
        emit_fusion_row(&mut rep, "LoRA", &single, &multi);
    }

    // ---- SHiRA-WM ---------------------------------------------------------
    {
        let mut single = Vec::new();
        let mut adapters = Vec::new();
        for (i, &task) in fusion_tasks.iter().enumerate() {
            let trainer = Trainer::new(rt, "llama", base.clone())?;
            let (b, t) = (trainer.model.dim("batch"), trainer.model.dim("seq_len"));
            let mut data = llama_data(std::slice::from_ref(&task), b, t, cfg.seed);
            let out = trainer.train(
                TrainKind::Shira(MaskStrategy::WeightMagnitude),
                cfg.adapter_steps,
                Schedule::Linear { lr: cfg.lr_shira as f32, floor_frac: 0.1 },
                &mut data,
                cfg.seed ^ (60 + i as u64),
            )?;
            let adapter =
                trainer.export_shira(&out, task.name(), MaskStrategy::WeightMagnitude);
            let mut w = base.clone();
            SwitchEngine::new().switch_to_shira(&mut w, &adapter, 1.0);
            let acc =
                100.0 * crate::train::eval::eval_task(rt, &w, task,
                                                      cfg.eval_examples, cfg.seed)?;
            single.push(acc);
            adapters.push(adapter);
        }
        let refs: Vec<&crate::adapter::ShiraAdapter> = adapters.iter().collect();
        let fused_adapter = fusion::fuse_shira(&refs, "fused3")?;
        let mut w = base.clone();
        SwitchEngine::new().switch_to_shira(&mut w, &fused_adapter, 1.0);
        let mut multi = Vec::new();
        for &task in &fusion_tasks {
            multi.push(100.0 * crate::train::eval::eval_task(
                rt, &w, task, cfg.eval_examples, cfg.seed,
            )?);
        }
        // interference stats as a bonus line
        let report = fusion::analyze_shira(&refs);
        emit_fusion_row(&mut rep, "SHiRA-WM", &single, &multi);
        rep.line("");
        rep.line(format!(
            "SHiRA interference: mean support overlap {:.4}, mean AᵀA density {:.4}, collisions {}",
            report.mean_overlap, report.mean_ata_density, report.collisions
        ));
    }
    rep.line("");
    rep.line("Paper shape: SHiRA-WM's multi-adapter %Drop ≪ LoRA's (4.4% vs 11.1%).");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

fn emit_fusion_row(rep: &mut Report, label: &str, single: &[f64], multi: &[f64]) {
    let s_avg = single.iter().sum::<f64>() / single.len() as f64;
    let m_avg = multi.iter().sum::<f64>() / multi.len() as f64;
    let drop = s_avg - m_avg;
    rep.line(format!(
        "| {label} | {:.1} | {:.1} | {:.1} | {s_avg:.1} | {:.1} | {:.1} | {:.1} | {m_avg:.1} | {drop:.2} |",
        single[0], single[1], single[2], multi[0], multi[1], multi[2]
    ));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pct_changed_full_is_100() {
        // pure-logic check via a fake outcome is covered in integration;
        // here we only pin the fusion-row formatting.
        let mut rep = Report::new("t", "t");
        emit_fusion_row(&mut rep, "X", &[80.0, 70.0, 60.0], &[75.0, 65.0, 55.0]);
        assert!(rep.lines[0].contains("| X |"));
        assert!(rep.lines[0].contains("5.00"));
    }
}
