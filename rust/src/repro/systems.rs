//! Systems experiments: Fig. 5 (scatter vs fuse sweep), Table 5 (pipeline
//! stage latencies), Table 6 (training memory/speed), plus the §3.2
//! orthogonality analysis.  The cargo benches regenerate Fig. 5/Table 5
//! with full statistical protocol; these drivers are the quick CLI view.

use anyhow::Result;

use super::{ensure_llama_base, Report};
use crate::adapter::mask::MaskStrategy;
use crate::adapter::sparse::SparseDelta;
use crate::adapter::ShiraAdapter;
use crate::config::RunConfig;
use crate::coordinator::fusion;
use crate::coordinator::switch::SwitchEngine;
use crate::data::tasks::ALL_TASKS;
use crate::model::tensor::Tensor2;
use crate::model::weights::WeightStore;
use crate::runtime::{HostValue, Runtime};
use crate::train::schedule::Schedule;
use crate::train::{Trainer, TrainKind};
use crate::util::alloc::fmt_bytes;
use crate::util::rng::Rng;

/// One scatter-vs-fuse measurement at a given dim (Fig. 5's x-axis).
pub struct SwitchSample {
    /// Square tensor dimension measured.
    pub dim: usize,
    /// Mean SHiRA scatter-apply time, microseconds.
    pub scatter_us: f64,
    /// Mean dense LoRA fuse time, microseconds.
    pub fuse_us: f64,
    /// fuse / scatter ratio.
    pub speedup: f64,
}

/// Measure mean scatter and fuse times over `reps` random weights
/// (paper: 10 randomly initialized weights per dimension).
pub fn measure_switch(dim: usize, frac: f64, rank: usize, reps: usize, seed: u64) -> SwitchSample {
    let mut rng = Rng::new(seed);
    let k = ((dim * dim) as f64 * frac) as usize;
    let mut scatter_total = 0.0;
    let mut fuse_total = 0.0;
    for _ in 0..reps {
        let mut w = Tensor2::zeros(dim, dim);
        rng.fill_normal(&mut w.data, 0.0, 1.0);
        let idx = rng.sample_indices(dim * dim, k);
        let mut delta = vec![0.0f32; k];
        rng.fill_normal(&mut delta, 0.0, 0.1);
        let sd = SparseDelta::new(dim, dim, idx, delta);
        let mut a = Tensor2::zeros(dim, rank);
        let mut b = Tensor2::zeros(rank, dim);
        rng.fill_normal(&mut a.data, 0.0, 0.1);
        rng.fill_normal(&mut b.data, 0.0, 0.1);

        let t0 = std::time::Instant::now();
        sd.apply(&mut w, 1.0);
        scatter_total += t0.elapsed().as_secs_f64() * 1e6;

        let t1 = std::time::Instant::now();
        w.add_outer_product(&a, &b, 2.0);
        fuse_total += t1.elapsed().as_secs_f64() * 1e6;
        std::hint::black_box(&w.data[0]);
    }
    let scatter_us = scatter_total / reps as f64;
    let fuse_us = fuse_total / reps as f64;
    SwitchSample {
        dim,
        scatter_us,
        fuse_us,
        speedup: fuse_us / scatter_us.max(1e-9),
    }
}

/// Fig. 5: LoRA-fuse vs SHiRA-scatter across tensor dimensions.
pub fn fig5(cfg: &RunConfig) -> Result<Vec<Report>> {
    let mut rep = Report::new(
        "fig5",
        "SHiRA scatter vs LoRA fuse — mean time per weight tensor (CPU)",
    );
    rep.line("| dim | SHiRA scatter (us) | LoRA fuse (us) | speedup |");
    rep.line("|---|---|---|---|");
    for dim in [512, 1024, 2048, 4096] {
        let s = measure_switch(dim, 0.02, 32, 10, cfg.seed);
        rep.line(format!(
            "| {} | {:.1} | {:.1} | {:.1}x |",
            s.dim, s.scatter_us, s.fuse_us, s.speedup
        ));
    }
    rep.line("");
    rep.line("Paper shape (Fig. 5): speedup grows with dim, ~10x at 4096.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

/// Table 5: HF pipeline stage latencies (load/fuse/unfuse/unload) for a
/// full model's worth of adapters, SHiRA vs LoRA.
pub fn table5(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let meta = rt.manifest.model("llama").map_err(|e| anyhow::anyhow!("{e}"))?;
    let base = WeightStore::init(&meta.params, cfg.seed);
    let mut rng = Rng::new(cfg.seed ^ 0x7AB1E5);

    // Build one SHiRA and one LoRA adapter covering every target.
    let shira_tensors: Vec<(String, SparseDelta)> = meta
        .shira
        .iter()
        .map(|seg| {
            let numel = seg.shape.0 * seg.shape.1;
            let idx = rng.sample_indices(numel, seg.k);
            let mut d = vec![0.0f32; seg.k];
            rng.fill_normal(&mut d, 0.0, 0.1);
            (
                seg.name.clone(),
                SparseDelta::new(seg.shape.0, seg.shape.1, idx, d),
            )
        })
        .collect();
    let shira = ShiraAdapter {
        name: "t5-shira".into(),
        strategy: "rand".into(),
        tensors: shira_tensors,
    };
    let lora_tensors: Vec<crate::adapter::LoraTensor> = meta
        .lora
        .iter()
        .map(|seg| {
            let mut a = Tensor2::zeros(seg.shape.0, seg.rank);
            let mut b = Tensor2::zeros(seg.rank, seg.shape.1);
            rng.fill_normal(&mut a.data, 0.0, 0.1);
            rng.fill_normal(&mut b.data, 0.0, 0.1);
            crate::adapter::LoraTensor {
                target: seg.name.clone(),
                a,
                b,
            }
        })
        .collect();
    let lora = crate::adapter::LoraAdapter {
        name: "t5-lora".into(),
        scale: rt.manifest.adapter.lora_scale as f32,
        tensors: lora_tensors,
    };

    let shira_bytes = crate::adapter::io::encode_shira(&shira);
    let lora_bytes = crate::adapter::io::encode_lora(&lora);
    let mut weights = base;
    let mut engine = SwitchEngine::new();
    let reps = 20;
    let mut acc = [[0.0f64; 4]; 2];
    for _ in 0..reps {
        let t = engine.hf_pipeline_shira(&mut weights, &shira_bytes, 1.0);
        acc[0][0] += t.load_us;
        acc[0][1] += t.fuse_us;
        acc[0][2] += t.unfuse_us;
        acc[0][3] += t.unload_us;
        let t = engine.hf_pipeline_lora(&mut weights, &lora_bytes);
        acc[1][0] += t.load_us;
        acc[1][1] += t.fuse_us;
        acc[1][2] += t.unfuse_us;
        acc[1][3] += t.unload_us;
    }
    let mut rep = Report::new(
        "table5",
        "Pipeline stage latency (load/fuse(apply)/unfuse(revert)/unload), whole model",
    );
    rep.line("| Stage | SHiRA (us) | LoRA (us) |");
    rep.line("|---|---|---|");
    for (i, stage) in ["load", "fuse", "unfuse", "unload"].iter().enumerate() {
        rep.line(format!(
            "| {stage} | {:.1} | {:.1} |",
            acc[0][i] / reps as f64,
            acc[1][i] / reps as f64
        ));
    }
    rep.line("");
    rep.line("Paper shape (Table 5, CPU column): fuse/unfuse dominate for LoRA;");
    rep.line("SHiRA's apply/revert are a small fraction of LoRA's fuse/unfuse.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

/// Table 6: peak training memory + steps/s per adapter kind.
pub fn table6(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let base = ensure_llama_base(rt, cfg, "llama_a")?;
    let trainer = Trainer::new(rt, "llama", base)?;
    let (b, t) = (trainer.model.dim("batch"), trainer.model.dim("seq_len"));
    let steps = 20.min(cfg.adapter_steps);
    let kinds: Vec<(&str, TrainKind)> = vec![
        ("LoRA-PEFT", TrainKind::Lora),
        ("DoRA-PEFT", TrainKind::Dora),
        ("SHiRA-PEFT (sparse, App. D)", TrainKind::Shira(MaskStrategy::WeightMagnitude)),
        ("SHiRA grad-hook (dense, App. C)", TrainKind::ShiraDense(MaskStrategy::WeightMagnitude)),
        ("Full FT (pre-LoRA partial-FT bound)", TrainKind::Full),
    ];
    let mut rep = Report::new(
        "table6",
        "Peak training memory and steps/s per adapter implementation",
    );
    rep.line("| Adapter | trainable | peak mem | Δ vs LoRA | steps/s | Δ vs LoRA |");
    rep.line("|---|---|---|---|---|---|");
    let mut lora_ref: Option<(usize, f64)> = None;
    for (i, (label, kind)) in kinds.iter().enumerate() {
        let mut data = |_step: usize, rng: &mut Rng| {
            let batch =
                crate::data::tasks::mixture_batch(&ALL_TASKS, b, t, cfg.seed, rng);
            vec![
                HostValue::i32(batch.x, vec![b, t]),
                HostValue::i32(batch.y, vec![b, t]),
                HostValue::f32(batch.mask, vec![b, t]),
            ]
        };
        let out = trainer.train(
            *kind,
            steps,
            Schedule::Const(1e-3),
            &mut data,
            cfg.seed ^ (70 + i as u64),
        )?;
        let (mem, sps) = (out.peak_bytes, out.steps_per_sec);
        let (dm, ds) = match lora_ref {
            Some((m0, s0)) => (
                format!("{:+.1}%", 100.0 * (mem as f64 - m0 as f64) / m0 as f64),
                format!("{:+.1}%", 100.0 * (sps - s0) / s0),
            ),
            None => {
                lora_ref = Some((mem, sps));
                ("+0%".into(), "+0%".into())
            }
        };
        rep.line(format!(
            "| {label} | {} | {} | {dm} | {sps:.2} | {ds} |",
            out.trainable_params,
            fmt_bytes(mem)
        ));
    }
    rep.line("");
    rep.line("Paper shape (Table 6): SHiRA-PEFT < LoRA < DoRA peak memory;");
    rep.line("SHiRA trains at ~LoRA speed; the dense grad-hook variant shows why");
    rep.line("the sparse App.-D formulation is the memory-efficient one.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

/// §3.2 orthogonality analysis: AᵀA density for SHiRA vs LoRA across
/// sparsity levels.
pub fn orthogonality(rt: &Runtime, cfg: &RunConfig) -> Result<Vec<Report>> {
    let _ = rt;
    let mut rep = Report::new(
        "orthogonality",
        "Adapter interference: support overlap and A1ᵀA2 density vs sparsity",
    );
    rep.line("| sparsity (frac trainable) | mean overlap | A1ᵀA2 density | collisions |");
    rep.line("|---|---|---|---|");
    for frac in [0.005, 0.01, 0.02, 0.05, 0.10, 0.25] {
        let mk = |seed: u64| {
            let mut rng = Rng::new(seed);
            let n = 128;
            let k = ((n * n) as f64 * frac).max(1.0) as usize;
            let idx = rng.sample_indices(n * n, k);
            let mut d = vec![0.0f32; k];
            rng.fill_normal(&mut d, 0.0, 0.1);
            ShiraAdapter {
                name: format!("o{seed}"),
                strategy: "rand".into(),
                tensors: vec![("w".into(), SparseDelta::new(n, n, idx, d))],
            }
        };
        let a = mk(cfg.seed ^ 1);
        let b = mk(cfg.seed ^ 2);
        let r = fusion::analyze_shira(&[&a, &b]);
        rep.line(format!(
            "| {frac:.3} | {:.4} | {:.4} | {} |",
            r.mean_overlap, r.mean_ata_density, r.collisions
        ));
    }
    rep.line("| 1.000 (LoRA fused) | 1.0000 | 1.0000 | all |");
    rep.line("");
    rep.line("Paper claim (§3.2): at 1-2% sparsity the product A1ᵀA2 is almost");
    rep.line("entirely zero — adapters barely interact; dense LoRA products always do.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

/// Relative Frobenius distance between two weight stores (0 = bit
/// identical), measured tensor by tensor against `reference`'s norm.
fn rel_frobenius(a: &WeightStore, reference: &WeightStore) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (name, ta) in a.iter() {
        let tb = reference.get(name);
        for (&x, &y) in ta.data.iter().zip(tb.data.iter()) {
            num += f64::from(x - y) * f64::from(x - y);
            den += f64::from(y) * f64::from(y);
        }
    }
    (num / den.max(1e-30)).sqrt()
}

/// Gate eval (DESIGN.md §17): train the linear top-k gate, then per
/// repro task family compare **merged-expert serving** (the gate's
/// weighted set made resident through the router) against
/// **single-adapter serving** (the task's oracle expert alone): gate
/// top-1 accuracy, the gate's weight mass on the oracle, and the
/// weight-space divergence between the two resident models.
/// Artifact-free like [`fig5`], reproducible from `cfg.seed` alone.
pub fn gate(cfg: &RunConfig) -> Result<Vec<Report>> {
    use crate::coordinator::engine::Router;
    use crate::coordinator::gate::{features_from_tokens, Gate};
    use crate::coordinator::selection::Selection;
    use crate::coordinator::store::{AdapterStore, StoreConfig};
    use crate::data::synth::{adapter_names, toy_base, toy_shira_zoo};
    use crate::data::tasks::generate;
    use crate::train::gate::{oracle_expert, top_member, train_gate};

    const DIM: usize = 64;
    const NNZ: usize = 200;
    const EXAMPLES: usize = 32;
    const SEQ_LEN: usize = 32;
    let names = adapter_names(ALL_TASKS.len());
    let trained = train_gate(&names, 2, 2000, cfg.seed);
    let base = toy_base(DIM, cfg.seed);
    let mut store = AdapterStore::with_config(
        StoreConfig {
            cache_bytes: 64 << 20,
            prefetch_depth: 0,
            plan_cache_bytes: 0,
            ..StoreConfig::default()
        },
        None,
    );
    for a in &toy_shira_zoo(DIM, &names, NNZ, cfg.seed) {
        store.add_shira(a);
    }
    let mut merged = Router::new(base.clone(), None, false);
    let mut single = Router::new(base, None, false);
    let mut rep = Report::new(
        "gate",
        "Learned top-k gating: merged-expert vs single-adapter serving per task",
    );
    rep.line(format!(
        "trained linear gate: top-2 over {} experts, held-out accuracy {:.1}%, \
         final loss {:.3} (steps {}, seed {})",
        names.len(),
        100.0 * trained.accuracy,
        trained.final_loss,
        trained.steps,
        cfg.seed
    ));
    rep.line("");
    rep.line("| task | gate top-1 | weight on oracle | merged-vs-single rel ||dW|| | max |dW| |");
    rep.line("|---|---|---|---|---|");
    let mut rng = Rng::new(cfg.seed).stream("repro/gate");
    for task in ALL_TASKS {
        let mut top1 = 0usize;
        let mut mass = 0.0f64;
        let mut rel = 0.0f64;
        let mut max_div = 0.0f32;
        for _ in 0..EXAMPLES {
            let ex = generate(task, SEQ_LEN, cfg.seed, &mut rng);
            let f = features_from_tokens(&ex.tokens);
            let oracle = &names[oracle_expert(&f, names.len())];
            let sel = trained
                .gate
                .select(&f, &names)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            if top_member(&trained.gate, &f, &names).as_deref() == Some(oracle.as_str()) {
                top1 += 1;
            }
            if let Selection::Set { members } = &sel {
                mass += members
                    .iter()
                    .find(|(n, _)| n == oracle)
                    .map(|(_, w)| f64::from(*w))
                    .unwrap_or(0.0);
            }
            merged
                .apply(&mut store, &sel)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            single
                .apply(&mut store, &Selection::single(oracle))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            rel += rel_frobenius(merged.weights(), single.weights());
            max_div = max_div.max(merged.weights().max_abs_diff(single.weights()));
        }
        let n = EXAMPLES as f64;
        rep.line(format!(
            "| {} | {:.1}% | {:.2} | {:.4} | {:.4} |",
            task.name(),
            100.0 * top1 as f64 / n,
            mass / n,
            rel / n,
            max_div
        ));
    }
    rep.line("");
    rep.line("Reading: high top-1 + high oracle mass means the gate recovers the");
    rep.line("per-task expert; small rel ||dW|| means serving the merged top-2 set");
    rep.line("stays close in weight space to dedicated single-adapter serving —");
    rep.line("the SHiRA sparse-fusion claim, now reachable without naming a set.");
    rep.write(cfg)?;
    rep.print(cfg);
    Ok(vec![rep])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_switch_prefers_scatter_at_scale() {
        // Even a single small rep shows scatter << fuse at dim 512.
        let s = measure_switch(512, 0.02, 32, 2, 1);
        assert!(s.scatter_us > 0.0);
        assert!(s.fuse_us > s.scatter_us, "{} vs {}", s.fuse_us, s.scatter_us);
    }
}
