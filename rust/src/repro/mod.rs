//! Experiment drivers: one per paper table/figure (DESIGN.md §6 index).
//!
//! Each driver produces a `Report` (markdown table + config header) that is
//! printed and written under `reports/`.  Shared infrastructure here:
//! checkpoint-cached base pretraining and adapter-training helpers.

pub mod language;
pub mod systems;
pub mod vision;

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::model::weights::WeightStore;
use crate::runtime::{HostValue, Runtime};
use crate::train::schedule::Schedule;
use crate::train::{checkpoint, Trainer, TrainKind};
use crate::util::rng::Rng;

/// A rendered experiment report.
#[derive(Clone, Debug)]
pub struct Report {
    /// Report id (also the output filename stem, e.g. "table5").
    pub id: String,
    /// Human-readable title.
    pub title: String,
    /// Markdown body lines.
    pub lines: Vec<String>,
}

impl Report {
    /// Empty report with an id and title.
    pub fn new(id: &str, title: &str) -> Self {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            lines: Vec::new(),
        }
    }

    /// Append one markdown line.
    pub fn line(&mut self, s: impl Into<String>) {
        self.lines.push(s.into());
    }

    /// Render to markdown, embedding the exact config in the header so
    /// results are reproducible from the report alone.
    pub fn render(&self, cfg: &RunConfig) -> String {
        let mut out = format!("# {} — {}\n\nconfig: `{}`\n\n", self.id, self.title,
                              cfg.to_json().to_string_compact());
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        out
    }

    /// Write the rendered report under `cfg.report_dir`.
    pub fn write(&self, cfg: &RunConfig) -> Result<PathBuf> {
        let dir = PathBuf::from(&cfg.report_dir);
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{}.md", self.id));
        std::fs::write(&path, self.render(cfg))?;
        Ok(path)
    }

    /// Print the rendered report to stdout.
    pub fn print(&self, cfg: &RunConfig) {
        println!("{}", self.render(cfg));
    }
}

// ---------------------------------------------------------------------------
// Base-model preparation (checkpoint-cached)
// ---------------------------------------------------------------------------

/// Pretrain (or load cached) nanollama base weights.
///
/// Pretraining mixes generic bigram text with task-FORMAT exposure under a
/// different hidden-table seed, mirroring an LLM that has seen text of the
/// task domains but not the eval mappings (DESIGN.md §3).
pub fn ensure_llama_base(rt: &Runtime, cfg: &RunConfig, which: &str) -> Result<WeightStore> {
    let seed = match which {
        "llama_a" => cfg.seed,
        "llama_b" => cfg.seed ^ 0xB10C_0BA5E,
        other => return Err(anyhow!("unknown llama base {other}")),
    };
    let path = checkpoint::checkpoint_dir().join(format!(
        "{which}_s{seed}_p{}.ckpt",
        cfg.pretrain_steps
    ));
    if let Ok(store) = checkpoint::load(&path) {
        return Ok(store);
    }
    let meta = rt.manifest.model("llama").map_err(|e| anyhow!("{e}"))?.clone();
    let (b, t, v) = (meta.dim("batch"), meta.dim("seq_len"), meta.dim("vocab"));
    let base = WeightStore::init(&meta.params, seed);
    let mut trainer = Trainer::new(rt, "llama", base)?;
    let pretrain_table_seed = seed ^ 0x5EED;
    let mut data = move |_step: usize, rng: &mut Rng| {
        let batch = if rng.below(2) == 0 {
            crate::data::tasks::pretrain_batch(v, b, t, rng)
        } else {
            crate::data::tasks::mixture_batch(
                &crate::data::tasks::ALL_TASKS,
                b,
                t,
                pretrain_table_seed,
                rng,
            )
        };
        vec![
            HostValue::i32(batch.x, vec![b, t]),
            HostValue::i32(batch.y, vec![b, t]),
            HostValue::f32(batch.mask, vec![b, t]),
        ]
    };
    let out = trainer.train(
        TrainKind::Full,
        cfg.pretrain_steps,
        Schedule::Cosine { lr: 3e-3 },
        &mut data,
        seed,
    )?;
    crate::log_info!(
        "pretrained {which}: loss {:.3} -> {:.3} ({:.2} steps/s)",
        out.first_loss(),
        out.last_loss(),
        out.steps_per_sec
    );
    trainer.absorb_full_theta(&out.theta);
    checkpoint::save(&path, &trainer.base)?;
    Ok(trainer.base)
}

/// Pretrain (or load cached) nanosd base weights against the style world's
/// ground-truth content renderer.
pub fn ensure_sd_base(
    rt: &Runtime,
    cfg: &RunConfig,
    world: &crate::data::style::StyleWorld,
) -> Result<WeightStore> {
    let seed = cfg.seed ^ 0x5D;
    let path = checkpoint::checkpoint_dir().join(format!(
        "sd_s{seed}_p{}.ckpt",
        cfg.pretrain_steps
    ));
    if let Ok(store) = checkpoint::load(&path) {
        return Ok(store);
    }
    let meta = rt.manifest.model("sd").map_err(|e| anyhow!("{e}"))?.clone();
    let b = meta.dim("batch");
    let base = WeightStore::init(&meta.params, seed);
    let mut trainer = Trainer::new(rt, "sd", base)?;
    let w = world.clone();
    let mut data = move |_step: usize, rng: &mut Rng| {
        let mut zs = Vec::with_capacity(b * w.d_z);
        let mut imgs = Vec::with_capacity(b * w.d_img);
        for _ in 0..b {
            let c = rng.below(crate::data::style::N_CONCEPTS);
            let z = w.sample_z(c, rng);
            let img = w.base_image(&z);
            zs.extend_from_slice(&z);
            imgs.extend_from_slice(&img);
        }
        vec![
            HostValue::f32(zs, vec![b, w.d_z]),
            HostValue::f32(imgs, vec![b, w.d_img]),
        ]
    };
    let out = trainer.train(
        TrainKind::Full,
        cfg.pretrain_steps,
        Schedule::Cosine { lr: 5e-3 },
        &mut data,
        seed,
    )?;
    crate::log_info!(
        "pretrained sd: loss {:.4} -> {:.4}",
        out.first_loss(),
        out.last_loss()
    );
    trainer.absorb_full_theta(&out.theta);
    checkpoint::save(&path, &trainer.base)?;
    Ok(trainer.base)
}

/// Shared style world for all vision experiments.
pub fn style_world(rt: &Runtime, cfg: &RunConfig) -> crate::data::style::StyleWorld {
    let meta = rt.manifest.model("sd").expect("sd meta");
    crate::data::style::StyleWorld::new(meta.dim("d_z"), meta.dim("d_img"), cfg.seed ^ 0x57)
}

/// Run one repro experiment by id.
pub fn run(rt: &Runtime, cfg: &RunConfig, exp: &str) -> Result<Vec<Report>> {
    match exp {
        "table1" => vision::table1(rt, cfg),
        "fig4" => vision::fig4(rt, cfg),
        "fig6" => vision::fig6(rt, cfg),
        "fig7" => vision::fig7(rt, cfg),
        "table2" => language::table2(rt, cfg),
        "table3" => language::table3(rt, cfg),
        "table4" => language::table4(rt, cfg),
        "table5" => systems::table5(rt, cfg),
        "table6" => systems::table6(rt, cfg),
        "fig5" => systems::fig5(cfg),
        "gate" => systems::gate(cfg),
        "orthogonality" => systems::orthogonality(rt, cfg),
        "all" => {
            let mut all = Vec::new();
            for e in [
                "fig5", "gate", "table5", "table6", "orthogonality", "table1", "fig4",
                "fig6", "fig7", "table2", "table3", "table4",
            ] {
                all.extend(run(rt, cfg, e)?);
            }
            Ok(all)
        }
        other => Err(anyhow!(
            "unknown experiment '{other}' (try table1..6, fig4/5/6/7, gate, \
             orthogonality, all)"
        )),
    }
}
