//! Evaluation: multiple-choice accuracy for the commonsense proxy suite
//! (paper Tables 2-4) and SPS for the style suite (Table 1, Figs 4/6/7).
//!
//! Evaluation always runs in FUSED mode: the adapter has been applied to
//! the resident weights (by the switch engine) and the plain `*_fwd`
//! artifact executes — the exact inference dataflow the paper deploys.

use anyhow::Result;

use crate::data::style::{Style, StyleWorld};
use crate::data::tasks::{self, Example, Task};
use crate::model::weights::WeightStore;
use crate::runtime::{HostValue, Runtime};
use crate::util::rng::Rng;

/// Marshal a weight store in manifest order for `model`.
pub fn weight_inputs(rt: &Runtime, model: &str, w: &WeightStore) -> Vec<HostValue> {
    let meta = rt.manifest.model(model).expect("model meta");
    meta.params
        .iter()
        .map(|(name, shape)| HostValue::f32(w.get(name).data.clone(), shape.clone()))
        .collect()
}

/// Accuracy of the resident weights on one task's eval set.
///
/// The model scores each example by the logit at the answer slot
/// (position T-2 predicts the final token); prediction = argmax over the
/// example's candidate answers.
pub fn eval_task(
    rt: &Runtime,
    weights: &WeightStore,
    task: Task,
    n_examples: usize,
    seed: u64,
) -> Result<f64> {
    let meta = rt.manifest.model("llama").expect("llama meta");
    let (b, t, v) = (meta.dim("batch"), meta.dim("seq_len"), meta.dim("vocab"));
    let examples = tasks::eval_set(task, n_examples, t, seed);
    let exe = rt.load("llama_fwd")?;
    let base_inputs = weight_inputs(rt, "llama", weights);
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in examples.chunks(b) {
        // pad the final chunk by repeating the last example
        let mut batch: Vec<&Example> = chunk.iter().collect();
        while batch.len() < b {
            batch.push(&chunk[chunk.len() - 1]);
        }
        let mut x = Vec::with_capacity(b * t);
        for ex in &batch {
            for (pos, &tok) in ex.tokens.iter().enumerate() {
                x.push(if pos == t - 1 { tasks::QUERY } else { tok });
            }
        }
        let mut inputs = base_inputs.clone();
        inputs.push(HostValue::i32(x, vec![b, t]));
        let out = exe.run(&inputs)?;
        let logits = out[0].as_f32(); // (b, t, v)
        for (i, ex) in chunk.iter().enumerate() {
            let row = &logits[i * t * v + (t - 2) * v..i * t * v + (t - 1) * v];
            let pred = ex
                .choices
                .iter()
                .copied()
                .max_by(|&a, &c| {
                    row[a as usize]
                        .partial_cmp(&row[c as usize])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .unwrap();
            if pred == ex.answer {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total as f64)
}

/// Accuracy over several tasks; returns (per-task, average) as percentages.
pub fn eval_tasks(
    rt: &Runtime,
    weights: &WeightStore,
    task_list: &[Task],
    n_examples: usize,
    seed: u64,
) -> Result<(Vec<(Task, f64)>, f64)> {
    let mut per = Vec::with_capacity(task_list.len());
    for &task in task_list {
        let acc = 100.0 * eval_task(rt, weights, task, n_examples, seed)?;
        per.push((task, acc));
    }
    let avg = per.iter().map(|(_, a)| *a).sum::<f64>() / per.len().max(1) as f64;
    Ok((per, avg))
}

/// Mean SPS of the resident `nanosd` weights for `style` at strength
/// `alpha` over `n_batches` eval batches (`unseen` = held-out concepts,
/// the koala test of Figs 1/7).
pub fn eval_style(
    rt: &Runtime,
    weights: &WeightStore,
    world: &StyleWorld,
    style: Style,
    alpha: f32,
    n_batches: usize,
    unseen: bool,
    seed: u64,
) -> Result<f64> {
    let meta = rt.manifest.model("sd").expect("sd meta");
    let b = meta.dim("batch");
    let (dz, dimg) = (world.d_z, world.d_img);
    let exe = rt.load("sd_fwd")?;
    let base_inputs = weight_inputs(rt, "sd", weights);
    let ds = crate::data::style::StyleDataset::new(world.clone(), style, seed);
    let mut rng = Rng::new(seed).stream("style-eval");
    let mut sum = 0.0;
    let mut count = 0usize;
    for _ in 0..n_batches {
        let (zs, _) = ds.eval_batch(b, unseen, &mut rng);
        let mut inputs = base_inputs.clone();
        inputs.push(HostValue::f32(zs.clone(), vec![b, dz]));
        let out = exe.run(&inputs)?;
        let imgs = out[0].as_f32();
        for i in 0..b {
            let z = &zs[i * dz..(i + 1) * dz];
            let img = &imgs[i * dimg..(i + 1) * dimg];
            sum += world.sps(img, z, style, alpha);
            count += 1;
        }
    }
    Ok(sum / count as f64)
}

/// Mean SPS against the dual-style target (multi-adapter generation).
pub fn eval_style_multi(
    rt: &Runtime,
    weights: &WeightStore,
    world: &StyleWorld,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let meta = rt.manifest.model("sd").expect("sd meta");
    let b = meta.dim("batch");
    let (dz, dimg) = (world.d_z, world.d_img);
    let exe = rt.load("sd_fwd")?;
    let base_inputs = weight_inputs(rt, "sd", weights);
    let mut rng = Rng::new(seed).stream("style-eval-multi");
    let mut sum = 0.0;
    let mut count = 0usize;
    for _ in 0..n_batches {
        // all concepts, including ones unseen by both adapters (the koala)
        let mut zs = Vec::with_capacity(b * dz);
        for _ in 0..b {
            let c = rng.below(crate::data::style::N_CONCEPTS);
            zs.extend(world.sample_z(c, &mut rng));
        }
        let mut inputs = base_inputs.clone();
        inputs.push(HostValue::f32(zs.clone(), vec![b, dz]));
        let out = exe.run(&inputs)?;
        let imgs = out[0].as_f32();
        for i in 0..b {
            let z = &zs[i * dz..(i + 1) * dz];
            let img = &imgs[i * dimg..(i + 1) * dimg];
            sum += world.sps_multi(img, z);
            count += 1;
        }
    }
    Ok(sum / count as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn runtime() -> Option<Runtime> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::new(&dir).expect("runtime"))
        } else {
            None
        }
    }

    #[test]
    fn random_model_is_near_chance() {
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("llama").unwrap();
        let w = WeightStore::init(&meta.params, 99);
        // 2-choice task: untrained model should be within noise of 50%
        let acc = eval_task(&rt, &w, Task::ArcEasy, 64, 7).unwrap();
        assert!((0.2..=0.8).contains(&acc), "acc={acc}");
    }

    #[test]
    fn eval_is_deterministic() {
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("llama").unwrap();
        let w = WeightStore::init(&meta.params, 3);
        let a = eval_task(&rt, &w, Task::BoolQ, 32, 5).unwrap();
        let b = eval_task(&rt, &w, Task::BoolQ, 32, 5).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn style_eval_runs() {
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("sd").unwrap();
        let w = WeightStore::init(&meta.params, 4);
        let world = StyleWorld::new(16, 48, 5);
        let s = eval_style(&rt, &w, &world, Style::Bluefire, 1.0, 2, false, 1).unwrap();
        assert!((0.0..=40.0).contains(&s), "sps={s}");
        let sm = eval_style_multi(&rt, &w, &world, 2, 1).unwrap();
        assert!((0.0..=40.0).contains(&sm));
    }
}
