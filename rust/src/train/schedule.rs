//! Learning-rate schedules (paper Table 8: linear for LLM, cosine for LVM).

/// A learning-rate schedule over a fixed-length run.
///
/// # Examples
///
/// ```
/// use shira::train::schedule::Schedule;
///
/// let s = Schedule::Linear { lr: 1.0, floor_frac: 0.1 };
/// assert_eq!(s.at(0, 101), 1.0);
/// assert!((s.at(100, 101) - 0.1).abs() < 1e-6);
/// assert_eq!(s.peak(), 1.0);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// Constant learning rate.
    Const(f32),
    /// Linear decay from lr to `floor_frac`·lr over the run.
    Linear {
        /// Peak (initial) learning rate.
        lr: f32,
        /// Final lr as a fraction of the peak.
        floor_frac: f32,
    },
    /// Cosine decay from lr to ~0 over the run.
    Cosine {
        /// Peak (initial) learning rate.
        lr: f32,
    },
}

impl Schedule {
    /// Learning rate at `step` of a `total`-step run.
    pub fn at(&self, step: usize, total: usize) -> f32 {
        let t = if total <= 1 {
            0.0
        } else {
            step as f32 / (total - 1) as f32
        };
        match *self {
            Schedule::Const(lr) => lr,
            Schedule::Linear { lr, floor_frac } => {
                lr * (1.0 - (1.0 - floor_frac) * t)
            }
            Schedule::Cosine { lr } => {
                lr * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
            }
        }
    }

    /// The schedule's peak learning rate.
    pub fn peak(&self) -> f32 {
        match *self {
            Schedule::Const(lr) => lr,
            Schedule::Linear { lr, .. } => lr,
            Schedule::Cosine { lr } => lr,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_is_flat() {
        let s = Schedule::Const(0.01);
        assert_eq!(s.at(0, 100), 0.01);
        assert_eq!(s.at(99, 100), 0.01);
    }

    #[test]
    fn linear_decays_to_floor() {
        let s = Schedule::Linear { lr: 1.0, floor_frac: 0.1 };
        assert_eq!(s.at(0, 101), 1.0);
        assert!((s.at(100, 101) - 0.1).abs() < 1e-6);
        assert!(s.at(50, 101) < 1.0 && s.at(50, 101) > 0.1);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = Schedule::Cosine { lr: 2.0 };
        assert_eq!(s.at(0, 11), 2.0);
        assert!(s.at(10, 11).abs() < 1e-6);
        // monotone decreasing
        let vals: Vec<f32> = (0..11).map(|i| s.at(i, 11)).collect();
        assert!(vals.windows(2).all(|w| w[1] <= w[0] + 1e-7));
    }

    #[test]
    fn single_step_run_uses_peak() {
        for s in [
            Schedule::Const(0.5),
            Schedule::Linear { lr: 0.5, floor_frac: 0.1 },
            Schedule::Cosine { lr: 0.5 },
        ] {
            assert_eq!(s.at(0, 1), 0.5);
            assert_eq!(s.peak(), 0.5);
        }
    }
}
