//! Training orchestrator: drives the AOT train-step executables from rust.
//!
//! The L2 train steps are pure functions  `(base.., theta, m, v, [idx,]
//! step, lr, batch..) -> (theta', m', v', loss)`; this module owns the loop,
//! the mask calibration (Grad/SNIP via the `*_grad_probe` artifact), theta
//! initialization per adapter kind, checkpointing, and the byte accounting
//! behind Table 6.  Python never runs here — only compiled artifacts.

pub mod checkpoint;
pub mod eval;
pub mod gate;
pub mod schedule;

use anyhow::{anyhow, Result};

use crate::adapter::mask::{generate_mask, MaskStrategy};
use crate::adapter::sparse::SparseDelta;
use crate::adapter::{LoraAdapter, LoraTensor, ShiraAdapter};
use crate::model::tensor::Tensor2;
use crate::model::weights::WeightStore;
use crate::runtime::manifest::ModelMeta;
use crate::runtime::{HostValue, Runtime};
use crate::util::alloc::MemLedger;
use crate::util::rng::Rng;
use schedule::Schedule;

/// Which adapter formulation to train.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainKind {
    /// Sparse high-rank adapter with the given mask strategy.
    Shira(MaskStrategy),
    /// Low-rank adapter baseline.
    Lora,
    /// Weight-decomposed low-rank adapter baseline.
    Dora,
    /// SHiRA with DoRA-style magnitude columns.
    ShiraDora(MaskStrategy),
    /// Full finetuning (used for base-model pretraining).
    Full,
    /// Appendix-C ablation: dense theta + Pallas gradient masking.
    ShiraDense(MaskStrategy),
}

impl TrainKind {
    /// Suffix of this kind's `*_train_*` artifact name.
    pub fn artifact_suffix(&self) -> &'static str {
        match self {
            TrainKind::Shira(_) => "shira",
            TrainKind::Lora => "lora",
            TrainKind::Dora => "dora",
            TrainKind::ShiraDora(_) => "shira_dora",
            TrainKind::Full => "full",
            TrainKind::ShiraDense(_) => "shira_dense",
        }
    }

    /// Human-readable label ("shira-snip", "lora", ...).
    pub fn label(&self) -> String {
        match self {
            TrainKind::Shira(s) => format!("shira-{}", s.name()),
            TrainKind::Lora => "lora".into(),
            TrainKind::Dora => "dora".into(),
            TrainKind::ShiraDora(s) => format!("shira-{}-dora", s.name()),
            TrainKind::Full => "full".into(),
            TrainKind::ShiraDense(s) => format!("shira-dense-{}", s.name()),
        }
    }

    /// The mask strategy, for sparse kinds.
    pub fn mask_strategy(&self) -> Option<MaskStrategy> {
        match self {
            TrainKind::Shira(s) | TrainKind::ShiraDora(s) | TrainKind::ShiraDense(s) => {
                Some(*s)
            }
            _ => None,
        }
    }

    /// Does this kind's train step take the sparse idx vector as input?
    pub fn needs_idx_input(&self) -> bool {
        matches!(self, TrainKind::Shira(_) | TrainKind::ShiraDora(_))
    }
}

/// Result of one training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    /// The trained kind's [`TrainKind::label`].
    pub kind_label: String,
    /// Final trainable vector, in the kind's theta layout.
    pub theta: Vec<f32>,
    /// Mask indices (sparse kinds; local flat indices per target segment).
    pub idx: Vec<i32>,
    /// Per-step training losses.
    pub losses: Vec<f32>,
    /// Training throughput.
    pub steps_per_sec: f64,
    /// Peak logical training memory (params + trainable + optimizer + batch).
    pub peak_bytes: usize,
    /// Trainable parameter count (= theta length).
    pub trainable_params: usize,
}

impl TrainOutcome {
    /// Loss at step 0 (NaN for an empty run).
    pub fn first_loss(&self) -> f32 {
        *self.losses.first().unwrap_or(&f32::NAN)
    }

    /// Loss at the final step (NaN for an empty run).
    pub fn last_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }
}

/// Provides batches in artifact input order (llama: x,y,mask; sd: z,target).
pub type BatchFn<'a> = dyn FnMut(usize, &mut Rng) -> Vec<HostValue> + 'a;

/// Drives the AOT train-step executables: mask calibration, theta
/// initialization, the step loop, checkpoint-compatible export, and the
/// Table-6 memory accounting.
pub struct Trainer<'rt> {
    /// The runtime executing the train-step artifacts.
    pub rt: &'rt Runtime,
    /// The model's manifest entry.
    pub model: ModelMeta,
    /// Base weights the adapter trains against.
    pub base: WeightStore,
    /// Logical-memory ledger (Table 6 accounting).
    pub ledger: MemLedger,
}

impl<'rt> Trainer<'rt> {
    /// Trainer for `model_name` over `base` weights.
    pub fn new(rt: &'rt Runtime, model_name: &str, base: WeightStore) -> Result<Self> {
        let model = rt
            .manifest
            .model(model_name)
            .map_err(|e| anyhow!("{e}"))?
            .clone();
        Ok(Trainer {
            rt,
            model,
            base,
            ledger: MemLedger::new(),
        })
    }

    /// Fresh base weights from the manifest spec (pre-pretraining).
    pub fn fresh_base(rt: &Runtime, model_name: &str, seed: u64) -> Result<WeightStore> {
        let model = rt.manifest.model(model_name).map_err(|e| anyhow!("{e}"))?;
        Ok(WeightStore::init(&model.params, seed))
    }

    /// Base weights marshalled in manifest param order.
    pub fn base_inputs(&self) -> Vec<HostValue> {
        self.model
            .params
            .iter()
            .map(|(name, shape)| {
                HostValue::f32(self.base.get(name).data.clone(), shape.clone())
            })
            .collect()
    }

    // ---------------------------------------------------------------
    // Mask calibration (SHiRA-Grad / SHiRA-SNIP)
    // ---------------------------------------------------------------

    /// Accumulate |grad| over `n_batches` calibration batches using the
    /// `*_grad_probe` artifact; returns the probe-layout vector.
    pub fn calibrate_grads(
        &self,
        n_batches: usize,
        data: &mut BatchFn,
        rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let art = format!("{}_grad_probe", self.model.name_family());
        let exe = self.rt.load(&art)?;
        let probe_len: usize = self.model.probe.iter().map(|s| s.len).sum();
        let mut acc = vec![0.0f32; probe_len];
        for b in 0..n_batches {
            let mut inputs = self.base_inputs();
            inputs.extend(data(b, rng));
            let out = exe.run(&inputs)?;
            for (a, &g) in acc.iter_mut().zip(out[0].as_f32()) {
                *a += g;
            }
        }
        Ok(acc)
    }

    /// Build the concatenated local-index vector for the SHiRA layout.
    pub fn build_masks(
        &self,
        strategy: MaskStrategy,
        grad_abs: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Vec<i32> {
        let mut idx = Vec::with_capacity(self.model.theta_len["shira"]);
        let probe_off: std::collections::HashMap<&str, usize> = self
            .model
            .probe
            .iter()
            .map(|s| (s.name.as_str(), s.off))
            .collect();
        for seg in &self.model.shira {
            let w = self.base.get(&seg.name);
            let g_seg = grad_abs.map(|g| {
                let off = probe_off[seg.name.as_str()];
                &g[off..off + w.numel()]
            });
            let mut stream = rng.stream(&format!("mask/{}/{}", strategy.name(), seg.name));
            let local = generate_mask(strategy, w, seg.k, g_seg, &mut stream);
            idx.extend(local.iter().map(|&i| i as i32));
        }
        idx
    }

    // ---------------------------------------------------------------
    // Theta initialization
    // ---------------------------------------------------------------

    /// Initialize theta for `kind` (and return it).  For sparse kinds,
    /// `idx` must be the concatenated local indices from `build_masks`.
    pub fn init_theta(&self, kind: TrainKind, idx: &[i32], rng: &mut Rng) -> Vec<f32> {
        match kind {
            TrainKind::Shira(_) => self.gather_base(idx),
            TrainKind::Lora | TrainKind::Dora => {
                let segs = if matches!(kind, TrainKind::Lora) {
                    &self.model.lora
                } else {
                    &self.model.dora
                };
                let total = self.model.theta_len[kind.artifact_suffix()];
                let mut theta = vec![0.0f32; total];
                for seg in segs {
                    // A ~ N(0, 0.02), B = 0 (standard LoRA init)
                    let mut stream = rng.stream(&format!("lora_a/{}", seg.name));
                    stream.fill_normal(
                        &mut theta[seg.a_off..seg.a_off + seg.a_len],
                        0.0,
                        0.02,
                    );
                    if let (Some(mo), Some(ml)) = (seg.mag_off, seg.mag_len) {
                        let w = self.base.get(&seg.name);
                        for c in 0..ml {
                            let mut acc = 0.0f32;
                            for r in 0..w.rows {
                                let v = w.at(r, c);
                                acc += v * v;
                            }
                            theta[mo + c] = (acc + 1e-6).sqrt();
                        }
                    }
                }
                theta
            }
            TrainKind::ShiraDora(_) => {
                let total = self.model.theta_len["shira_dora"];
                let mut theta = vec![0.0f32; total];
                let gathered = self.gather_base(idx);
                theta[..gathered.len()].copy_from_slice(&gathered);
                for seg in &self.model.shira_dora {
                    if let (Some(mo), Some(ml)) = (seg.mag_off, seg.mag_len) {
                        let w = self.base.get(&seg.name);
                        for c in 0..ml {
                            let mut acc = 0.0f32;
                            for r in 0..w.rows {
                                let v = w.at(r, c);
                                acc += v * v;
                            }
                            theta[mo + c] = (acc + 1e-6).sqrt();
                        }
                    }
                }
                theta
            }
            TrainKind::Full => {
                let mut theta = Vec::with_capacity(self.model.theta_len["full"]);
                for (name, _) in &self.model.params {
                    theta.extend_from_slice(&self.base.get(name).data);
                }
                theta
            }
            TrainKind::ShiraDense(_) => {
                let mut theta = Vec::new();
                for seg in &self.model.probe {
                    theta.extend_from_slice(&self.base.get(&seg.name).data);
                }
                theta
            }
        }
    }

    fn gather_base(&self, idx: &[i32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(idx.len());
        for seg in &self.model.shira {
            let w = self.base.get(&seg.name);
            for &i in &idx[seg.off..seg.off + seg.k] {
                out.push(w.data[i as usize]);
            }
        }
        out
    }

    /// Dense {0,1} mask over the probe layout from sparse indices
    /// (Appendix-C formulation).
    pub fn dense_mask_from_idx(&self, idx: &[i32]) -> Vec<f32> {
        let total: usize = self.model.probe.iter().map(|s| s.len).sum();
        let mut mask = vec![0.0f32; total];
        let probe_off: std::collections::HashMap<&str, usize> = self
            .model
            .probe
            .iter()
            .map(|s| (s.name.as_str(), s.off))
            .collect();
        for seg in &self.model.shira {
            let off = probe_off[seg.name.as_str()];
            for &i in &idx[seg.off..seg.off + seg.k] {
                mask[off + i as usize] = 1.0;
            }
        }
        mask
    }

    // ---------------------------------------------------------------
    // The training loop
    // ---------------------------------------------------------------

    /// Full training run for `kind`: calibrate masks (gradient-based
    /// strategies probe first), initialize theta, then drive the AOT
    /// train-step artifact for `steps` steps.
    pub fn train(
        &self,
        kind: TrainKind,
        steps: usize,
        sched: Schedule,
        data: &mut BatchFn,
        seed: u64,
    ) -> Result<TrainOutcome> {
        let mut rng = Rng::new(seed);
        // masks
        let idx: Vec<i32> = match kind.mask_strategy() {
            Some(strategy) if strategy.needs_gradients() => {
                let mut calib_rng = rng.stream("calib");
                let grads = self.calibrate_grads(4, data, &mut calib_rng)?;
                self.build_masks(strategy, Some(&grads), &mut rng)
            }
            Some(strategy) => self.build_masks(strategy, None, &mut rng),
            None => Vec::new(),
        };
        let theta0 = self.init_theta(kind, &idx, &mut rng);
        self.train_with(kind, steps, sched, data, seed, theta0, idx)
    }

    /// Training loop with pre-built theta/idx (used by benches for control).
    #[allow(clippy::too_many_arguments)]
    pub fn train_with(
        &self,
        kind: TrainKind,
        steps: usize,
        sched: Schedule,
        data: &mut BatchFn,
        seed: u64,
        theta0: Vec<f32>,
        idx: Vec<i32>,
    ) -> Result<TrainOutcome> {
        let mut rng = Rng::new(seed).stream("train");
        let art = format!(
            "{}_train_{}",
            self.model.name_family(),
            kind.artifact_suffix()
        );
        let exe = self.rt.load(&art)?;
        let k = theta0.len();
        let mut theta = theta0;
        let mut m = vec![0.0f32; k];
        let mut v = vec![0.0f32; k];
        let dense_mask = if matches!(kind, TrainKind::ShiraDense(_)) {
            self.dense_mask_from_idx(&idx)
        } else {
            Vec::new()
        };

        // Table-6 accounting: what a training process must keep resident.
        let base_bytes = if matches!(kind, TrainKind::Full) {
            0 // full-FT: params ARE theta
        } else {
            self.base.nbytes()
        };
        self.ledger.alloc("base_params", base_bytes);
        self.ledger.alloc("trainable", 4 * k);
        self.ledger.alloc("optimizer", 8 * k); // adam m+v
        self.ledger.alloc("mask_idx", 4 * idx.len() + 4 * dense_mask.len());

        let base_inputs = if matches!(kind, TrainKind::Full) {
            Vec::new()
        } else {
            self.base_inputs()
        };

        let mut losses = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        let mut batch_bytes_logged = false;
        for step in 0..steps {
            let batch = data(step, &mut rng);
            if !batch_bytes_logged {
                let bytes: usize = batch.iter().map(|b| b.nbytes()).sum();
                self.ledger.alloc("batch", bytes);
                batch_bytes_logged = true;
            }
            let mut inputs = base_inputs.clone();
            inputs.push(HostValue::f32(std::mem::take(&mut theta), vec![k]));
            inputs.push(HostValue::f32(std::mem::take(&mut m), vec![k]));
            inputs.push(HostValue::f32(std::mem::take(&mut v), vec![k]));
            if kind.needs_idx_input() {
                inputs.push(HostValue::i32(idx.clone(), vec![idx.len()]));
            }
            inputs.push(HostValue::scalar_i32(step as i32));
            inputs.push(HostValue::scalar_f32(sched.at(step, steps)));
            inputs.extend(batch);
            if !dense_mask.is_empty() {
                inputs.push(HostValue::f32(dense_mask.clone(), vec![dense_mask.len()]));
            }
            let mut out = exe.run(&inputs)?;
            let loss = out[3].as_f32()[0];
            v = std::mem::replace(&mut out[2], HostValue::f32(vec![], vec![0])).into_f32();
            m = std::mem::replace(&mut out[1], HostValue::f32(vec![], vec![0])).into_f32();
            theta = std::mem::replace(&mut out[0], HostValue::f32(vec![], vec![0])).into_f32();
            losses.push(loss);
            if !loss.is_finite() {
                return Err(anyhow!("{art}: loss diverged at step {step}"));
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let peak = self.ledger.peak_total();
        // release (keeps ledger reusable across runs)
        self.ledger.free("base_params", base_bytes);
        self.ledger.free("trainable", 4 * k);
        self.ledger.free("optimizer", 8 * k);
        self.ledger.free("mask_idx", 4 * idx.len() + 4 * dense_mask.len());
        if batch_bytes_logged {
            let b = self.ledger.live("batch");
            self.ledger.free("batch", b);
        }

        Ok(TrainOutcome {
            kind_label: kind.label(),
            theta,
            idx,
            losses,
            steps_per_sec: steps as f64 / elapsed.max(1e-9),
            peak_bytes: peak,
            trainable_params: k,
        })
    }

    // ---------------------------------------------------------------
    // Export / import
    // ---------------------------------------------------------------

    /// Convert a trained sparse theta into a portable SHiRA adapter.
    pub fn export_shira(
        &self,
        outcome: &TrainOutcome,
        name: &str,
        strategy: MaskStrategy,
    ) -> ShiraAdapter {
        let mut tensors = Vec::with_capacity(self.model.shira.len());
        for seg in &self.model.shira {
            let w = self.base.get(&seg.name);
            let mut pairs: Vec<(u32, f32)> = (0..seg.k)
                .map(|j| {
                    let local = outcome.idx[seg.off + j] as u32;
                    let delta = outcome.theta[seg.off + j] - w.data[local as usize];
                    (local, delta)
                })
                .collect();
            pairs.sort_unstable_by_key(|&(i, _)| i);
            let (idx, delta): (Vec<u32>, Vec<f32>) = pairs.into_iter().unzip();
            tensors.push((
                seg.name.clone(),
                SparseDelta::new(seg.shape.0, seg.shape.1, idx, delta),
            ));
        }
        ShiraAdapter {
            name: name.to_string(),
            strategy: strategy.name().to_string(),
            tensors,
        }
    }

    /// Convert a trained LoRA theta into a portable LoRA adapter.
    pub fn export_lora(&self, outcome: &TrainOutcome, name: &str) -> LoraAdapter {
        let scale = self.rt.manifest.adapter.lora_scale as f32;
        let mut tensors = Vec::with_capacity(self.model.lora.len());
        for seg in &self.model.lora {
            let (n, mm) = seg.shape;
            let a = Tensor2::from_vec(
                n,
                seg.rank,
                outcome.theta[seg.a_off..seg.a_off + seg.a_len].to_vec(),
            );
            let b = Tensor2::from_vec(
                seg.rank,
                mm,
                outcome.theta[seg.b_off..seg.b_off + seg.b_len].to_vec(),
            );
            tensors.push(LoraTensor {
                target: seg.name.clone(),
                a,
                b,
            });
        }
        LoraAdapter {
            name: name.to_string(),
            scale,
            tensors,
        }
    }

    /// Write a full-FT theta back into the base weight store (pretraining).
    pub fn absorb_full_theta(&mut self, theta: &[f32]) {
        let mut off = 0;
        for (name, shape) in self.model.params.clone() {
            let numel: usize = shape.iter().product();
            self.base
                .get_mut(&name)
                .data
                .copy_from_slice(&theta[off..off + numel]);
            off += numel;
        }
        assert_eq!(off, theta.len());
    }
}

impl ModelMeta {
    /// Artifact name prefix for this model family.
    pub fn name_family(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn runtime() -> Option<Runtime> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::new(&dir).expect("runtime"))
        } else {
            None
        }
    }

    fn sd_data<'a>(
        world: &'a crate::data::style::StyleWorld,
        ds: &'a crate::data::style::StyleDataset,
        batch: usize,
    ) -> impl FnMut(usize, &mut Rng) -> Vec<HostValue> + 'a {
        let dz = world.d_z;
        let dimg = world.d_img;
        move |_step, rng| {
            let (z, t) = ds.train_batch(batch, rng);
            vec![
                HostValue::f32(z, vec![batch, dz]),
                HostValue::f32(t, vec![batch, dimg]),
            ]
        }
    }

    #[test]
    fn kind_labels_and_suffixes() {
        assert_eq!(TrainKind::Lora.label(), "lora");
        assert_eq!(
            TrainKind::Shira(MaskStrategy::Snip).label(),
            "shira-snip"
        );
        assert_eq!(
            TrainKind::ShiraDora(MaskStrategy::WeightMagnitude).artifact_suffix(),
            "shira_dora"
        );
        assert!(TrainKind::Shira(MaskStrategy::Rand).needs_idx_input());
        assert!(!TrainKind::Lora.needs_idx_input());
    }

    #[test]
    fn sd_shira_training_reduces_loss_and_exports() {
        let Some(rt) = runtime() else { return };
        let base = Trainer::fresh_base(&rt, "sd", 42).unwrap();
        let trainer = Trainer::new(&rt, "sd", base).unwrap();
        let world = crate::data::style::StyleWorld::new(16, 48, 5);
        let ds = crate::data::style::StyleDataset::new(
            world.clone(),
            crate::data::style::Style::Bluefire,
            5,
        );
        let batch = trainer.model.dim("batch");
        let mut data = sd_data(&world, &ds, batch);
        let out = trainer
            .train(
                TrainKind::Shira(MaskStrategy::Rand),
                12,
                Schedule::Const(5e-3),
                &mut data,
                1,
            )
            .unwrap();
        assert!(out.last_loss() < out.first_loss(), "{:?}", out.losses);
        assert!(out.steps_per_sec > 0.0);
        let adapter = trainer.export_shira(&out, "bf", MaskStrategy::Rand);
        assert_eq!(adapter.tensors.len(), trainer.model.shira.len());
        assert!(adapter.param_count() > 0);
        // deltas should be nonzero after training
        let total_delta: f32 = adapter
            .tensors
            .iter()
            .flat_map(|(_, d)| d.delta.iter())
            .map(|x| x.abs())
            .sum();
        assert!(total_delta > 0.0);
    }

    #[test]
    fn sd_lora_training_reduces_loss() {
        let Some(rt) = runtime() else { return };
        let base = Trainer::fresh_base(&rt, "sd", 42).unwrap();
        let trainer = Trainer::new(&rt, "sd", base).unwrap();
        let world = crate::data::style::StyleWorld::new(16, 48, 5);
        let ds = crate::data::style::StyleDataset::new(
            world.clone(),
            crate::data::style::Style::Paintings,
            6,
        );
        let batch = trainer.model.dim("batch");
        let mut data = sd_data(&world, &ds, batch);
        let out = trainer
            .train(TrainKind::Lora, 12, Schedule::Const(5e-3), &mut data, 2)
            .unwrap();
        assert!(out.last_loss() < out.first_loss());
        let adapter = trainer.export_lora(&out, "paint");
        assert_eq!(adapter.tensors.len(), trainer.model.lora.len());
    }

    #[test]
    fn memory_accounting_orders_kinds() {
        // Table 6 shape: shira trainable+optimizer bytes < lora < dora.
        let Some(rt) = runtime() else { return };
        let llama = rt.manifest.model("llama").unwrap();
        let k_shira = llama.theta_len["shira"];
        let k_lora = llama.theta_len["lora"];
        let k_dora = llama.theta_len["dora"];
        assert!(k_shira < k_lora, "{k_shira} vs {k_lora}");
        assert!(k_lora < k_dora);
    }

    #[test]
    fn grad_calibration_produces_nonzero_stats() {
        let Some(rt) = runtime() else { return };
        let base = Trainer::fresh_base(&rt, "sd", 7).unwrap();
        let trainer = Trainer::new(&rt, "sd", base).unwrap();
        let world = crate::data::style::StyleWorld::new(16, 48, 5);
        let ds = crate::data::style::StyleDataset::new(
            world.clone(),
            crate::data::style::Style::Bluefire,
            5,
        );
        let batch = trainer.model.dim("batch");
        let mut data = sd_data(&world, &ds, batch);
        let mut rng = Rng::new(3);
        let g = trainer.calibrate_grads(2, &mut data, &mut rng).unwrap();
        let probe_len: usize = trainer.model.probe.iter().map(|s| s.len).sum();
        assert_eq!(g.len(), probe_len);
        assert!(g.iter().any(|&x| x > 0.0));
        assert!(g.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn masks_respect_layout_ks() {
        let Some(rt) = runtime() else { return };
        let base = Trainer::fresh_base(&rt, "llama", 7).unwrap();
        let trainer = Trainer::new(&rt, "llama", base).unwrap();
        let mut rng = Rng::new(4);
        let idx = trainer.build_masks(MaskStrategy::WeightMagnitude, None, &mut rng);
        assert_eq!(idx.len(), trainer.model.theta_len["shira"]);
        for seg in &trainer.model.shira {
            let slice = &idx[seg.off..seg.off + seg.k];
            assert!(slice
                .iter()
                .all(|&i| (i as usize) < seg.shape.0 * seg.shape.1));
        }
    }
}
