//! Weight-store checkpoints: pretrained base models are cached on disk so
//! the repro drivers don't re-pretrain for every experiment.
//!
//! Format: magic "SHCK", version, count, per tensor (name, rows, cols,
//! f32 data), FNV-64 trailer — same conventions as adapter/io.rs.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::model::tensor::Tensor2;
use crate::model::weights::WeightStore;

const MAGIC: u32 = 0x5348_434B;
const VERSION: u32 = 1;

fn fnv64(b: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &x in b {
        h ^= x as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Serialize a weight store (magic, version, tensors, FNV-64 trailer).
pub fn encode(store: &WeightStore) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&(store.len() as u32).to_le_bytes());
    for (name, t) in store.iter() {
        buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
        buf.extend_from_slice(name.as_bytes());
        buf.extend_from_slice(&(t.rows as u32).to_le_bytes());
        buf.extend_from_slice(&(t.cols as u32).to_le_bytes());
        for &x in &t.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    let crc = fnv64(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// Decode [`encode`]d bytes, rejecting truncation and corruption via the
/// checksum trailer.
pub fn decode(bytes: &[u8]) -> Result<WeightStore> {
    if bytes.len() < 20 {
        return Err(anyhow!("checkpoint too short"));
    }
    let body = &bytes[..bytes.len() - 8];
    let want = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if fnv64(body) != want {
        return Err(anyhow!("checkpoint checksum mismatch"));
    }
    let mut i = 0usize;
    let u32_at = |i: &mut usize| -> Result<u32> {
        if *i + 4 > body.len() {
            return Err(anyhow!("truncated checkpoint"));
        }
        let v = u32::from_le_bytes(body[*i..*i + 4].try_into().unwrap());
        *i += 4;
        Ok(v)
    };
    if u32_at(&mut i)? != MAGIC {
        return Err(anyhow!("not a checkpoint file"));
    }
    if u32_at(&mut i)? != VERSION {
        return Err(anyhow!("unsupported checkpoint version"));
    }
    let count = u32_at(&mut i)? as usize;
    let mut store = WeightStore::new();
    for _ in 0..count {
        let nlen = u32_at(&mut i)? as usize;
        if i + nlen > body.len() {
            return Err(anyhow!("truncated name"));
        }
        let name = String::from_utf8(body[i..i + nlen].to_vec())
            .map_err(|_| anyhow!("bad name utf8"))?;
        i += nlen;
        let rows = u32_at(&mut i)? as usize;
        let cols = u32_at(&mut i)? as usize;
        let numel = rows * cols;
        if i + numel * 4 > body.len() {
            return Err(anyhow!("truncated tensor data"));
        }
        let data: Vec<f32> = body[i..i + numel * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        i += numel * 4;
        store.insert(&name, Tensor2::from_vec(rows, cols, data));
    }
    Ok(store)
}

/// Write a checkpoint file (creating parent directories).
pub fn save(path: &Path, store: &WeightStore) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::File::create(path)?.write_all(&encode(store))?;
    Ok(())
}

/// Read and [`decode`] a checkpoint file.
pub fn load(path: &Path) -> Result<WeightStore> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    decode(&bytes)
}

/// Default checkpoint directory (sibling of the artifacts dir).
pub fn checkpoint_dir() -> PathBuf {
    crate::runtime::manifest::Manifest::default_dir().join("checkpoints")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> WeightStore {
        WeightStore::init(
            &[
                ("embed".into(), vec![16, 8]),
                ("l0.ln1".into(), vec![8]),
                ("l0.wq".into(), vec![8, 8]),
            ],
            3,
        )
    }

    #[test]
    fn roundtrip_bit_exact() {
        let s = store();
        let s2 = decode(&encode(&s)).unwrap();
        assert!(s.bit_equal(&s2));
    }

    #[test]
    fn corruption_rejected() {
        let mut b = encode(&store());
        let mid = b.len() / 2;
        b[mid] ^= 1;
        assert!(decode(&b).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("shira-ckpt-test");
        let p = dir.join("m.ckpt");
        save(&p, &store()).unwrap();
        assert!(load(&p).unwrap().bit_equal(&store()));
    }

    #[test]
    fn truncation_rejected() {
        let b = encode(&store());
        assert!(decode(&b[..b.len() - 12]).is_err());
        assert!(decode(&b[..2]).is_err());
    }
}
