//! Gate trainer (DESIGN.md §17): fits the linear top-k
//! [`LinearGate`] on the seeded synthetic request distribution with
//! plain SGD on a softmax cross-entropy loss.
//!
//! Requests lean toward one task dialect
//! ([`request_features`]), and the supervision signal is the
//! **oracle expert**: the expert owning the request's dominant dialect
//! (dialects map onto the roster round-robin when there are fewer
//! experts than dialects).  The trainer and the serving gate share one
//! feature space — [`features_from_tokens`](crate::coordinator::gate::features_from_tokens)
//! end to end — so training accuracy transfers directly to routing
//! accuracy.  Everything is seeded: the same `(experts, top_k, steps,
//! seed)` always yields bit-identical gate parameters, which is what
//! lets gated serving replay across thread and replica counts.

use crate::coordinator::gate::{request_features, Gate, LinearGate, N_FEATURES};
use crate::coordinator::selection::Selection;
use crate::util::rng::Rng;

/// Held-out examples scored for [`GateTrainReport::accuracy`].
pub const EVAL_EXAMPLES: usize = 256;

/// The oracle expert for one feature vector: the dominant task-dialect
/// bin (the trailing "other" bin never labels), mapped round-robin onto
/// an `n_experts`-wide roster.  This is the supervision target for
/// [`train_gate`] and the ground truth for the repro eval.
pub fn oracle_expert(features: &[f32; N_FEATURES], n_experts: usize) -> usize {
    let mut best = 0;
    for d in 1..N_FEATURES - 1 {
        if features[d] > features[best] {
            best = d;
        }
    }
    best % n_experts.max(1)
}

/// What [`train_gate`] produced: the fitted gate plus held-out metrics.
#[derive(Clone, Debug)]
pub struct GateTrainReport {
    /// The fitted top-k gate, ready for
    /// [`ServerBuilder::gate`](crate::coordinator::server::ServerBuilder::gate)
    /// / [`FleetBuilder::gate`](crate::coordinator::fleet::FleetBuilder::gate).
    pub gate: LinearGate,
    /// SGD steps taken (one example per step).
    pub steps: usize,
    /// Held-out top-1 routing accuracy against the oracle expert, over
    /// [`EVAL_EXAMPLES`] fresh seeded requests.
    pub accuracy: f64,
    /// Mean training cross-entropy over the final 10% of steps.
    pub final_loss: f64,
}

/// Fit a [`LinearGate`] over `experts` with `steps` SGD steps on the
/// seeded synthetic request stream.  Deterministic in `(experts,
/// top_k, steps, seed)`; `steps` is clamped to at least 1.
pub fn train_gate(experts: &[String], top_k: usize, steps: usize, seed: u64) -> GateTrainReport {
    let n = experts.len().max(1);
    let steps = steps.max(1);
    let mut w = vec![0.0f32; n * N_FEATURES];
    let mut b = vec![0.0f32; n];
    let mut rng = Rng::new(seed).stream("gate/train");
    let tail_from = steps - (steps + 9) / 10;
    let mut tail_loss = 0.0f64;
    let mut tail_count = 0usize;
    for step in 0..steps {
        let f = request_features(rng.next_u64());
        let label = oracle_expert(&f, n);
        let probs = softmax_scores(&w, &b, &f, n);
        if step >= tail_from {
            tail_loss += -f64::from(probs[label].max(1e-9)).ln();
            tail_count += 1;
        }
        // dL/dscore_i = p_i - [i == label]; linear LR decay to a floor.
        let lr = 0.5f32 * (1.0 - step as f32 / steps as f32).max(0.1);
        for i in 0..n {
            let g = probs[i] - if i == label { 1.0 } else { 0.0 };
            b[i] -= lr * g;
            let row = &mut w[i * N_FEATURES..(i + 1) * N_FEATURES];
            for (wv, x) in row.iter_mut().zip(f.iter()) {
                *wv -= lr * g * x;
            }
        }
    }
    let gate = LinearGate::new(experts, top_k, w, b);
    // Held-out accuracy on a disjoint seeded stream: does the gate's
    // heaviest member match the oracle expert?
    let mut eval_rng = Rng::new(seed).stream("gate/eval");
    let mut correct = 0usize;
    for _ in 0..EVAL_EXAMPLES {
        let f = request_features(eval_rng.next_u64());
        let label = oracle_expert(&f, n);
        if top_member(&gate, &f, experts).as_deref() == experts.get(label).map(String::as_str) {
            correct += 1;
        }
    }
    GateTrainReport {
        gate,
        steps,
        accuracy: correct as f64 / EVAL_EXAMPLES as f64,
        final_loss: tail_loss / tail_count.max(1) as f64,
    }
}

/// Softmax over the gate's raw linear scores (stable shift-by-max).
fn softmax_scores(w: &[f32], b: &[f32], f: &[f32; N_FEATURES], n: usize) -> Vec<f32> {
    let mut probs = vec![0.0f32; n];
    for (i, p) in probs.iter_mut().enumerate() {
        let row = &w[i * N_FEATURES..(i + 1) * N_FEATURES];
        *p = b[i] + row.iter().zip(f.iter()).map(|(wv, x)| wv * x).sum::<f32>();
    }
    let max = probs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for p in &mut probs {
        *p = (*p - max).exp();
        z += *p;
    }
    for p in &mut probs {
        *p /= z;
    }
    probs
}

/// The heaviest member of the gate's selection for `f` over `roster`
/// (name-ascending on exact weight ties, mirroring the gate's own
/// tie-break), or `None` when the gate cannot select.
pub fn top_member(gate: &LinearGate, f: &[f32; N_FEATURES], roster: &[String]) -> Option<String> {
    match gate.select(f, roster) {
        Ok(Selection::Set { members }) => members
            .iter()
            .max_by(|a, b| {
                a.1.partial_cmp(&b.1)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| b.0.cmp(&a.0))
            })
            .map(|(name, _)| name.clone()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn experts(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("adapter{i}")).collect()
    }

    #[test]
    fn trained_gate_routes_to_the_oracle_expert() {
        let ex = experts(4);
        let out = train_gate(&ex, 2, 2000, 0x9A7E);
        assert!(out.accuracy > 0.9, "held-out accuracy {}", out.accuracy);
        assert!(out.final_loss < 0.6, "final loss {}", out.final_loss);
        assert_eq!(out.steps, 2000);
        // Training beats the untrained seeded init by a wide margin.
        let untrained = LinearGate::seeded(&ex, 2, 0x9A7E);
        let mut rng = Rng::new(0x9A7E).stream("gate/eval");
        let mut base_correct = 0usize;
        for _ in 0..EVAL_EXAMPLES {
            let f = request_features(rng.next_u64());
            let label = oracle_expert(&f, ex.len());
            if top_member(&untrained, &f, &ex).as_deref() == Some(ex[label].as_str()) {
                base_correct += 1;
            }
        }
        let base_acc = base_correct as f64 / EVAL_EXAMPLES as f64;
        assert!(
            out.accuracy > base_acc + 0.2,
            "trained {} vs untrained {}",
            out.accuracy,
            base_acc
        );
    }

    #[test]
    fn training_is_deterministic_in_the_seed() {
        let ex = experts(3);
        let a = train_gate(&ex, 2, 500, 7);
        let b = train_gate(&ex, 2, 500, 7);
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.final_loss, b.final_loss);
        let f = request_features(99);
        assert_eq!(
            a.gate.select(&f, &ex).ok(),
            b.gate.select(&f, &ex).ok()
        );
        // A different seed trains a different (but still accurate) gate.
        let c = train_gate(&ex, 2, 500, 8);
        assert!(c.accuracy > 0.5);
    }

    #[test]
    fn oracle_expert_wraps_round_robin_and_ignores_other_bin() {
        let mut f = [0.0f32; N_FEATURES];
        f[5] = 0.6;
        f[N_FEATURES - 1] = 0.4;
        assert_eq!(oracle_expert(&f, 8), 5);
        assert_eq!(oracle_expert(&f, 3), 2);
        assert_eq!(oracle_expert(&f, 0), 0);
    }
}
