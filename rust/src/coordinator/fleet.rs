//! Fleet serving (DESIGN.md §14): N worker replicas behind one
//! affinity-routing front end.
//!
//! Each replica owns its own resident [`WeightStore`] and [`Router`] —
//! the PR 5 per-request machinery unchanged — while all replicas share
//! ONE [`AdapterStore`] (so an adapter decodes once fleet-wide, and one
//! plan cache serves every replica) and one [`ThreadPool`], both behind
//! `Arc`.  A request is routed to the replica where its [`Selection`]
//! is cheapest to reach, down the affinity cost ladder:
//!
//! 1. **exact** — the selection is already resident on the replica;
//! 2. **plan** — the replica is live on a single adapter with a
//!    resident pairwise transition plan to the incoming single
//!    (the PR 4 one-pass path);
//! 3. **warm** — every adapter the selection names is decoded in the
//!    shared cache (base counts as warm everywhere: zero names);
//! 4. **cold** — somebody has to fetch.
//!
//! Ties break deterministically on (cost, queue length, replica id).
//! Quarantined replicas and replicas at their queue bound are excluded;
//! when every replica with queue room is health-excluded the request
//! backoff-requeues until a quarantine TTL expires
//! ([`Placement::AllQuarantined`]), and only a genuinely full fleet
//! ([`Placement::Full`]) sheds to the configured [`FailurePolicy`].
//!
//! ## Self-healing (DESIGN.md §16)
//!
//! Replica health is a state machine, not a sticky flag:
//! Healthy → Suspect (failures below the threshold) → Quarantined
//! (TTL with exponential backoff per re-quarantine) → Probation (one
//! canary request after a bit-verified recovery pass) → Healthy.  On
//! quarantine the replica's queue is drained and re-dispatched to
//! healthy replicas under a per-request deadline + retry budget; when
//! the TTL expires the replica reverts to base, re-syncs its resident
//! weights, and must pass the BitOracle's bit-identity gate before the
//! scheduler offers it a canary.
//!
//! ## Determinism harness
//!
//! [`Fleet::run_trace`] is the seeded deterministic scheduler: a
//! single-threaded virtual-time loop in which every nondeterministic
//! choice (how many queue-drain steps run after each ingest, which busy
//! replica drains next) comes from one [`Rng`] stream, on top of the
//! PR 6 fault-injection ordinal mechanism — one shared
//! [`FaultInjector`](super::fault::FaultInjector) is armed across the
//! store and every replica, so its per-site ordinals fire at the same
//! global points on every replay.  Any interleaving therefore replays
//! from `(trace seed, schedule seed, fault seed)` alone.
//!
//! A per-request **bit-identity oracle** rides along: a fault-free
//! serial reference (its own [`Router`] over a
//! [`fork_reference`](AdapterStore::fork_reference) of the shared
//! store) materializes the reference bytes for every selection key, and
//! after every apply the harness checks EVERY replica's resident
//! weights against the reference for its active key — which is exactly
//! the rollback-isolation assertion: a fault on one replica can never
//! perturb another replica's resident bytes.
//!
//! [`Fleet::run_trace_concurrent`] runs the same components for real:
//! bounded `sync_channel` queues into `std::thread::scope` workers.
//! Scheduling there is OS-nondeterministic, so the oracle checks each
//! replica against the serial reference after its own applies and
//! cross-checks the whole fleet once the workers join.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::engine::Router;
use super::error::ServeError;
use super::fault::{FaultInjector, FaultPlan, FaultSite};
use super::gate::{request_features, Gate};
use super::metrics::FairnessLedger;
use super::pool::{lock_pool, SharedExpertPool};
use super::selection::Selection;
use super::server::FailurePolicy;
use super::store::{AdapterStore, StoreConfig, StoreStats};
use super::switch::SwitchPath;
use crate::adapter::{LoraAdapter, ShiraAdapter};
use crate::data::trace::Request;
use crate::model::weights::WeightStore;
use crate::util::rng::Rng;
use crate::util::stats::Sample;
use crate::util::threadpool::ThreadPool;

/// Lock a mutex, adopting the data even when a peer holding it
/// panicked.  Fleet state is re-validated by the oracle after every
/// apply and the routers keep their own transactional guard, so a
/// poisoned lock carries no information a recovery path needs.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Replica health states (DESIGN.md §16).  The legal transitions are
/// Healthy → Suspect (a failure below the quarantine threshold),
/// Suspect → Quarantined (threshold reached), Quarantined → Probation
/// (TTL expired and the recovery pass landed bit-verified base
/// weights), Probation → Healthy (canary served, or a failure-free
/// probation window elapsed) and Probation → Quarantined (the canary
/// failed; the TTL doubles per re-quarantine, capped at 2^6x).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Serving normally.
    Healthy,
    /// One or more recent failures, still below the quarantine
    /// threshold; routes normally.
    Suspect,
    /// Refusing all traffic until the quarantine TTL expires.
    Quarantined,
    /// Recovered and bit-verified; admitted one canary request at a
    /// time until a success (or a quiet probation window) re-promotes.
    Probation,
}

impl HealthState {
    /// Stable label for reports and summaries.
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Suspect => "suspect",
            HealthState::Quarantined => "quarantined",
            HealthState::Probation => "probation",
        }
    }
}

/// Backoff hint the scheduler returns when only probation-capped
/// replicas were excluded (no quarantine TTL to wait out — just the
/// in-flight canary), microseconds.
const PROBATION_RETRY_US: u64 = 50;

/// Largest exponent the quarantine-TTL backoff may reach (TTL << 6 =
/// 64x the base TTL); keeps repeated re-quarantines from overflowing
/// the virtual clock.
const MAX_TTL_SHIFT: u32 = 6;

/// Per-replica health state machine (DESIGN.md §16): consecutive
/// failures, quarantine trips with exponential TTL backoff, and the
/// probe/recovery counters the report surfaces.
#[derive(Clone, Debug)]
struct ReplicaHealth {
    state: HealthState,
    failures_in_row: u32,
    /// Quarantine trips so far — drives the exponential TTL backoff.
    trips: u64,
    /// Clock (us) at which the current quarantine expires into a probe.
    until_us: u64,
    /// Clock (us) at which the current probation began.
    probation_since_us: u64,
    /// Probes: quarantine TTLs that expired into a recovery pass.
    probes: u64,
    /// Recoveries: probations promoted back to Healthy.
    recoveries: u64,
}

impl ReplicaHealth {
    fn new() -> Self {
        ReplicaHealth {
            state: HealthState::Healthy,
            failures_in_row: 0,
            trips: 0,
            until_us: 0,
            probation_since_us: 0,
            probes: 0,
            recoveries: 0,
        }
    }

    /// Remaining quarantine TTL at `now_us` (0 unless quarantined).
    fn retry_in_us(&self, now_us: u64) -> u64 {
        if self.state == HealthState::Quarantined {
            self.until_us.saturating_sub(now_us).max(1)
        } else {
            0
        }
    }

    /// Record a failed apply at `now_us`.  Returns true when this
    /// failure newly quarantined the replica (threshold crossed, or a
    /// probation canary failed) — the caller then drains its queue.
    fn note_failure(&mut self, now_us: u64, threshold: u32, ttl_us: u64) -> bool {
        self.failures_in_row += 1;
        let trip = match self.state {
            // A failed canary re-quarantines immediately.
            HealthState::Probation => true,
            HealthState::Quarantined => false,
            HealthState::Healthy | HealthState::Suspect => {
                self.state = HealthState::Suspect;
                self.failures_in_row >= threshold
            }
        };
        if trip {
            let shift = self.trips.min(u64::from(MAX_TTL_SHIFT));
            self.state = HealthState::Quarantined;
            self.until_us = now_us.saturating_add(ttl_us.max(1) << shift);
            self.trips += 1;
        }
        trip
    }

    /// Record a successful apply: clears the failure streak, and a
    /// probation canary success completes the recovery.
    fn note_success(&mut self) {
        self.failures_in_row = 0;
        if self.state == HealthState::Probation {
            self.recoveries += 1;
        }
        if self.state != HealthState::Quarantined {
            self.state = HealthState::Healthy;
        }
    }

    /// True when the quarantine TTL has expired: the replica may run its
    /// recovery pass and enter probation.
    fn probe_due(&self, now_us: u64) -> bool {
        self.state == HealthState::Quarantined && now_us >= self.until_us
    }

    /// Enter probation at `now_us` (after the recovery pass verified).
    fn begin_probation(&mut self, now_us: u64) {
        self.state = HealthState::Probation;
        self.probation_since_us = now_us;
        self.failures_in_row = 0;
        self.probes += 1;
    }

    /// Promote a failure-free probation back to Healthy once a full
    /// probation window (`window_us`) passed without a canary — so a
    /// recovered replica converges to Healthy even when no more traffic
    /// arrives to serve as the canary.
    fn poll_probation(&mut self, now_us: u64, window_us: u64) {
        if self.state == HealthState::Probation
            && now_us.saturating_sub(self.probation_since_us) >= window_us.max(1)
        {
            self.recoveries += 1;
            self.state = HealthState::Healthy;
        }
    }
}

/// Affinity cost: the selection is already resident on the replica.
const COST_EXACT: u8 = 0;
/// Affinity cost: a resident pairwise transition plan reaches it.
const COST_PLAN: u8 = 1;
/// Affinity cost: every named adapter is decoded in the shared cache.
const COST_WARM: u8 = 2;
/// Affinity cost: at least one adapter must be fetched cold.
const COST_COLD: u8 = 3;

/// One replica's scheduler-visible state: what the affinity router
/// needs to cost a placement, nothing more.  Snapshots are cheap to
/// build from either the deterministic harness (direct field reads) or
/// the concurrent front end (atomics + a small mutex).
#[derive(Clone, Debug)]
pub struct ReplicaView {
    /// Replica index (stable tie-breaker).
    pub id: usize,
    /// Requests queued on the replica (channel + batcher backlog).
    pub queued: usize,
    /// Canonical key of the selection resident on the replica, when one
    /// has been applied.
    pub active_key: Option<String>,
    /// Name of the single adapter the replica's switch path holds, when
    /// it is live in single mode — the `from` side of a pairwise
    /// transition plan.
    pub active_single: Option<String>,
    /// Health state the scheduler must respect: Quarantined replicas
    /// are excluded outright; Probation replicas admit one canary.
    pub health: HealthState,
    /// Remaining quarantine TTL at snapshot time, microseconds (0
    /// unless quarantined) — the backoff hint a health-excluded
    /// placement carries back to the caller.
    pub retry_in_us: u64,
}

/// Cost of making `sel` resident on the replica `view` describes, down
/// the module-level ladder (exact > plan > warm > cold).
fn affinity_cost(view: &ReplicaView, sel: &Selection, key: &str, store: &AdapterStore) -> u8 {
    if view.active_key.as_deref() == Some(key) {
        return COST_EXACT;
    }
    if let Selection::Single { name, .. } = sel {
        if let Some(from) = view.active_single.as_deref() {
            if from != name && store.has_transition_plan(from, name) {
                return COST_PLAN;
            }
        }
    }
    if sel.names().iter().all(|n| store.is_resident(n)) {
        return COST_WARM;
    }
    COST_COLD
}

/// Where the scheduler placed (or refused) a request — the admission
/// decision, with the transient case distinguished from genuine
/// overload so the two are never shed identically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Route to this replica.
    Replica(usize),
    /// Every replica with queue room is health-excluded (waiting out a
    /// quarantine TTL, or probation-capped by its in-flight canary):
    /// transient — backoff-requeue and retry once the earliest TTL
    /// expires, instead of shedding.
    AllQuarantined {
        /// Smallest remaining TTL among the excluding replicas,
        /// microseconds (at least 1).
        retry_in_us: u64,
    },
    /// Every replica's bounded queue is genuinely full: overload —
    /// shed to the configured failure policy.
    Full,
}

/// Pick the replica where `sel` is cheapest to reach, or classify why
/// no replica can take it ([`Placement`]).  Pure over its inputs, so
/// every scheduling decision is replayable and directly
/// property-testable.
///
/// Ties break on `(cost, queued, id)` — strictly deterministic.  With
/// `force_cold` every candidate costs [`COST_COLD`], collapsing the
/// ladder: placement degenerates to least-loaded/lowest-id, which must
/// change only WHERE requests run, never their results.
pub fn pick_replica(
    views: &[ReplicaView],
    sel: &Selection,
    store: &AdapterStore,
    queue_depth: usize,
    force_cold: bool,
) -> Placement {
    let key = sel.key();
    let mut best: Option<(u8, usize, usize)> = None;
    let mut health_excluded = false;
    let mut min_retry = u64::MAX;
    for v in views {
        match v.health {
            HealthState::Quarantined => {
                health_excluded = true;
                min_retry = min_retry.min(v.retry_in_us.max(1));
                continue;
            }
            // Probation admits exactly one in-flight canary request.
            HealthState::Probation if v.queued >= 1 => {
                health_excluded = true;
                min_retry = min_retry.min(PROBATION_RETRY_US);
                continue;
            }
            _ => {}
        }
        if v.queued >= queue_depth {
            continue;
        }
        let cost = if force_cold {
            COST_COLD
        } else {
            affinity_cost(v, sel, &key, store)
        };
        let cand = (cost, v.queued, v.id);
        if best.map(|b| cand < b).unwrap_or(true) {
            best = Some(cand);
        }
    }
    match best {
        Some((_, _, id)) => Placement::Replica(id),
        None if health_excluded => Placement::AllQuarantined {
            retry_in_us: if min_retry == u64::MAX { 1 } else { min_retry },
        },
        None => Placement::Full,
    }
}

/// The fault-free serial reference the determinism harness checks
/// against: its own [`Router`] over a fork of the shared store's flash
/// (no faults, no cache coupling), materializing reference bytes once
/// per selection key.  The engines' property-tested invariant — serving
/// a selection from ANY prior state lands identical bytes — is what
/// makes a by-key cache sound.
struct BitOracle {
    store: AdapterStore,
    router: Router,
    refs: HashMap<String, WeightStore>,
    base: WeightStore,
    checks: u64,
    failures: Vec<String>,
}

impl BitOracle {
    /// Materialize (or recall) the reference weights for `sel`.
    fn reference(&mut self, sel: &Selection) {
        let key = sel.key();
        if self.refs.contains_key(&key) {
            return;
        }
        match self.router.apply(&mut self.store, sel) {
            Ok(_) => {
                self.refs.insert(key, self.router.weights().clone());
            }
            Err(e) => self
                .failures
                .push(format!("reference apply failed for {key:?}: {e}")),
        }
    }

    /// Check one replica's resident weights against the reference for
    /// its active key (no key, or the empty base key, checks against
    /// base bytes).
    fn check_replica(&mut self, id: usize, active_key: Option<&str>, weights: &WeightStore) {
        self.checks += 1;
        let key = match active_key {
            None | Some("") => {
                if !weights.bit_equal(&self.base) {
                    self.failures
                        .push(format!("replica {id}: base-state bytes diverge from base"));
                }
                return;
            }
            Some(k) => k,
        };
        match self.refs.get(key) {
            Some(r) if weights.bit_equal(r) => {}
            Some(_) => self.failures.push(format!(
                "replica {id}: resident bytes diverge from the fault-free reference for {key:?}"
            )),
            None => self
                .failures
                .push(format!("replica {id}: no reference for active key {key:?}")),
        }
    }
}

/// One worker replica: its own router (owning its resident weights) and
/// its own affinity batcher, plus virtual-time and health bookkeeping.
struct Replica {
    id: usize,
    router: Router,
    batcher: DynamicBatcher,
    /// Virtual clock, microseconds: when this replica next becomes free.
    clock_us: u64,
    served: u64,
    health: ReplicaHealth,
}

/// Mutable run-wide accounting shared by both execution modes.
struct Accum {
    fairness: FairnessLedger,
    waits: Sample,
    /// Terminal disposition per request id ("served",
    /// "degraded-to-base", "skipped", "shed-degraded", "shed-skipped",
    /// "deadline-exceeded") — the per-request outcome record the
    /// acceptance criterion compares across replica counts.  Every
    /// request lands exactly one terminal action: nothing is silently
    /// lost on a drain.
    actions: BTreeMap<u64, &'static str>,
    outcomes: Vec<FleetOutcome>,
    served: u64,
    shed: u64,
    degraded: u64,
    skipped: u64,
    gated: u64,
    requeues: u64,
    deadline_exceeded: u64,
    switches: u64,
    transitions: u64,
    fallbacks: u64,
    fused: u64,
    oracle: Option<BitOracle>,
}

impl Accum {
    fn new(slo_us: u64, oracle: Option<BitOracle>) -> Accum {
        Accum {
            fairness: FairnessLedger::new(slo_us),
            waits: Sample::new(),
            actions: BTreeMap::new(),
            outcomes: Vec::new(),
            served: 0,
            shed: 0,
            degraded: 0,
            skipped: 0,
            gated: 0,
            requeues: 0,
            deadline_exceeded: 0,
            switches: 0,
            transitions: 0,
            fallbacks: 0,
            fused: 0,
            oracle,
        }
    }

    fn record_path(&mut self, path: Option<SwitchPath>) {
        match path {
            Some(SwitchPath::Transition) => self.transitions += 1,
            Some(SwitchPath::Fallback) => self.fallbacks += 1,
            Some(SwitchPath::Fused) => self.fused += 1,
            None => {}
        }
    }

    /// Fold the gate-resolution pass's accounting in before placement
    /// starts.  Pre-assigned actions are terminal for gate-skipped
    /// requests; "gate-degraded-to-base" survives serving because the
    /// serve paths only `or_insert` their "served" label.
    fn fold_resolution(&mut self, res: &GateResolution) {
        self.gated += res.gated;
        self.degraded += res.degraded;
        self.skipped += res.skipped;
        for &(id, action) in &res.actions {
            self.actions.insert(id, action);
        }
        self.outcomes.extend(res.outcomes.iter().cloned());
    }
}

/// Outcome of the gate-resolution pass.  Both execution modes run it up
/// front on the ingest thread — before any batching, placement or
/// worker spawns — so gating is deterministic regardless of thread
/// count and the placed trace never contains a [`Selection::Auto`].
struct GateResolution {
    /// The trace with every auto rewritten explicit (gate-skipped
    /// requests removed).
    requests: Vec<Request>,
    gated: u64,
    degraded: u64,
    skipped: u64,
    /// Dispositions assigned at resolution time, per request id.
    actions: Vec<(u64, &'static str)>,
    outcomes: Vec<FleetOutcome>,
}

/// A request waiting out a retry/requeue backoff in the deterministic
/// harness's virtual time.
struct PendingRetry {
    /// Virtual instant at which the request re-dispatches.
    ready_us: u64,
    /// Re-dispatch attempts already consumed.
    attempts: u32,
    req: Request,
}

/// Deterministic-mode run state: the virtual front-end clock plus the
/// drain-and-requeue bookkeeping (DESIGN.md §16).
struct DetState {
    /// Virtual front-end clock, microseconds: the max of arrivals seen
    /// and replica completion times — what deadlines, backoffs and
    /// quarantine TTLs measure against.
    now_us: u64,
    /// Requests parked behind a backoff, awaiting re-dispatch.
    pending: Vec<PendingRetry>,
    /// Re-dispatch attempts consumed per queued request id.
    attempts: HashMap<u64, u32>,
}

/// How one failed or shed batch was handled under the failure policy —
/// the fleet's analogue of
/// [`RequestOutcome`](super::server::RequestOutcome).
#[derive(Clone, Debug)]
pub struct FleetOutcome {
    /// Canonical key of the affected selection.
    pub selection: String,
    /// Requests in the affected batch (1 for admission sheds).
    pub requests: u64,
    /// Replica involved, or `None` for admission-control sheds and
    /// deadline expiries.
    pub replica: Option<usize>,
    /// Terminal: `"degraded-to-base"`, `"skipped"`, `"shed-degraded"`,
    /// `"shed-skipped"`, `"gate-skipped"` or `"deadline-exceeded"`.
    /// Non-terminal: `"requeued"` (the requests re-dispatch and land a
    /// later terminal outcome) and `"gate-degraded-to-base"` (the
    /// request continues on base weights).
    pub action: &'static str,
    /// Display form of the triggering error.
    pub error: String,
}

/// End-of-run report for one fleet trace.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Worker replicas in the fleet.
    pub replicas: usize,
    /// Requests in the trace.
    pub requests: u64,
    /// Requests served (including degraded ones).
    pub served: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Requests served on base weights after their selection failed.
    pub degraded: u64,
    /// Requests dropped.
    pub skipped: u64,
    /// [`Selection::Auto`] requests the gate resolved into explicit
    /// selections before placement.
    pub gated: u64,
    /// Lifetime served counts per expert from the shared
    /// [`ExpertPool`](super::pool::ExpertPool), sorted by name (empty
    /// when the fleet has no pool).
    pub expert_utilization: Vec<(String, u64)>,
    /// Selection switches across all replicas.
    pub switches: u64,
    /// Switches that took the one-pass direct transition path.
    pub transitions: u64,
    /// Switches that fell back to revert+apply.
    pub fallbacks: u64,
    /// Switches served by the incremental fused-mode engine.
    pub fused_switches: u64,
    /// Failed mutations rolled back to base across all replicas
    /// (including routers rebuilt during recovery).
    pub rollbacks: u64,
    /// Requests re-dispatched after a failure, a quarantine drain, or
    /// an all-quarantined backoff.
    pub requeues: u64,
    /// Requests whose end-to-end deadline elapsed unserved.
    pub deadline_exceeded: u64,
    /// Quarantine trips across all replicas (a replica re-quarantined
    /// twice counts twice).
    pub quarantine_trips: u64,
    /// Quarantine TTL expiries that ran a recovery pass.
    pub probes: u64,
    /// Probations promoted back to Healthy.
    pub recoveries: u64,
    /// Final health state per replica, in id order (names from
    /// [`HealthState::name`]).
    pub replica_health: Vec<&'static str>,
    /// Replicas still quarantined at end of run.
    pub quarantined_replicas: usize,
    /// Requests served per replica (placement distribution).
    pub per_replica_served: Vec<u64>,
    /// Bit-identity oracle comparisons performed.
    pub oracle_checks: u64,
    /// Oracle divergences (one line each; empty = bit-identical).
    pub oracle_failures: Vec<String>,
    /// Median queueing wait (virtual time), microseconds.
    pub p50_wait_us: f64,
    /// 99th-percentile queueing wait (virtual time), microseconds.
    pub p99_wait_us: f64,
    /// Largest replica virtual clock at end of run, microseconds.
    pub makespan_us: u64,
    /// Terminal disposition per request id — the per-request outcome
    /// record compared bit-for-bit across replica counts.
    pub actions: BTreeMap<u64, &'static str>,
    /// One entry per failed or shed batch the policy handled.
    pub outcomes: Vec<FleetOutcome>,
    /// Per-selection fairness/SLO ledger.
    pub fairness: FairnessLedger,
    /// Shared adapter-store lifecycle counters.
    pub store: StoreStats,
    /// Human-readable multi-line summary.
    pub summary: String,
}

/// Builder for [`Fleet`], mirroring
/// [`ServerBuilder`](super::server::ServerBuilder) — but runtime-free:
/// a fleet operates at the routing/weights level (no PJRT artifacts),
/// so the determinism harness, the chaos tests and the bench gate all
/// run in CI.
///
/// Defaults: 2 replicas, queue depth 16, [`StoreConfig::default`],
/// [`BatcherConfig::default`], no pool, fail-fast policy, SLO
/// disabled, 50us virtual service time, quarantine after 3 consecutive
/// failures, 250ms base quarantine TTL, retry budget 3 with 100us base
/// backoff, deadline disabled, oracle on, force-cold off.
pub struct FleetBuilder {
    base: WeightStore,
    replicas: usize,
    queue_depth: usize,
    store_cfg: StoreConfig,
    batcher_cfg: BatcherConfig,
    pool: Option<Arc<ThreadPool>>,
    shira: Vec<ShiraAdapter>,
    lora: Vec<LoraAdapter>,
    unfused_lora: bool,
    failure_policy: FailurePolicy,
    fault_plan: Option<FaultPlan>,
    slo_us: u64,
    service_us: u64,
    quarantine_after: u32,
    quarantine_ttl_us: u64,
    deadline_us: u64,
    retry_budget: u32,
    retry_backoff_us: u64,
    oracle: bool,
    force_cold: bool,
    gate: Option<Arc<dyn Gate>>,
    expert_pool: Option<SharedExpertPool>,
}

impl FleetBuilder {
    /// Worker replicas (clamped to at least 1).
    pub fn replicas(mut self, n: usize) -> Self {
        self.replicas = n;
        self
    }

    /// Per-replica queue bound (clamped to at least 1): requests beyond
    /// it are shed to the failure policy.
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = n;
        self
    }

    /// Full shared-store configuration (cache budgets, format, prefetch
    /// depth, retry/quarantine tunables).
    pub fn store_config(mut self, cfg: StoreConfig) -> Self {
        self.store_cfg = cfg;
        self
    }

    /// Per-replica batcher tunables.
    pub fn batcher_config(mut self, cfg: BatcherConfig) -> Self {
        self.batcher_cfg = cfg;
        self
    }

    /// Thread pool shared by the store's prefetch and every replica's
    /// engine waves.
    pub fn pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Register SHiRA adapters on the shared store's flash tier.
    pub fn shira_adapters(mut self, zoo: &[ShiraAdapter]) -> Self {
        self.shira.extend(zoo.iter().cloned());
        self
    }

    /// Register LoRA adapters on the shared store's flash tier.
    pub fn lora_adapters(mut self, zoo: &[LoraAdapter]) -> Self {
        self.lora.extend(zoo.iter().cloned());
        self
    }

    /// Serve LoRA singles unfused (branches on the forward pass).
    pub fn unfused_lora(mut self, on: bool) -> Self {
        self.unfused_lora = on;
        self
    }

    /// What to do with failed batches and shed requests.
    pub fn failure_policy(mut self, policy: FailurePolicy) -> Self {
        self.failure_policy = policy;
        self
    }

    /// Arm ONE deterministic fault plan across the shared store and
    /// every replica's engines: per-site ordinals count fleet-wide, so
    /// a seeded plan fires at the same global points on every replay.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Queueing-wait SLO for the fairness ledger, microseconds (0
    /// disables violation counting).
    pub fn slo_us(mut self, us: u64) -> Self {
        self.slo_us = us;
        self
    }

    /// Virtual service time per request, microseconds (clamped to at
    /// least 1) — what the deterministic harness charges a replica's
    /// clock for each served request.
    pub fn service_us(mut self, us: u64) -> Self {
        self.service_us = us;
        self
    }

    /// Consecutive failed applies before a replica is quarantined
    /// (clamped to at least 1).
    pub fn quarantine_after(mut self, n: u32) -> Self {
        self.quarantine_after = n;
        self
    }

    /// Base replica-quarantine TTL, microseconds (clamped to at least
    /// 1): how long a quarantined replica sits out before its recovery
    /// pass and probation.  Doubles per re-quarantine up to 64x, and
    /// doubles as the failure-free probation window.
    pub fn replica_quarantine_ttl_us(mut self, us: u64) -> Self {
        self.quarantine_ttl_us = us;
        self
    }

    /// End-to-end request deadline, microseconds (0 disables): a
    /// request still unserved this long after arrival is declared
    /// [`ServeError::DeadlineExceeded`] instead of retrying forever —
    /// virtual time under [`Fleet::run_trace`], wall time under
    /// [`Fleet::run_trace_concurrent`].
    pub fn deadline_us(mut self, us: u64) -> Self {
        self.deadline_us = us;
        self
    }

    /// Re-dispatch attempts one request may consume (after apply
    /// failures or quarantine drains) before the failure policy takes
    /// over.  Backoff between attempts is exponential.
    pub fn retry_budget(mut self, n: u32) -> Self {
        self.retry_budget = n;
        self
    }

    /// Base backoff between re-dispatch attempts, microseconds
    /// (clamped to at least 1; doubles per attempt already consumed).
    pub fn retry_backoff_us(mut self, us: u64) -> Self {
        self.retry_backoff_us = us;
        self
    }

    /// Enable/disable the per-request bit-identity oracle (on by
    /// default; benches disable it for timed runs after gating).
    pub fn oracle(mut self, on: bool) -> Self {
        self.oracle = on;
        self
    }

    /// Treat every placement as cold: collapses the affinity ladder so
    /// routing degenerates to least-loaded/lowest-id.  Placement
    /// changes; per-request results must not (property-tested).
    pub fn force_cold(mut self, on: bool) -> Self {
        self.force_cold = on;
        self
    }

    /// Gate that resolves [`Selection::Auto`] requests into explicit
    /// selections before placement (see
    /// [`gate`](super::gate)).  Without one, auto requests fail with a
    /// `"gate"`-kind error under the failure policy.
    pub fn gate(mut self, gate: Arc<dyn Gate>) -> Self {
        self.gate = Some(gate);
        self
    }

    /// Expert pool whose active roster the gate scores over; resolved
    /// selections count per-expert utilization on it.  Shareable with a
    /// [`Server`](super::server::Server) and with management code that
    /// registers/retires experts while traffic flows.
    pub fn expert_pool(mut self, pool: SharedExpertPool) -> Self {
        self.expert_pool = Some(pool);
        self
    }

    /// Assemble the fleet: one shared store, N replica routers over
    /// clones of the base weights, one optional fault injector armed
    /// across all of them.
    pub fn build(self) -> Fleet {
        let n = self.replicas.max(1);
        let mut store = AdapterStore::with_config(self.store_cfg, self.pool.clone());
        for a in &self.shira {
            store.add_shira(a);
        }
        for a in &self.lora {
            store.add_lora(a);
        }
        let injector = self.fault_plan.map(FaultPlan::injector);
        if let Some(f) = &injector {
            store.set_fault(Arc::clone(f));
        }
        let mut replicas = Vec::with_capacity(n);
        for id in 0..n {
            let mut router = Router::new(self.base.clone(), self.pool.clone(), self.unfused_lora);
            if let Some(f) = &injector {
                router.set_fault(Arc::clone(f));
            }
            replicas.push(Replica {
                id,
                router,
                batcher: DynamicBatcher::new(self.batcher_cfg.clone()),
                clock_us: 0,
                served: 0,
                health: ReplicaHealth::new(),
            });
        }
        Fleet {
            store: Arc::new(Mutex::new(store)),
            replicas,
            base: self.base,
            pool: self.pool,
            injector,
            queue_depth: self.queue_depth.max(1),
            failure_policy: self.failure_policy,
            slo_us: self.slo_us,
            service_us: self.service_us.max(1),
            quarantine_after: self.quarantine_after.max(1),
            quarantine_ttl_us: self.quarantine_ttl_us.max(1),
            deadline_us: self.deadline_us,
            retry_budget: self.retry_budget,
            retry_backoff_us: self.retry_backoff_us.max(1),
            carried_rollbacks: 0,
            oracle: self.oracle,
            force_cold: self.force_cold,
            unfused_lora: self.unfused_lora,
            gate: self.gate,
            expert_pool: self.expert_pool,
        }
    }
}

/// A concurrent serving front end over N worker replicas (module docs;
/// DESIGN.md §14).  Built with [`Fleet::builder`]; driven either by the
/// seeded deterministic harness ([`Fleet::run_trace`]) or for real
/// through MPSC queues and scoped threads
/// ([`Fleet::run_trace_concurrent`]).
pub struct Fleet {
    store: Arc<Mutex<AdapterStore>>,
    replicas: Vec<Replica>,
    base: WeightStore,
    /// Retained so recovery can rebuild a wedged replica's router.
    pool: Option<Arc<ThreadPool>>,
    /// Retained so a rebuilt router re-arms the SAME injector (per-site
    /// ordinals stay fleet-global across rebuilds).
    injector: Option<Arc<FaultInjector>>,
    queue_depth: usize,
    failure_policy: FailurePolicy,
    slo_us: u64,
    service_us: u64,
    quarantine_after: u32,
    quarantine_ttl_us: u64,
    deadline_us: u64,
    retry_budget: u32,
    retry_backoff_us: u64,
    /// Rollback counts carried over from routers replaced during
    /// recovery, so the report never undercounts.
    carried_rollbacks: u64,
    oracle: bool,
    force_cold: bool,
    unfused_lora: bool,
    /// Resolves [`Selection::Auto`] requests before placement.
    gate: Option<Arc<dyn Gate>>,
    /// Roster the gate scores over; counts per-expert utilization.
    expert_pool: Option<SharedExpertPool>,
}

impl Fleet {
    /// Builder over `base` weights (each replica serves its own clone).
    pub fn builder(base: WeightStore) -> FleetBuilder {
        FleetBuilder {
            base,
            replicas: 2,
            queue_depth: 16,
            store_cfg: StoreConfig::default(),
            batcher_cfg: BatcherConfig::default(),
            pool: None,
            shira: Vec::new(),
            lora: Vec::new(),
            unfused_lora: false,
            failure_policy: FailurePolicy::default(),
            fault_plan: None,
            slo_us: 0,
            service_us: 50,
            quarantine_after: 3,
            quarantine_ttl_us: 250_000,
            deadline_us: 0,
            retry_budget: 3,
            retry_backoff_us: 100,
            oracle: true,
            force_cold: false,
            gate: None,
            expert_pool: None,
        }
    }

    /// Worker replicas in the fleet.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// The replicas' routers, in id order — each exposes its resident
    /// weights and active key for end-state assertions.
    pub fn routers(&self) -> impl Iterator<Item = &Router> {
        self.replicas.iter().map(|r| &r.router)
    }

    /// Handle on the shared adapter store (pin audits, stats).
    pub fn store(&self) -> Arc<Mutex<AdapterStore>> {
        Arc::clone(&self.store)
    }

    /// Restore every replica to base weights, release every pin, and
    /// drop all queued requests.
    pub fn revert_all(&mut self) {
        let mut store = relock(&self.store);
        for rep in &mut self.replicas {
            rep.router.revert_all(&mut store);
            rep.batcher.clear();
        }
    }

    /// Resolve one auto request: fire any planned gate fault, score the
    /// pool's roster with the gate, count utilization.  Pure in the
    /// payload seed — the same seed over the same roster always yields
    /// the same selection, on any replica count.
    fn resolve_auto(&mut self, payload_seed: u64) -> Result<Selection, ServeError> {
        if let Some(f) = &self.injector {
            if f.should_fire(FaultSite::Gate) {
                return Err(ServeError::Gate {
                    reason: FaultInjector::GATE_FAULT_MSG.to_string(),
                });
            }
        }
        let gate = self.gate.as_ref().ok_or_else(|| ServeError::Gate {
            reason: "no gate configured (auto selections need a gate)".into(),
        })?;
        let pool = self.expert_pool.as_ref().ok_or_else(|| ServeError::Gate {
            reason: "no expert pool configured (auto selections need one)"
                .into(),
        })?;
        let roster = lock_pool(pool).roster();
        let sel = gate.select(&request_features(payload_seed), &roster)?;
        lock_pool(pool).record_served(&sel.names());
        Ok(sel)
    }

    /// The gate-resolution pass, policy-aware: autos resolve to explicit
    /// selections; on a gate failure `FailFast` surfaces the error
    /// (nothing has been queued yet, so a plain `Err` is clean),
    /// `DegradeToBase` rewrites to [`Selection::Base`], `SkipRequest`
    /// drops the request with a terminal disposition.
    fn resolve(&mut self, trace: &[Request]) -> Result<GateResolution, ServeError> {
        let mut res = GateResolution {
            requests: Vec::with_capacity(trace.len()),
            gated: 0,
            degraded: 0,
            skipped: 0,
            actions: Vec::new(),
            outcomes: Vec::new(),
        };
        for r in trace {
            if !matches!(r.selection, Selection::Auto) {
                res.requests.push(r.clone());
                continue;
            }
            match self.resolve_auto(r.payload_seed) {
                Ok(sel) => {
                    res.gated += 1;
                    let mut rr = r.clone();
                    rr.selection = sel;
                    res.requests.push(rr);
                }
                Err(e) => match self.failure_policy {
                    FailurePolicy::FailFast => return Err(e),
                    FailurePolicy::DegradeToBase => {
                        res.degraded += 1;
                        res.actions.push((r.id, "gate-degraded-to-base"));
                        res.outcomes.push(FleetOutcome {
                            selection: Selection::Auto.key(),
                            requests: 1,
                            replica: None,
                            action: "gate-degraded-to-base",
                            error: e.to_string(),
                        });
                        let mut rr = r.clone();
                        rr.selection = Selection::Base;
                        res.requests.push(rr);
                    }
                    FailurePolicy::SkipRequest => {
                        res.skipped += 1;
                        res.actions.push((r.id, "gate-skipped"));
                        res.outcomes.push(FleetOutcome {
                            selection: Selection::Auto.key(),
                            requests: 1,
                            replica: None,
                            action: "gate-skipped",
                            error: e.to_string(),
                        });
                    }
                },
            }
        }
        Ok(res)
    }

    /// Rewrite every [`Selection::Auto`] in `trace` into the gate's
    /// explicit selection — the same rewrite both run modes perform
    /// before placement.  Public so replay tests can serve the returned
    /// explicit trace and compare resident weights and placement
    /// bit-for-bit against the auto-served run.
    pub fn resolve_trace(&mut self, trace: &[Request]) -> Result<Vec<Request>, ServeError> {
        Ok(self.resolve(trace)?.requests)
    }

    /// Scheduler-visible snapshot of every replica (deterministic mode
    /// reads the live structs directly) at virtual time `now_us`.
    fn views(&self, now_us: u64) -> Vec<ReplicaView> {
        self.replicas
            .iter()
            .map(|r| ReplicaView {
                id: r.id,
                queued: r.batcher.pending(),
                active_key: r.router.active_key().map(str::to_string),
                active_single: r.router.active_single().map(str::to_string),
                health: r.health.state,
                retry_in_us: r.health.retry_in_us(now_us),
            })
            .collect()
    }

    /// Build the fault-free serial reference for the oracle.
    fn make_oracle(&self) -> BitOracle {
        let store = relock(&self.store).fork_reference();
        BitOracle {
            store,
            router: Router::new(self.base.clone(), None, self.unfused_lora),
            refs: HashMap::new(),
            base: self.base.clone(),
            checks: 0,
            failures: Vec::new(),
        }
    }

    /// Run `trace` through the seeded deterministic scheduler (module
    /// docs): single-threaded, virtual-time, every interleaving choice
    /// drawn from `seed`'s stream — so any failing seed replays its
    /// exact interleaving, and the bit-identity oracle checks every
    /// replica after every apply.
    pub fn run_trace(&mut self, trace: &[Request], seed: u64) -> Result<FleetReport, ServeError> {
        for q in trace {
            q.selection.validate()?;
        }
        let resolved = self.resolve(trace)?;
        let mut rng = Rng::new(seed).stream("fleet/schedule");
        let oracle = if self.oracle {
            Some(self.make_oracle())
        } else {
            None
        };
        let mut acc = Accum::new(self.slo_us, oracle);
        acc.fold_resolution(&resolved);
        let mut rs = DetState {
            now_us: 0,
            pending: Vec::new(),
            attempts: HashMap::new(),
        };
        for q in &resolved.requests {
            rs.now_us = rs.now_us.max(q.arrival_us);
            self.poll_health(&mut rs, &mut acc);
            self.flush_due(&mut rs, &mut acc)?;
            self.dispatch(q.clone(), 0, &mut rs, &mut acc)?;
            let steps = rng.below(self.replicas.len() + 1);
            for _ in 0..steps {
                if !self.drain_one(&mut rng, &mut rs, &mut acc)? {
                    break;
                }
            }
        }
        // Settle: serve the backlog, re-dispatch requeued requests, and
        // walk every quarantined replica through probe → probation →
        // healthy, warping virtual time to the next due event whenever
        // the fleet would otherwise stall.  Terminates because every
        // failure-requeue consumes finite retry budget, every backoff
        // is strictly in the future, and probation idle-promotes.
        loop {
            while self.drain_one(&mut rng, &mut rs, &mut acc)? {}
            self.poll_health(&mut rs, &mut acc);
            self.flush_due(&mut rs, &mut acc)?;
            if self.replicas.iter().any(|r| !r.batcher.is_empty()) {
                continue;
            }
            let settled = rs.pending.is_empty()
                && self.replicas.iter().all(|r| {
                    matches!(r.health.state, HealthState::Healthy | HealthState::Suspect)
                });
            if settled {
                break;
            }
            rs.now_us = self.next_event_us(&rs).max(rs.now_us + 1);
        }
        Ok(self.finish(acc, trace.len() as u64))
    }

    /// Earliest virtual instant at which anything can change: a retry
    /// backoff elapses, a quarantine TTL expires, or a probation window
    /// closes.
    fn next_event_us(&self, rs: &DetState) -> u64 {
        let mut next = u64::MAX;
        for p in &rs.pending {
            next = next.min(p.ready_us);
        }
        for rep in &self.replicas {
            match rep.health.state {
                HealthState::Quarantined => next = next.min(rep.health.until_us),
                HealthState::Probation => {
                    next = next.min(
                        rep.health
                            .probation_since_us
                            .saturating_add(self.quarantine_ttl_us.max(1)),
                    );
                }
                _ => {}
            }
        }
        if next == u64::MAX {
            0
        } else {
            next
        }
    }

    /// Probe every replica whose quarantine TTL expired (running its
    /// recovery pass) and promote failure-free probations.
    fn poll_health(&mut self, rs: &mut DetState, acc: &mut Accum) {
        for r in 0..self.replicas.len() {
            if self.replicas[r].health.probe_due(rs.now_us) {
                self.recover_replica(r, rs.now_us, acc);
            }
            self.replicas[r]
                .health
                .poll_probation(rs.now_us, self.quarantine_ttl_us);
        }
    }

    /// Recovery pass (DESIGN.md §16): the quarantine TTL expired, so
    /// revert the replica to base via its transactional router, re-sync
    /// its resident weights from the shared store, and verify the
    /// result bit-identical before probation admits a canary.  A router
    /// whose bytes still diverge is rebuilt from pristine base weights
    /// (its rollback count carries into the report) with the SAME fault
    /// injector re-armed.
    fn recover_replica(&mut self, r: usize, now_us: u64, acc: &mut Accum) {
        {
            let mut store = relock(&self.store);
            let rep = &mut self.replicas[r];
            if rep.router.apply(&mut store, &Selection::Base).is_err() {
                // The transactional guard already rolled the weights
                // back; revert_all additionally releases every pin the
                // wedged apply may still hold.
                rep.router.revert_all(&mut store);
            }
        }
        if !self.replicas[r].router.weights().bit_equal(&self.base) {
            self.carried_rollbacks += self.replicas[r].router.rollbacks();
            let mut router = Router::new(self.base.clone(), self.pool.clone(), self.unfused_lora);
            if let Some(f) = &self.injector {
                router.set_fault(Arc::clone(f));
            }
            self.replicas[r].router = router;
        }
        self.replicas[r].health.begin_probation(now_us);
        // The bit-identity gate: a recovered replica may not rejoin the
        // rotation unless its resident bytes match the fault-free
        // reference.
        if let Some(oracle) = acc.oracle.as_mut() {
            let rep = &self.replicas[r];
            oracle.check_replica(rep.id, rep.router.active_key(), rep.router.weights());
        }
    }

    /// Re-dispatch every pending retry whose backoff elapsed, in
    /// deterministic (ready time, request id) order.
    fn flush_due(&mut self, rs: &mut DetState, acc: &mut Accum) -> Result<(), ServeError> {
        loop {
            let due = rs
                .pending
                .iter()
                .enumerate()
                .filter(|(_, p)| p.ready_us <= rs.now_us)
                .min_by_key(|(_, p)| (p.ready_us, p.req.id))
                .map(|(i, _)| i);
            let Some(i) = due else { return Ok(()) };
            let p = rs.pending.swap_remove(i);
            self.dispatch(p.req, p.attempts, rs, acc)?;
        }
    }

    /// Route one request (fresh from the trace, or re-dispatched after
    /// a failure or backoff) with `attempts` re-dispatch attempts
    /// already consumed: enforce the end-to-end deadline, backoff-
    /// requeue on a health-excluded fleet, and shed to the failure
    /// policy only on genuine overload.
    fn dispatch(
        &mut self,
        req: Request,
        attempts: u32,
        rs: &mut DetState,
        acc: &mut Accum,
    ) -> Result<(), ServeError> {
        if self.deadline_us > 0
            && rs.now_us >= req.arrival_us.saturating_add(self.deadline_us)
        {
            return self.expire(req, attempts, rs, acc);
        }
        let placement = {
            let store = relock(&self.store);
            pick_replica(
                &self.views(rs.now_us),
                &req.selection,
                &store,
                self.queue_depth,
                self.force_cold,
            )
        };
        match placement {
            Placement::Replica(r) => {
                rs.attempts.insert(req.id, attempts);
                self.replicas[r].batcher.push(req);
                Ok(())
            }
            Placement::AllQuarantined { retry_in_us } => {
                // Transient: every queue-room replica is waiting out a
                // TTL (or its canary).  Park without consuming retry
                // budget — the fleet, not the request, is at fault.
                acc.requeues += 1;
                rs.pending.push(PendingRetry {
                    ready_us: rs.now_us.saturating_add(retry_in_us.max(1)),
                    attempts,
                    req,
                });
                Ok(())
            }
            Placement::Full => self.shed(&req, rs.now_us, acc),
        }
    }

    /// Declare a request dead: its end-to-end deadline elapsed before
    /// any replica served it.  Terminal and accounted — never silently
    /// lost.
    fn expire(
        &mut self,
        req: Request,
        attempts: u32,
        rs: &mut DetState,
        acc: &mut Accum,
    ) -> Result<(), ServeError> {
        let key = req.selection.key();
        let err = ServeError::DeadlineExceeded {
            selection: key.clone(),
            deadline_us: self.deadline_us,
            waited_us: rs.now_us.saturating_sub(req.arrival_us),
            attempts,
        };
        if matches!(self.failure_policy, FailurePolicy::FailFast) {
            for rp in &mut self.replicas {
                rp.batcher.clear();
            }
            rs.pending.clear();
            return Err(err);
        }
        acc.deadline_exceeded += 1;
        acc.fairness.record_deadline_exceeded(&key);
        acc.actions.insert(req.id, "deadline-exceeded");
        acc.outcomes.push(FleetOutcome {
            selection: key,
            requests: 1,
            replica: None,
            action: "deadline-exceeded",
            error: err.to_string(),
        });
        Ok(())
    }

    /// Park one request on the deterministic retry queue with the
    /// exponential backoff its attempt count earns (consumes one
    /// attempt).
    fn requeue(&self, req: Request, attempts: u32, key: &str, rs: &mut DetState, acc: &mut Accum) {
        let backoff = self.retry_backoff_us.max(1) << u64::from(attempts.min(16));
        acc.requeues += 1;
        acc.fairness.record_retry(key);
        rs.pending.push(PendingRetry {
            ready_us: rs.now_us.saturating_add(backoff),
            attempts: attempts + 1,
            req,
        });
    }

    /// Admission control: apply the failure policy to a request no
    /// replica can accept.
    fn shed(&mut self, req: &Request, now_us: u64, acc: &mut Accum) -> Result<(), ServeError> {
        let key = req.selection.key();
        match self.failure_policy {
            FailurePolicy::FailFast => Err(ServeError::Overloaded {
                selection: key,
                replicas: self.replicas.len(),
                queue_depth: self.queue_depth,
            }),
            FailurePolicy::DegradeToBase => {
                // Retry the placement as a base request: base is the
                // cheapest selection to make resident anywhere, so this
                // only fails when every queue is genuinely full (a
                // health-excluded replica cannot take base either).
                let target = {
                    let store = relock(&self.store);
                    pick_replica(
                        &self.views(now_us),
                        &Selection::Base,
                        &store,
                        self.queue_depth,
                        self.force_cold,
                    )
                };
                acc.shed += 1;
                acc.fairness.record_shed(&key);
                match target {
                    Placement::Replica(r) => {
                        acc.degraded += 1;
                        acc.actions.insert(req.id, "shed-degraded");
                        acc.outcomes.push(FleetOutcome {
                            selection: key,
                            requests: 1,
                            replica: Some(r),
                            action: "shed-degraded",
                            error: "admission: no replica can take the selection".into(),
                        });
                        let mut base_req = req.clone();
                        base_req.selection = Selection::Base;
                        self.replicas[r].batcher.push(base_req);
                    }
                    Placement::AllQuarantined { .. } | Placement::Full => {
                        acc.skipped += 1;
                        acc.actions.insert(req.id, "shed-skipped");
                        acc.outcomes.push(FleetOutcome {
                            selection: key,
                            requests: 1,
                            replica: None,
                            action: "shed-skipped",
                            error: "admission: all replica queues full".into(),
                        });
                    }
                }
                Ok(())
            }
            FailurePolicy::SkipRequest => {
                acc.shed += 1;
                acc.skipped += 1;
                acc.fairness.record_shed(&key);
                acc.actions.insert(req.id, "shed-skipped");
                acc.outcomes.push(FleetOutcome {
                    selection: key,
                    requests: 1,
                    replica: None,
                    action: "shed-skipped",
                    error: "admission: all replica queues full".into(),
                });
                Ok(())
            }
        }
    }

    /// Serve one batch on one seeded-randomly-chosen busy replica.
    /// Returns false when the whole fleet is idle.
    fn drain_one(
        &mut self,
        rng: &mut Rng,
        rs: &mut DetState,
        acc: &mut Accum,
    ) -> Result<bool, ServeError> {
        let busy: Vec<usize> = self
            .replicas
            .iter()
            .filter(|r| !r.batcher.is_empty())
            .map(|r| r.id)
            .collect();
        if busy.is_empty() {
            return Ok(false);
        }
        let r = busy[rng.below(busy.len())];
        self.serve_one(r, rs, acc)?;
        Ok(true)
    }

    /// Take the next batch on replica `r`, make its selection resident,
    /// account virtual time and fairness, and run the oracle over the
    /// WHOLE fleet (rollback isolation: no other replica's bytes may
    /// have moved).
    fn serve_one(&mut self, r: usize, rs: &mut DetState, acc: &mut Accum) -> Result<(), ServeError> {
        let active = self.replicas[r].router.active_key().map(str::to_string);
        let Some((sel, batch)) = self.replicas[r].batcher.next_batch(active.as_deref()) else {
            return Ok(());
        };
        let key = sel.key();
        // The Apply fault site: a planned replica crash fails the whole
        // apply before it reaches the store — the coarsest failure the
        // self-healing machinery must absorb.
        let crash = self
            .injector
            .as_ref()
            .map(|f| f.should_crash_apply(r))
            .unwrap_or(false);
        let result = if crash {
            Err(ServeError::Runtime(FaultInjector::APPLY_CRASH_MSG.into()))
        } else {
            let mut store = relock(&self.store);
            let depth = store.prefetch_depth();
            if depth > 0 {
                let mut names: Vec<String> = Vec::new();
                for s in self.replicas[r].batcher.upcoming(depth, &[key.as_str()]) {
                    for n in s.names() {
                        if !names.iter().any(|x| x == n) {
                            names.push(n.to_string());
                        }
                    }
                }
                store.prefetch(&names);
            }
            self.replicas[r].router.apply(&mut store, &sel)
        };
        match result {
            Ok(applied) => {
                let rep = &mut self.replicas[r];
                rep.health.note_success();
                if applied.switched {
                    acc.switches += 1;
                    acc.record_path(applied.path);
                }
                let newest = batch.iter().map(|q| q.arrival_us).max().unwrap_or(0);
                let start = rep.clock_us.max(newest);
                for q in &batch {
                    let wait = start.saturating_sub(q.arrival_us);
                    acc.fairness.record_wait(&key, wait);
                    acc.waits.push(wait as f64);
                    acc.actions.entry(q.id).or_insert("served");
                }
                rep.clock_us = start + self.service_us * batch.len() as u64;
                rep.served += batch.len() as u64;
                // Serving advances the front-end clock: deadlines are
                // end-to-end, so queueing delay counts against them.
                rs.now_us = rs.now_us.max(rep.clock_us);
                acc.served += batch.len() as u64;
                self.check_fleet(acc, Some(&sel));
                Ok(())
            }
            Err(e) => self.handle_failure(r, &sel, &batch, e, rs, acc),
        }
    }

    /// Oracle sweep over every replica (plus the fleet-wide plan-pin
    /// audit) after an apply — in the deterministic harness this runs
    /// after failures too, which is exactly the rollback-isolation
    /// assertion.
    fn check_fleet(&mut self, acc: &mut Accum, incoming: Option<&Selection>) {
        let Some(oracle) = acc.oracle.as_mut() else {
            return;
        };
        if let Some(sel) = incoming {
            oracle.reference(sel);
        }
        for rep in &self.replicas {
            oracle.check_replica(rep.id, rep.router.active_key(), rep.router.weights());
        }
        let store = relock(&self.store);
        if store.pinned_plan_count() != 0 {
            oracle
                .failures
                .push("transition-plan pin leaked across an apply".to_string());
        }
    }

    /// Handle a batch whose selection could not be made resident:
    /// advance the replica's health state machine, failover-requeue
    /// every request with retry budget left (exponential backoff,
    /// re-dispatched across replicas), terminate the budget-exhausted
    /// leftovers under the failure policy, and — when this failure
    /// newly quarantined the replica — drain its queue so nothing waits
    /// on a dead replica.  Then re-run the fleet oracle: the failing
    /// replica must be back on base bytes and every OTHER replica's
    /// resident bytes must be untouched.
    fn handle_failure(
        &mut self,
        r: usize,
        sel: &Selection,
        batch: &[Request],
        e: ServeError,
        rs: &mut DetState,
        acc: &mut Accum,
    ) -> Result<(), ServeError> {
        let key = sel.key();
        let newly_quarantined = self.replicas[r].health.note_failure(
            rs.now_us,
            self.quarantine_after,
            self.quarantine_ttl_us,
        );
        if matches!(self.failure_policy, FailurePolicy::FailFast) {
            for rp in &mut self.replicas {
                rp.batcher.clear();
            }
            rs.pending.clear();
            return Err(e);
        }
        let mut leftover: Vec<Request> = Vec::new();
        for q in batch {
            let attempts = rs.attempts.get(&q.id).copied().unwrap_or(0);
            if attempts < self.retry_budget {
                self.requeue(q.clone(), attempts, &key, rs, acc);
            } else {
                leftover.push(q.clone());
            }
        }
        let requeued = (batch.len() - leftover.len()) as u64;
        if requeued > 0 {
            acc.outcomes.push(FleetOutcome {
                selection: key.clone(),
                requests: requeued,
                replica: Some(r),
                action: "requeued",
                error: e.to_string(),
            });
        }
        if !leftover.is_empty() {
            self.exhaust(r, &key, &leftover, &e, acc);
        }
        if newly_quarantined {
            self.drain_replica(r, rs, acc);
        }
        self.check_fleet(acc, None);
        Ok(())
    }

    /// Terminal handling for requests whose retry budget is spent: the
    /// pre-§16 policy arms (degrade to base on this replica, or skip).
    fn exhaust(&mut self, r: usize, key: &str, batch: &[Request], e: &ServeError, acc: &mut Accum) {
        let n = batch.len() as u64;
        match self.failure_policy {
            FailurePolicy::DegradeToBase => {
                let ok = {
                    let mut store = relock(&self.store);
                    self.replicas[r].router.apply(&mut store, &Selection::Base).is_ok()
                };
                let rep = &mut self.replicas[r];
                if ok {
                    let newest = batch.iter().map(|q| q.arrival_us).max().unwrap_or(0);
                    let start = rep.clock_us.max(newest);
                    for q in batch {
                        let wait = start.saturating_sub(q.arrival_us);
                        acc.fairness.record_wait(key, wait);
                        acc.waits.push(wait as f64);
                        acc.actions.insert(q.id, "degraded-to-base");
                    }
                    rep.clock_us = start + self.service_us * n;
                    rep.served += n;
                    acc.served += n;
                    acc.degraded += n;
                } else {
                    for q in batch {
                        acc.actions.insert(q.id, "skipped");
                    }
                    acc.skipped += n;
                }
                acc.outcomes.push(FleetOutcome {
                    selection: key.to_string(),
                    requests: n,
                    replica: Some(r),
                    action: if ok { "degraded-to-base" } else { "skipped" },
                    error: e.to_string(),
                });
            }
            // FailFast exits handle_failure before reaching here; treat
            // it like SkipRequest for safety.
            FailurePolicy::FailFast | FailurePolicy::SkipRequest => {
                for q in batch {
                    acc.actions.insert(q.id, "skipped");
                }
                acc.skipped += n;
                acc.outcomes.push(FleetOutcome {
                    selection: key.to_string(),
                    requests: n,
                    replica: Some(r),
                    action: "skipped",
                    error: e.to_string(),
                });
            }
        }
    }

    /// Drain a newly quarantined replica's queue: every queued request
    /// re-dispatches to the healthy remainder of the fleet (consuming
    /// one attempt), and budget-exhausted ones terminate as skipped —
    /// accounted, never silently lost.
    fn drain_replica(&mut self, r: usize, rs: &mut DetState, acc: &mut Accum) {
        loop {
            let Some((sel, batch)) = self.replicas[r].batcher.next_batch(None) else {
                break;
            };
            let key = sel.key();
            for q in batch {
                let attempts = rs.attempts.get(&q.id).copied().unwrap_or(0);
                if attempts < self.retry_budget {
                    self.requeue(q, attempts, &key, rs, acc);
                } else {
                    acc.skipped += 1;
                    acc.actions.insert(q.id, "skipped");
                    acc.outcomes.push(FleetOutcome {
                        selection: key.clone(),
                        requests: 1,
                        replica: Some(r),
                        action: "skipped",
                        error: "drained from a quarantined replica with no retry budget left"
                            .into(),
                    });
                }
            }
        }
    }

    /// Assemble the end-of-run report.
    fn finish(&mut self, mut acc: Accum, requests: u64) -> FleetReport {
        let store = relock(&self.store).stats();
        let makespan_us = self.replicas.iter().map(|r| r.clock_us).max().unwrap_or(0);
        let rollbacks: u64 = self.carried_rollbacks
            + self
                .replicas
                .iter()
                .map(|r| r.router.rollbacks())
                .sum::<u64>();
        let quarantined = self
            .replicas
            .iter()
            .filter(|r| r.health.state == HealthState::Quarantined)
            .count();
        let quarantine_trips: u64 = self.replicas.iter().map(|r| r.health.trips).sum();
        let probes: u64 = self.replicas.iter().map(|r| r.health.probes).sum();
        let recoveries: u64 = self.replicas.iter().map(|r| r.health.recoveries).sum();
        let replica_health: Vec<&'static str> = self
            .replicas
            .iter()
            .map(|r| r.health.state.name())
            .collect();
        let per_replica_served: Vec<u64> = self.replicas.iter().map(|r| r.served).collect();
        let (oracle_checks, oracle_failures) = match &acc.oracle {
            Some(o) => (o.checks, o.failures.clone()),
            None => (0, Vec::new()),
        };
        let (p50, p99) = if acc.waits.is_empty() {
            (0.0, 0.0)
        } else {
            (acc.waits.percentile(50.0), acc.waits.percentile(99.0))
        };
        let mut summary = format!(
            "fleet: replicas={} requests={} served={} shed={} degraded={} \
             skipped={} deadline_exceeded={} quarantined={}\n\
             switches={} (transition={} fallback={} fused={}) rollbacks={}\n\
             health: trips={} probes={} recoveries={} requeues={} states=[{}]\n\
             wait: p50={:.1}us p99={:.1}us makespan={}us\n\
             oracle: checks={} failures={}",
            self.replicas.len(),
            requests,
            acc.served,
            acc.shed,
            acc.degraded,
            acc.skipped,
            acc.deadline_exceeded,
            quarantined,
            acc.switches,
            acc.transitions,
            acc.fallbacks,
            acc.fused,
            rollbacks,
            quarantine_trips,
            probes,
            recoveries,
            acc.requeues,
            replica_health.join(","),
            p50,
            p99,
            makespan_us,
            oracle_checks,
            oracle_failures.len(),
        );
        if !acc.fairness.is_empty() {
            summary.push('\n');
            summary.push_str(&acc.fairness.summary_lines());
        }
        let expert_utilization = self
            .expert_pool
            .as_ref()
            .map(|p| lock_pool(p).utilization())
            .unwrap_or_default();
        if acc.gated > 0 || !expert_utilization.is_empty() {
            let util: Vec<String> = expert_utilization
                .iter()
                .map(|(name, served)| format!("{name}={served}"))
                .collect();
            summary.push('\n');
            summary.push_str(&format!(
                "gate: gated={} experts=[{}]",
                acc.gated,
                util.join(",")
            ));
        }
        FleetReport {
            replicas: self.replicas.len(),
            requests,
            served: acc.served,
            shed: acc.shed,
            degraded: acc.degraded,
            skipped: acc.skipped,
            gated: acc.gated,
            expert_utilization,
            switches: acc.switches,
            transitions: acc.transitions,
            fallbacks: acc.fallbacks,
            fused_switches: acc.fused,
            rollbacks,
            requeues: acc.requeues,
            deadline_exceeded: acc.deadline_exceeded,
            quarantine_trips,
            probes,
            recoveries,
            replica_health,
            quarantined_replicas: quarantined,
            per_replica_served,
            oracle_checks,
            oracle_failures,
            p50_wait_us: p50,
            p99_wait_us: p99,
            makespan_us,
            actions: acc.actions,
            outcomes: acc.outcomes,
            fairness: acc.fairness,
            store,
            summary,
        }
    }

    /// Run `trace` through real MPSC queues and one scoped worker
    /// thread per replica (module docs).  The front end routes each
    /// request off live replica snapshots and sheds to the failure
    /// policy when the chosen queue is full; workers drain their
    /// channels into their own affinity batchers and serve batch by
    /// batch against the shared store.  The oracle (when enabled)
    /// checks each replica after its own applies and cross-checks the
    /// whole fleet after the workers join.
    pub fn run_trace_concurrent(&mut self, trace: &[Request]) -> Result<FleetReport, ServeError> {
        for q in trace {
            q.selection.validate()?;
        }
        // Gate-resolve up front on this thread: gating stays
        // deterministic even though worker scheduling is not.
        let resolved = self.resolve(trace)?;
        let oracle = if self.oracle {
            Some(self.make_oracle())
        } else {
            None
        };
        let mut acc0 = Accum::new(self.slo_us, oracle);
        acc0.fold_resolution(&resolved);
        let shared = Mutex::new(acc0);
        let slots: Vec<Slot> = (0..self.replicas.len()).map(|_| Slot::default()).collect();
        let stop = AtomicBool::new(false);
        let first_error: Mutex<Option<ServeError>> = Mutex::new(None);
        let requeue: Mutex<Vec<(u64, u32, Request)>> = Mutex::new(Vec::new());
        let meta: Mutex<HashMap<u64, (u64, u32)>> = Mutex::new(HashMap::new());
        let carried = AtomicU64::new(0);
        let ctx = WorkerCtx {
            slots: &slots,
            store: &*self.store,
            shared: &shared,
            stop: &stop,
            first_error: &first_error,
            epoch: Instant::now(),
            requeue: &requeue,
            meta: &meta,
            base: &self.base,
            pool: self.pool.clone(),
            injector: self.injector.clone(),
            carried_rollbacks: &carried,
            unfused_lora: self.unfused_lora,
            policy: self.failure_policy,
            service_us: self.service_us,
            quarantine_after: self.quarantine_after,
            quarantine_ttl_us: self.quarantine_ttl_us,
            deadline_us: self.deadline_us,
            retry_budget: self.retry_budget,
            retry_backoff_us: self.retry_backoff_us,
            queue_depth: self.queue_depth,
            force_cold: self.force_cold,
        };
        let mut senders: Vec<SyncSender<Request>> = Vec::with_capacity(self.replicas.len());
        let mut receivers: Vec<Receiver<Request>> = Vec::with_capacity(self.replicas.len());
        for _ in 0..self.replicas.len() {
            let (tx, rx) = sync_channel::<Request>(self.queue_depth);
            senders.push(tx);
            receivers.push(rx);
        }
        std::thread::scope(|scope| {
            for (rep, rx) in self.replicas.iter_mut().zip(receivers) {
                let ctx = &ctx;
                scope.spawn(move || replica_worker(rep, rx, ctx));
            }
            for q in &resolved.requests {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                front_drain_requeue(&senders, &ctx);
                front_route(q, 0, &senders, &ctx);
            }
            // Settle: keep re-dispatching the requeue until every
            // displaced request reaches a terminal disposition and the
            // fleet heals, then hang up so the workers exit.
            while !stop.load(Ordering::SeqCst) {
                front_drain_requeue(&senders, &ctx);
                let queued: usize = slots.iter().map(|s| s.queued.load(Ordering::SeqCst)).sum();
                let parked = relock(&requeue).len();
                let healed = slots.iter().all(|s| {
                    matches!(
                        health_from_u8(s.health.load(Ordering::SeqCst)),
                        HealthState::Healthy | HealthState::Suspect
                    )
                });
                if queued == 0 && parked == 0 && healed {
                    break;
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            drop(senders);
        });
        self.carried_rollbacks += carried.load(Ordering::SeqCst);
        let mut acc = shared.into_inner().unwrap_or_else(|p| p.into_inner());
        // End-of-run cross-replica sweep: with the workers joined it is
        // safe to read every replica's weights again.
        self.check_fleet(&mut acc, None);
        if let Some(e) = relock(&first_error).take() {
            for rep in &mut self.replicas {
                rep.batcher.clear();
            }
            return Err(e);
        }
        Ok(self.finish(acc, trace.len() as u64))
    }
}

/// Wire encoding of [`HealthState`] for the slot atomics.
const HEALTH_HEALTHY: u8 = 0;
const HEALTH_SUSPECT: u8 = 1;
const HEALTH_QUARANTINED: u8 = 2;
const HEALTH_PROBATION: u8 = 3;

fn health_to_u8(s: HealthState) -> u8 {
    match s {
        HealthState::Healthy => HEALTH_HEALTHY,
        HealthState::Suspect => HEALTH_SUSPECT,
        HealthState::Quarantined => HEALTH_QUARANTINED,
        HealthState::Probation => HEALTH_PROBATION,
    }
}

fn health_from_u8(v: u8) -> HealthState {
    match v {
        HEALTH_SUSPECT => HealthState::Suspect,
        HEALTH_QUARANTINED => HealthState::Quarantined,
        HEALTH_PROBATION => HealthState::Probation,
        _ => HealthState::Healthy,
    }
}

/// Live per-replica scheduler state shared between the concurrent
/// front end and its worker.
#[derive(Default)]
struct Slot {
    /// Requests outstanding on the replica (channel + batcher).
    queued: AtomicUsize,
    /// Mirror of the replica's health state (`HEALTH_*` encoding).
    health: AtomicU8,
    /// Mirror of the quarantine expiry, microseconds since the run
    /// epoch (meaningful while quarantined).
    until_us: AtomicU64,
    /// Mirror of the replica's (active key, active single) pair.
    active: Mutex<(Option<String>, Option<String>)>,
}

/// Everything a concurrent worker or the front end needs by reference —
/// one struct so the call graph stays narrow.
struct WorkerCtx<'a> {
    slots: &'a [Slot],
    store: &'a Mutex<AdapterStore>,
    shared: &'a Mutex<Accum>,
    stop: &'a AtomicBool,
    first_error: &'a Mutex<Option<ServeError>>,
    /// Wall-clock epoch of the run: health TTLs, backoffs and deadlines
    /// measure microseconds since this instant.
    epoch: Instant,
    /// Requests displaced by failures, drains or an all-quarantined
    /// fleet, parked for front-end re-dispatch:
    /// (re-dispatch instant us, attempts consumed, request).
    requeue: &'a Mutex<Vec<(u64, u32, Request)>>,
    /// Per request id: (first-seen wall instant us, attempts consumed)
    /// — what the end-to-end deadline and retry budget measure.
    meta: &'a Mutex<HashMap<u64, (u64, u32)>>,
    /// Pristine base weights for recovery rebuilds.
    base: &'a WeightStore,
    pool: Option<Arc<ThreadPool>>,
    injector: Option<Arc<FaultInjector>>,
    /// Rollback counts of routers replaced during recovery.
    carried_rollbacks: &'a AtomicU64,
    unfused_lora: bool,
    policy: FailurePolicy,
    service_us: u64,
    quarantine_after: u32,
    quarantine_ttl_us: u64,
    deadline_us: u64,
    retry_budget: u32,
    retry_backoff_us: u64,
    queue_depth: usize,
    force_cold: bool,
}

/// Microseconds since the run epoch (the concurrent mode's clock).
fn wall_us(ctx: &WorkerCtx<'_>) -> u64 {
    ctx.epoch.elapsed().as_micros() as u64
}

/// Snapshot every slot into scheduler views for the front end.
fn slot_views(slots: &[Slot], now_us: u64) -> Vec<ReplicaView> {
    slots
        .iter()
        .enumerate()
        .map(|(id, s)| {
            let (active_key, active_single) = relock(&s.active).clone();
            let health = health_from_u8(s.health.load(Ordering::SeqCst));
            let retry_in_us = if health == HealthState::Quarantined {
                s.until_us
                    .load(Ordering::SeqCst)
                    .saturating_sub(now_us)
                    .max(1)
            } else {
                0
            };
            ReplicaView {
                id,
                queued: s.queued.load(Ordering::SeqCst),
                active_key,
                active_single,
                health,
                retry_in_us,
            }
        })
        .collect()
}

/// Re-dispatch every parked request whose backoff elapsed.
fn front_drain_requeue(senders: &[SyncSender<Request>], ctx: &WorkerCtx<'_>) {
    loop {
        let now = wall_us(ctx);
        let next = {
            let mut rq = relock(ctx.requeue);
            let due = rq.iter().position(|(ready, _, _)| *ready <= now);
            due.map(|i| rq.swap_remove(i))
        };
        let Some((_, attempts, req)) = next else { return };
        front_route(&req, attempts, senders, ctx);
    }
}

/// Terminal deadline-exceeded handling for the concurrent front end.
fn expire_concurrent(req: &Request, key: &str, waited_us: u64, attempts: u32, ctx: &WorkerCtx<'_>) {
    let err = ServeError::DeadlineExceeded {
        selection: key.to_string(),
        deadline_us: ctx.deadline_us,
        waited_us,
        attempts,
    };
    if let FailurePolicy::FailFast = ctx.policy {
        let mut fe = relock(ctx.first_error);
        if fe.is_none() {
            *fe = Some(err);
        }
        drop(fe);
        ctx.stop.store(true, Ordering::SeqCst);
        return;
    }
    let mut acc = relock(ctx.shared);
    acc.deadline_exceeded += 1;
    acc.fairness.record_deadline_exceeded(key);
    acc.actions.insert(req.id, "deadline-exceeded");
    acc.outcomes.push(FleetOutcome {
        selection: key.to_string(),
        requests: 1,
        replica: None,
        action: "deadline-exceeded",
        error: err.to_string(),
    });
}

/// Route one request from the concurrent front end: enforce the
/// end-to-end (wall-clock) deadline, park on an all-quarantined fleet,
/// and shed to the failure policy only on genuine overload (or when the
/// chosen queue filled in the race window).
fn front_route(req: &Request, attempts: u32, senders: &[SyncSender<Request>], ctx: &WorkerCtx<'_>) {
    let key = req.selection.key();
    let now = wall_us(ctx);
    let first_seen = {
        let mut meta = relock(ctx.meta);
        meta.entry(req.id).or_insert((now, attempts)).0
    };
    if ctx.deadline_us > 0 && now >= first_seen.saturating_add(ctx.deadline_us) {
        expire_concurrent(req, &key, now.saturating_sub(first_seen), attempts, ctx);
        return;
    }
    let placement = {
        let store = relock(ctx.store);
        pick_replica(
            &slot_views(ctx.slots, now),
            &req.selection,
            &store,
            ctx.queue_depth,
            ctx.force_cold,
        )
    };
    match placement {
        Placement::Replica(r) => {
            ctx.slots[r].queued.fetch_add(1, Ordering::SeqCst);
            if senders[r].try_send(req.clone()).is_ok() {
                return;
            }
            ctx.slots[r].queued.fetch_sub(1, Ordering::SeqCst);
            // Race: the chosen queue filled first — genuine overload.
            front_shed(req, &key, senders, ctx);
        }
        Placement::AllQuarantined { retry_in_us } => {
            // Transient: park for re-dispatch once a TTL expires (no
            // retry budget consumed — the fleet, not the request, is
            // at fault).
            relock(ctx.requeue).push((
                now.saturating_add(retry_in_us.max(1)),
                attempts,
                req.clone(),
            ));
            relock(ctx.shared).requeues += 1;
        }
        Placement::Full => front_shed(req, &key, senders, ctx),
    }
}

/// Shed one request the front end could not place (genuine overload)
/// to the failure policy.
fn front_shed(req: &Request, key: &str, senders: &[SyncSender<Request>], ctx: &WorkerCtx<'_>) {
    let key = key.to_string();
    match ctx.policy {
        FailurePolicy::FailFast => {
            let mut fe = relock(ctx.first_error);
            if fe.is_none() {
                *fe = Some(ServeError::Overloaded {
                    selection: key,
                    replicas: ctx.slots.len(),
                    queue_depth: ctx.queue_depth,
                });
            }
            drop(fe);
            ctx.stop.store(true, Ordering::SeqCst);
        }
        FailurePolicy::DegradeToBase => {
            let target = {
                let store = relock(ctx.store);
                pick_replica(
                    &slot_views(ctx.slots, wall_us(ctx)),
                    &Selection::Base,
                    &store,
                    ctx.queue_depth,
                    ctx.force_cold,
                )
            };
            let mut sent_to = None;
            if let Placement::Replica(r) = target {
                ctx.slots[r].queued.fetch_add(1, Ordering::SeqCst);
                let mut base_req = req.clone();
                base_req.selection = Selection::Base;
                if senders[r].try_send(base_req).is_ok() {
                    sent_to = Some(r);
                } else {
                    ctx.slots[r].queued.fetch_sub(1, Ordering::SeqCst);
                }
            }
            let mut acc = relock(ctx.shared);
            acc.shed += 1;
            acc.fairness.record_shed(&key);
            match sent_to {
                Some(r) => {
                    acc.degraded += 1;
                    acc.actions.insert(req.id, "shed-degraded");
                    acc.outcomes.push(FleetOutcome {
                        selection: key,
                        requests: 1,
                        replica: Some(r),
                        action: "shed-degraded",
                        error: "admission: no replica can take the selection".into(),
                    });
                }
                None => {
                    acc.skipped += 1;
                    acc.actions.insert(req.id, "shed-skipped");
                    acc.outcomes.push(FleetOutcome {
                        selection: key,
                        requests: 1,
                        replica: None,
                        action: "shed-skipped",
                        error: "admission: all replica queues full".into(),
                    });
                }
            }
        }
        FailurePolicy::SkipRequest => {
            let mut acc = relock(ctx.shared);
            acc.shed += 1;
            acc.skipped += 1;
            acc.fairness.record_shed(&key);
            acc.actions.insert(req.id, "shed-skipped");
            acc.outcomes.push(FleetOutcome {
                selection: key,
                requests: 1,
                replica: None,
                action: "shed-skipped",
                error: "admission: all replica queues full".into(),
            });
        }
    }
}

/// One concurrent worker: drain the channel into the replica's affinity
/// batcher, serve batch by batch, poll the health state machine on a
/// short timeout so quarantine TTLs expire into recovery even with no
/// traffic, and exit when the channel disconnects, the backlog is
/// empty, AND the replica has converged to a steady health state (so
/// the run always ends fully healed).
fn replica_worker(rep: &mut Replica, rx: Receiver<Request>, ctx: &WorkerCtx<'_>) {
    loop {
        if ctx.stop.load(Ordering::SeqCst) {
            rep.batcher.clear();
            ctx.slots[rep.id].queued.store(0, Ordering::SeqCst);
            return;
        }
        worker_poll_health(rep, ctx);
        let mut disconnected = false;
        loop {
            match rx.try_recv() {
                Ok(q) => rep.batcher.push(q),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if rep.batcher.is_empty() {
            if disconnected {
                if matches!(
                    rep.health.state,
                    HealthState::Healthy | HealthState::Suspect
                ) {
                    return;
                }
                // Still quarantined/probation: keep polling the TTL.
                std::thread::sleep(Duration::from_micros(200));
                continue;
            }
            match rx.recv_timeout(Duration::from_micros(500)) {
                Ok(q) => {
                    rep.batcher.push(q);
                    continue;
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => continue,
            }
        }
        if rep.health.state == HealthState::Quarantined {
            // Traffic raced in before the front saw the quarantine:
            // drain it back for failover instead of serving it here.
            worker_drain(rep, ctx);
            continue;
        }
        serve_batch_concurrent(rep, ctx);
    }
}

/// Publish a replica's post-apply routing state to its slot.
fn publish_slot(rep: &Replica, ctx: &WorkerCtx<'_>) {
    *relock(&ctx.slots[rep.id].active) = (
        rep.router.active_key().map(str::to_string),
        rep.router.active_single().map(str::to_string),
    );
}

/// Publish a replica's health state (and quarantine expiry) to its
/// slot so the front end's scheduler sees it.
fn publish_health(rep: &Replica, ctx: &WorkerCtx<'_>) {
    ctx.slots[rep.id]
        .until_us
        .store(rep.health.until_us, Ordering::SeqCst);
    ctx.slots[rep.id]
        .health
        .store(health_to_u8(rep.health.state), Ordering::SeqCst);
}

/// Walk the replica's health state machine against the wall clock:
/// an expired quarantine TTL runs the recovery pass, and a failure-free
/// probation window promotes back to Healthy.
fn worker_poll_health(rep: &mut Replica, ctx: &WorkerCtx<'_>) {
    let now = wall_us(ctx);
    if rep.health.probe_due(now) {
        worker_recover(rep, ctx, now);
    }
    rep.health.poll_probation(now, ctx.quarantine_ttl_us);
    publish_health(rep, ctx);
}

/// Concurrent twin of [`Fleet::recover_replica`]: revert to base via
/// the transactional router (rebuilding it from pristine base weights
/// if its bytes still diverge), verify bit-identity against the oracle,
/// and enter probation.
fn worker_recover(rep: &mut Replica, ctx: &WorkerCtx<'_>, now_us: u64) {
    {
        let mut store = relock(ctx.store);
        if rep.router.apply(&mut store, &Selection::Base).is_err() {
            rep.router.revert_all(&mut store);
        }
    }
    if !rep.router.weights().bit_equal(ctx.base) {
        ctx.carried_rollbacks
            .fetch_add(rep.router.rollbacks(), Ordering::SeqCst);
        let mut router = Router::new(ctx.base.clone(), ctx.pool.clone(), ctx.unfused_lora);
        if let Some(f) = &ctx.injector {
            router.set_fault(Arc::clone(f));
        }
        rep.router = router;
    }
    rep.health.begin_probation(now_us);
    publish_slot(rep, ctx);
    let mut acc = relock(ctx.shared);
    if let Some(oracle) = acc.oracle.as_mut() {
        oracle.check_replica(rep.id, rep.router.active_key(), rep.router.weights());
    }
}

/// Requeue each request in `batch` for front-end re-dispatch (budget
/// permitting) and return the budget-exhausted leftovers for the
/// caller to terminate under the policy.  Accounts the requeue
/// counters and one "requeued" outcome.
fn requeue_batch(
    key: &str,
    batch: Vec<Request>,
    replica: usize,
    why: &str,
    ctx: &WorkerCtx<'_>,
    now_us: u64,
) -> Vec<Request> {
    let mut requeued = 0u64;
    let mut exhausted: Vec<Request> = Vec::new();
    {
        let mut meta = relock(ctx.meta);
        let mut rq = relock(ctx.requeue);
        for q in batch {
            let entry = meta.entry(q.id).or_insert((now_us, 0));
            if entry.1 < ctx.retry_budget {
                let backoff = ctx.retry_backoff_us.max(1) << u64::from(entry.1.min(16));
                entry.1 += 1;
                rq.push((now_us.saturating_add(backoff), entry.1, q));
                requeued += 1;
            } else {
                exhausted.push(q);
            }
        }
    }
    if requeued > 0 {
        let mut acc = relock(ctx.shared);
        acc.requeues += requeued;
        for _ in 0..requeued {
            acc.fairness.record_retry(key);
        }
        acc.outcomes.push(FleetOutcome {
            selection: key.to_string(),
            requests: requeued,
            replica: Some(replica),
            action: "requeued",
            error: why.to_string(),
        });
    }
    exhausted
}

/// Drain a quarantined replica's backlog back to the front end's
/// requeue; budget-exhausted requests terminate as skipped (accounted,
/// never silently lost).
fn worker_drain(rep: &mut Replica, ctx: &WorkerCtx<'_>) {
    let now = wall_us(ctx);
    loop {
        let Some((sel, batch)) = rep.batcher.next_batch(None) else {
            return;
        };
        let key = sel.key();
        let n = batch.len();
        let exhausted = requeue_batch(
            &key,
            batch,
            rep.id,
            "drained from a quarantined replica",
            ctx,
            now,
        );
        ctx.slots[rep.id].queued.fetch_sub(n, Ordering::SeqCst);
        if !exhausted.is_empty() {
            let mut acc = relock(ctx.shared);
            acc.skipped += exhausted.len() as u64;
            for q in &exhausted {
                acc.actions.insert(q.id, "skipped");
            }
            acc.outcomes.push(FleetOutcome {
                selection: key,
                requests: exhausted.len() as u64,
                replica: Some(rep.id),
                action: "skipped",
                error: "drained from a quarantined replica with no retry budget left".into(),
            });
        }
    }
}

/// Serve one batch inside a concurrent worker (the worker-thread twin
/// of [`Fleet::serve_one`]): apply under the store lock, account
/// virtual time and fairness under the accumulator lock, and run the
/// oracle on this replica's own bytes.
fn serve_batch_concurrent(rep: &mut Replica, ctx: &WorkerCtx<'_>) {
    let active = rep.router.active_key().map(str::to_string);
    let Some((sel, batch)) = rep.batcher.next_batch(active.as_deref()) else {
        return;
    };
    let key = sel.key();
    let n = batch.len() as u64;
    let n_reqs = batch.len();
    // The Apply fault site: a planned replica crash fails the whole
    // apply before it reaches the store.
    let crash = ctx
        .injector
        .as_ref()
        .map(|f| f.should_crash_apply(rep.id))
        .unwrap_or(false);
    let result = if crash {
        Err(ServeError::Runtime(FaultInjector::APPLY_CRASH_MSG.into()))
    } else {
        let mut store = relock(ctx.store);
        let depth = store.prefetch_depth();
        if depth > 0 {
            let mut names: Vec<String> = Vec::new();
            for s in rep.batcher.upcoming(depth, &[key.as_str()]) {
                for nm in s.names() {
                    if !names.iter().any(|x| x == nm) {
                        names.push(nm.to_string());
                    }
                }
            }
            store.prefetch(&names);
        }
        rep.router.apply(&mut store, &sel)
    };
    match result {
        Ok(applied) => {
            rep.health.note_success();
            publish_health(rep, ctx);
            let newest = batch.iter().map(|q| q.arrival_us).max().unwrap_or(0);
            let start = rep.clock_us.max(newest);
            rep.clock_us = start + ctx.service_us * n;
            rep.served += n;
            publish_slot(rep, ctx);
            ctx.slots[rep.id].queued.fetch_sub(n_reqs, Ordering::SeqCst);
            let mut acc = relock(ctx.shared);
            if applied.switched {
                acc.switches += 1;
                acc.record_path(applied.path);
            }
            for q in &batch {
                let wait = start.saturating_sub(q.arrival_us);
                acc.fairness.record_wait(&key, wait);
                acc.waits.push(wait as f64);
                acc.actions.entry(q.id).or_insert("served");
            }
            acc.served += n;
            if let Some(oracle) = acc.oracle.as_mut() {
                oracle.reference(&sel);
                oracle.check_replica(rep.id, rep.router.active_key(), rep.router.weights());
            }
        }
        Err(e) => {
            let now = wall_us(ctx);
            let newly_quarantined =
                rep.health
                    .note_failure(now, ctx.quarantine_after, ctx.quarantine_ttl_us);
            publish_health(rep, ctx);
            if let FailurePolicy::FailFast = ctx.policy {
                let mut fe = relock(ctx.first_error);
                if fe.is_none() {
                    *fe = Some(e);
                }
                drop(fe);
                ctx.stop.store(true, Ordering::SeqCst);
                rep.batcher.clear();
                publish_slot(rep, ctx);
                ctx.slots[rep.id].queued.store(0, Ordering::SeqCst);
                return;
            }
            // Failover: requeue what still has retry budget; the
            // leftovers terminate under the policy.
            let exhausted = requeue_batch(&key, batch, rep.id, &e.to_string(), ctx, now);
            let n_left = exhausted.len() as u64;
            let mut degraded_ok = false;
            if !exhausted.is_empty() {
                if let FailurePolicy::DegradeToBase = ctx.policy {
                    degraded_ok = {
                        let mut store = relock(ctx.store);
                        rep.router.apply(&mut store, &Selection::Base).is_ok()
                    };
                    if degraded_ok {
                        let newest = exhausted.iter().map(|q| q.arrival_us).max().unwrap_or(0);
                        let start = rep.clock_us.max(newest);
                        rep.clock_us = start + ctx.service_us * n_left;
                        rep.served += n_left;
                    }
                }
            }
            publish_slot(rep, ctx);
            ctx.slots[rep.id].queued.fetch_sub(n_reqs, Ordering::SeqCst);
            {
                let mut acc = relock(ctx.shared);
                if !exhausted.is_empty() {
                    if degraded_ok {
                        for q in &exhausted {
                            acc.actions.insert(q.id, "degraded-to-base");
                        }
                        acc.served += n_left;
                        acc.degraded += n_left;
                    } else {
                        for q in &exhausted {
                            acc.actions.insert(q.id, "skipped");
                        }
                        acc.skipped += n_left;
                    }
                    acc.outcomes.push(FleetOutcome {
                        selection: key.clone(),
                        requests: n_left,
                        replica: Some(rep.id),
                        action: if degraded_ok { "degraded-to-base" } else { "skipped" },
                        error: e.to_string(),
                    });
                }
                if let Some(oracle) = acc.oracle.as_mut() {
                    oracle.check_replica(rep.id, rep.router.active_key(), rep.router.weights());
                }
            }
            if newly_quarantined {
                worker_drain(rep, ctx);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{adapter_names, fleet_trace, toy_base, toy_shira_zoo};
    use crate::util::proptest as pt;

    const DIM: usize = 32;
    const NNZ: usize = 60;

    fn zoo_names(n: usize) -> Vec<String> {
        adapter_names(n)
    }

    fn small_fleet(replicas: usize, seed: u64) -> Fleet {
        let names = zoo_names(4);
        Fleet::builder(toy_base(DIM, seed))
            .replicas(replicas)
            .queue_depth(64)
            .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, seed))
            .store_config(StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 0,
                plan_cache_bytes: 0,
                ..StoreConfig::default()
            })
            .build()
    }

    fn view(id: usize, queued: usize, key: Option<&str>, single: Option<&str>) -> ReplicaView {
        ReplicaView {
            id,
            queued,
            active_key: key.map(str::to_string),
            active_single: single.map(str::to_string),
            health: HealthState::Healthy,
            retry_in_us: 0,
        }
    }

    #[test]
    fn cost_ladder_orders_exact_plan_warm_cold() {
        let names = zoo_names(3);
        let pool = Arc::new(crate::util::threadpool::ThreadPool::new(2));
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 2,
                ..StoreConfig::default()
            },
            Some(Arc::clone(&pool)),
        );
        for a in &toy_shira_zoo(DIM, &names, NNZ, 7) {
            store.add_shira(a);
        }
        // adapter0/adapter1 resident with an adapter0->adapter1 plan;
        // adapter2 cold.  Plan builds are async: join the pool, then let
        // the next prefetch call drain the staged build into the cache.
        store.fetch("adapter0").unwrap();
        store.fetch("adapter1").unwrap();
        store.prefetch_transitions("adapter0", &["adapter1".to_string()]);
        pool.join();
        store.prefetch_transitions("adapter0", &[]);
        assert!(store.has_transition_plan("adapter0", "adapter1"));
        let sel = Selection::single("adapter1");
        let key = sel.key();
        assert_eq!(
            affinity_cost(&view(0, 0, Some(&key), Some("adapter1")), &sel, &key, &store),
            COST_EXACT
        );
        assert_eq!(
            affinity_cost(&view(1, 0, Some("adapter0@1"), Some("adapter0")), &sel, &key, &store),
            COST_PLAN
        );
        assert_eq!(
            affinity_cost(&view(2, 0, None, None), &sel, &key, &store),
            COST_WARM
        );
        let cold = Selection::single("adapter2");
        assert_eq!(
            affinity_cost(&view(3, 0, None, None), &cold, &cold.key(), &store),
            COST_COLD
        );
        // Base is warm anywhere (no names to fetch), exact on a base
        // replica.
        assert_eq!(
            affinity_cost(&view(4, 0, Some(""), None), &Selection::Base, "", &store),
            COST_EXACT
        );
        assert_eq!(
            affinity_cost(&view(5, 0, None, None), &Selection::Base, "", &store),
            COST_WARM
        );
        // pick_replica prefers the exact replica over the plan replica
        // over warm over cold, regardless of ordering in the slice.
        let views = vec![
            view(0, 3, None, None),                            // warm
            view(1, 3, Some("adapter0@1"), Some("adapter0")), // plan
            view(2, 3, Some(&key), Some("adapter1")),         // exact
        ];
        assert_eq!(pick_replica(&views, &sel, &store, 8, false), Placement::Replica(2));
        assert_eq!(
            pick_replica(&views[..2], &sel, &store, 8, false),
            Placement::Replica(1)
        );
        assert_eq!(
            pick_replica(&views[..1], &sel, &store, 8, false),
            Placement::Replica(0)
        );
        // force_cold collapses the ladder: least-loaded wins.
        let views = vec![
            view(0, 5, Some(&key), Some("adapter1")),
            view(1, 2, None, None),
        ];
        assert_eq!(pick_replica(&views, &sel, &store, 8, true), Placement::Replica(1));
    }

    #[test]
    fn prop_scheduler_respects_health_bounds_and_ties() {
        // Over random replica states the scheduler never selects a
        // quarantined replica or a probation replica with its canary in
        // flight, never exceeds the queue bound, breaks ties
        // deterministically, and classifies the no-candidate case
        // correctly: AllQuarantined iff at least one replica was
        // health-excluded, Full iff every replica was queue-full.
        let names = zoo_names(3);
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 0,
                ..StoreConfig::default()
            },
            None,
        );
        for a in &toy_shira_zoo(DIM, &names, NNZ, 3) {
            store.add_shira(a);
        }
        store.fetch("adapter0").unwrap();
        let healths = [
            HealthState::Healthy,
            HealthState::Suspect,
            HealthState::Quarantined,
            HealthState::Probation,
        ];
        let excluded = |v: &ReplicaView| {
            v.health == HealthState::Quarantined
                || (v.health == HealthState::Probation && v.queued >= 1)
        };
        pt::forall(
            0xF1EE7,
            60,
            |r: &mut Rng| {
                let depth = 1 + r.below(6);
                let views: Vec<(usize, usize, u8)> = (0..1 + r.below(6))
                    .map(|_| (r.below(8), r.below(4), r.below(3) as u8))
                    .collect();
                (depth, views, r.below(3))
            },
            |&(depth, ref raw, which)| {
                let views: Vec<ReplicaView> = raw
                    .iter()
                    .enumerate()
                    .map(|(id, &(queued, health, state))| ReplicaView {
                        id,
                        queued,
                        active_key: (state == 1).then(|| "adapter0@1".to_string()),
                        active_single: (state == 1).then(|| "adapter0".to_string()),
                        health: healths[health],
                        retry_in_us: if healths[health] == HealthState::Quarantined {
                            500
                        } else {
                            0
                        },
                    })
                    .collect();
                let sel = match which {
                    0 => Selection::Base,
                    1 => Selection::single("adapter0"),
                    _ => Selection::single("adapter2"),
                };
                let pick = pick_replica(&views, &sel, &store, depth, false);
                // Determinism: the same inputs pick the same replica.
                if pick != pick_replica(&views, &sel, &store, depth, false) {
                    return false;
                }
                match pick {
                    Placement::AllQuarantined { retry_in_us } => {
                        retry_in_us >= 1
                            && views.iter().any(|v| excluded(v))
                            && views.iter().all(|v| excluded(v) || v.queued >= depth)
                    }
                    Placement::Full => {
                        !views.iter().any(|v| excluded(v))
                            && views.iter().all(|v| v.queued >= depth)
                    }
                    Placement::Replica(id) => {
                        let v = &views[id];
                        if excluded(v) || v.queued >= depth {
                            return false;
                        }
                        // No strictly better candidate was skipped.
                        let key = sel.key();
                        let cost = affinity_cost(v, &sel, &key, &store);
                        views
                            .iter()
                            .filter(|w| !excluded(w) && w.queued < depth)
                            .all(|w| {
                                (affinity_cost(w, &sel, &key, &store), w.queued, w.id)
                                    >= (cost, v.queued, v.id)
                            })
                    }
                }
            },
        );
    }

    #[test]
    fn deterministic_run_replays_bit_identically_from_one_seed() {
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 120, 4, 0xAB);
        let run = |schedule_seed: u64| {
            let mut fleet = small_fleet(3, 5);
            let report = fleet.run_trace(&trace, schedule_seed).unwrap();
            let finals: Vec<Option<String>> = fleet
                .routers()
                .map(|r| r.active_key().map(str::to_string))
                .collect();
            (report, finals)
        };
        let (a, fa) = run(0xD5);
        let (b, fb) = run(0xD5);
        assert_eq!(a.actions, b.actions);
        assert_eq!(a.per_replica_served, b.per_replica_served);
        assert_eq!(a.summary, b.summary);
        assert_eq!(fa, fb);
        assert!(a.oracle_failures.is_empty(), "{:?}", a.oracle_failures);
        assert_eq!(a.served, 120);
        // A different schedule seed may place work differently but every
        // request still lands "served" with the oracle green.
        let (c, _) = run(0xE6);
        assert_eq!(a.actions, c.actions);
        assert!(c.oracle_failures.is_empty(), "{:?}", c.oracle_failures);
    }

    #[test]
    fn force_cold_changes_placement_only() {
        // Satellite 2 (second half): force-cold routing may move work
        // between replicas but never changes per-request results.
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 100, 6, 0xCC);
        let run = |force: bool| {
            let names = zoo_names(4);
            let mut fleet = Fleet::builder(toy_base(DIM, 9))
                .replicas(3)
                .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, 9))
                .store_config(StoreConfig {
                    cache_bytes: 64 << 20,
                    prefetch_depth: 0,
                    plan_cache_bytes: 0,
                    ..StoreConfig::default()
                })
                .force_cold(force)
                .build();
            let report = fleet.run_trace(&trace, 0x11).unwrap();
            assert!(report.oracle_failures.is_empty(), "{:?}", report.oracle_failures);
            report
        };
        let warm = run(false);
        let cold = run(true);
        assert_eq!(warm.actions, cold.actions, "results must not change");
        assert_eq!(warm.served, cold.served);
        // Affinity routing must beat cold routing on switches for a
        // bursty trace (that is the point of the ladder).
        assert!(
            warm.switches <= cold.switches,
            "affinity {} vs cold {}",
            warm.switches,
            cold.switches
        );
    }

    #[test]
    fn admission_control_sheds_to_policy() {
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 40, 2, 0x5EED);
        // Tiny queue, no draining headroom: 1 replica, depth 1.
        let build = |policy: FailurePolicy| {
            let names = zoo_names(4);
            Fleet::builder(toy_base(DIM, 3))
                .replicas(1)
                .queue_depth(1)
                .failure_policy(policy)
                .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, 3))
                .store_config(StoreConfig {
                    cache_bytes: 64 << 20,
                    prefetch_depth: 0,
                    plan_cache_bytes: 0,
                    ..StoreConfig::default()
                })
                .build()
        };
        // Zero drain steps ever happening is not guaranteed by the rng,
        // so force the overload deterministically: seed 0 gives some
        // ingests with no drain in between for a depth-1 queue.
        let mut fleet = build(FailurePolicy::FailFast);
        let err = fleet.run_trace(&trace, 0).unwrap_err();
        match err {
            ServeError::Overloaded {
                replicas, queue_depth, ..
            } => {
                assert_eq!((replicas, queue_depth), (1, 1));
            }
            other => panic!("expected Overloaded, got {other}"),
        }
        let mut fleet = build(FailurePolicy::SkipRequest);
        let report = fleet.run_trace(&trace, 0).unwrap();
        assert!(report.shed > 0);
        assert_eq!(report.shed, report.fairness.total_shed());
        assert_eq!(report.served + report.skipped, 40);
        assert!(report
            .outcomes
            .iter()
            .all(|o| o.action == "shed-skipped" && o.replica.is_none()));
        assert!(report.oracle_failures.is_empty(), "{:?}", report.oracle_failures);
    }

    #[test]
    fn concurrent_mode_serves_everything_with_green_oracle() {
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 80, 4, 0xC0);
        let mut fleet = Fleet::builder(toy_base(DIM, 11))
            .replicas(2)
            .queue_depth(128)
            .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, 11))
            .store_config(StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 0,
                plan_cache_bytes: 0,
                ..StoreConfig::default()
            })
            .build();
        let report = fleet.run_trace_concurrent(&trace).unwrap();
        assert_eq!(report.served, 80);
        assert!(report.oracle_failures.is_empty(), "{:?}", report.oracle_failures);
        assert!(report.actions.values().all(|&a| a == "served"));
        // Fleet-wide pin audit: after revert_all nothing stays pinned.
        fleet.revert_all();
        let store = fleet.store();
        let guard = store.lock().unwrap();
        assert_eq!(guard.pinned_count(), 0);
        assert_eq!(guard.pinned_plan_count(), 0);
    }

    #[test]
    fn builder_clamps_and_defaults() {
        let fleet = Fleet::builder(toy_base(DIM, 1))
            .replicas(0)
            .queue_depth(0)
            .build();
        assert_eq!(fleet.replica_count(), 1);
        assert_eq!(fleet.queue_depth, 1);
        let fleet = Fleet::builder(toy_base(DIM, 1)).build();
        assert_eq!(fleet.replica_count(), 2);
        assert_eq!(fleet.queue_depth, 16);
        assert!(fleet.oracle);
        assert_eq!(fleet.quarantine_ttl_us, 250_000);
        assert_eq!(fleet.deadline_us, 0);
        assert_eq!(fleet.retry_budget, 3);
        assert_eq!(fleet.retry_backoff_us, 100);
        // Zero TTL/backoff clamp to 1 so backoff shifts stay nonzero.
        let fleet = Fleet::builder(toy_base(DIM, 1))
            .replica_quarantine_ttl_us(0)
            .retry_backoff_us(0)
            .build();
        assert_eq!(fleet.quarantine_ttl_us, 1);
        assert_eq!(fleet.retry_backoff_us, 1);
    }

    #[test]
    fn replica_health_state_machine_trips_probes_and_recovers() {
        let mut h = ReplicaHealth::new();
        assert_eq!(h.state, HealthState::Healthy);
        assert_eq!(h.retry_in_us(0), 0);
        // Below the threshold failures only mark the replica Suspect.
        assert!(!h.note_failure(1_000, 3, 250));
        assert!(!h.note_failure(1_000, 3, 250));
        assert_eq!(h.state, HealthState::Suspect);
        // The threshold failure trips a quarantine with the base TTL.
        assert!(h.note_failure(1_000, 3, 250));
        assert_eq!(h.state, HealthState::Quarantined);
        assert_eq!(h.trips, 1);
        assert_eq!(h.until_us, 1_250);
        assert_eq!(h.retry_in_us(1_000), 250);
        // Further failures while quarantined do not re-trip.
        assert!(!h.note_failure(1_100, 3, 250));
        assert!(!h.probe_due(1_249));
        assert!(h.probe_due(1_250));
        // A failed probation canary re-quarantines immediately with a
        // doubled TTL (exponential backoff per re-quarantine).
        h.begin_probation(1_250);
        assert_eq!(h.state, HealthState::Probation);
        assert_eq!(h.probes, 1);
        assert!(h.note_failure(1_300, 3, 250));
        assert_eq!(h.state, HealthState::Quarantined);
        assert_eq!(h.trips, 2);
        assert_eq!(h.until_us, 1_300 + 500);
        // A canary success completes the recovery.
        h.begin_probation(1_800);
        h.note_success();
        assert_eq!(h.state, HealthState::Healthy);
        assert_eq!(h.recoveries, 1);
        assert_eq!(h.failures_in_row, 0);
        // A quiet probation window self-promotes (no traffic needed);
        // trips is 2 now so the next TTL is base << 2.
        assert!(h.note_failure(2_000, 1, 250));
        assert_eq!(h.until_us, 2_000 + 1_000);
        h.begin_probation(5_000);
        h.poll_probation(5_400, 500);
        assert_eq!(h.state, HealthState::Probation);
        h.poll_probation(5_500, 500);
        assert_eq!(h.state, HealthState::Healthy);
        assert_eq!(h.recoveries, 2);
        // The TTL backoff shift saturates at MAX_TTL_SHIFT.
        let mut h = ReplicaHealth::new();
        h.trips = 40;
        h.note_failure(0, 1, 100);
        assert_eq!(h.until_us, 100 << MAX_TTL_SHIFT);
    }

    #[test]
    fn scheduler_distinguishes_all_quarantined_from_full() {
        let names = zoo_names(2);
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 0,
                ..StoreConfig::default()
            },
            None,
        );
        for a in &toy_shira_zoo(DIM, &names, NNZ, 2) {
            store.add_shira(a);
        }
        let sel = Selection::single("adapter0");
        let mk = |id: usize, queued: usize, health: HealthState, retry: u64| ReplicaView {
            id,
            queued,
            active_key: None,
            active_single: None,
            health,
            retry_in_us: retry,
        };
        // Every replica quarantined: transient — report the soonest
        // TTL expiry so the front end can requeue with a backoff.
        let views = vec![
            mk(0, 0, HealthState::Quarantined, 700),
            mk(1, 0, HealthState::Quarantined, 300),
        ];
        assert_eq!(
            pick_replica(&views, &sel, &store, 8, false),
            Placement::AllQuarantined { retry_in_us: 300 }
        );
        // Health-excluded plus queue-full still reads as transient: the
        // quarantined replica will come back.
        let views = vec![
            mk(0, 8, HealthState::Healthy, 0),
            mk(1, 0, HealthState::Quarantined, 300),
        ];
        assert_eq!(
            pick_replica(&views, &sel, &store, 8, false),
            Placement::AllQuarantined { retry_in_us: 300 }
        );
        // Genuinely full (all healthy, all at the bound): Overloaded
        // territory — shedding, not waiting, is correct.
        let views = vec![
            mk(0, 8, HealthState::Healthy, 0),
            mk(1, 8, HealthState::Suspect, 0),
        ];
        assert_eq!(pick_replica(&views, &sel, &store, 8, false), Placement::Full);
        // A probation replica admits exactly one canary at a time.
        let views = vec![mk(0, 0, HealthState::Probation, 0)];
        assert_eq!(pick_replica(&views, &sel, &store, 8, false), Placement::Replica(0));
        let views = vec![mk(0, 1, HealthState::Probation, 0)];
        assert_eq!(
            pick_replica(&views, &sel, &store, 8, false),
            Placement::AllQuarantined { retry_in_us: PROBATION_RETRY_US }
        );
    }

    #[test]
    fn crash_quarantine_probe_recover_round_trip() {
        // Tentpole gate in miniature: crash every replica's first apply,
        // watch each one trip quarantine, drain, probe, pass the
        // bit-identity gate, and end Healthy — with every request
        // terminally accounted and the run replay-identical.
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 60, 4, 0x9E);
        let run = || {
            let names = zoo_names(4);
            let mut fleet = Fleet::builder(toy_base(DIM, 13))
                .replicas(2)
                .queue_depth(64)
                .failure_policy(FailurePolicy::DegradeToBase)
                .quarantine_after(1)
                .replica_quarantine_ttl_us(400)
                .retry_backoff_us(50)
                .fault_plan(
                    FaultPlan::new().crash_replica_at(0, 1).crash_replica_at(1, 1),
                )
                .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, 13))
                .store_config(StoreConfig {
                    cache_bytes: 64 << 20,
                    prefetch_depth: 0,
                    plan_cache_bytes: 0,
                    ..StoreConfig::default()
                })
                .build();
            fleet.run_trace(&trace, 0x77).unwrap()
        };
        let a = run();
        assert!(a.quarantine_trips >= 2, "{}", a.summary);
        assert!(a.probes >= 2, "{}", a.summary);
        assert!(a.recoveries >= 2, "{}", a.summary);
        assert!(a.requeues >= 1, "{}", a.summary);
        assert_eq!(a.deadline_exceeded, 0);
        assert!(
            a.replica_health.iter().all(|&h| h == "healthy"),
            "end states {:?}",
            a.replica_health
        );
        assert_eq!(a.quarantined_replicas, 0);
        // Nothing silently lost on the drain: every request has a
        // terminal disposition and the counters add back up.
        assert_eq!(a.actions.len(), trace.len());
        assert_eq!(a.served + a.shed + a.skipped + a.deadline_exceeded, 60);
        // Recovered replicas passed the bit-identity gate and kept it
        // green for the rest of the run.
        assert!(a.oracle_checks > 0);
        assert!(a.oracle_failures.is_empty(), "{:?}", a.oracle_failures);
        // Replay-identical from the same (trace, schedule, fault) seeds.
        let b = run();
        assert_eq!(a.actions, b.actions);
        assert_eq!(a.summary, b.summary);
        assert_eq!(a.per_replica_served, b.per_replica_served);
    }

    #[test]
    fn gated_fleet_resolves_autos_and_replays_explicitly() {
        use crate::coordinator::gate::LinearGate;
        use crate::coordinator::pool::ExpertPool;
        let trace = fleet_trace(&[Selection::Auto], 40, 4, 0x6A);
        let build = |with_gate: bool, plan: Option<FaultPlan>| {
            let names = zoo_names(4);
            let pool = ExpertPool::shared(0);
            for n in &names {
                lock_pool(&pool).register(n).unwrap();
            }
            let mut b = Fleet::builder(toy_base(DIM, 21))
                .replicas(2)
                .queue_depth(64)
                .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, 21))
                .store_config(StoreConfig {
                    cache_bytes: 64 << 20,
                    prefetch_depth: 0,
                    plan_cache_bytes: 0,
                    ..StoreConfig::default()
                });
            if with_gate {
                b = b
                    .gate(Arc::new(LinearGate::seeded(&names, 2, 0x6A7E)))
                    .expert_pool(Arc::clone(&pool));
            }
            if let Some(p) = plan {
                b = b.failure_policy(FailurePolicy::DegradeToBase).fault_plan(p);
            }
            b.build()
        };
        // Auto-served run: every request gate-resolves, serves, and
        // counts utilization.
        let mut auto_fleet = build(true, None);
        let a = auto_fleet.run_trace(&trace, 0xD5).unwrap();
        assert_eq!((a.gated, a.served), (40, 40));
        assert!(a.oracle_failures.is_empty(), "{:?}", a.oracle_failures);
        assert!(a.summary.contains("gate: gated=40"), "{}", a.summary);
        let util_total: u64 = a.expert_utilization.iter().map(|(_, n)| n).sum();
        assert!(util_total >= 40, "utilization {util_total}");
        // The gate's rewrite is public: resolving the same trace on an
        // identically-seeded fleet yields an explicit trace whose serve
        // is action-, placement- and bit-identical to the auto run.
        let explicit = build(true, None).resolve_trace(&trace).unwrap();
        assert!(explicit
            .iter()
            .all(|q| matches!(q.selection, Selection::Set { .. })));
        let mut explicit_fleet = build(false, None);
        let b = explicit_fleet.run_trace(&explicit, 0xD5).unwrap();
        assert_eq!(b.gated, 0);
        assert_eq!(a.actions, b.actions);
        assert_eq!(a.per_replica_served, b.per_replica_served);
        for (ra, rb) in auto_fleet.routers().zip(explicit_fleet.routers()) {
            assert_eq!(ra.active_key(), rb.active_key());
            assert!(ra.weights().bit_equal(rb.weights()));
        }
        // A planned gate fault degrades that one request to base and
        // leaves the rest gated; every request stays accounted.
        let mut faulted = build(true, Some(FaultPlan::new().fail_gate_at(1)));
        let c = faulted.run_trace(&trace, 0xD5).unwrap();
        assert_eq!((c.gated, c.degraded), (39, 1));
        assert_eq!(c.actions.len(), 40);
        assert!(c
            .outcomes
            .iter()
            .any(|o| o.action == "gate-degraded-to-base"
                && o.replica.is_none()
                && o.selection == "@auto"
                && o.error.contains("injected fault")));
    }

    #[test]
    fn deadline_expires_requests_instead_of_retrying_forever() {
        // One replica, quarantined on its first apply with a TTL far
        // past every request's deadline: the retry path must give up at
        // the deadline and account the requests, not spin.
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 20, 1, 0x41);
        let mut fleet = Fleet::builder(toy_base(DIM, 5))
            .replicas(1)
            .queue_depth(64)
            .failure_policy(FailurePolicy::SkipRequest)
            .quarantine_after(1)
            .replica_quarantine_ttl_us(10_000_000)
            .deadline_us(5_000)
            .fault_plan(FaultPlan::new().crash_replica_at(0, 1))
            .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, 5))
            .store_config(StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 0,
                plan_cache_bytes: 0,
                ..StoreConfig::default()
            })
            .build();
        let report = fleet.run_trace(&trace, 0x3).unwrap();
        assert!(report.deadline_exceeded > 0, "{}", report.summary);
        assert_eq!(report.deadline_exceeded, report.fairness.total_deadline_exceeded());
        assert_eq!(report.actions.len(), trace.len());
        assert_eq!(
            report.served + report.shed + report.skipped + report.deadline_exceeded,
            20
        );
        // Expired requests carry no replica and a real deadline error.
        assert!(report
            .outcomes
            .iter()
            .filter(|o| o.action == "deadline-exceeded")
            .all(|o| o.replica.is_none() && o.error.contains("deadline")));
        // The replica still recovers once its TTL expires, so the run
        // ends all-Healthy even though its traffic timed out.
        assert!(
            report.replica_health.iter().all(|&h| h == "healthy"),
            "end states {:?}",
            report.replica_health
        );
    }
}
