//! Fleet serving (DESIGN.md §14): N worker replicas behind one
//! affinity-routing front end.
//!
//! Each replica owns its own resident [`WeightStore`] and [`Router`] —
//! the PR 5 per-request machinery unchanged — while all replicas share
//! ONE [`AdapterStore`] (so an adapter decodes once fleet-wide, and one
//! plan cache serves every replica) and one [`ThreadPool`], both behind
//! `Arc`.  A request is routed to the replica where its [`Selection`]
//! is cheapest to reach, down the affinity cost ladder:
//!
//! 1. **exact** — the selection is already resident on the replica;
//! 2. **plan** — the replica is live on a single adapter with a
//!    resident pairwise transition plan to the incoming single
//!    (the PR 4 one-pass path);
//! 3. **warm** — every adapter the selection names is decoded in the
//!    shared cache (base counts as warm everywhere: zero names);
//! 4. **cold** — somebody has to fetch.
//!
//! Ties break deterministically on (cost, queue length, replica id).
//! Quarantined replicas and replicas at their queue bound are excluded;
//! when no replica can take the request, admission control sheds it to
//! the configured [`FailurePolicy`].
//!
//! ## Determinism harness
//!
//! [`Fleet::run_trace`] is the seeded deterministic scheduler: a
//! single-threaded virtual-time loop in which every nondeterministic
//! choice (how many queue-drain steps run after each ingest, which busy
//! replica drains next) comes from one [`Rng`] stream, on top of the
//! PR 6 fault-injection ordinal mechanism — one shared
//! [`FaultInjector`](super::fault::FaultInjector) is armed across the
//! store and every replica, so its per-site ordinals fire at the same
//! global points on every replay.  Any interleaving therefore replays
//! from `(trace seed, schedule seed, fault seed)` alone.
//!
//! A per-request **bit-identity oracle** rides along: a fault-free
//! serial reference (its own [`Router`] over a
//! [`fork_reference`](AdapterStore::fork_reference) of the shared
//! store) materializes the reference bytes for every selection key, and
//! after every apply the harness checks EVERY replica's resident
//! weights against the reference for its active key — which is exactly
//! the rollback-isolation assertion: a fault on one replica can never
//! perturb another replica's resident bytes.
//!
//! [`Fleet::run_trace_concurrent`] runs the same components for real:
//! bounded `sync_channel` queues into `std::thread::scope` workers.
//! Scheduling there is OS-nondeterministic, so the oracle checks each
//! replica against the serial reference after its own applies and
//! cross-checks the whole fleet once the workers join.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex, MutexGuard};

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::engine::Router;
use super::error::ServeError;
use super::fault::FaultPlan;
use super::metrics::FairnessLedger;
use super::selection::Selection;
use super::server::FailurePolicy;
use super::store::{AdapterStore, StoreConfig, StoreStats};
use super::switch::SwitchPath;
use crate::adapter::{LoraAdapter, ShiraAdapter};
use crate::data::trace::Request;
use crate::model::weights::WeightStore;
use crate::util::rng::Rng;
use crate::util::stats::Sample;
use crate::util::threadpool::ThreadPool;

/// Lock a mutex, adopting the data even when a peer holding it
/// panicked.  Fleet state is re-validated by the oracle after every
/// apply and the routers keep their own transactional guard, so a
/// poisoned lock carries no information a recovery path needs.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Affinity cost: the selection is already resident on the replica.
const COST_EXACT: u8 = 0;
/// Affinity cost: a resident pairwise transition plan reaches it.
const COST_PLAN: u8 = 1;
/// Affinity cost: every named adapter is decoded in the shared cache.
const COST_WARM: u8 = 2;
/// Affinity cost: at least one adapter must be fetched cold.
const COST_COLD: u8 = 3;

/// One replica's scheduler-visible state: what the affinity router
/// needs to cost a placement, nothing more.  Snapshots are cheap to
/// build from either the deterministic harness (direct field reads) or
/// the concurrent front end (atomics + a small mutex).
#[derive(Clone, Debug)]
pub struct ReplicaView {
    /// Replica index (stable tie-breaker).
    pub id: usize,
    /// Requests queued on the replica (channel + batcher backlog).
    pub queued: usize,
    /// Canonical key of the selection resident on the replica, when one
    /// has been applied.
    pub active_key: Option<String>,
    /// Name of the single adapter the replica's switch path holds, when
    /// it is live in single mode — the `from` side of a pairwise
    /// transition plan.
    pub active_single: Option<String>,
    /// Sticky health flag: the replica failed too many applies in a row
    /// and no longer receives new requests.
    pub quarantined: bool,
}

/// Cost of making `sel` resident on the replica `view` describes, down
/// the module-level ladder (exact > plan > warm > cold).
fn affinity_cost(view: &ReplicaView, sel: &Selection, key: &str, store: &AdapterStore) -> u8 {
    if view.active_key.as_deref() == Some(key) {
        return COST_EXACT;
    }
    if let Selection::Single { name, .. } = sel {
        if let Some(from) = view.active_single.as_deref() {
            if from != name && store.has_transition_plan(from, name) {
                return COST_PLAN;
            }
        }
    }
    if sel.names().iter().all(|n| store.is_resident(n)) {
        return COST_WARM;
    }
    COST_COLD
}

/// Pick the replica where `sel` is cheapest to reach, or `None` when
/// every replica is quarantined or at its queue bound (the admission
/// decision).  Pure over its inputs, so every scheduling decision is
/// replayable and directly property-testable.
///
/// Ties break on `(cost, queued, id)` — strictly deterministic.  With
/// `force_cold` every candidate costs [`COST_COLD`], collapsing the
/// ladder: placement degenerates to least-loaded/lowest-id, which must
/// change only WHERE requests run, never their results.
pub fn pick_replica(
    views: &[ReplicaView],
    sel: &Selection,
    store: &AdapterStore,
    queue_depth: usize,
    force_cold: bool,
) -> Option<usize> {
    let key = sel.key();
    let mut best: Option<(u8, usize, usize)> = None;
    for v in views {
        if v.quarantined || v.queued >= queue_depth {
            continue;
        }
        let cost = if force_cold {
            COST_COLD
        } else {
            affinity_cost(v, sel, &key, store)
        };
        let cand = (cost, v.queued, v.id);
        if best.map(|b| cand < b).unwrap_or(true) {
            best = Some(cand);
        }
    }
    best.map(|(_, _, id)| id)
}

/// The fault-free serial reference the determinism harness checks
/// against: its own [`Router`] over a fork of the shared store's flash
/// (no faults, no cache coupling), materializing reference bytes once
/// per selection key.  The engines' property-tested invariant — serving
/// a selection from ANY prior state lands identical bytes — is what
/// makes a by-key cache sound.
struct BitOracle {
    store: AdapterStore,
    router: Router,
    refs: HashMap<String, WeightStore>,
    base: WeightStore,
    checks: u64,
    failures: Vec<String>,
}

impl BitOracle {
    /// Materialize (or recall) the reference weights for `sel`.
    fn reference(&mut self, sel: &Selection) {
        let key = sel.key();
        if self.refs.contains_key(&key) {
            return;
        }
        match self.router.apply(&mut self.store, sel) {
            Ok(_) => {
                self.refs.insert(key, self.router.weights().clone());
            }
            Err(e) => self
                .failures
                .push(format!("reference apply failed for {key:?}: {e}")),
        }
    }

    /// Check one replica's resident weights against the reference for
    /// its active key (no key, or the empty base key, checks against
    /// base bytes).
    fn check_replica(&mut self, id: usize, active_key: Option<&str>, weights: &WeightStore) {
        self.checks += 1;
        let key = match active_key {
            None | Some("") => {
                if !weights.bit_equal(&self.base) {
                    self.failures
                        .push(format!("replica {id}: base-state bytes diverge from base"));
                }
                return;
            }
            Some(k) => k,
        };
        match self.refs.get(key) {
            Some(r) if weights.bit_equal(r) => {}
            Some(_) => self.failures.push(format!(
                "replica {id}: resident bytes diverge from the fault-free reference for {key:?}"
            )),
            None => self
                .failures
                .push(format!("replica {id}: no reference for active key {key:?}")),
        }
    }
}

/// One worker replica: its own router (owning its resident weights) and
/// its own affinity batcher, plus virtual-time and health bookkeeping.
struct Replica {
    id: usize,
    router: Router,
    batcher: DynamicBatcher,
    /// Virtual clock, microseconds: when this replica next becomes free.
    clock_us: u64,
    served: u64,
    failures_in_row: u32,
    quarantined: bool,
}

/// Mutable run-wide accounting shared by both execution modes.
struct Accum {
    fairness: FairnessLedger,
    waits: Sample,
    /// Terminal disposition per request id ("served",
    /// "degraded-to-base", "skipped", "shed-degraded", "shed-skipped")
    /// — the per-request outcome record the acceptance criterion
    /// compares across replica counts.
    actions: BTreeMap<u64, &'static str>,
    outcomes: Vec<FleetOutcome>,
    served: u64,
    shed: u64,
    degraded: u64,
    skipped: u64,
    switches: u64,
    transitions: u64,
    fallbacks: u64,
    fused: u64,
    oracle: Option<BitOracle>,
}

impl Accum {
    fn new(slo_us: u64, oracle: Option<BitOracle>) -> Accum {
        Accum {
            fairness: FairnessLedger::new(slo_us),
            waits: Sample::new(),
            actions: BTreeMap::new(),
            outcomes: Vec::new(),
            served: 0,
            shed: 0,
            degraded: 0,
            skipped: 0,
            switches: 0,
            transitions: 0,
            fallbacks: 0,
            fused: 0,
            oracle,
        }
    }

    fn record_path(&mut self, path: Option<SwitchPath>) {
        match path {
            Some(SwitchPath::Transition) => self.transitions += 1,
            Some(SwitchPath::Fallback) => self.fallbacks += 1,
            Some(SwitchPath::Fused) => self.fused += 1,
            None => {}
        }
    }
}

/// How one failed or shed batch was handled under the failure policy —
/// the fleet's analogue of
/// [`RequestOutcome`](super::server::RequestOutcome).
#[derive(Clone, Debug)]
pub struct FleetOutcome {
    /// Canonical key of the affected selection.
    pub selection: String,
    /// Requests in the affected batch (1 for admission sheds).
    pub requests: u64,
    /// Replica involved, or `None` for admission-control sheds.
    pub replica: Option<usize>,
    /// `"degraded-to-base"`, `"skipped"`, `"shed-degraded"` or
    /// `"shed-skipped"`.
    pub action: &'static str,
    /// Display form of the triggering error.
    pub error: String,
}

/// End-of-run report for one fleet trace.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Worker replicas in the fleet.
    pub replicas: usize,
    /// Requests in the trace.
    pub requests: u64,
    /// Requests served (including degraded ones).
    pub served: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Requests served on base weights after their selection failed.
    pub degraded: u64,
    /// Requests dropped.
    pub skipped: u64,
    /// Selection switches across all replicas.
    pub switches: u64,
    /// Switches that took the one-pass direct transition path.
    pub transitions: u64,
    /// Switches that fell back to revert+apply.
    pub fallbacks: u64,
    /// Switches served by the incremental fused-mode engine.
    pub fused_switches: u64,
    /// Failed mutations rolled back to base across all replicas.
    pub rollbacks: u64,
    /// Replicas quarantined by consecutive failures.
    pub quarantined_replicas: usize,
    /// Requests served per replica (placement distribution).
    pub per_replica_served: Vec<u64>,
    /// Bit-identity oracle comparisons performed.
    pub oracle_checks: u64,
    /// Oracle divergences (one line each; empty = bit-identical).
    pub oracle_failures: Vec<String>,
    /// Median queueing wait (virtual time), microseconds.
    pub p50_wait_us: f64,
    /// 99th-percentile queueing wait (virtual time), microseconds.
    pub p99_wait_us: f64,
    /// Largest replica virtual clock at end of run, microseconds.
    pub makespan_us: u64,
    /// Terminal disposition per request id — the per-request outcome
    /// record compared bit-for-bit across replica counts.
    pub actions: BTreeMap<u64, &'static str>,
    /// One entry per failed or shed batch the policy handled.
    pub outcomes: Vec<FleetOutcome>,
    /// Per-selection fairness/SLO ledger.
    pub fairness: FairnessLedger,
    /// Shared adapter-store lifecycle counters.
    pub store: StoreStats,
    /// Human-readable multi-line summary.
    pub summary: String,
}

/// Builder for [`Fleet`], mirroring
/// [`ServerBuilder`](super::server::ServerBuilder) — but runtime-free:
/// a fleet operates at the routing/weights level (no PJRT artifacts),
/// so the determinism harness, the chaos tests and the bench gate all
/// run in CI.
///
/// Defaults: 2 replicas, queue depth 16, [`StoreConfig::default`],
/// [`BatcherConfig::default`], no pool, fail-fast policy, SLO
/// disabled, 50us virtual service time, quarantine after 3 consecutive
/// failures, oracle on, force-cold off.
pub struct FleetBuilder {
    base: WeightStore,
    replicas: usize,
    queue_depth: usize,
    store_cfg: StoreConfig,
    batcher_cfg: BatcherConfig,
    pool: Option<Arc<ThreadPool>>,
    shira: Vec<ShiraAdapter>,
    lora: Vec<LoraAdapter>,
    unfused_lora: bool,
    failure_policy: FailurePolicy,
    fault_plan: Option<FaultPlan>,
    slo_us: u64,
    service_us: u64,
    quarantine_after: u32,
    oracle: bool,
    force_cold: bool,
}

impl FleetBuilder {
    /// Worker replicas (clamped to at least 1).
    pub fn replicas(mut self, n: usize) -> Self {
        self.replicas = n;
        self
    }

    /// Per-replica queue bound (clamped to at least 1): requests beyond
    /// it are shed to the failure policy.
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = n;
        self
    }

    /// Full shared-store configuration (cache budgets, format, prefetch
    /// depth, retry/quarantine tunables).
    pub fn store_config(mut self, cfg: StoreConfig) -> Self {
        self.store_cfg = cfg;
        self
    }

    /// Per-replica batcher tunables.
    pub fn batcher_config(mut self, cfg: BatcherConfig) -> Self {
        self.batcher_cfg = cfg;
        self
    }

    /// Thread pool shared by the store's prefetch and every replica's
    /// engine waves.
    pub fn pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Register SHiRA adapters on the shared store's flash tier.
    pub fn shira_adapters(mut self, zoo: &[ShiraAdapter]) -> Self {
        self.shira.extend(zoo.iter().cloned());
        self
    }

    /// Register LoRA adapters on the shared store's flash tier.
    pub fn lora_adapters(mut self, zoo: &[LoraAdapter]) -> Self {
        self.lora.extend(zoo.iter().cloned());
        self
    }

    /// Serve LoRA singles unfused (branches on the forward pass).
    pub fn unfused_lora(mut self, on: bool) -> Self {
        self.unfused_lora = on;
        self
    }

    /// What to do with failed batches and shed requests.
    pub fn failure_policy(mut self, policy: FailurePolicy) -> Self {
        self.failure_policy = policy;
        self
    }

    /// Arm ONE deterministic fault plan across the shared store and
    /// every replica's engines: per-site ordinals count fleet-wide, so
    /// a seeded plan fires at the same global points on every replay.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Queueing-wait SLO for the fairness ledger, microseconds (0
    /// disables violation counting).
    pub fn slo_us(mut self, us: u64) -> Self {
        self.slo_us = us;
        self
    }

    /// Virtual service time per request, microseconds (clamped to at
    /// least 1) — what the deterministic harness charges a replica's
    /// clock for each served request.
    pub fn service_us(mut self, us: u64) -> Self {
        self.service_us = us;
        self
    }

    /// Consecutive failed applies before a replica is quarantined
    /// (clamped to at least 1).
    pub fn quarantine_after(mut self, n: u32) -> Self {
        self.quarantine_after = n;
        self
    }

    /// Enable/disable the per-request bit-identity oracle (on by
    /// default; benches disable it for timed runs after gating).
    pub fn oracle(mut self, on: bool) -> Self {
        self.oracle = on;
        self
    }

    /// Treat every placement as cold: collapses the affinity ladder so
    /// routing degenerates to least-loaded/lowest-id.  Placement
    /// changes; per-request results must not (property-tested).
    pub fn force_cold(mut self, on: bool) -> Self {
        self.force_cold = on;
        self
    }

    /// Assemble the fleet: one shared store, N replica routers over
    /// clones of the base weights, one optional fault injector armed
    /// across all of them.
    pub fn build(self) -> Fleet {
        let n = self.replicas.max(1);
        let mut store = AdapterStore::with_config(self.store_cfg, self.pool.clone());
        for a in &self.shira {
            store.add_shira(a);
        }
        for a in &self.lora {
            store.add_lora(a);
        }
        let injector = self.fault_plan.map(FaultPlan::injector);
        if let Some(f) = &injector {
            store.set_fault(Arc::clone(f));
        }
        let mut replicas = Vec::with_capacity(n);
        for id in 0..n {
            let mut router = Router::new(self.base.clone(), self.pool.clone(), self.unfused_lora);
            if let Some(f) = &injector {
                router.set_fault(Arc::clone(f));
            }
            replicas.push(Replica {
                id,
                router,
                batcher: DynamicBatcher::new(self.batcher_cfg.clone()),
                clock_us: 0,
                served: 0,
                failures_in_row: 0,
                quarantined: false,
            });
        }
        Fleet {
            store: Arc::new(Mutex::new(store)),
            replicas,
            base: self.base,
            queue_depth: self.queue_depth.max(1),
            failure_policy: self.failure_policy,
            slo_us: self.slo_us,
            service_us: self.service_us.max(1),
            quarantine_after: self.quarantine_after.max(1),
            oracle: self.oracle,
            force_cold: self.force_cold,
            unfused_lora: self.unfused_lora,
        }
    }
}

/// A concurrent serving front end over N worker replicas (module docs;
/// DESIGN.md §14).  Built with [`Fleet::builder`]; driven either by the
/// seeded deterministic harness ([`Fleet::run_trace`]) or for real
/// through MPSC queues and scoped threads
/// ([`Fleet::run_trace_concurrent`]).
pub struct Fleet {
    store: Arc<Mutex<AdapterStore>>,
    replicas: Vec<Replica>,
    base: WeightStore,
    queue_depth: usize,
    failure_policy: FailurePolicy,
    slo_us: u64,
    service_us: u64,
    quarantine_after: u32,
    oracle: bool,
    force_cold: bool,
    unfused_lora: bool,
}

impl Fleet {
    /// Builder over `base` weights (each replica serves its own clone).
    pub fn builder(base: WeightStore) -> FleetBuilder {
        FleetBuilder {
            base,
            replicas: 2,
            queue_depth: 16,
            store_cfg: StoreConfig::default(),
            batcher_cfg: BatcherConfig::default(),
            pool: None,
            shira: Vec::new(),
            lora: Vec::new(),
            unfused_lora: false,
            failure_policy: FailurePolicy::default(),
            fault_plan: None,
            slo_us: 0,
            service_us: 50,
            quarantine_after: 3,
            oracle: true,
            force_cold: false,
        }
    }

    /// Worker replicas in the fleet.
    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// The replicas' routers, in id order — each exposes its resident
    /// weights and active key for end-state assertions.
    pub fn routers(&self) -> impl Iterator<Item = &Router> {
        self.replicas.iter().map(|r| &r.router)
    }

    /// Handle on the shared adapter store (pin audits, stats).
    pub fn store(&self) -> Arc<Mutex<AdapterStore>> {
        Arc::clone(&self.store)
    }

    /// Restore every replica to base weights, release every pin, and
    /// drop all queued requests.
    pub fn revert_all(&mut self) {
        let mut store = relock(&self.store);
        for rep in &mut self.replicas {
            rep.router.revert_all(&mut store);
            rep.batcher.clear();
        }
    }

    /// Scheduler-visible snapshot of every replica (deterministic mode
    /// reads the live structs directly).
    fn views(&self) -> Vec<ReplicaView> {
        self.replicas
            .iter()
            .map(|r| ReplicaView {
                id: r.id,
                queued: r.batcher.pending(),
                active_key: r.router.active_key().map(str::to_string),
                active_single: r.router.active_single().map(str::to_string),
                quarantined: r.quarantined,
            })
            .collect()
    }

    /// Build the fault-free serial reference for the oracle.
    fn make_oracle(&self) -> BitOracle {
        let store = relock(&self.store).fork_reference();
        BitOracle {
            store,
            router: Router::new(self.base.clone(), None, self.unfused_lora),
            refs: HashMap::new(),
            base: self.base.clone(),
            checks: 0,
            failures: Vec::new(),
        }
    }

    /// Run `trace` through the seeded deterministic scheduler (module
    /// docs): single-threaded, virtual-time, every interleaving choice
    /// drawn from `seed`'s stream — so any failing seed replays its
    /// exact interleaving, and the bit-identity oracle checks every
    /// replica after every apply.
    pub fn run_trace(&mut self, trace: &[Request], seed: u64) -> Result<FleetReport, ServeError> {
        for q in trace {
            q.selection.validate()?;
        }
        let mut rng = Rng::new(seed).stream("fleet/schedule");
        let oracle = if self.oracle {
            Some(self.make_oracle())
        } else {
            None
        };
        let mut acc = Accum::new(self.slo_us, oracle);
        for q in trace {
            self.ingest(q, &mut acc)?;
            let steps = rng.below(self.replicas.len() + 1);
            for _ in 0..steps {
                if !self.drain_one(&mut rng, &mut acc)? {
                    break;
                }
            }
        }
        while self.drain_one(&mut rng, &mut acc)? {}
        Ok(self.finish(acc, trace.len() as u64))
    }

    /// Route one arriving request, shedding to the failure policy when
    /// no replica can take it.
    fn ingest(&mut self, req: &Request, acc: &mut Accum) -> Result<(), ServeError> {
        let target = {
            let store = relock(&self.store);
            pick_replica(
                &self.views(),
                &req.selection,
                &store,
                self.queue_depth,
                self.force_cold,
            )
        };
        match target {
            Some(r) => {
                self.replicas[r].batcher.push(req.clone());
                Ok(())
            }
            None => self.shed(req, acc),
        }
    }

    /// Admission control: apply the failure policy to a request no
    /// replica can accept.
    fn shed(&mut self, req: &Request, acc: &mut Accum) -> Result<(), ServeError> {
        let key = req.selection.key();
        match self.failure_policy {
            FailurePolicy::FailFast => Err(ServeError::Overloaded {
                selection: key,
                replicas: self.replicas.len(),
                queue_depth: self.queue_depth,
            }),
            FailurePolicy::DegradeToBase => {
                // Retry the placement as a base request: base is the
                // cheapest selection to make resident anywhere, so this
                // only fails when every queue is genuinely full.
                let target = {
                    let store = relock(&self.store);
                    pick_replica(
                        &self.views(),
                        &Selection::Base,
                        &store,
                        self.queue_depth,
                        self.force_cold,
                    )
                };
                acc.shed += 1;
                acc.fairness.record_shed(&key);
                match target {
                    Some(r) => {
                        acc.degraded += 1;
                        acc.actions.insert(req.id, "shed-degraded");
                        acc.outcomes.push(FleetOutcome {
                            selection: key,
                            requests: 1,
                            replica: Some(r),
                            action: "shed-degraded",
                            error: "admission: no replica can take the selection".into(),
                        });
                        let mut base_req = req.clone();
                        base_req.selection = Selection::Base;
                        self.replicas[r].batcher.push(base_req);
                    }
                    None => {
                        acc.skipped += 1;
                        acc.actions.insert(req.id, "shed-skipped");
                        acc.outcomes.push(FleetOutcome {
                            selection: key,
                            requests: 1,
                            replica: None,
                            action: "shed-skipped",
                            error: "admission: all replica queues full".into(),
                        });
                    }
                }
                Ok(())
            }
            FailurePolicy::SkipRequest => {
                acc.shed += 1;
                acc.skipped += 1;
                acc.fairness.record_shed(&key);
                acc.actions.insert(req.id, "shed-skipped");
                acc.outcomes.push(FleetOutcome {
                    selection: key,
                    requests: 1,
                    replica: None,
                    action: "shed-skipped",
                    error: "admission: all replica queues full".into(),
                });
                Ok(())
            }
        }
    }

    /// Serve one batch on one seeded-randomly-chosen busy replica.
    /// Returns false when the whole fleet is idle.
    fn drain_one(&mut self, rng: &mut Rng, acc: &mut Accum) -> Result<bool, ServeError> {
        let busy: Vec<usize> = self
            .replicas
            .iter()
            .filter(|r| !r.batcher.is_empty())
            .map(|r| r.id)
            .collect();
        if busy.is_empty() {
            return Ok(false);
        }
        let r = busy[rng.below(busy.len())];
        self.serve_one(r, acc)?;
        Ok(true)
    }

    /// Take the next batch on replica `r`, make its selection resident,
    /// account virtual time and fairness, and run the oracle over the
    /// WHOLE fleet (rollback isolation: no other replica's bytes may
    /// have moved).
    fn serve_one(&mut self, r: usize, acc: &mut Accum) -> Result<(), ServeError> {
        let rep = &mut self.replicas[r];
        let active = rep.router.active_key().map(str::to_string);
        let Some((sel, batch)) = rep.batcher.next_batch(active.as_deref()) else {
            return Ok(());
        };
        let key = sel.key();
        let result = {
            let mut store = relock(&self.store);
            let depth = store.prefetch_depth();
            if depth > 0 {
                let mut names: Vec<String> = Vec::new();
                for s in rep.batcher.upcoming(depth, &[key.as_str()]) {
                    for n in s.names() {
                        if !names.iter().any(|x| x == n) {
                            names.push(n.to_string());
                        }
                    }
                }
                store.prefetch(&names);
            }
            rep.router.apply(&mut store, &sel)
        };
        match result {
            Ok(applied) => {
                rep.failures_in_row = 0;
                if applied.switched {
                    acc.switches += 1;
                    acc.record_path(applied.path);
                }
                let newest = batch.iter().map(|q| q.arrival_us).max().unwrap_or(0);
                let start = rep.clock_us.max(newest);
                for q in &batch {
                    let wait = start.saturating_sub(q.arrival_us);
                    acc.fairness.record_wait(&key, wait);
                    acc.waits.push(wait as f64);
                    acc.actions.entry(q.id).or_insert("served");
                }
                rep.clock_us = start + self.service_us * batch.len() as u64;
                rep.served += batch.len() as u64;
                acc.served += batch.len() as u64;
                self.check_fleet(acc, Some(&sel));
                Ok(())
            }
            Err(e) => self.handle_failure(r, &sel, &batch, e, acc),
        }
    }

    /// Oracle sweep over every replica (plus the fleet-wide plan-pin
    /// audit) after an apply — in the deterministic harness this runs
    /// after failures too, which is exactly the rollback-isolation
    /// assertion.
    fn check_fleet(&mut self, acc: &mut Accum, incoming: Option<&Selection>) {
        let Some(oracle) = acc.oracle.as_mut() else {
            return;
        };
        if let Some(sel) = incoming {
            oracle.reference(sel);
        }
        for rep in &self.replicas {
            oracle.check_replica(rep.id, rep.router.active_key(), rep.router.weights());
        }
        let store = relock(&self.store);
        if store.pinned_plan_count() != 0 {
            oracle
                .failures
                .push("transition-plan pin leaked across an apply".to_string());
        }
    }

    /// Apply the failure policy to a batch whose selection could not be
    /// made resident, then re-run the fleet oracle: the failing
    /// replica must be back on base bytes and every OTHER replica's
    /// resident bytes must be untouched.
    fn handle_failure(
        &mut self,
        r: usize,
        sel: &Selection,
        batch: &[Request],
        e: ServeError,
        acc: &mut Accum,
    ) -> Result<(), ServeError> {
        let key = sel.key();
        let n = batch.len() as u64;
        let rep = &mut self.replicas[r];
        rep.failures_in_row += 1;
        if rep.failures_in_row >= self.quarantine_after {
            rep.quarantined = true;
        }
        match self.failure_policy {
            FailurePolicy::FailFast => {
                for rp in &mut self.replicas {
                    rp.batcher.clear();
                }
                Err(e)
            }
            FailurePolicy::DegradeToBase => {
                let ok = {
                    let mut store = relock(&self.store);
                    rep.router.apply(&mut store, &Selection::Base).is_ok()
                };
                if ok {
                    let newest = batch.iter().map(|q| q.arrival_us).max().unwrap_or(0);
                    let start = rep.clock_us.max(newest);
                    for q in batch {
                        let wait = start.saturating_sub(q.arrival_us);
                        acc.fairness.record_wait(&key, wait);
                        acc.waits.push(wait as f64);
                        acc.actions.insert(q.id, "degraded-to-base");
                    }
                    rep.clock_us = start + self.service_us * n;
                    rep.served += n;
                    acc.served += n;
                    acc.degraded += n;
                } else {
                    for q in batch {
                        acc.actions.insert(q.id, "skipped");
                    }
                    acc.skipped += n;
                }
                acc.outcomes.push(FleetOutcome {
                    selection: key,
                    requests: n,
                    replica: Some(r),
                    action: if ok { "degraded-to-base" } else { "skipped" },
                    error: e.to_string(),
                });
                self.check_fleet(acc, None);
                Ok(())
            }
            FailurePolicy::SkipRequest => {
                for q in batch {
                    acc.actions.insert(q.id, "skipped");
                }
                acc.skipped += n;
                acc.outcomes.push(FleetOutcome {
                    selection: key,
                    requests: n,
                    replica: Some(r),
                    action: "skipped",
                    error: e.to_string(),
                });
                self.check_fleet(acc, None);
                Ok(())
            }
        }
    }

    /// Assemble the end-of-run report.
    fn finish(&mut self, mut acc: Accum, requests: u64) -> FleetReport {
        let store = relock(&self.store).stats();
        let makespan_us = self.replicas.iter().map(|r| r.clock_us).max().unwrap_or(0);
        let rollbacks: u64 = self.replicas.iter().map(|r| r.router.rollbacks()).sum();
        let quarantined = self.replicas.iter().filter(|r| r.quarantined).count();
        let per_replica_served: Vec<u64> = self.replicas.iter().map(|r| r.served).collect();
        let (oracle_checks, oracle_failures) = match &acc.oracle {
            Some(o) => (o.checks, o.failures.clone()),
            None => (0, Vec::new()),
        };
        let (p50, p99) = if acc.waits.is_empty() {
            (0.0, 0.0)
        } else {
            (acc.waits.percentile(50.0), acc.waits.percentile(99.0))
        };
        let mut summary = format!(
            "fleet: replicas={} requests={} served={} shed={} degraded={} \
             skipped={} quarantined={}\n\
             switches={} (transition={} fallback={} fused={}) rollbacks={}\n\
             wait: p50={:.1}us p99={:.1}us makespan={}us\n\
             oracle: checks={} failures={}",
            self.replicas.len(),
            requests,
            acc.served,
            acc.shed,
            acc.degraded,
            acc.skipped,
            quarantined,
            acc.switches,
            acc.transitions,
            acc.fallbacks,
            acc.fused,
            rollbacks,
            p50,
            p99,
            makespan_us,
            oracle_checks,
            oracle_failures.len(),
        );
        if !acc.fairness.is_empty() {
            summary.push('\n');
            summary.push_str(&acc.fairness.summary_lines());
        }
        FleetReport {
            replicas: self.replicas.len(),
            requests,
            served: acc.served,
            shed: acc.shed,
            degraded: acc.degraded,
            skipped: acc.skipped,
            switches: acc.switches,
            transitions: acc.transitions,
            fallbacks: acc.fallbacks,
            fused_switches: acc.fused,
            rollbacks,
            quarantined_replicas: quarantined,
            per_replica_served,
            oracle_checks,
            oracle_failures,
            p50_wait_us: p50,
            p99_wait_us: p99,
            makespan_us,
            actions: acc.actions,
            outcomes: acc.outcomes,
            fairness: acc.fairness,
            store,
            summary,
        }
    }

    /// Run `trace` through real MPSC queues and one scoped worker
    /// thread per replica (module docs).  The front end routes each
    /// request off live replica snapshots and sheds to the failure
    /// policy when the chosen queue is full; workers drain their
    /// channels into their own affinity batchers and serve batch by
    /// batch against the shared store.  The oracle (when enabled)
    /// checks each replica after its own applies and cross-checks the
    /// whole fleet after the workers join.
    pub fn run_trace_concurrent(&mut self, trace: &[Request]) -> Result<FleetReport, ServeError> {
        for q in trace {
            q.selection.validate()?;
        }
        let oracle = if self.oracle {
            Some(self.make_oracle())
        } else {
            None
        };
        let shared = Mutex::new(Accum::new(self.slo_us, oracle));
        let slots: Vec<Slot> = (0..self.replicas.len()).map(|_| Slot::default()).collect();
        let stop = AtomicBool::new(false);
        let first_error: Mutex<Option<ServeError>> = Mutex::new(None);
        let ctx = WorkerCtx {
            slots: &slots,
            store: &*self.store,
            shared: &shared,
            stop: &stop,
            first_error: &first_error,
            policy: self.failure_policy,
            service_us: self.service_us,
            quarantine_after: self.quarantine_after,
            queue_depth: self.queue_depth,
            force_cold: self.force_cold,
        };
        let mut senders: Vec<SyncSender<Request>> = Vec::with_capacity(self.replicas.len());
        let mut receivers: Vec<Receiver<Request>> = Vec::with_capacity(self.replicas.len());
        for _ in 0..self.replicas.len() {
            let (tx, rx) = sync_channel::<Request>(self.queue_depth);
            senders.push(tx);
            receivers.push(rx);
        }
        std::thread::scope(|scope| {
            for (rep, rx) in self.replicas.iter_mut().zip(receivers) {
                let ctx = &ctx;
                scope.spawn(move || replica_worker(rep, rx, ctx));
            }
            for q in trace {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                front_route(q, &senders, &ctx);
            }
            drop(senders);
        });
        let mut acc = shared.into_inner().unwrap_or_else(|p| p.into_inner());
        // End-of-run cross-replica sweep: with the workers joined it is
        // safe to read every replica's weights again.
        self.check_fleet(&mut acc, None);
        if let Some(e) = relock(&first_error).take() {
            for rep in &mut self.replicas {
                rep.batcher.clear();
            }
            return Err(e);
        }
        Ok(self.finish(acc, trace.len() as u64))
    }
}

/// Live per-replica scheduler state shared between the concurrent
/// front end and its worker.
#[derive(Default)]
struct Slot {
    /// Requests outstanding on the replica (channel + batcher).
    queued: AtomicUsize,
    /// Mirror of the replica's sticky quarantine flag.
    quarantined: AtomicBool,
    /// Mirror of the replica's (active key, active single) pair.
    active: Mutex<(Option<String>, Option<String>)>,
}

/// Everything a concurrent worker or the front end needs by reference —
/// one struct so the call graph stays narrow.
struct WorkerCtx<'a> {
    slots: &'a [Slot],
    store: &'a Mutex<AdapterStore>,
    shared: &'a Mutex<Accum>,
    stop: &'a AtomicBool,
    first_error: &'a Mutex<Option<ServeError>>,
    policy: FailurePolicy,
    service_us: u64,
    quarantine_after: u32,
    queue_depth: usize,
    force_cold: bool,
}

/// Snapshot every slot into scheduler views for the front end.
fn slot_views(slots: &[Slot]) -> Vec<ReplicaView> {
    slots
        .iter()
        .enumerate()
        .map(|(id, s)| {
            let (active_key, active_single) = relock(&s.active).clone();
            ReplicaView {
                id,
                queued: s.queued.load(Ordering::SeqCst),
                active_key,
                active_single,
                quarantined: s.quarantined.load(Ordering::SeqCst),
            }
        })
        .collect()
}

/// Route one request from the concurrent front end, shedding to the
/// failure policy when no replica can take it (or the chosen queue
/// filled in the race window).
fn front_route(req: &Request, senders: &[SyncSender<Request>], ctx: &WorkerCtx<'_>) {
    let key = req.selection.key();
    let target = {
        let store = relock(ctx.store);
        pick_replica(
            &slot_views(ctx.slots),
            &req.selection,
            &store,
            ctx.queue_depth,
            ctx.force_cold,
        )
    };
    if let Some(r) = target {
        ctx.slots[r].queued.fetch_add(1, Ordering::SeqCst);
        if senders[r].try_send(req.clone()).is_ok() {
            return;
        }
        ctx.slots[r].queued.fetch_sub(1, Ordering::SeqCst);
    }
    match ctx.policy {
        FailurePolicy::FailFast => {
            let mut fe = relock(ctx.first_error);
            if fe.is_none() {
                *fe = Some(ServeError::Overloaded {
                    selection: key,
                    replicas: ctx.slots.len(),
                    queue_depth: ctx.queue_depth,
                });
            }
            drop(fe);
            ctx.stop.store(true, Ordering::SeqCst);
        }
        FailurePolicy::DegradeToBase => {
            let target = {
                let store = relock(ctx.store);
                pick_replica(
                    &slot_views(ctx.slots),
                    &Selection::Base,
                    &store,
                    ctx.queue_depth,
                    ctx.force_cold,
                )
            };
            let mut sent_to = None;
            if let Some(r) = target {
                ctx.slots[r].queued.fetch_add(1, Ordering::SeqCst);
                let mut base_req = req.clone();
                base_req.selection = Selection::Base;
                if senders[r].try_send(base_req).is_ok() {
                    sent_to = Some(r);
                } else {
                    ctx.slots[r].queued.fetch_sub(1, Ordering::SeqCst);
                }
            }
            let mut acc = relock(ctx.shared);
            acc.shed += 1;
            acc.fairness.record_shed(&key);
            match sent_to {
                Some(r) => {
                    acc.degraded += 1;
                    acc.actions.insert(req.id, "shed-degraded");
                    acc.outcomes.push(FleetOutcome {
                        selection: key,
                        requests: 1,
                        replica: Some(r),
                        action: "shed-degraded",
                        error: "admission: no replica can take the selection".into(),
                    });
                }
                None => {
                    acc.skipped += 1;
                    acc.actions.insert(req.id, "shed-skipped");
                    acc.outcomes.push(FleetOutcome {
                        selection: key,
                        requests: 1,
                        replica: None,
                        action: "shed-skipped",
                        error: "admission: all replica queues full".into(),
                    });
                }
            }
        }
        FailurePolicy::SkipRequest => {
            let mut acc = relock(ctx.shared);
            acc.shed += 1;
            acc.skipped += 1;
            acc.fairness.record_shed(&key);
            acc.actions.insert(req.id, "shed-skipped");
            acc.outcomes.push(FleetOutcome {
                selection: key,
                requests: 1,
                replica: None,
                action: "shed-skipped",
                error: "admission: all replica queues full".into(),
            });
        }
    }
}

/// One concurrent worker: drain the channel into the replica's affinity
/// batcher, serve batch by batch, exit when the channel disconnects and
/// the backlog is empty (or a fleet-wide stop is flagged).
fn replica_worker(rep: &mut Replica, rx: Receiver<Request>, ctx: &WorkerCtx<'_>) {
    loop {
        if ctx.stop.load(Ordering::SeqCst) {
            rep.batcher.clear();
            ctx.slots[rep.id].queued.store(0, Ordering::SeqCst);
            return;
        }
        let mut disconnected = false;
        loop {
            match rx.try_recv() {
                Ok(q) => rep.batcher.push(q),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if rep.batcher.is_empty() {
            if disconnected {
                return;
            }
            match rx.recv() {
                Ok(q) => {
                    rep.batcher.push(q);
                    continue;
                }
                Err(_) => return,
            }
        }
        serve_batch_concurrent(rep, ctx);
    }
}

/// Publish a replica's post-apply routing state to its slot.
fn publish_slot(rep: &Replica, ctx: &WorkerCtx<'_>) {
    *relock(&ctx.slots[rep.id].active) = (
        rep.router.active_key().map(str::to_string),
        rep.router.active_single().map(str::to_string),
    );
}

/// Serve one batch inside a concurrent worker (the worker-thread twin
/// of [`Fleet::serve_one`]): apply under the store lock, account
/// virtual time and fairness under the accumulator lock, and run the
/// oracle on this replica's own bytes.
fn serve_batch_concurrent(rep: &mut Replica, ctx: &WorkerCtx<'_>) {
    let active = rep.router.active_key().map(str::to_string);
    let Some((sel, batch)) = rep.batcher.next_batch(active.as_deref()) else {
        return;
    };
    let key = sel.key();
    let n = batch.len() as u64;
    let result = {
        let mut store = relock(ctx.store);
        let depth = store.prefetch_depth();
        if depth > 0 {
            let mut names: Vec<String> = Vec::new();
            for s in rep.batcher.upcoming(depth, &[key.as_str()]) {
                for nm in s.names() {
                    if !names.iter().any(|x| x == nm) {
                        names.push(nm.to_string());
                    }
                }
            }
            store.prefetch(&names);
        }
        rep.router.apply(&mut store, &sel)
    };
    match result {
        Ok(applied) => {
            rep.failures_in_row = 0;
            let newest = batch.iter().map(|q| q.arrival_us).max().unwrap_or(0);
            let start = rep.clock_us.max(newest);
            rep.clock_us = start + ctx.service_us * n;
            rep.served += n;
            publish_slot(rep, ctx);
            ctx.slots[rep.id]
                .queued
                .fetch_sub(batch.len(), Ordering::SeqCst);
            let mut acc = relock(ctx.shared);
            if applied.switched {
                acc.switches += 1;
                acc.record_path(applied.path);
            }
            for q in &batch {
                let wait = start.saturating_sub(q.arrival_us);
                acc.fairness.record_wait(&key, wait);
                acc.waits.push(wait as f64);
                acc.actions.entry(q.id).or_insert("served");
            }
            acc.served += n;
            if let Some(oracle) = acc.oracle.as_mut() {
                oracle.reference(&sel);
                oracle.check_replica(rep.id, rep.router.active_key(), rep.router.weights());
            }
        }
        Err(e) => {
            rep.failures_in_row += 1;
            if rep.failures_in_row >= ctx.quarantine_after {
                rep.quarantined = true;
                ctx.slots[rep.id].quarantined.store(true, Ordering::SeqCst);
            }
            match ctx.policy {
                FailurePolicy::FailFast => {
                    let mut fe = relock(ctx.first_error);
                    if fe.is_none() {
                        *fe = Some(e);
                    }
                    drop(fe);
                    ctx.stop.store(true, Ordering::SeqCst);
                    rep.batcher.clear();
                    publish_slot(rep, ctx);
                    ctx.slots[rep.id].queued.store(0, Ordering::SeqCst);
                }
                FailurePolicy::DegradeToBase => {
                    let ok = {
                        let mut store = relock(ctx.store);
                        rep.router.apply(&mut store, &Selection::Base).is_ok()
                    };
                    if ok {
                        let newest = batch.iter().map(|q| q.arrival_us).max().unwrap_or(0);
                        let start = rep.clock_us.max(newest);
                        rep.clock_us = start + ctx.service_us * n;
                        rep.served += n;
                    }
                    publish_slot(rep, ctx);
                    ctx.slots[rep.id]
                        .queued
                        .fetch_sub(batch.len(), Ordering::SeqCst);
                    let mut acc = relock(ctx.shared);
                    if ok {
                        for q in &batch {
                            acc.actions.insert(q.id, "degraded-to-base");
                        }
                        acc.served += n;
                        acc.degraded += n;
                    } else {
                        for q in &batch {
                            acc.actions.insert(q.id, "skipped");
                        }
                        acc.skipped += n;
                    }
                    acc.outcomes.push(FleetOutcome {
                        selection: key,
                        requests: n,
                        replica: Some(rep.id),
                        action: if ok { "degraded-to-base" } else { "skipped" },
                        error: e.to_string(),
                    });
                    if let Some(oracle) = acc.oracle.as_mut() {
                        oracle.check_replica(
                            rep.id,
                            rep.router.active_key(),
                            rep.router.weights(),
                        );
                    }
                }
                FailurePolicy::SkipRequest => {
                    publish_slot(rep, ctx);
                    ctx.slots[rep.id]
                        .queued
                        .fetch_sub(batch.len(), Ordering::SeqCst);
                    let mut acc = relock(ctx.shared);
                    for q in &batch {
                        acc.actions.insert(q.id, "skipped");
                    }
                    acc.skipped += n;
                    acc.outcomes.push(FleetOutcome {
                        selection: key,
                        requests: n,
                        replica: Some(rep.id),
                        action: "skipped",
                        error: e.to_string(),
                    });
                    if let Some(oracle) = acc.oracle.as_mut() {
                        oracle.check_replica(
                            rep.id,
                            rep.router.active_key(),
                            rep.router.weights(),
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{adapter_names, fleet_trace, toy_base, toy_shira_zoo};
    use crate::util::proptest as pt;

    const DIM: usize = 32;
    const NNZ: usize = 60;

    fn zoo_names(n: usize) -> Vec<String> {
        adapter_names(n)
    }

    fn small_fleet(replicas: usize, seed: u64) -> Fleet {
        let names = zoo_names(4);
        Fleet::builder(toy_base(DIM, seed))
            .replicas(replicas)
            .queue_depth(64)
            .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, seed))
            .store_config(StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 0,
                plan_cache_bytes: 0,
                ..StoreConfig::default()
            })
            .build()
    }

    fn view(id: usize, queued: usize, key: Option<&str>, single: Option<&str>) -> ReplicaView {
        ReplicaView {
            id,
            queued,
            active_key: key.map(str::to_string),
            active_single: single.map(str::to_string),
            quarantined: false,
        }
    }

    #[test]
    fn cost_ladder_orders_exact_plan_warm_cold() {
        let names = zoo_names(3);
        let pool = Arc::new(crate::util::threadpool::ThreadPool::new(2));
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 2,
                ..StoreConfig::default()
            },
            Some(Arc::clone(&pool)),
        );
        for a in &toy_shira_zoo(DIM, &names, NNZ, 7) {
            store.add_shira(a);
        }
        // adapter0/adapter1 resident with an adapter0->adapter1 plan;
        // adapter2 cold.  Plan builds are async: join the pool, then let
        // the next prefetch call drain the staged build into the cache.
        store.fetch("adapter0").unwrap();
        store.fetch("adapter1").unwrap();
        store.prefetch_transitions("adapter0", &["adapter1".to_string()]);
        pool.join();
        store.prefetch_transitions("adapter0", &[]);
        assert!(store.has_transition_plan("adapter0", "adapter1"));
        let sel = Selection::single("adapter1");
        let key = sel.key();
        assert_eq!(
            affinity_cost(&view(0, 0, Some(&key), Some("adapter1")), &sel, &key, &store),
            COST_EXACT
        );
        assert_eq!(
            affinity_cost(&view(1, 0, Some("adapter0@1"), Some("adapter0")), &sel, &key, &store),
            COST_PLAN
        );
        assert_eq!(
            affinity_cost(&view(2, 0, None, None), &sel, &key, &store),
            COST_WARM
        );
        let cold = Selection::single("adapter2");
        assert_eq!(
            affinity_cost(&view(3, 0, None, None), &cold, &cold.key(), &store),
            COST_COLD
        );
        // Base is warm anywhere (no names to fetch), exact on a base
        // replica.
        assert_eq!(
            affinity_cost(&view(4, 0, Some(""), None), &Selection::Base, "", &store),
            COST_EXACT
        );
        assert_eq!(
            affinity_cost(&view(5, 0, None, None), &Selection::Base, "", &store),
            COST_WARM
        );
        // pick_replica prefers the exact replica over the plan replica
        // over warm over cold, regardless of ordering in the slice.
        let views = vec![
            view(0, 3, None, None),                            // warm
            view(1, 3, Some("adapter0@1"), Some("adapter0")), // plan
            view(2, 3, Some(&key), Some("adapter1")),         // exact
        ];
        assert_eq!(pick_replica(&views, &sel, &store, 8, false), Some(2));
        assert_eq!(pick_replica(&views[..2], &sel, &store, 8, false), Some(1));
        assert_eq!(pick_replica(&views[..1], &sel, &store, 8, false), Some(0));
        // force_cold collapses the ladder: least-loaded wins.
        let views = vec![
            view(0, 5, Some(&key), Some("adapter1")),
            view(1, 2, None, None),
        ];
        assert_eq!(pick_replica(&views, &sel, &store, 8, true), Some(1));
    }

    #[test]
    fn prop_scheduler_respects_quarantine_bounds_and_ties() {
        // Satellite 2: over random replica states the scheduler never
        // selects a quarantined replica, never exceeds the queue bound,
        // and breaks ties deterministically (same inputs, same pick;
        // equal-cost candidates resolve to the lowest (queued, id)).
        let names = zoo_names(3);
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 0,
                ..StoreConfig::default()
            },
            None,
        );
        for a in &toy_shira_zoo(DIM, &names, NNZ, 3) {
            store.add_shira(a);
        }
        store.fetch("adapter0").unwrap();
        pt::forall(
            0xF1EE7,
            60,
            |r: &mut Rng| {
                let depth = 1 + r.below(6);
                let views: Vec<(usize, bool, u8)> = (0..1 + r.below(6))
                    .map(|_| (r.below(8), r.below(4) == 0, r.below(3) as u8))
                    .collect();
                (depth, views, r.below(3))
            },
            |&(depth, ref raw, which)| {
                let views: Vec<ReplicaView> = raw
                    .iter()
                    .enumerate()
                    .map(|(id, &(queued, quarantined, state))| ReplicaView {
                        id,
                        queued,
                        active_key: (state == 1).then(|| "adapter0@1".to_string()),
                        active_single: (state == 1).then(|| "adapter0".to_string()),
                        quarantined,
                    })
                    .collect();
                let sel = match which {
                    0 => Selection::Base,
                    1 => Selection::single("adapter0"),
                    _ => Selection::single("adapter2"),
                };
                let pick = pick_replica(&views, &sel, &store, depth, false);
                // Determinism: the same inputs pick the same replica.
                if pick != pick_replica(&views, &sel, &store, depth, false) {
                    return false;
                }
                match pick {
                    None => views.iter().all(|v| v.quarantined || v.queued >= depth),
                    Some(id) => {
                        let v = &views[id];
                        if v.quarantined || v.queued >= depth {
                            return false;
                        }
                        // No strictly better candidate was skipped.
                        let key = sel.key();
                        let cost = affinity_cost(v, &sel, &key, &store);
                        views
                            .iter()
                            .filter(|w| !w.quarantined && w.queued < depth)
                            .all(|w| {
                                (affinity_cost(w, &sel, &key, &store), w.queued, w.id)
                                    >= (cost, v.queued, v.id)
                            })
                    }
                }
            },
        );
    }

    #[test]
    fn deterministic_run_replays_bit_identically_from_one_seed() {
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 120, 4, 0xAB);
        let run = |schedule_seed: u64| {
            let mut fleet = small_fleet(3, 5);
            let report = fleet.run_trace(&trace, schedule_seed).unwrap();
            let finals: Vec<Option<String>> = fleet
                .routers()
                .map(|r| r.active_key().map(str::to_string))
                .collect();
            (report, finals)
        };
        let (a, fa) = run(0xD5);
        let (b, fb) = run(0xD5);
        assert_eq!(a.actions, b.actions);
        assert_eq!(a.per_replica_served, b.per_replica_served);
        assert_eq!(a.summary, b.summary);
        assert_eq!(fa, fb);
        assert!(a.oracle_failures.is_empty(), "{:?}", a.oracle_failures);
        assert_eq!(a.served, 120);
        // A different schedule seed may place work differently but every
        // request still lands "served" with the oracle green.
        let (c, _) = run(0xE6);
        assert_eq!(a.actions, c.actions);
        assert!(c.oracle_failures.is_empty(), "{:?}", c.oracle_failures);
    }

    #[test]
    fn force_cold_changes_placement_only() {
        // Satellite 2 (second half): force-cold routing may move work
        // between replicas but never changes per-request results.
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 100, 6, 0xCC);
        let run = |force: bool| {
            let names = zoo_names(4);
            let mut fleet = Fleet::builder(toy_base(DIM, 9))
                .replicas(3)
                .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, 9))
                .store_config(StoreConfig {
                    cache_bytes: 64 << 20,
                    prefetch_depth: 0,
                    plan_cache_bytes: 0,
                    ..StoreConfig::default()
                })
                .force_cold(force)
                .build();
            let report = fleet.run_trace(&trace, 0x11).unwrap();
            assert!(report.oracle_failures.is_empty(), "{:?}", report.oracle_failures);
            report
        };
        let warm = run(false);
        let cold = run(true);
        assert_eq!(warm.actions, cold.actions, "results must not change");
        assert_eq!(warm.served, cold.served);
        // Affinity routing must beat cold routing on switches for a
        // bursty trace (that is the point of the ladder).
        assert!(
            warm.switches <= cold.switches,
            "affinity {} vs cold {}",
            warm.switches,
            cold.switches
        );
    }

    #[test]
    fn admission_control_sheds_to_policy() {
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 40, 2, 0x5EED);
        // Tiny queue, no draining headroom: 1 replica, depth 1.
        let build = |policy: FailurePolicy| {
            let names = zoo_names(4);
            Fleet::builder(toy_base(DIM, 3))
                .replicas(1)
                .queue_depth(1)
                .failure_policy(policy)
                .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, 3))
                .store_config(StoreConfig {
                    cache_bytes: 64 << 20,
                    prefetch_depth: 0,
                    plan_cache_bytes: 0,
                    ..StoreConfig::default()
                })
                .build()
        };
        // Zero drain steps ever happening is not guaranteed by the rng,
        // so force the overload deterministically: seed 0 gives some
        // ingests with no drain in between for a depth-1 queue.
        let mut fleet = build(FailurePolicy::FailFast);
        let err = fleet.run_trace(&trace, 0).unwrap_err();
        match err {
            ServeError::Overloaded {
                replicas, queue_depth, ..
            } => {
                assert_eq!((replicas, queue_depth), (1, 1));
            }
            other => panic!("expected Overloaded, got {other}"),
        }
        let mut fleet = build(FailurePolicy::SkipRequest);
        let report = fleet.run_trace(&trace, 0).unwrap();
        assert!(report.shed > 0);
        assert_eq!(report.shed, report.fairness.total_shed());
        assert_eq!(report.served + report.skipped, 40);
        assert!(report
            .outcomes
            .iter()
            .all(|o| o.action == "shed-skipped" && o.replica.is_none()));
        assert!(report.oracle_failures.is_empty(), "{:?}", report.oracle_failures);
    }

    #[test]
    fn concurrent_mode_serves_everything_with_green_oracle() {
        let names = zoo_names(4);
        let sels = Selection::singles(&names);
        let trace = fleet_trace(&sels, 80, 4, 0xC0);
        let mut fleet = Fleet::builder(toy_base(DIM, 11))
            .replicas(2)
            .queue_depth(128)
            .shira_adapters(&toy_shira_zoo(DIM, &names, NNZ, 11))
            .store_config(StoreConfig {
                cache_bytes: 64 << 20,
                prefetch_depth: 0,
                plan_cache_bytes: 0,
                ..StoreConfig::default()
            })
            .build();
        let report = fleet.run_trace_concurrent(&trace).unwrap();
        assert_eq!(report.served, 80);
        assert!(report.oracle_failures.is_empty(), "{:?}", report.oracle_failures);
        assert!(report.actions.values().all(|&a| a == "served"));
        // Fleet-wide pin audit: after revert_all nothing stays pinned.
        fleet.revert_all();
        let store = fleet.store();
        let guard = store.lock().unwrap();
        assert_eq!(guard.pinned_count(), 0);
        assert_eq!(guard.pinned_plan_count(), 0);
    }

    #[test]
    fn builder_clamps_and_defaults() {
        let fleet = Fleet::builder(toy_base(DIM, 1))
            .replicas(0)
            .queue_depth(0)
            .build();
        assert_eq!(fleet.replica_count(), 1);
        assert_eq!(fleet.queue_depth, 1);
        let fleet = Fleet::builder(toy_base(DIM, 1)).build();
        assert_eq!(fleet.replica_count(), 2);
        assert_eq!(fleet.queue_depth, 16);
        assert!(fleet.oracle);
    }
}
