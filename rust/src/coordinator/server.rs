//! The serving loop: router → affinity batcher → switch engine → PJRT
//! executor, with byte-budgeted adapter caching and full metrics.
//!
//! This is the deployment the paper argues for (Appendix A): one resident
//! copy of the base weights, many adapters on "flash" (the encoded-bytes
//! store), rapid in-place switching on the request path.
//!
//! Under [`Policy::ShiraFusion`] requests name adapter *sets* (a
//! [`SetSpec`] string such as `"style@0.5+task"`); set specs are
//! canonicalized so the batcher's affinity policy extends to set identity,
//! and transitions between sets run through the incremental
//! [`FusionEngine`] — touching only the adapters that changed.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::fusion_engine::{FusionEngine, FusionPlan, SetSpec};
use super::metrics::ServeMetrics;
use super::switch::{Policy, SwitchEngine, SwitchPath};
use crate::adapter::LoraAdapter;
use crate::data::trace::Request;
use crate::model::weights::WeightStore;
use crate::runtime::manifest::LoraSeg;
use crate::runtime::{HostValue, Runtime};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

pub use super::store::{AdapterStore, AnyAdapter, StoreConfig, StoreStats};

/// End-of-run report.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// The policy the trace was served under.
    pub policy: Policy,
    /// Wall-clock seconds for the whole trace.
    pub wall_secs: f64,
    /// Requests completed.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Adapter (or adapter-set) switches performed.
    pub switches: u64,
    /// Switches that took the one-pass direct transition path.
    pub transitions: u64,
    /// Switches that fell back to revert+apply.
    pub fallbacks: u64,
    /// Store-built shard-plan sets the engine ignored as mismatched.
    pub plan_mismatches: u64,
    /// Requests per wall-clock second.
    pub throughput_rps: f64,
    /// Mean weight-mutation time per switch, microseconds.
    pub mean_switch_us: f64,
    /// Median switch time, microseconds.
    pub p50_switch_us: f64,
    /// 99th-percentile switch time, microseconds.
    pub p99_switch_us: f64,
    /// Mean executor time per batch, microseconds.
    pub mean_exec_us: f64,
    /// Median executor time, microseconds.
    pub p50_exec_us: f64,
    /// 99th-percentile executor time, microseconds.
    pub p99_exec_us: f64,
    /// 99th-percentile end-to-end request latency, microseconds.
    pub p99_latency_us: f64,
    /// Decoded-adapter cache hit rate over the run.
    pub cache_hit_rate: f64,
    /// Adapter-store lifecycle counters (cache, prefetch, residency).
    pub store: StoreStats,
    /// Human-readable multi-line summary (see `ServeMetrics::summary`).
    pub summary: String,
}

/// The serving coordinator: owns the switch engine (and, in fused mode,
/// the incremental fusion engine), the adapter store and the batcher, and
/// drives request traces to completion against a [`Runtime`].
pub struct Server<'rt> {
    rt: &'rt Runtime,
    /// The switch engine holding the resident base weights.
    pub engine: SwitchEngine,
    /// The adapter lifecycle store: flash bytes, decode cache, prefetch.
    pub store: AdapterStore,
    batcher: DynamicBatcher,
    policy: Policy,
    model: String,
    alpha: f32,
    fusion: Option<FusionEngine>,
    /// Name pinned in the store for the currently-applied adapter.
    pinned_active: Option<String>,
    /// Names pinned in the store for the active fusion roster.
    pinned_roster: Vec<String>,
}

impl<'rt> Server<'rt> {
    /// Server with a host-sized switch-work pool and default store
    /// settings at the given cache budget.
    pub fn new(
        rt: &'rt Runtime,
        base: WeightStore,
        policy: Policy,
        model: &str,
        cache_bytes: usize,
    ) -> Result<Self> {
        let pool = Arc::new(ThreadPool::host_sized());
        Self::with_pool(rt, base, policy, model, cache_bytes, pool)
    }

    /// Server with an explicit switch-work pool; the pool is shared with
    /// the engine (scatter/restore overlap across target tensors) and the
    /// store (background prefetch decode).
    pub fn with_pool(
        rt: &'rt Runtime,
        base: WeightStore,
        policy: Policy,
        model: &str,
        cache_bytes: usize,
        pool: Arc<ThreadPool>,
    ) -> Result<Self> {
        Self::with_store_config(
            rt,
            base,
            policy,
            model,
            StoreConfig {
                cache_bytes,
                ..StoreConfig::default()
            },
            pool,
        )
    }

    /// Server with full adapter-store tunables (cache budget, on-flash
    /// format, prefetch depth) — the CLI's `--cache-bytes`,
    /// `--prefetch-depth` and `--format` knobs land here.
    pub fn with_store_config(
        rt: &'rt Runtime,
        base: WeightStore,
        policy: Policy,
        model: &str,
        store_cfg: StoreConfig,
        pool: Arc<ThreadPool>,
    ) -> Result<Self> {
        let meta = rt.manifest.model(model).map_err(|e| anyhow!("{e}"))?;
        let max_batch = meta.dim("batch");
        Ok(Server {
            rt,
            engine: SwitchEngine::with_pool(base, Some(Arc::clone(&pool))),
            store: AdapterStore::with_config(store_cfg, Some(pool)),
            batcher: DynamicBatcher::new(BatcherConfig {
                max_batch,
                max_wait_rounds: 4,
            }),
            policy,
            model: model.to_string(),
            alpha: 1.0,
            fusion: None,
            pinned_active: None,
            pinned_roster: Vec::new(),
        })
    }

    /// Strength at which SHiRA adapters are applied (single-adapter mode).
    pub fn set_alpha(&mut self, alpha: f32) {
        self.alpha = alpha;
    }

    /// Build the incremental fused-mode engine over the named adapters
    /// (the fusion roster) and snapshot the base weights.  All members
    /// must be SHiRA adapters present in the store; each is pinned there
    /// for as long as the roster is live, so no cache pressure can evict
    /// an adapter that fused-mode serving may touch.  Any active
    /// single-adapter switch is reverted first so the snapshot sees base
    /// values.  [`Self::run_trace`] calls this lazily under
    /// [`Policy::ShiraFusion`] with every adapter the trace names.
    pub fn enable_fusion(&mut self, names: &[String]) -> Result<()> {
        // Release the previous roster's pins up front: the fetch loop
        // below pins each new member the moment it lands, and stale pins
        // must neither crowd the new members out of the cache nor leak
        // when the rosters are disjoint.
        self.unpin_roster();
        let result = self.build_fusion(names);
        if result.is_err() {
            // Don't leave a half-built roster pinned.
            self.unpin_roster();
        }
        result
    }

    fn build_fusion(&mut self, names: &[String]) -> Result<()> {
        let mut roster = Vec::with_capacity(names.len());
        for n in names {
            if n.contains('+') || n.contains('@') {
                // '+' and '@' are SetSpec metacharacters: such a name
                // could never be addressed by a fused-set request.
                return Err(anyhow!(
                    "fusion roster member {n:?} contains a set-spec \
                     metacharacter ('+' or '@')"
                ));
            }
            match &self.store.fetch(n)?.adapter {
                AnyAdapter::Shira(a) => {
                    roster.push(Arc::clone(a));
                    // Pin as fetched, so a later member's decode can
                    // never evict this one mid-build (pin only fails for
                    // oversized-uncached entries, which were never
                    // resident to protect).
                    if self.store.pin(n) {
                        self.pinned_roster.push(n.clone());
                    }
                }
                AnyAdapter::Lora(_) => {
                    return Err(anyhow!("fusion roster member {n} is not a SHiRA adapter"))
                }
            }
        }
        // Unwind any previous fused state BEFORE snapshotting: a live
        // engine's writes are invisible to `revert`, and dropping it
        // without deactivating would bake its deltas into the new base.
        if let Some(mut f) = self.fusion.take() {
            f.deactivate(&mut self.engine.weights);
        }
        self.engine.revert();
        // The reverted single-adapter switch no longer needs residency.
        if let Some(prev) = self.pinned_active.take() {
            self.store.unpin(&prev);
        }
        let plan = FusionPlan::build(roster)?;
        let mut fusion = FusionEngine::with_pool(plan, self.engine.pool().cloned());
        fusion.activate(&mut self.engine.weights)?;
        self.fusion = Some(fusion);
        Ok(())
    }

    /// Tear down fused-mode serving, restoring base weights exactly and
    /// releasing the roster's store pins.
    pub fn disable_fusion(&mut self) {
        self.unpin_roster();
        if let Some(mut f) = self.fusion.take() {
            f.deactivate(&mut self.engine.weights);
        }
    }

    fn unpin_roster(&mut self) {
        for n in self.pinned_roster.drain(..) {
            self.store.unpin(&n);
        }
    }

    /// The fused-mode engine, when enabled.
    pub fn fusion(&self) -> Option<&FusionEngine> {
        self.fusion.as_ref()
    }

    /// Pack a LoRA adapter into the flat theta the unfused artifact expects.
    fn pack_lora_theta(a: &LoraAdapter, segs: &[LoraSeg], total: usize) -> Vec<f32> {
        let mut theta = vec![0.0f32; total];
        for seg in segs {
            if let Some(t) = a.find(&seg.name) {
                theta[seg.a_off..seg.a_off + seg.a_len].copy_from_slice(&t.a.data);
                theta[seg.b_off..seg.b_off + seg.b_len].copy_from_slice(&t.b.data);
            }
        }
        theta
    }

    /// Run a full trace to completion; returns the report.
    ///
    /// Under [`Policy::ShiraFusion`] each request's `adapter` field is a
    /// [`SetSpec`] string; it is canonicalized before batching so two
    /// spellings of the same set batch together, and the batcher's
    /// affinity keeps consecutive batches on the currently-fused set.
    pub fn run_trace(&mut self, trace: &[Request]) -> Result<ServeReport> {
        let meta = self.rt.manifest.model(&self.model).map_err(|e| anyhow!("{e}"))?.clone();
        let (b, t) = (meta.dim("batch"), meta.dim("seq_len"));
        let vocab = meta.dim("vocab");
        let fwd = self.rt.load(&format!("{}_fwd", self.model))?;
        let unfused = if self.policy == Policy::LoraUnfused {
            Some(self.rt.load(&format!("{}_fwd_unfused_lora", self.model))?)
        } else {
            None
        };
        let theta_total = meta.theta_len.get("lora").copied().unwrap_or(0);

        if self.policy == Policy::ShiraFusion {
            // One parse per request: canonicalize the set id (so "b+a@1"
            // batches with "a+b") and collect every adapter the trace
            // names from the same parsed specs.
            let mut names: Vec<String> = Vec::new();
            let mut ids = Vec::with_capacity(trace.len());
            for r in trace {
                let spec = SetSpec::parse(&r.adapter)?;
                for (n, _) in &spec.members {
                    if !names.iter().any(|x| x == n) {
                        names.push(n.clone());
                    }
                }
                ids.push(spec.id());
            }
            // (Re)build the engine when the trace names adapters outside
            // the current roster — keeping already-enabled members so
            // earlier sets stay addressable.  An empty trace enables
            // nothing and returns a zeroed report like the other policies.
            let needs_roster = match &self.fusion {
                None => !names.is_empty(),
                Some(f) => names
                    .iter()
                    .any(|n| f.plan().member_index(n).is_none()),
            };
            if needs_roster {
                if let Some(f) = &self.fusion {
                    for a in f.plan().roster() {
                        if !names.iter().any(|x| x == &a.name) {
                            names.push(a.name.clone());
                        }
                    }
                }
                names.sort();
                self.enable_fusion(&names)?;
            }
            for (r, id) in trace.iter().zip(ids) {
                let mut req = r.clone();
                req.adapter = id;
                self.batcher.push(req);
            }
        } else {
            for r in trace {
                self.batcher.push(r.clone());
            }
        }
        let mut current_set: Option<String> = None;

        let mut metrics = ServeMetrics::new();
        let wall0 = Instant::now();
        loop {
            let active: Option<String> = if self.policy == Policy::ShiraFusion {
                current_set.clone()
            } else {
                self.engine.active_name().map(|s| s.to_string())
            };
            let (adapter_name, batch) = match self.batcher.next_batch(active.as_deref()) {
                Some(next) => next,
                None => break,
            };
            // ---- prefetch stage -----------------------------------------
            // Affinity lookahead: decode the adapters the batcher will
            // schedule next in the background, so their switches hit the
            // staging area instead of paying decode on the request path.
            // (Fused mode pins its whole roster resident at enable time.)
            if self.policy != Policy::ShiraFusion && self.store.prefetch_depth() > 0 {
                let ahead = self
                    .batcher
                    .upcoming(self.store.prefetch_depth(), &[adapter_name.as_str()]);
                if !ahead.is_empty() {
                    self.store.prefetch(&ahead);
                }
            }
            // ---- switch stage -------------------------------------------
            let needs_switch;
            let mut switch_us = 0.0;
            let mut lora_theta: Option<Vec<f32>> = None;
            if self.policy == Policy::ShiraFusion {
                needs_switch = current_set.as_deref() != Some(adapter_name.as_str());
                if needs_switch {
                    let spec = SetSpec::parse(&adapter_name)?;
                    let t0 = Instant::now();
                    let fusion = self
                        .fusion
                        .as_mut()
                        .expect("fusion engine enabled above");
                    // Incremental transition: only adapters that changed
                    // between the sets are touched.
                    fusion.apply_set(&mut self.engine.weights, &spec.members)?;
                    switch_us = t0.elapsed().as_secs_f64() * 1e6;
                    current_set = Some(adapter_name.clone());
                }
            } else {
                needs_switch = self.engine.active_name() != Some(adapter_name.as_str());
                if needs_switch || self.policy == Policy::LoraUnfused {
                    let entry = self.store.fetch(&adapter_name)?;
                    // Pin the adapter we are about to apply; the previous
                    // active adapter's pin is released.  An in-flight
                    // switch can therefore never lose its cache entry.
                    // (Unfused LoRA never mutates the weights — there is
                    // no applied adapter to keep resident, and its
                    // `needs_switch` is true every batch, which would
                    // leak one pin per batch.)
                    if needs_switch && self.policy != Policy::LoraUnfused {
                        self.store.pin(&adapter_name);
                        if let Some(prev) = self.pinned_active.replace(adapter_name.clone())
                        {
                            if prev != adapter_name {
                                self.store.unpin(&prev);
                            }
                        }
                    }
                    let t0 = Instant::now();
                    match (&entry.adapter, self.policy) {
                        (AnyAdapter::Shira(a), Policy::ShiraScatter) => {
                            // Hot pair with a resident pairwise plan: one
                            // pass over the A∪B support union, ONE pool
                            // dispatch wave.  Cold pair (or first switch):
                            // classic revert+apply.  Bytes are identical
                            // on both paths; the plan is pinned for the
                            // duration of the in-flight transition.
                            let plan = active
                                .as_deref()
                                .filter(|prev| *prev != adapter_name.as_str())
                                .and_then(|prev| {
                                    self.store.begin_transition(prev, &adapter_name)
                                });
                            let path = match plan {
                                Some(tp) => {
                                    let (_t, path) = self.engine.transition_to(
                                        Arc::clone(a),
                                        Some(Arc::clone(&entry.plans)),
                                        &tp,
                                        self.alpha,
                                    );
                                    self.store.end_transition(
                                        active.as_deref().unwrap_or_default(),
                                        &adapter_name,
                                    );
                                    path
                                }
                                None => {
                                    // Arc-shared activation: no tensor
                                    // copy on the request path, snapshots
                                    // land in the engine arena, and the
                                    // store-built shard plans skip plan
                                    // construction (shard-aligned decode).
                                    self.engine.switch_to_shira_planned(
                                        Arc::clone(a),
                                        Some(Arc::clone(&entry.plans)),
                                        self.alpha,
                                    );
                                    SwitchPath::Fallback
                                }
                            };
                            metrics
                                .record_switch_path(path == SwitchPath::Transition);
                        }
                        (AnyAdapter::Lora(a), Policy::LoraFuse) => {
                            self.engine.switch_to_lora_shared(Arc::clone(a));
                        }
                        (AnyAdapter::Lora(a), Policy::LoraUnfused) => {
                            // weights stay at base; branches ride the fwd
                            // pass
                            lora_theta =
                                Some(Self::pack_lora_theta(a, &meta.lora, theta_total));
                        }
                        (a, p) => {
                            return Err(anyhow!(
                                "adapter {} family does not match policy {}",
                                a.name(),
                                p.name()
                            ))
                        }
                    }
                    switch_us = t0.elapsed().as_secs_f64() * 1e6;
                }
            }

            // ---- transition-plan prefetch -------------------------------
            // Pairwise plans need both adapters decoded, so this runs
            // after the switch stage: the now-active adapter is resident
            // and pinned, and `upcoming` is told to skip names whose pair
            // is already planned — the lookahead surfaces only pairs the
            // plan cache is missing.  Builds run off the serving thread;
            // the switch that needs a still-cold pair just falls back.
            if self.policy == Policy::ShiraScatter && self.store.prefetch_depth() > 0 {
                let planned = self.store.planned_to_names(&adapter_name);
                let mut exclude: Vec<&str> =
                    planned.iter().map(|s| s.as_str()).collect();
                exclude.push(adapter_name.as_str());
                let pair_ahead = self
                    .batcher
                    .upcoming(self.store.prefetch_depth(), &exclude);
                if !pair_ahead.is_empty() {
                    self.store.prefetch_transitions(&adapter_name, &pair_ahead);
                }
            }

            // ---- execute stage ------------------------------------------
            let t0 = Instant::now();
            let mut rng = Rng::new(batch[0].payload_seed);
            let mut tokens = Vec::with_capacity(b * t);
            for r in &batch {
                let mut prng = rng.stream(&format!("payload/{}", r.id));
                for _ in 0..t {
                    tokens.push(prng.below(vocab) as i32);
                }
            }
            while tokens.len() < b * t {
                // pad with the last request's stream
                tokens.push(rng.below(vocab) as i32);
            }
            let mut inputs: Vec<HostValue> = meta
                .params
                .iter()
                .map(|(name, shape)| {
                    HostValue::f32(self.engine.weights.get(name).data.clone(), shape.clone())
                })
                .collect();
            if let Some(theta) = lora_theta {
                inputs.push(HostValue::f32(theta, vec![theta_total]));
            }
            inputs.push(HostValue::i32(tokens, vec![b, t]));
            let exe = if self.policy == Policy::LoraUnfused {
                unfused.as_ref().unwrap()
            } else {
                &fwd
            };
            let out = exe.run(&inputs)?;
            debug_assert!(out[0].as_f32().iter().all(|x| x.is_finite()));
            let exec_us = t0.elapsed().as_secs_f64() * 1e6;

            metrics.record_batch(batch.len(), needs_switch, switch_us, exec_us);
        }
        let wall = wall0.elapsed().as_secs_f64();
        let store_stats = self.store.stats();
        metrics.set_store(store_stats.clone());
        metrics.set_plan_mismatches(self.engine.plan_mismatches);
        let p99 = metrics.request_latency.percentile_us(99.0);
        let (p50_switch, p99_switch) = if metrics.switch_us.is_empty() {
            (0.0, 0.0)
        } else {
            (
                metrics.switch_us.percentile(50.0),
                metrics.switch_us.percentile(99.0),
            )
        };
        let (p50_exec, p99_exec) = if metrics.exec_us.is_empty() {
            (0.0, 0.0)
        } else {
            (
                metrics.exec_us.percentile(50.0),
                metrics.exec_us.percentile(99.0),
            )
        };
        Ok(ServeReport {
            policy: self.policy,
            wall_secs: wall,
            requests: metrics.requests,
            batches: metrics.batches,
            switches: metrics.switches,
            transitions: metrics.transitions,
            fallbacks: metrics.fallbacks,
            plan_mismatches: metrics.plan_mismatches,
            throughput_rps: metrics.requests as f64 / wall.max(1e-9),
            mean_switch_us: metrics.switch_us.mean(),
            p50_switch_us: p50_switch,
            p99_switch_us: p99_switch,
            mean_exec_us: metrics.exec_us.mean(),
            p50_exec_us: p50_exec,
            p99_exec_us: p99_exec,
            p99_latency_us: p99,
            cache_hit_rate: store_stats.hit_rate(),
            store: store_stats,
            summary: metrics.summary(wall),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::sparse::SparseDelta;
    use crate::adapter::{LoraTensor, ShiraAdapter};
    use crate::data::trace::{generate_trace, TracePattern};
    use crate::model::tensor::Tensor2;
    use crate::runtime::manifest::Manifest;

    fn runtime() -> Option<Runtime> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::new(&dir).expect("runtime"))
        } else {
            None
        }
    }

    fn make_shira(rt: &Runtime, name: &str, seed: u64) -> ShiraAdapter {
        let meta = rt.manifest.model("llama").unwrap();
        let mut rng = Rng::new(seed);
        let tensors = meta
            .shira
            .iter()
            .map(|seg| {
                let idx = rng.sample_indices(seg.numel(), seg.k);
                let mut d = vec![0.0; seg.k];
                rng.fill_normal(&mut d, 0.0, 0.01);
                (
                    seg.name.clone(),
                    SparseDelta::new(seg.shape.0, seg.shape.1, idx, d),
                )
            })
            .collect();
        ShiraAdapter {
            name: name.into(),
            strategy: "rand".into(),
            tensors,
        }
    }

    fn make_lora(rt: &Runtime, name: &str, seed: u64) -> LoraAdapter {
        let meta = rt.manifest.model("llama").unwrap();
        let mut rng = Rng::new(seed);
        let tensors = meta
            .lora
            .iter()
            .map(|seg| {
                let mut a = Tensor2::zeros(seg.shape.0, seg.rank);
                let mut b = Tensor2::zeros(seg.rank, seg.shape.1);
                rng.fill_normal(&mut a.data, 0.0, 0.01);
                rng.fill_normal(&mut b.data, 0.0, 0.01);
                LoraTensor {
                    target: seg.name.clone(),
                    a,
                    b,
                }
            })
            .collect();
        LoraAdapter {
            name: name.into(),
            scale: rt.manifest.adapter.lora_scale as f32,
            tensors,
        }
    }

    fn serve(policy: Policy, n: usize) -> Option<ServeReport> {
        let rt = runtime()?;
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let mut server = Server::new(&rt, base, policy, "llama", 1 << 20).unwrap();
        let names: Vec<String> = (0..3).map(|i| format!("ad{i}")).collect();
        for (i, name) in names.iter().enumerate() {
            match policy {
                Policy::ShiraScatter | Policy::ShiraFusion => {
                    server.store.add_shira(&make_shira(&rt, name, i as u64))
                }
                _ => server.store.add_lora(&make_lora(&rt, name, i as u64)),
            }
        }
        let trace = generate_trace(&names, n, TracePattern::Bursty { burst: 6 }, 1e4, 1);
        Some(server.run_trace(&trace).unwrap())
    }

    #[test]
    fn shira_serving_completes_all_requests() {
        let Some(rep) = serve(Policy::ShiraScatter, 24) else { return };
        assert_eq!(rep.requests, 24);
        assert!(rep.batches >= 3);
        assert!(rep.switches >= 1);
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.summary.contains("requests=24"));
        // The lifecycle counters ride the report and the summary.
        assert!(rep.store.misses >= 1);
        assert!(rep.store.resident_entries >= 1);
        assert!(rep.summary.contains("store:"));
        // Every ShiraScatter switch is classified transition-or-fallback
        // (which one depends on whether the background plan build won the
        // race — the bytes are identical either way).
        assert_eq!(rep.transitions + rep.fallbacks, rep.switches);
        assert!(rep.summary.contains("paths: transition="));
        assert!(rep.summary.contains("plans: hits="));
    }

    #[test]
    fn lora_fuse_serving_completes() {
        let Some(rep) = serve(Policy::LoraFuse, 16) else { return };
        assert_eq!(rep.requests, 16);
        assert!(rep.mean_switch_us > 0.0);
    }

    #[test]
    fn lora_unfused_serving_completes() {
        let Some(rep) = serve(Policy::LoraUnfused, 16) else { return };
        assert_eq!(rep.requests, 16);
    }

    #[test]
    fn single_member_sets_serve_under_fusion_policy() {
        // Plain adapter names are valid one-member set specs, so the
        // fused-mode server handles single-adapter traces too.
        let Some(rep) = serve(Policy::ShiraFusion, 16) else { return };
        assert_eq!(rep.requests, 16);
        assert!(rep.switches >= 1);
    }

    #[test]
    fn fused_set_serving_completes_and_restores_base() {
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let mut server =
            Server::new(&rt, base.clone(), Policy::ShiraFusion, "llama", 1 << 20).unwrap();
        for (i, name) in ["ad0", "ad1", "ad2"].iter().enumerate() {
            server.store.add_shira(&make_shira(&rt, name, i as u64));
        }
        // Two spellings of the same set share one canonical identity, so
        // they batch together and cost no extra transition.
        let sets = vec![
            "ad0+ad1".to_string(),
            "ad1+ad0".to_string(),
            "ad1@0.5+ad2".to_string(),
            "ad0+ad1+ad2@2".to_string(),
        ];
        let trace = generate_trace(&sets, 16, TracePattern::Bursty { burst: 4 }, 1e4, 5);
        let rep = server.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 16);
        assert!(rep.switches >= 1);
        let fusion = server.fusion().expect("fusion enabled lazily");
        assert_eq!(fusion.plan().len(), 3);
        assert!(fusion.updates() > 0);
        // Re-enabling over a different roster must unwind the live fused
        // state first, or the new base snapshot would bake it in.
        server
            .enable_fusion(&["ad0".to_string(), "ad1".to_string()])
            .unwrap();
        assert_eq!(server.fusion().unwrap().plan().len(), 2);
        server.disable_fusion();
        server.engine.revert();
        assert!(server.engine.weights.bit_equal(&base));
    }

    #[test]
    fn base_weights_restored_after_serving_shira() {
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let mut server =
            Server::new(&rt, base.clone(), Policy::ShiraScatter, "llama", 1 << 20)
                .unwrap();
        server.store.add_shira(&make_shira(&rt, "a", 1));
        let trace = generate_trace(
            &["a".to_string()],
            8,
            TracePattern::UniformMix,
            1e4,
            2,
        );
        server.run_trace(&trace).unwrap();
        server.engine.revert();
        assert!(server.engine.weights.bit_equal(&base));
    }

    #[test]
    fn policy_family_mismatch_errors() {
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let mut server =
            Server::new(&rt, base, Policy::ShiraScatter, "llama", 1 << 20).unwrap();
        server.store.add_lora(&make_lora(&rt, "l", 1));
        let trace = generate_trace(
            &["l".to_string()],
            4,
            TracePattern::UniformMix,
            1e4,
            3,
        );
        assert!(server.run_trace(&trace).is_err());
    }
}
