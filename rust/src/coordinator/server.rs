//! The serving loop: selection router → affinity batcher → engines →
//! PJRT executor, with byte-budgeted adapter caching and full metrics.
//!
//! This is the deployment the paper argues for (Appendix A): one resident
//! copy of the base weights, many adapters on "flash" (the encoded-bytes
//! store), rapid in-place switching on the request path.  Every
//! [`Request`] carries a [`Selection`] — base weights, one adapter, or a
//! weighted adapter set — and one [`Server::run_trace`] routes all three
//! uniformly per-request through the [`Router`]: there is no
//! construction-time policy fork and no `enable_fusion` side channel
//! (fusion rosters grow lazily as set selections arrive).
//!
//! Servers are built with [`ServerBuilder`] (replacing the old
//! `new`/`with_pool`/`with_store_config` constructor trio), and every
//! fallible call returns the structured
//! [`ServeError`](super::error::ServeError) so callers can branch on the
//! failure instead of string-matching.  See `rust/README.md` for the
//! old-API → new-API migration table.

use std::sync::Arc;
use std::time::Instant;

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::engine::Router;
use super::fault::{FaultInjector, FaultPlan, FaultSite};
use super::fusion_engine::FusionEngine;
use super::gate::{request_features, Gate};
use super::metrics::ServeMetrics;
use super::pool::{lock_pool, SharedExpertPool};
use crate::adapter::io::Format;
use crate::adapter::LoraAdapter;
use crate::data::trace::Request;
use crate::model::weights::WeightStore;
use crate::runtime::manifest::LoraSeg;
use crate::runtime::{Executable, HostValue, Runtime};
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;

pub use super::error::ServeError;
pub use super::selection::{Selection, SelectionKind};
pub use super::store::{AdapterStore, AnyAdapter, StoreConfig, StoreStats};

/// What to do with a batch whose selection cannot be made resident
/// (store failure, quarantine, or a rolled-back mutation) — the
/// degraded-mode half of the failure model (DESIGN.md §13.4).
///
/// Whatever the policy, the router has already restored a consistent
/// state before it surfaces the error: pre-dispatch failures never
/// touched the weights and mutation failures rolled back to base.  The
/// policy only decides what happens to the REQUESTS.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FailurePolicy {
    /// Abort the trace on the first failed selection, draining the
    /// queue and returning the error (the legacy behavior, and the
    /// default).
    #[default]
    FailFast,
    /// Serve the failed batch on base weights and keep going — requests
    /// complete, degraded; counted in [`ServeMetrics::degraded`] and
    /// recorded in [`ServeReport::outcomes`].
    DegradeToBase,
    /// Drop the failed batch (its requests never execute) and keep
    /// going; counted in [`ServeMetrics::skipped`] and recorded in
    /// [`ServeReport::outcomes`].
    SkipRequest,
}

/// How one failed selection batch was handled under the failure policy.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// Canonical key of the selection that failed.
    pub selection: String,
    /// Requests in the affected batch.
    pub requests: u64,
    /// `"degraded-to-base"`, `"skipped"`, `"gate-degraded-to-base"` or
    /// `"gate-skipped"`.
    pub action: &'static str,
    /// Display form of the error that triggered the policy.
    pub error: String,
}

/// What the gate-resolution pass did to one trace: the rewritten
/// requests plus the counters/outcomes the serve loop folds into its
/// metrics.
struct Resolution {
    requests: Vec<Request>,
    gated: u64,
    degraded: u64,
    skipped: u64,
    outcomes: Vec<RequestOutcome>,
}

/// End-of-run report.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Wall-clock seconds for the whole trace.
    pub wall_secs: f64,
    /// Requests completed.
    pub requests: u64,
    /// Requests that selected the base model.
    pub base_requests: u64,
    /// Requests that selected a single adapter.
    pub single_requests: u64,
    /// Requests that selected a fused adapter set.
    pub set_requests: u64,
    /// Requests that arrived as `Selection::Auto` and were resolved by
    /// the gate (counted under the resolved kind above too).
    pub gated: u64,
    /// Per-expert served-request counters from the expert pool, sorted
    /// by name (empty when no pool is configured).
    pub expert_utilization: Vec<(String, u64)>,
    /// Batches executed.
    pub batches: u64,
    /// Selection switches performed (resident state changed).
    pub switches: u64,
    /// Switches that took the one-pass direct transition path.
    pub transitions: u64,
    /// Switches that fell back to revert+apply.
    pub fallbacks: u64,
    /// Switches served by the incremental fused-mode engine.
    pub fused_switches: u64,
    /// Store-built shard-plan sets the engine ignored as mismatched.
    pub plan_mismatches: u64,
    /// Requests per wall-clock second.
    pub throughput_rps: f64,
    /// Mean weight-mutation time per switch, microseconds.
    pub mean_switch_us: f64,
    /// Median switch time, microseconds.
    pub p50_switch_us: f64,
    /// 99th-percentile switch time, microseconds.
    pub p99_switch_us: f64,
    /// Mean executor time per batch, microseconds.
    pub mean_exec_us: f64,
    /// Median executor time, microseconds.
    pub p50_exec_us: f64,
    /// 99th-percentile executor time, microseconds.
    pub p99_exec_us: f64,
    /// 99th-percentile end-to-end request latency, microseconds.
    pub p99_latency_us: f64,
    /// Decoded-adapter cache hit rate over the run.
    pub cache_hit_rate: f64,
    /// Adapter-store lifecycle counters (cache, prefetch, residency).
    pub store: StoreStats,
    /// Failed mutations rolled back to base during this trace.
    pub rollbacks: u64,
    /// Requests served on base weights under `DegradeToBase`.
    pub degraded: u64,
    /// Requests dropped under `SkipRequest`.
    pub skipped: u64,
    /// One entry per failed batch the failure policy handled (empty
    /// under `FailFast`, which returns the error instead).
    pub outcomes: Vec<RequestOutcome>,
    /// Human-readable multi-line summary (see `ServeMetrics::summary`).
    pub summary: String,
}

/// Builder for [`Server`]: model, store tunables, batcher tunables,
/// thread pool, and the unfused-LoRA serving mode.
///
/// Defaults: model `"llama"`, [`StoreConfig::default`] (8 MiB decode
/// cache, v2 flash format, prefetch depth 2, 4 MiB plan cache), a
/// host-sized shared pool, batcher sized to the model's batch dim, LoRA
/// singles dense-fused.
///
/// ```no_run
/// # fn main() -> Result<(), shira::coordinator::error::ServeError> {
/// use shira::coordinator::server::Server;
/// use shira::model::weights::WeightStore;
/// use shira::runtime::Runtime;
///
/// let rt = Runtime::with_default_artifacts()
///     .map_err(shira::coordinator::error::ServeError::runtime)?;
/// let meta = rt.manifest.model("llama").unwrap();
/// let base = WeightStore::init(&meta.params, 7);
/// let server = Server::builder(&rt, base)
///     .model("llama")
///     .cache_bytes(8 << 20)
///     .prefetch_depth(2)
///     .build()?;
/// # let _ = server; Ok(()) }
/// ```
pub struct ServerBuilder<'rt> {
    rt: &'rt Runtime,
    base: WeightStore,
    model: String,
    store_cfg: StoreConfig,
    batcher_cfg: Option<BatcherConfig>,
    pool: Option<Arc<ThreadPool>>,
    unfused_lora: bool,
    failure_policy: FailurePolicy,
    fault_plan: Option<FaultPlan>,
    gate: Option<Arc<dyn Gate>>,
    expert_pool: Option<SharedExpertPool>,
}

impl<'rt> ServerBuilder<'rt> {
    /// Builder over a runtime and the resident base weights.
    pub fn new(rt: &'rt Runtime, base: WeightStore) -> Self {
        ServerBuilder {
            rt,
            base,
            model: "llama".to_string(),
            store_cfg: StoreConfig::default(),
            batcher_cfg: None,
            pool: None,
            unfused_lora: false,
            failure_policy: FailurePolicy::default(),
            fault_plan: None,
            gate: None,
            expert_pool: None,
        }
    }

    /// Install a gate that resolves [`Selection::Auto`] requests into
    /// explicit selections before any batching or placement happens.
    /// Without one, auto requests fail gate resolution (and degrade or
    /// skip under the matching [`FailurePolicy`]).
    pub fn gate(mut self, gate: Arc<dyn Gate>) -> Self {
        self.gate = Some(gate);
        self
    }

    /// Share an expert pool: the roster the gate scores over, with
    /// register/retire lifecycle and per-expert utilization counters
    /// (surfaced in [`ServeReport::expert_utilization`]).
    pub fn expert_pool(mut self, pool: SharedExpertPool) -> Self {
        self.expert_pool = Some(pool);
        self
    }

    /// What to do with batches whose selection cannot be made resident
    /// (default [`FailurePolicy::FailFast`], the legacy behavior).
    pub fn failure_policy(mut self, policy: FailurePolicy) -> Self {
        self.failure_policy = policy;
        self
    }

    /// Arm a deterministic fault plan: ONE injector is built from it
    /// and threaded into both the adapter store (fetch/decode faults,
    /// slow fetches) and the router's engines (wave panics), so a
    /// chaos scenario shares one ordinal space end to end.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Model name in the manifest (default `"llama"`).
    pub fn model(mut self, model: &str) -> Self {
        self.model = model.to_string();
        self
    }

    /// Byte budget of the decoded-adapter cache.
    pub fn cache_bytes(mut self, bytes: usize) -> Self {
        self.store_cfg.cache_bytes = bytes;
        self
    }

    /// On-flash encoding for adapters added to the store.
    pub fn format(mut self, format: Format) -> Self {
        self.store_cfg.format = format;
        self
    }

    /// Keep SHiRA deltas binary16-resident in the decode cache when the
    /// flash image is `v2-f16`: values stay `u16` bits and are widened
    /// lane-wise inside the scatter kernels at apply time, halving the
    /// resident delta bytes (DESIGN.md §15).  Serving bytes are
    /// bit-identical to f32-resident serving of the same file, because
    /// binary16 → f32 widening is exact.
    pub fn f16_resident(mut self, on: bool) -> Self {
        self.store_cfg.f16_resident = on;
        self
    }

    /// Background-prefetch lookahead depth (0 disables prefetch).
    pub fn prefetch_depth(mut self, depth: usize) -> Self {
        self.store_cfg.prefetch_depth = depth;
        self
    }

    /// Byte budget of the pairwise transition-plan cache (0 disables
    /// direct A→B transitions).
    pub fn plan_cache_bytes(mut self, bytes: usize) -> Self {
        self.store_cfg.plan_cache_bytes = bytes;
        self
    }

    /// Replace the whole store configuration at once.
    pub fn store_config(mut self, cfg: StoreConfig) -> Self {
        self.store_cfg = cfg;
        self
    }

    /// Batcher tunables (default: max batch = the model's batch dim,
    /// aging bound 4 rounds).
    pub fn batcher_config(mut self, cfg: BatcherConfig) -> Self {
        self.batcher_cfg = Some(cfg);
        self
    }

    /// Share an explicit thread pool between the engines (scatter and
    /// fused-refresh dispatch) and the store (background prefetch
    /// decode + plan builds).  Default: a host-sized pool.
    pub fn pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Serve LoRA singles *unfused*: weights stay at base and the
    /// adapter's branches ride the forward pass through the
    /// `*_fwd_unfused_lora` artifact (the paper's LoRA-unfused
    /// baseline).  SHiRA selections are unaffected.
    pub fn unfused_lora(mut self, on: bool) -> Self {
        self.unfused_lora = on;
        self
    }

    /// Build the server.  Fails with [`ServeError::UnknownModel`] when
    /// the manifest has no such model.
    pub fn build(self) -> Result<Server<'rt>, ServeError> {
        let meta = self
            .rt
            .manifest
            .model(&self.model)
            .map_err(|_| ServeError::UnknownModel(self.model.clone()))?;
        let max_batch = meta.dim("batch");
        let pool = self
            .pool
            .unwrap_or_else(|| Arc::new(ThreadPool::host_sized()));
        let mut store = AdapterStore::with_config(self.store_cfg, Some(Arc::clone(&pool)));
        let mut router = Router::new(self.base, Some(pool), self.unfused_lora);
        let mut fault = None;
        if let Some(plan) = &self.fault_plan {
            let injector = plan.injector();
            store.set_fault(Arc::clone(&injector));
            router.set_fault(Arc::clone(&injector));
            // The server keeps its own handle: gate faults fire at
            // resolution time, before the store or engines are involved.
            fault = Some(injector);
        }
        let batcher = DynamicBatcher::new(self.batcher_cfg.unwrap_or(BatcherConfig {
            max_batch,
            max_wait_rounds: 4,
        }));
        Ok(Server {
            rt: self.rt,
            model: self.model,
            router,
            store,
            batcher,
            policy: self.failure_policy,
            fault,
            gate: self.gate,
            expert_pool: self.expert_pool,
        })
    }
}

/// The serving coordinator: owns the [`Router`] (resident weights + both
/// engines + pins), the adapter store and the batcher, and drives
/// request traces to completion against a [`Runtime`].
pub struct Server<'rt> {
    rt: &'rt Runtime,
    model: String,
    /// Per-request routing state machine (weights, engines, residency).
    pub router: Router,
    /// The adapter lifecycle store: flash bytes, decode cache, prefetch.
    pub store: AdapterStore,
    batcher: DynamicBatcher,
    policy: FailurePolicy,
    fault: Option<Arc<FaultInjector>>,
    gate: Option<Arc<dyn Gate>>,
    expert_pool: Option<SharedExpertPool>,
}

impl<'rt> Server<'rt> {
    /// Start building a server over `rt` and the resident base weights.
    pub fn builder(rt: &'rt Runtime, base: WeightStore) -> ServerBuilder<'rt> {
        ServerBuilder::new(rt, base)
    }

    /// The resident weights (base + whatever the active selection
    /// applied).
    pub fn weights(&self) -> &WeightStore {
        self.router.weights()
    }

    /// The fused-mode engine, once a set selection has built it.
    pub fn fusion(&self) -> Option<&FusionEngine> {
        self.router.fusion()
    }

    /// Restore base weights exactly and release every residency pin
    /// (drops the fusion roster; the next set selection rebuilds it).
    pub fn revert_all(&mut self) {
        self.router.revert_all(&mut self.store);
    }

    /// Resolve one auto request: fire any planned gate fault, score the
    /// pool's roster with the gate, count utilization.  Pure in the
    /// payload seed — the same seed over the same roster always yields
    /// the same selection.
    fn resolve_auto(&mut self, payload_seed: u64) -> Result<Selection, ServeError> {
        if let Some(f) = &self.fault {
            if f.should_fire(FaultSite::Gate) {
                return Err(ServeError::Gate {
                    reason: FaultInjector::GATE_FAULT_MSG.to_string(),
                });
            }
        }
        let gate = self.gate.as_ref().ok_or_else(|| ServeError::Gate {
            reason: "no gate configured (auto selections need a gate)".into(),
        })?;
        let pool = self.expert_pool.as_ref().ok_or_else(|| ServeError::Gate {
            reason: "no expert pool configured (auto selections need one)"
                .into(),
        })?;
        let roster = lock_pool(pool).roster();
        let sel = gate.select(&request_features(payload_seed), &roster)?;
        lock_pool(pool).record_served(&sel.names());
        Ok(sel)
    }

    /// The gate-resolution pass, policy-aware: autos resolve to explicit
    /// selections; on a gate failure `FailFast` surfaces the error,
    /// `DegradeToBase` rewrites to [`Selection::Base`], `SkipRequest`
    /// drops the request.
    fn resolve(&mut self, trace: &[Request]) -> Result<Resolution, ServeError> {
        let mut res = Resolution {
            requests: Vec::with_capacity(trace.len()),
            gated: 0,
            degraded: 0,
            skipped: 0,
            outcomes: Vec::new(),
        };
        for r in trace {
            if !matches!(r.selection, Selection::Auto) {
                res.requests.push(r.clone());
                continue;
            }
            match self.resolve_auto(r.payload_seed) {
                Ok(sel) => {
                    res.gated += 1;
                    let mut rr = r.clone();
                    rr.selection = sel;
                    res.requests.push(rr);
                }
                Err(e) => match self.policy {
                    FailurePolicy::FailFast => return Err(e),
                    FailurePolicy::DegradeToBase => {
                        res.degraded += 1;
                        res.outcomes.push(RequestOutcome {
                            selection: Selection::Auto.key(),
                            requests: 1,
                            action: "gate-degraded-to-base",
                            error: e.to_string(),
                        });
                        let mut rr = r.clone();
                        rr.selection = Selection::Base;
                        res.requests.push(rr);
                    }
                    FailurePolicy::SkipRequest => {
                        res.skipped += 1;
                        res.outcomes.push(RequestOutcome {
                            selection: Selection::Auto.key(),
                            requests: 1,
                            action: "gate-skipped",
                            error: e.to_string(),
                        });
                    }
                },
            }
        }
        Ok(res)
    }

    /// Rewrite every [`Selection::Auto`] in `trace` into the gate's
    /// explicit selection (the same rewrite [`Self::run_trace`] performs
    /// before batching).  Public so replay tests can serve the returned
    /// explicit trace and compare resident weights bit-for-bit against
    /// the auto-served run.
    pub fn resolve_trace(
        &mut self,
        trace: &[Request],
    ) -> Result<Vec<Request>, ServeError> {
        Ok(self.resolve(trace)?.requests)
    }

    /// Pack a LoRA adapter into the flat theta the unfused artifact expects.
    fn pack_lora_theta(a: &LoraAdapter, segs: &[LoraSeg], total: usize) -> Vec<f32> {
        let mut theta = vec![0.0f32; total];
        for seg in segs {
            if let Some(t) = a.find(&seg.name) {
                theta[seg.a_off..seg.a_off + seg.a_len].copy_from_slice(&t.a.data);
                theta[seg.b_off..seg.b_off + seg.b_len].copy_from_slice(&t.b.data);
            }
        }
        theta
    }

    /// Run a full trace to completion; returns the report.
    ///
    /// Each request's [`Selection`] is validated and queued by canonical
    /// identity (two spellings of one set batch together); per batch the
    /// router makes the selection resident — scatter, direct transition,
    /// fused one-wave update, or dense LoRA fuse, whichever the
    /// selection and adapter family call for — and the executor runs.
    /// A switch is counted only when the resident selection actually
    /// changes.
    pub fn run_trace(&mut self, trace: &[Request]) -> Result<ServeReport, ServeError> {
        let meta = self
            .rt
            .manifest
            .model(&self.model)
            .map_err(|_| ServeError::UnknownModel(self.model.clone()))?
            .clone();
        let (b, t) = (meta.dim("batch"), meta.dim("seq_len"));
        let vocab = meta.dim("vocab");
        let fwd = self
            .rt
            .load(&format!("{}_fwd", self.model))
            .map_err(ServeError::runtime)?;
        // Loaded lazily on the first unfused-LoRA batch.
        let mut unfused_exe: Option<Arc<Executable>> = None;
        let theta_total = meta.theta_len.get("lora").copied().unwrap_or(0);

        let mut metrics = ServeMetrics::new();
        let mut outcomes: Vec<RequestOutcome> = Vec::new();
        // Rollbacks are cumulative on the router; report this trace's share.
        let rollbacks0 = self.router.rollbacks();
        // Validate every selection before enqueueing any, so a malformed
        // request rejects the trace without leaving a partial queue.
        for r in trace {
            r.selection.validate()?;
        }
        // ---- gate-resolution stage ----------------------------------
        // Autos are rewritten into the gate's explicit selections BEFORE
        // any batching: downstream, a gated trace is indistinguishable
        // from the same trace written explicitly, so batcher affinity
        // and transition-plan prefetch see the resolved keys and
        // determinism reduces to the explicit-trace argument
        // (DESIGN.md §17.3).
        let resolved = self.resolve(trace)?;
        metrics.record_gated(resolved.gated);
        metrics.record_degraded(resolved.degraded);
        metrics.record_skipped(resolved.skipped);
        outcomes.extend(resolved.outcomes);
        for r in &resolved.requests {
            metrics.record_selection(r.selection.kind());
            self.batcher.push(r.clone());
        }
        let wall0 = Instant::now();
        loop {
            let active = self.router.active_key().map(str::to_string);
            let (sel, batch) = match self.batcher.next_batch(active.as_deref()) {
                Some(next) => next,
                None => break,
            };
            let key = sel.key();
            // ---- prefetch stage -----------------------------------------
            // Affinity lookahead: decode the adapters of the selections
            // the batcher will schedule next in the background, so their
            // switches hit the staging area instead of paying decode on
            // the request path.  The window is wider than the prefetch
            // depth because Base queues contribute no names; the store
            // bounds the submissions to its depth.  (Roster members are
            // pinned resident.)
            let depth = self.store.prefetch_depth();
            if depth > 0 {
                let ahead = self.batcher.upcoming(2 * depth + 1, &[key.as_str()]);
                let mut names: Vec<String> = Vec::new();
                for s in &ahead {
                    for n in s.names() {
                        if !names.iter().any(|x| x == n) {
                            names.push(n.to_string());
                        }
                    }
                }
                if !names.is_empty() {
                    self.store.prefetch(&names);
                }
            }
            // ---- switch stage -------------------------------------------
            // The router reports its own weight-mutation time
            // (`Applied::switch_us`): store fetch/decode and roster builds
            // stay OUT of the switch metric, as they always have.
            // Whatever the failure policy, a failed apply left the router
            // consistent: pre-dispatch errors never touched the weights
            // and mutation failures rolled back to base (engine.rs).
            let applied = match self.router.apply(&mut self.store, &sel) {
                Ok(applied) => applied,
                Err(e) => match self.policy {
                    FailurePolicy::FailFast => {
                        // Drain the queue: a later trace must not replay
                        // this failed trace's tail.
                        self.batcher.clear();
                        return Err(e);
                    }
                    FailurePolicy::SkipRequest => {
                        metrics.record_skipped(batch.len() as u64);
                        outcomes.push(RequestOutcome {
                            selection: key.clone(),
                            requests: batch.len() as u64,
                            action: "skipped",
                            error: e.to_string(),
                        });
                        continue;
                    }
                    FailurePolicy::DegradeToBase => {
                        metrics.record_degraded(batch.len() as u64);
                        outcomes.push(RequestOutcome {
                            selection: key.clone(),
                            requests: batch.len() as u64,
                            action: "degraded-to-base",
                            error: e.to_string(),
                        });
                        match self.router.apply(&mut self.store, &Selection::Base) {
                            Ok(applied) => applied,
                            Err(e) => {
                                // Even base is unservable: fail the trace.
                                self.batcher.clear();
                                return Err(e);
                            }
                        }
                    }
                },
            };
            let switch_us = if applied.switched { applied.switch_us } else { 0.0 };
            if applied.switched {
                if let Some(path) = applied.path {
                    metrics.record_switch_path(path);
                }
            }

            // ---- transition-plan prefetch -------------------------------
            // Pairwise plans need both adapters decoded, so this runs
            // after the switch stage: the now-active single is resident
            // and pinned.  The lookahead window is wider than the depth
            // and filtered AFTER the fact — base/set queues and adapters
            // whose pair is already planned must not use up the depth
            // budget, or mixed traces would starve the plan cache.
            // Builds run off the serving thread; a switch that needs a
            // still-cold pair just falls back.
            if let Selection::Single { name, .. } = &sel {
                if depth > 0 {
                    let planned = self.store.planned_to_names(name);
                    let ahead = self.batcher.upcoming(4 * depth + 2, &[key.as_str()]);
                    let mut tos: Vec<String> = Vec::new();
                    for s in &ahead {
                        if let Selection::Single { name: n, .. } = s {
                            if n != name
                                && !planned.iter().any(|p| p == n)
                                && !tos.iter().any(|x| x == n)
                            {
                                tos.push(n.clone());
                                if tos.len() == depth {
                                    break;
                                }
                            }
                        }
                    }
                    if !tos.is_empty() {
                        self.store.prefetch_transitions(name, &tos);
                    }
                }
            }

            // ---- execute stage ------------------------------------------
            let t0 = Instant::now();
            let lora_theta = applied
                .unfused_lora
                .as_deref()
                .map(|a| Self::pack_lora_theta(a, &meta.lora, theta_total));
            let mut rng = Rng::new(batch[0].payload_seed);
            let mut tokens = Vec::with_capacity(b * t);
            for r in &batch {
                let mut prng = rng.stream(&format!("payload/{}", r.id));
                for _ in 0..t {
                    tokens.push(prng.below(vocab) as i32);
                }
            }
            while tokens.len() < b * t {
                // pad with the last request's stream
                tokens.push(rng.below(vocab) as i32);
            }
            let mut inputs: Vec<HostValue> = meta
                .params
                .iter()
                .map(|(name, shape)| {
                    HostValue::f32(
                        self.router.weights().get(name).data.clone(),
                        shape.clone(),
                    )
                })
                .collect();
            let unfused_batch = lora_theta.is_some();
            if let Some(theta) = lora_theta {
                inputs.push(HostValue::f32(theta, vec![theta_total]));
                if unfused_exe.is_none() {
                    match self.rt.load(&format!("{}_fwd_unfused_lora", self.model)) {
                        Ok(exe) => unfused_exe = Some(exe),
                        Err(e) => {
                            self.batcher.clear();
                            return Err(ServeError::runtime(e));
                        }
                    }
                }
            }
            inputs.push(HostValue::i32(tokens, vec![b, t]));
            let exe = if unfused_batch {
                unfused_exe.as_ref().expect("loaded above")
            } else {
                &fwd
            };
            let out = match exe.run(&inputs) {
                Ok(out) => out,
                Err(e) => {
                    self.batcher.clear();
                    return Err(ServeError::runtime(e));
                }
            };
            debug_assert!(out[0].as_f32().iter().all(|x| x.is_finite()));
            let exec_us = t0.elapsed().as_secs_f64() * 1e6;

            metrics.record_batch(batch.len(), applied.switched, switch_us, exec_us);
        }
        let wall = wall0.elapsed().as_secs_f64();
        let store_stats = self.store.stats();
        metrics.set_store(store_stats.clone());
        metrics.set_plan_mismatches(self.router.single_counters().plan_mismatches);
        metrics.rollbacks = self.router.rollbacks() - rollbacks0;
        let p99 = metrics.request_latency.percentile_us(99.0);
        let (p50_switch, p99_switch) = if metrics.switch_us.is_empty() {
            (0.0, 0.0)
        } else {
            (
                metrics.switch_us.percentile(50.0),
                metrics.switch_us.percentile(99.0),
            )
        };
        let (p50_exec, p99_exec) = if metrics.exec_us.is_empty() {
            (0.0, 0.0)
        } else {
            (
                metrics.exec_us.percentile(50.0),
                metrics.exec_us.percentile(99.0),
            )
        };
        Ok(ServeReport {
            wall_secs: wall,
            requests: metrics.requests,
            base_requests: metrics.base_requests,
            single_requests: metrics.single_requests,
            set_requests: metrics.set_requests,
            gated: metrics.gated,
            expert_utilization: self
                .expert_pool
                .as_ref()
                .map(|p| lock_pool(p).utilization())
                .unwrap_or_default(),
            batches: metrics.batches,
            switches: metrics.switches,
            transitions: metrics.transitions,
            fallbacks: metrics.fallbacks,
            fused_switches: metrics.fused_switches,
            plan_mismatches: metrics.plan_mismatches,
            throughput_rps: metrics.requests as f64 / wall.max(1e-9),
            mean_switch_us: metrics.switch_us.mean(),
            p50_switch_us: p50_switch,
            p99_switch_us: p99_switch,
            mean_exec_us: metrics.exec_us.mean(),
            p50_exec_us: p50_exec,
            p99_exec_us: p99_exec,
            p99_latency_us: p99,
            cache_hit_rate: store_stats.hit_rate(),
            store: store_stats,
            rollbacks: metrics.rollbacks,
            degraded: metrics.degraded,
            skipped: metrics.skipped,
            outcomes,
            summary: metrics.summary(wall),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::sparse::SparseDelta;
    use crate::adapter::{LoraTensor, ShiraAdapter};
    use crate::data::trace::{generate_trace, TracePattern};
    use crate::model::tensor::Tensor2;
    use crate::runtime::manifest::Manifest;

    fn runtime() -> Option<Runtime> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Runtime::new(&dir).expect("runtime"))
        } else {
            None
        }
    }

    fn make_shira(rt: &Runtime, name: &str, seed: u64) -> ShiraAdapter {
        let meta = rt.manifest.model("llama").unwrap();
        let mut rng = Rng::new(seed);
        let tensors = meta
            .shira
            .iter()
            .map(|seg| {
                let idx = rng.sample_indices(seg.numel(), seg.k);
                let mut d = vec![0.0; seg.k];
                rng.fill_normal(&mut d, 0.0, 0.01);
                (
                    seg.name.clone(),
                    SparseDelta::new(seg.shape.0, seg.shape.1, idx, d),
                )
            })
            .collect();
        ShiraAdapter {
            name: name.into(),
            strategy: "rand".into(),
            tensors,
        }
    }

    fn make_lora(rt: &Runtime, name: &str, seed: u64) -> LoraAdapter {
        let meta = rt.manifest.model("llama").unwrap();
        let mut rng = Rng::new(seed);
        let tensors = meta
            .lora
            .iter()
            .map(|seg| {
                let mut a = Tensor2::zeros(seg.shape.0, seg.rank);
                let mut b = Tensor2::zeros(seg.rank, seg.shape.1);
                rng.fill_normal(&mut a.data, 0.0, 0.01);
                rng.fill_normal(&mut b.data, 0.0, 0.01);
                LoraTensor {
                    target: seg.name.clone(),
                    a,
                    b,
                }
            })
            .collect();
        LoraAdapter {
            name: name.into(),
            scale: rt.manifest.adapter.lora_scale as f32,
            tensors,
        }
    }

    enum Zoo {
        Shira,
        Lora,
    }

    fn server_with<'rt>(rt: &'rt Runtime, zoo: Zoo, unfused: bool) -> (Server<'rt>, Vec<String>) {
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let mut server = Server::builder(rt, base)
            .model("llama")
            .cache_bytes(1 << 20)
            .unfused_lora(unfused)
            .build()
            .unwrap();
        let names: Vec<String> = (0..3).map(|i| format!("ad{i}")).collect();
        for (i, name) in names.iter().enumerate() {
            match zoo {
                Zoo::Shira => server.store.add_shira(&make_shira(rt, name, i as u64)),
                Zoo::Lora => server.store.add_lora(&make_lora(rt, name, i as u64)),
            }
        }
        (server, names)
    }

    #[test]
    fn shira_single_serving_completes_all_requests() {
        let Some(rt) = runtime() else { return };
        let (mut server, names) = server_with(&rt, Zoo::Shira, false);
        let trace = generate_trace(
            &Selection::singles(&names),
            24,
            TracePattern::Bursty { burst: 6 },
            1e4,
            1,
        );
        let rep = server.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 24);
        assert_eq!(rep.single_requests, 24);
        assert!(rep.batches >= 3);
        assert!(rep.switches >= 1);
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.summary.contains("requests=24"));
        // The lifecycle counters ride the report and the summary.
        assert!(rep.store.misses >= 1);
        assert!(rep.store.resident_entries >= 1);
        assert!(rep.summary.contains("store:"));
        // Every single-adapter switch is classified transition-or-fallback
        // (which one depends on whether the background plan build won the
        // race — the bytes are identical either way).
        assert_eq!(rep.transitions + rep.fallbacks, rep.switches);
        assert!(rep.summary.contains("paths: transition="));
        assert!(rep.summary.contains("plans: hits="));
    }

    #[test]
    fn lora_fuse_serving_completes() {
        let Some(rt) = runtime() else { return };
        let (mut server, names) = server_with(&rt, Zoo::Lora, false);
        let trace = generate_trace(
            &Selection::singles(&names),
            16,
            TracePattern::Bursty { burst: 6 },
            1e4,
            1,
        );
        let rep = server.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 16);
        assert!(rep.mean_switch_us > 0.0);
    }

    #[test]
    fn lora_unfused_serving_completes() {
        let Some(rt) = runtime() else { return };
        let (mut server, names) = server_with(&rt, Zoo::Lora, true);
        let trace = generate_trace(
            &Selection::singles(&names),
            16,
            TracePattern::Bursty { burst: 6 },
            1e4,
            1,
        );
        let rep = server.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 16);
        // Unfused serving never mutates the weights.
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        assert!(server.weights().bit_equal(&base));
    }

    #[test]
    fn singleton_sets_serve_through_fusion() {
        // A single adapter is just a one-member set: set selections over
        // one member serve through the fused-mode engine.
        let Some(rt) = runtime() else { return };
        let (mut server, names) = server_with(&rt, Zoo::Shira, false);
        let sels: Vec<Selection> = names
            .iter()
            .map(|n| Selection::set(&[(n.as_str(), 1.0)]))
            .collect();
        let trace = generate_trace(&sels, 16, TracePattern::Bursty { burst: 6 }, 1e4, 1);
        let rep = server.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 16);
        assert_eq!(rep.set_requests, 16);
        assert!(rep.switches >= 1);
        assert_eq!(rep.fused_switches, rep.switches);
    }

    #[test]
    fn fused_set_serving_completes_and_restores_base() {
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let mut server = Server::builder(&rt, base.clone())
            .cache_bytes(1 << 20)
            .build()
            .unwrap();
        for (i, name) in ["ad0", "ad1", "ad2"].iter().enumerate() {
            server.store.add_shira(&make_shira(&rt, name, i as u64));
        }
        // Two spellings of the same set share one canonical identity, so
        // they batch together and cost no extra transition.
        let sels: Vec<Selection> = ["ad0+ad1", "ad1+ad0", "ad1@0.5+ad2", "ad0+ad1+ad2@2"]
            .iter()
            .map(|s| Selection::parse(s).unwrap())
            .collect();
        let trace = generate_trace(&sels, 16, TracePattern::Bursty { burst: 4 }, 1e4, 5);
        let rep = server.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 16);
        assert!(rep.switches >= 1);
        assert_eq!(rep.fused_switches, rep.switches);
        let fusion = server.fusion().expect("fusion built lazily");
        assert_eq!(fusion.plan().len(), 3, "roster grew to every named member");
        assert!(fusion.updates() > 0);
        server.revert_all();
        assert!(server.weights().bit_equal(&base));
        assert!(server.fusion().is_none(), "revert_all drops the roster");
    }

    #[test]
    fn mixed_trace_routes_per_request_and_is_pool_invariant() {
        // The acceptance shape at the server level: ONE trace mixing
        // Base, Single and Set selections through one builder-built
        // server; identical final weights at 1 and 4 threads; exact
        // base restore afterwards.
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let sels = vec![
            Selection::Base,
            Selection::single("ad0"),
            Selection::single_at("ad1", 0.5),
            Selection::parse("ad0+ad2@0.5").unwrap(),
            Selection::parse("ad1+ad2").unwrap(),
        ];
        let trace = generate_trace(&sels, 24, TracePattern::Bursty { burst: 4 }, 1e4, 9);
        let mut finals = Vec::new();
        for threads in [1usize, 4] {
            let mut server = Server::builder(&rt, base.clone())
                .cache_bytes(1 << 20)
                .pool(Arc::new(ThreadPool::new(threads)))
                .build()
                .unwrap();
            for (i, name) in ["ad0", "ad1", "ad2"].iter().enumerate() {
                server.store.add_shira(&make_shira(&rt, name, i as u64));
            }
            let rep = server.run_trace(&trace).unwrap();
            assert_eq!(rep.requests, 24);
            assert_eq!(
                rep.base_requests + rep.single_requests + rep.set_requests,
                24
            );
            assert!(rep.base_requests > 0, "trace exercised base routing");
            assert!(rep.single_requests > 0, "trace exercised single routing");
            assert!(rep.set_requests > 0, "trace exercised set routing");
            assert!(rep.summary.contains("selections: base="));
            finals.push(server.weights().clone());
            server.revert_all();
            assert!(server.weights().bit_equal(&base), "threads={threads}");
        }
        assert!(
            finals[0].bit_equal(&finals[1]),
            "mixed-trace serving is pool-width invariant"
        );
    }

    #[test]
    fn structured_errors_surface_from_run_trace() {
        let Some(rt) = runtime() else { return };
        let (mut server, _names) = server_with(&rt, Zoo::Shira, false);
        // Unknown adapter → UnknownAdapter, not a string.
        let trace = generate_trace(
            &[Selection::single("ghost")],
            4,
            TracePattern::UniformMix,
            1e4,
            3,
        );
        assert!(matches!(
            server.run_trace(&trace),
            Err(ServeError::UnknownAdapter(n)) if n == "ghost"
        ));
        // A LoRA member inside a fused set → NotShira.
        server.store.add_lora(&make_lora(&rt, "lora0", 9));
        let trace = generate_trace(
            &[Selection::set(&[("ad0", 1.0), ("lora0", 1.0)])],
            4,
            TracePattern::UniformMix,
            1e4,
            3,
        );
        assert!(matches!(
            server.run_trace(&trace),
            Err(ServeError::NotShira(n)) if n == "lora0"
        ));
    }

    #[test]
    fn degrade_to_base_serves_failed_selections_on_base() {
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let mut server = Server::builder(&rt, base.clone())
            .cache_bytes(1 << 20)
            .failure_policy(FailurePolicy::DegradeToBase)
            .build()
            .unwrap();
        for (i, name) in ["ad0", "ad1"].iter().enumerate() {
            server.store.add_shira(&make_shira(&rt, name, i as u64));
        }
        // "ghost" is unknown: its batches degrade to base, the rest serve.
        let sels = vec![
            Selection::single("ad0"),
            Selection::single("ghost"),
            Selection::single("ad1"),
        ];
        let trace = generate_trace(&sels, 12, TracePattern::Bursty { burst: 4 }, 1e4, 11);
        let rep = server.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 12, "degraded requests still complete");
        assert!(rep.degraded > 0, "ghost batches served degraded");
        assert!(!rep.outcomes.is_empty());
        assert!(rep
            .outcomes
            .iter()
            .all(|o| o.action == "degraded-to-base" && o.selection == "ghost"));
        assert!(rep.summary.contains("degraded="), "{}", rep.summary);
        server.revert_all();
        assert!(server.weights().bit_equal(&base));
    }

    #[test]
    fn skip_request_drops_failed_batches_and_keeps_serving() {
        let Some(rt) = runtime() else { return };
        let (mut server, _names) = server_with(&rt, Zoo::Shira, false);
        server.policy = FailurePolicy::SkipRequest;
        let sels = vec![Selection::single("ad0"), Selection::single("ghost")];
        let trace = generate_trace(&sels, 12, TracePattern::Bursty { burst: 4 }, 1e4, 13);
        let rep = server.run_trace(&trace).unwrap();
        assert!(rep.skipped > 0, "ghost batches dropped");
        assert_eq!(rep.requests + rep.skipped, 12);
        assert!(rep.outcomes.iter().all(|o| o.action == "skipped"));
    }

    #[test]
    fn fault_plan_wave_panic_rolls_back_and_degrades() {
        // End-to-end chaos smoke: one injected wave panic under
        // DegradeToBase — the mutation rolls back, the batch serves on
        // base, and the report carries the resilience counters.
        let Some(rt) = runtime() else { return };
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let mut server = Server::builder(&rt, base.clone())
            .cache_bytes(1 << 20)
            .failure_policy(FailurePolicy::DegradeToBase)
            .fault_plan(FaultPlan::new().panic_wave_at(1))
            .build()
            .unwrap();
        for (i, name) in ["ad0", "ad1"].iter().enumerate() {
            server.store.add_shira(&make_shira(&rt, name, i as u64));
        }
        let sels = vec![Selection::single("ad0"), Selection::single("ad1")];
        let trace = generate_trace(&sels, 8, TracePattern::Bursty { burst: 4 }, 1e4, 17);
        let rep = server.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 8, "rolled-back batch still serves (degraded)");
        assert_eq!(rep.rollbacks, 1, "exactly the planned wave panic");
        assert!(rep.degraded > 0);
        assert!(rep
            .outcomes
            .iter()
            .any(|o| o.error.contains("rolled back")), "{:?}", rep.outcomes);
        assert!(rep.summary.contains("rollbacks=1"), "{}", rep.summary);
        server.revert_all();
        assert!(server.weights().bit_equal(&base));
    }

    use crate::coordinator::gate::LinearGate;
    use crate::coordinator::pool::ExpertPool;

    fn gated_server<'rt>(
        rt: &'rt Runtime,
        policy: FailurePolicy,
        fault: Option<FaultPlan>,
    ) -> Server<'rt> {
        let meta = rt.manifest.model("llama").unwrap();
        let base = WeightStore::init(&meta.params, 7);
        let names: Vec<String> = (0..3).map(|i| format!("ad{i}")).collect();
        let pool = ExpertPool::shared(0);
        for n in &names {
            lock_pool(&pool).register(n).unwrap();
        }
        let mut b = Server::builder(rt, base)
            .cache_bytes(1 << 20)
            .failure_policy(policy)
            .gate(Arc::new(LinearGate::seeded(&names, 2, 0x6A7E)))
            .expert_pool(pool);
        if let Some(plan) = fault {
            b = b.fault_plan(plan);
        }
        let mut server = b.build().unwrap();
        for (i, name) in names.iter().enumerate() {
            server.store.add_shira(&make_shira(rt, name, i as u64));
        }
        server
    }

    #[test]
    fn auto_serving_matches_explicit_replay_of_resolved_trace() {
        let Some(rt) = runtime() else { return };
        let trace = generate_trace(
            &[Selection::Auto],
            16,
            TracePattern::Bursty { burst: 4 },
            1e4,
            21,
        );
        // Serve the auto trace directly.
        let mut a = gated_server(&rt, FailurePolicy::FailFast, None);
        let rep = a.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 16);
        assert_eq!(rep.gated, 16, "every auto resolved through the gate");
        assert_eq!(rep.set_requests, 16, "gate emits weighted sets");
        assert!(rep.summary.contains("gated=16"), "{}", rep.summary);
        let served: u64 =
            rep.expert_utilization.iter().map(|(_, n)| n).sum();
        assert!(served >= 16, "utilization counters track gated requests");
        // Replay: resolve the autos to explicit sets on an identically
        // configured server, serve those, and demand bit-identical
        // resident weights.
        let mut b = gated_server(&rt, FailurePolicy::FailFast, None);
        let explicit = b.resolve_trace(&trace).unwrap();
        assert!(explicit
            .iter()
            .all(|r| matches!(r.selection, Selection::Set { .. })));
        let mut c = gated_server(&rt, FailurePolicy::FailFast, None);
        let rep2 = c.run_trace(&explicit).unwrap();
        assert_eq!(rep2.requests, 16);
        assert_eq!(rep2.gated, 0, "explicit replay never touches the gate");
        assert!(
            a.weights().bit_equal(c.weights()),
            "auto-served weights == explicit-replay weights"
        );
    }

    #[test]
    fn gate_failures_follow_the_failure_policy() {
        let Some(rt) = runtime() else { return };
        let trace = generate_trace(
            &[Selection::Auto],
            8,
            TracePattern::Bursty { burst: 4 },
            1e4,
            23,
        );
        // FailFast: the injected gate fault surfaces as a gate error.
        let mut s = gated_server(
            &rt,
            FailurePolicy::FailFast,
            Some(FaultPlan::new().fail_gate_at(1)),
        );
        let err = s.run_trace(&trace).unwrap_err();
        assert_eq!(err.kind(), "gate");
        assert!(err.to_string().contains("injected fault"), "{err}");
        // DegradeToBase: the faulted request serves on base, the rest
        // gate normally.
        let mut s = gated_server(
            &rt,
            FailurePolicy::DegradeToBase,
            Some(FaultPlan::new().fail_gate_at(1)),
        );
        let rep = s.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 8, "degraded request still serves");
        assert_eq!(rep.degraded, 1);
        assert_eq!(rep.gated, 7);
        assert!(rep
            .outcomes
            .iter()
            .any(|o| o.action == "gate-degraded-to-base"
                && o.selection == "@auto"));
        // SkipRequest: the faulted request is dropped.
        let mut s = gated_server(
            &rt,
            FailurePolicy::SkipRequest,
            Some(FaultPlan::new().fail_gate_at(1)),
        );
        let rep = s.run_trace(&trace).unwrap();
        assert_eq!(rep.requests, 7);
        assert_eq!(rep.skipped, 1);
        assert!(rep.outcomes.iter().any(|o| o.action == "gate-skipped"));
    }

    #[test]
    fn auto_without_gate_errors_with_gate_kind() {
        let Some(rt) = runtime() else { return };
        let (mut server, _names) = server_with(&rt, Zoo::Shira, false);
        let trace = generate_trace(
            &[Selection::Auto],
            4,
            TracePattern::UniformMix,
            1e4,
            3,
        );
        let err = server.run_trace(&trace).unwrap_err();
        assert_eq!(err.kind(), "gate");
        assert!(err.to_string().contains("no gate configured"), "{err}");
    }
}
