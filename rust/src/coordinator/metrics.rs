//! Serving metrics: stage timers, switch counters, per-kind selection
//! counters, latency distributions, and the adapter-store lifecycle
//! counters (cache, prefetch, residency).

use super::selection::SelectionKind;
use super::store::StoreStats;
use super::switch::SwitchPath;
use crate::util::alloc::fmt_bytes;
use crate::util::stats::{LatencyHist, Moments, Sample};

/// Accumulating counters and distributions for one serving run.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Per-switch weight-mutation time (scatter or fuse), microseconds.
    pub switch_us: Sample,
    /// Per-batch model execution time, microseconds.
    pub exec_us: Sample,
    /// Per-request end-to-end processing latency (switch share + exec).
    pub request_latency: LatencyHist,
    /// Batch occupancy (requests per executed batch, before padding).
    pub batch_fill: Moments,
    /// Selection switches performed (resident state changed).
    pub switches: u64,
    /// Switches that took the one-pass direct transition path (a resident
    /// pairwise plan walked the A∪B union once, one dispatch wave).
    pub transitions: u64,
    /// Switches that fell back to revert+apply (no previous adapter, cold
    /// pair, or plan mismatch).
    pub fallbacks: u64,
    /// Switches served by the incremental fused-mode engine (set
    /// transitions and roster-member singles; always one wave).
    pub fused_switches: u64,
    /// Store-built shard-plan sets the engine ignored as mismatched
    /// (set at end of run via [`Self::set_plan_mismatches`]).
    pub plan_mismatches: u64,
    /// Batches executed.
    pub batches: u64,
    /// Requests completed.
    pub requests: u64,
    /// Requests that selected the base model.
    pub base_requests: u64,
    /// Requests that selected a single adapter.
    pub single_requests: u64,
    /// Requests that selected a fused adapter set.
    pub set_requests: u64,
    /// Failed weight mutations rolled back to base by the transactional
    /// guard (DESIGN.md §13.1).
    pub rollbacks: u64,
    /// Requests served with base weights after their selection failed
    /// under the `DegradeToBase` policy.
    pub degraded: u64,
    /// Requests dropped after their selection failed under the
    /// `SkipRequest` policy.
    pub skipped: u64,
    /// Adapter-store lifecycle counters (set once at end of run via
    /// [`Self::set_store`]; includes retry/quarantine counts).
    pub store: StoreStats,
}

impl ServeMetrics {
    /// Zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Capture the adapter store's lifecycle counters for the summary.
    pub fn set_store(&mut self, s: StoreStats) {
        self.store = s;
    }

    /// Capture the switch engine's ignored-shard-plan count.
    pub fn set_plan_mismatches(&mut self, n: u64) {
        self.plan_mismatches = n;
    }

    /// Record which path one selection switch took (direct transition,
    /// revert+apply fallback, or the fused-mode engine).
    pub fn record_switch_path(&mut self, path: SwitchPath) {
        match path {
            SwitchPath::Transition => self.transitions += 1,
            SwitchPath::Fallback => self.fallbacks += 1,
            SwitchPath::Fused => self.fused_switches += 1,
        }
    }

    /// Record one transactional rollback (a mutation failed and the
    /// resident weights were restored to base).
    pub fn record_rollback(&mut self) {
        self.rollbacks += 1;
    }

    /// Record `n` requests served with base weights under degraded mode.
    pub fn record_degraded(&mut self, n: u64) {
        self.degraded += n;
    }

    /// Record `n` requests dropped under the skip policy.
    pub fn record_skipped(&mut self, n: u64) {
        self.skipped += n;
    }

    /// Count one incoming request by its selection kind.
    pub fn record_selection(&mut self, kind: SelectionKind) {
        match kind {
            SelectionKind::Base => self.base_requests += 1,
            SelectionKind::Single => self.single_requests += 1,
            SelectionKind::Set => self.set_requests += 1,
        }
    }

    /// Record one executed batch (and its switch, when one happened).
    pub fn record_batch(
        &mut self,
        n_requests: usize,
        switched: bool,
        switch_us: f64,
        exec_us: f64,
    ) {
        self.batches += 1;
        self.requests += n_requests as u64;
        self.batch_fill.push(n_requests as f64);
        if switched {
            self.switches += 1;
            self.switch_us.push(switch_us);
        }
        self.exec_us.push(exec_us);
        let per_request = (switch_us + exec_us) / n_requests.max(1) as f64;
        for _ in 0..n_requests {
            self.request_latency.record_us(per_request);
        }
    }

    /// Multi-line human-readable summary of the run so far.
    pub fn summary(&mut self, wall_secs: f64) -> String {
        let thr = self.requests as f64 / wall_secs.max(1e-9);
        format!(
            "requests={} batches={} switches={} fill={:.2}\n\
             selections: base={} single={} set={}\n\
             switch: mean={:.1}us p50={:.1}us | exec: mean={:.1}us\n\
             paths: transition={} fallback={} fused={} plan_mismatch={}\n\
             request latency: mean={:.1}us p50<={:.0}us p99<={:.0}us\n\
             store: hits={} misses={} evictions={} prefetch_hits={} \
             oversized={} resident={} ({} entries)\n\
             plans: hits={} misses={} evictions={} builds={} \
             resident={} ({} entries)\n\
             resilience: retries={} quarantines={} rollbacks={} \
             degraded={} skipped={}\n\
             throughput={:.1} req/s",
            self.requests,
            self.batches,
            self.switches,
            self.batch_fill.mean(),
            self.base_requests,
            self.single_requests,
            self.set_requests,
            self.switch_us.mean(),
            if self.switch_us.is_empty() {
                0.0
            } else {
                self.switch_us.percentile(50.0)
            },
            self.exec_us.mean(),
            self.transitions,
            self.fallbacks,
            self.fused_switches,
            self.plan_mismatches,
            self.request_latency.mean_us(),
            self.request_latency.percentile_us(50.0),
            self.request_latency.percentile_us(99.0),
            self.store.hits,
            self.store.misses,
            self.store.evictions,
            self.store.prefetch_hits,
            self.store.oversized_serves,
            fmt_bytes(self.store.resident_bytes),
            self.store.resident_entries,
            self.store.plan_hits,
            self.store.plan_misses,
            self.store.plan_evictions,
            self.store.plan_builds,
            fmt_bytes(self.store.plan_resident_bytes),
            self.store.plan_resident_entries,
            self.store.retries,
            self.store.quarantines,
            self.rollbacks,
            self.degraded,
            self.skipped,
            thr
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = ServeMetrics::new();
        m.record_batch(8, true, 100.0, 1000.0);
        m.record_batch(4, false, 0.0, 900.0);
        assert_eq!(m.requests, 12);
        assert_eq!(m.batches, 2);
        assert_eq!(m.switches, 1);
        assert_eq!(m.switch_us.len(), 1);
        assert_eq!(m.exec_us.len(), 2);
        assert!((m.batch_fill.mean() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn summary_formats() {
        let mut m = ServeMetrics::new();
        m.record_batch(8, true, 50.0, 500.0);
        let s = m.summary(1.0);
        assert!(s.contains("requests=8"));
        assert!(s.contains("throughput"));
    }

    #[test]
    fn summary_surfaces_store_counters() {
        let mut m = ServeMetrics::new();
        m.record_batch(4, true, 50.0, 500.0);
        m.set_store(StoreStats {
            hits: 7,
            misses: 3,
            evictions: 2,
            prefetch_issued: 5,
            prefetch_hits: 4,
            prefetch_waits: 1,
            oversized_serves: 1,
            resident_bytes: 2048,
            resident_entries: 2,
            plan_hits: 6,
            plan_misses: 2,
            plan_evictions: 1,
            plan_builds: 8,
            plan_resident_bytes: 4096,
            plan_resident_entries: 3,
            retries: 4,
            quarantines: 1,
        });
        let s = m.summary(1.0);
        assert!(s.contains("hits=7"), "{s}");
        assert!(s.contains("misses=3"), "{s}");
        assert!(s.contains("evictions=2"), "{s}");
        assert!(s.contains("prefetch_hits=4"), "{s}");
        assert!(s.contains("2 entries"), "{s}");
        assert!(s.contains("plans: hits=6 misses=2 evictions=1 builds=8"), "{s}");
        assert!(s.contains("retries=4 quarantines=1"), "{s}");
        assert!((m.store.hit_rate() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn resilience_counters_surface_in_summary() {
        let mut m = ServeMetrics::new();
        m.record_batch(4, false, 0.0, 100.0);
        m.record_rollback();
        m.record_rollback();
        m.record_degraded(3);
        m.record_skipped(1);
        assert_eq!((m.rollbacks, m.degraded, m.skipped), (2, 3, 1));
        let s = m.summary(1.0);
        assert!(
            s.contains(
                "resilience: retries=0 quarantines=0 rollbacks=2 degraded=3 skipped=1"
            ),
            "{s}"
        );
    }

    #[test]
    fn switch_paths_surface_in_summary() {
        let mut m = ServeMetrics::new();
        m.record_batch(4, true, 50.0, 500.0);
        m.record_switch_path(SwitchPath::Transition);
        m.record_batch(4, true, 30.0, 500.0);
        m.record_switch_path(SwitchPath::Fallback);
        m.record_batch(4, true, 40.0, 500.0);
        m.record_switch_path(SwitchPath::Transition);
        m.record_batch(4, true, 20.0, 500.0);
        m.record_switch_path(SwitchPath::Fused);
        m.set_plan_mismatches(5);
        assert_eq!((m.transitions, m.fallbacks, m.fused_switches), (2, 1, 1));
        let s = m.summary(1.0);
        assert!(
            s.contains("paths: transition=2 fallback=1 fused=1 plan_mismatch=5"),
            "{s}"
        );
    }

    #[test]
    fn selection_kinds_surface_in_summary() {
        let mut m = ServeMetrics::new();
        m.record_selection(SelectionKind::Base);
        m.record_selection(SelectionKind::Single);
        m.record_selection(SelectionKind::Single);
        m.record_selection(SelectionKind::Set);
        assert_eq!(
            (m.base_requests, m.single_requests, m.set_requests),
            (1, 2, 1)
        );
        m.record_batch(4, false, 0.0, 100.0);
        let s = m.summary(1.0);
        assert!(s.contains("selections: base=1 single=2 set=1"), "{s}");
    }
}
