//! Serving metrics: stage timers, switch counters, per-kind selection
//! counters, latency distributions, the adapter-store lifecycle counters
//! (cache, prefetch, residency), and the fleet's per-selection
//! fairness/SLO ledger.

use std::collections::BTreeMap;

use super::selection::SelectionKind;
use super::store::StoreStats;
use super::switch::SwitchPath;
use crate::util::alloc::fmt_bytes;
use crate::util::stats::{LatencyHist, Moments, Sample};

/// Per-selection fairness counters: how one canonical selection key
/// fared under fleet scheduling (queueing waits, SLO violations, sheds).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SelectionFairness {
    /// Requests of this selection that were served.
    pub requests: u64,
    /// Requests of this selection shed by admission control.
    pub shed: u64,
    /// Sum of queueing waits (arrival → service start), microseconds.
    pub total_wait_us: u64,
    /// Largest single queueing wait, microseconds.
    pub max_wait_us: u64,
    /// Served requests whose wait exceeded the ledger's SLO.
    pub slo_violations: u64,
    /// Re-dispatch attempts this selection consumed (failover retries
    /// plus drain requeues off a quarantined replica).
    pub retries: u64,
    /// Requests of this selection that died on their end-to-end deadline.
    pub deadline_exceeded: u64,
}

impl SelectionFairness {
    /// Mean queueing wait of served requests, microseconds.
    pub fn mean_wait_us(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_wait_us as f64 / self.requests as f64
        }
    }
}

/// Per-selection fairness/SLO accounting for a fleet run (DESIGN.md
/// §14.4): one [`SelectionFairness`] row per canonical selection key, in
/// deterministic (sorted) order.  An SLO of 0 disables violation
/// counting (every wait is within a zero-SLO only when it is zero, so 0
/// means "not configured", not "impossible").
#[derive(Clone, Debug, Default)]
pub struct FairnessLedger {
    slo_us: u64,
    rows: BTreeMap<String, SelectionFairness>,
}

impl FairnessLedger {
    /// Ledger with a queueing-wait SLO of `slo_us` microseconds (0
    /// disables violation counting).
    pub fn new(slo_us: u64) -> Self {
        FairnessLedger {
            slo_us,
            rows: BTreeMap::new(),
        }
    }

    /// The configured queueing-wait SLO, microseconds.
    pub fn slo_us(&self) -> u64 {
        self.slo_us
    }

    /// Record one served request of selection `key` that waited
    /// `wait_us` between arrival and service start.
    pub fn record_wait(&mut self, key: &str, wait_us: u64) {
        let row = self.rows.entry(key.to_string()).or_default();
        row.requests += 1;
        row.total_wait_us += wait_us;
        row.max_wait_us = row.max_wait_us.max(wait_us);
        if self.slo_us > 0 && wait_us > self.slo_us {
            row.slo_violations += 1;
        }
    }

    /// Record one request of selection `key` shed by admission control.
    pub fn record_shed(&mut self, key: &str) {
        self.rows.entry(key.to_string()).or_default().shed += 1;
    }

    /// Record one re-dispatch attempt (failover retry or drain requeue)
    /// for selection `key`.
    pub fn record_retry(&mut self, key: &str) {
        self.rows.entry(key.to_string()).or_default().retries += 1;
    }

    /// Record one request of selection `key` that exceeded its deadline.
    pub fn record_deadline_exceeded(&mut self, key: &str) {
        self.rows.entry(key.to_string()).or_default().deadline_exceeded += 1;
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Rows in sorted selection-key order (deterministic across runs).
    pub fn rows(&self) -> impl Iterator<Item = (&str, &SelectionFairness)> {
        self.rows.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Sum of SLO violations across all selections.
    pub fn total_violations(&self) -> u64 {
        self.rows.values().map(|r| r.slo_violations).sum()
    }

    /// Sum of sheds across all selections.
    pub fn total_shed(&self) -> u64 {
        self.rows.values().map(|r| r.shed).sum()
    }

    /// Sum of re-dispatch attempts across all selections.
    pub fn total_retries(&self) -> u64 {
        self.rows.values().map(|r| r.retries).sum()
    }

    /// Sum of deadline-exceeded requests across all selections.
    pub fn total_deadline_exceeded(&self) -> u64 {
        self.rows.values().map(|r| r.deadline_exceeded).sum()
    }

    /// Largest queueing wait any selection saw, microseconds.
    pub fn max_wait_us(&self) -> u64 {
        self.rows.values().map(|r| r.max_wait_us).max().unwrap_or(0)
    }

    /// One summary line per selection (key, served, mean/max wait, SLO
    /// violations, sheds), sorted by key.
    pub fn summary_lines(&self) -> String {
        let mut out = String::new();
        for (key, r) in self.rows() {
            let shown = if key.is_empty() { "<base>" } else { key };
            out.push_str(&format!(
                "fairness[{shown}]: served={} wait mean={:.1}us max={}us \
                 slo_violations={} shed={} retries={} deadline_exceeded={}\n",
                r.requests,
                r.mean_wait_us(),
                r.max_wait_us,
                r.slo_violations,
                r.shed,
                r.retries,
                r.deadline_exceeded
            ));
        }
        out.pop(); // trailing newline
        out
    }
}

/// Accumulating counters and distributions for one serving run.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Per-switch weight-mutation time (scatter or fuse), microseconds.
    pub switch_us: Sample,
    /// Per-batch model execution time, microseconds.
    pub exec_us: Sample,
    /// Per-request end-to-end processing latency (switch share + exec).
    pub request_latency: LatencyHist,
    /// Batch occupancy (requests per executed batch, before padding).
    pub batch_fill: Moments,
    /// Selection switches performed (resident state changed).
    pub switches: u64,
    /// Switches that took the one-pass direct transition path (a resident
    /// pairwise plan walked the A∪B union once, one dispatch wave).
    pub transitions: u64,
    /// Switches that fell back to revert+apply (no previous adapter, cold
    /// pair, or plan mismatch).
    pub fallbacks: u64,
    /// Switches served by the incremental fused-mode engine (set
    /// transitions and roster-member singles; always one wave).
    pub fused_switches: u64,
    /// Store-built shard-plan sets the engine ignored as mismatched
    /// (set at end of run via [`Self::set_plan_mismatches`]).
    pub plan_mismatches: u64,
    /// Batches executed.
    pub batches: u64,
    /// Requests completed.
    pub requests: u64,
    /// Requests that selected the base model.
    pub base_requests: u64,
    /// Requests that selected a single adapter.
    pub single_requests: u64,
    /// Requests that selected a fused adapter set.
    pub set_requests: u64,
    /// Requests that arrived as `Selection::Auto` and were resolved by
    /// the gate into an explicit set (counted under the resolved kind in
    /// the per-kind counters above, and separately here).
    pub gated: u64,
    /// Failed weight mutations rolled back to base by the transactional
    /// guard (DESIGN.md §13.1).
    pub rollbacks: u64,
    /// Requests served with base weights after their selection failed
    /// under the `DegradeToBase` policy.
    pub degraded: u64,
    /// Requests dropped after their selection failed under the
    /// `SkipRequest` policy.
    pub skipped: u64,
    /// Requests re-dispatched to another replica (drained off a
    /// quarantined replica's queue or retried after a failed apply).
    pub requeues: u64,
    /// Requests that died on their end-to-end deadline before any
    /// replica served them.
    pub deadline_exceeded: u64,
    /// Probation canaries admitted to quarantined replicas whose TTL
    /// expired (each runs a recovery pass first).
    pub probes: u64,
    /// Replicas restored to Healthy after a bit-verified recovery pass.
    pub recoveries: u64,
    /// Adapter-store lifecycle counters (set once at end of run via
    /// [`Self::set_store`]; includes retry/quarantine counts).
    pub store: StoreStats,
    /// Per-selection fairness/SLO ledger (fleet runs; empty — and absent
    /// from the summary — for single-server runs).
    pub fairness: FairnessLedger,
}

impl ServeMetrics {
    /// Zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Capture the adapter store's lifecycle counters for the summary.
    pub fn set_store(&mut self, s: StoreStats) {
        self.store = s;
    }

    /// Capture the switch engine's ignored-shard-plan count.
    pub fn set_plan_mismatches(&mut self, n: u64) {
        self.plan_mismatches = n;
    }

    /// Record which path one selection switch took (direct transition,
    /// revert+apply fallback, or the fused-mode engine).
    pub fn record_switch_path(&mut self, path: SwitchPath) {
        match path {
            SwitchPath::Transition => self.transitions += 1,
            SwitchPath::Fallback => self.fallbacks += 1,
            SwitchPath::Fused => self.fused_switches += 1,
        }
    }

    /// Record one transactional rollback (a mutation failed and the
    /// resident weights were restored to base).
    pub fn record_rollback(&mut self) {
        self.rollbacks += 1;
    }

    /// Record `n` requests served with base weights under degraded mode.
    pub fn record_degraded(&mut self, n: u64) {
        self.degraded += n;
    }

    /// Record `n` requests dropped under the skip policy.
    pub fn record_skipped(&mut self, n: u64) {
        self.skipped += n;
    }

    /// Record `n` requests re-dispatched to another replica.
    pub fn record_requeues(&mut self, n: u64) {
        self.requeues += n;
    }

    /// Record `n` requests that exceeded their end-to-end deadline.
    pub fn record_deadline_exceeded(&mut self, n: u64) {
        self.deadline_exceeded += n;
    }

    /// Record one probation canary admitted after a quarantine TTL
    /// expired.
    pub fn record_probe(&mut self) {
        self.probes += 1;
    }

    /// Record one replica restored to Healthy after a verified recovery.
    pub fn record_recovery(&mut self) {
        self.recoveries += 1;
    }

    /// Count one incoming request by its selection kind.  `Auto` arrives
    /// here only when the front end failed to resolve it (policy-degraded
    /// paths record the resolved kind instead); it counts as gated so the
    /// request is never invisible.
    pub fn record_selection(&mut self, kind: SelectionKind) {
        match kind {
            SelectionKind::Base => self.base_requests += 1,
            SelectionKind::Single => self.single_requests += 1,
            SelectionKind::Set => self.set_requests += 1,
            SelectionKind::Auto => self.gated += 1,
        }
    }

    /// Record `n` requests whose `Selection::Auto` the gate resolved
    /// into an explicit selection.
    pub fn record_gated(&mut self, n: u64) {
        self.gated += n;
    }

    /// Record one executed batch (and its switch, when one happened).
    pub fn record_batch(
        &mut self,
        n_requests: usize,
        switched: bool,
        switch_us: f64,
        exec_us: f64,
    ) {
        self.batches += 1;
        self.requests += n_requests as u64;
        self.batch_fill.push(n_requests as f64);
        if switched {
            self.switches += 1;
            self.switch_us.push(switch_us);
        }
        self.exec_us.push(exec_us);
        let per_request = (switch_us + exec_us) / n_requests.max(1) as f64;
        for _ in 0..n_requests {
            self.request_latency.record_us(per_request);
        }
    }

    /// Record one served request's queueing wait into the fairness
    /// ledger (fleet runs; see [`FairnessLedger::record_wait`]).
    pub fn record_wait(&mut self, key: &str, wait_us: u64) {
        self.fairness.record_wait(key, wait_us);
    }

    /// Multi-line human-readable summary of the run so far.
    pub fn summary(&mut self, wall_secs: f64) -> String {
        let thr = self.requests as f64 / wall_secs.max(1e-9);
        let mut s = format!(
            "requests={} batches={} switches={} fill={:.2}\n\
             selections: base={} single={} set={} gated={}\n\
             switch: mean={:.1}us p50={:.1}us | exec: mean={:.1}us\n\
             paths: transition={} fallback={} fused={} plan_mismatch={}\n\
             request latency: mean={:.1}us p50<={:.0}us p99<={:.0}us\n\
             store: hits={} misses={} evictions={} prefetch_hits={} \
             oversized={} resident={} ({} entries)\n\
             plans: hits={} misses={} evictions={} builds={} \
             resident={} ({} entries)\n\
             resilience: retries={} quarantines={} rollbacks={} \
             degraded={} skipped={} requeues={} deadline_exceeded={} \
             fetch_timeouts={}\n\
             recovery: probes={} recoveries={}\n\
             throughput={:.1} req/s",
            self.requests,
            self.batches,
            self.switches,
            self.batch_fill.mean(),
            self.base_requests,
            self.single_requests,
            self.set_requests,
            self.gated,
            self.switch_us.mean(),
            if self.switch_us.is_empty() {
                0.0
            } else {
                self.switch_us.percentile(50.0)
            },
            self.exec_us.mean(),
            self.transitions,
            self.fallbacks,
            self.fused_switches,
            self.plan_mismatches,
            self.request_latency.mean_us(),
            self.request_latency.percentile_us(50.0),
            self.request_latency.percentile_us(99.0),
            self.store.hits,
            self.store.misses,
            self.store.evictions,
            self.store.prefetch_hits,
            self.store.oversized_serves,
            fmt_bytes(self.store.resident_bytes),
            self.store.resident_entries,
            self.store.plan_hits,
            self.store.plan_misses,
            self.store.plan_evictions,
            self.store.plan_builds,
            fmt_bytes(self.store.plan_resident_bytes),
            self.store.plan_resident_entries,
            self.store.retries,
            self.store.quarantines,
            self.rollbacks,
            self.degraded,
            self.skipped,
            self.requeues,
            self.deadline_exceeded,
            self.store.fetch_timeouts,
            self.probes,
            self.recoveries,
            thr
        );
        if !self.fairness.is_empty() {
            s.push('\n');
            s.push_str(&self.fairness.summary_lines());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let mut m = ServeMetrics::new();
        m.record_batch(8, true, 100.0, 1000.0);
        m.record_batch(4, false, 0.0, 900.0);
        assert_eq!(m.requests, 12);
        assert_eq!(m.batches, 2);
        assert_eq!(m.switches, 1);
        assert_eq!(m.switch_us.len(), 1);
        assert_eq!(m.exec_us.len(), 2);
        assert!((m.batch_fill.mean() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn summary_formats() {
        let mut m = ServeMetrics::new();
        m.record_batch(8, true, 50.0, 500.0);
        let s = m.summary(1.0);
        assert!(s.contains("requests=8"));
        assert!(s.contains("throughput"));
    }

    #[test]
    fn summary_surfaces_store_counters() {
        let mut m = ServeMetrics::new();
        m.record_batch(4, true, 50.0, 500.0);
        m.set_store(StoreStats {
            hits: 7,
            misses: 3,
            evictions: 2,
            prefetch_issued: 5,
            prefetch_hits: 4,
            prefetch_waits: 1,
            oversized_serves: 1,
            resident_bytes: 2048,
            resident_entries: 2,
            plan_hits: 6,
            plan_misses: 2,
            plan_evictions: 1,
            plan_builds: 8,
            plan_resident_bytes: 4096,
            plan_resident_entries: 3,
            retries: 4,
            quarantines: 1,
            ..StoreStats::default()
        });
        let s = m.summary(1.0);
        assert!(s.contains("hits=7"), "{s}");
        assert!(s.contains("misses=3"), "{s}");
        assert!(s.contains("evictions=2"), "{s}");
        assert!(s.contains("prefetch_hits=4"), "{s}");
        assert!(s.contains("2 entries"), "{s}");
        assert!(s.contains("plans: hits=6 misses=2 evictions=1 builds=8"), "{s}");
        assert!(s.contains("retries=4 quarantines=1"), "{s}");
        assert!((m.store.hit_rate() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn resilience_counters_surface_in_summary() {
        let mut m = ServeMetrics::new();
        m.record_batch(4, false, 0.0, 100.0);
        m.record_rollback();
        m.record_rollback();
        m.record_degraded(3);
        m.record_skipped(1);
        assert_eq!((m.rollbacks, m.degraded, m.skipped), (2, 3, 1));
        let s = m.summary(1.0);
        assert!(
            s.contains(
                "resilience: retries=0 quarantines=0 rollbacks=2 degraded=3 skipped=1"
            ),
            "{s}"
        );
    }

    #[test]
    fn recovery_counters_surface_in_summary() {
        let mut m = ServeMetrics::new();
        m.record_batch(4, false, 0.0, 100.0);
        m.record_requeues(5);
        m.record_deadline_exceeded(2);
        m.record_probe();
        m.record_probe();
        m.record_recovery();
        assert_eq!(
            (m.requeues, m.deadline_exceeded, m.probes, m.recoveries),
            (5, 2, 2, 1)
        );
        let s = m.summary(1.0);
        assert!(s.contains("requeues=5 deadline_exceeded=2"), "{s}");
        assert!(s.contains("recovery: probes=2 recoveries=1"), "{s}");
    }

    #[test]
    fn fairness_retry_and_deadline_columns_accumulate() {
        let mut l = FairnessLedger::new(0);
        l.record_retry("a@1");
        l.record_retry("a@1");
        l.record_deadline_exceeded("a@1");
        l.record_retry("b@1");
        assert_eq!(l.total_retries(), 3);
        assert_eq!(l.total_deadline_exceeded(), 1);
        let a = l.rows().find(|(k, _)| *k == "a@1").unwrap().1;
        assert_eq!((a.retries, a.deadline_exceeded), (2, 1));
        assert!(l.summary_lines().contains("retries=2 deadline_exceeded=1"));
    }

    #[test]
    fn switch_paths_surface_in_summary() {
        let mut m = ServeMetrics::new();
        m.record_batch(4, true, 50.0, 500.0);
        m.record_switch_path(SwitchPath::Transition);
        m.record_batch(4, true, 30.0, 500.0);
        m.record_switch_path(SwitchPath::Fallback);
        m.record_batch(4, true, 40.0, 500.0);
        m.record_switch_path(SwitchPath::Transition);
        m.record_batch(4, true, 20.0, 500.0);
        m.record_switch_path(SwitchPath::Fused);
        m.set_plan_mismatches(5);
        assert_eq!((m.transitions, m.fallbacks, m.fused_switches), (2, 1, 1));
        let s = m.summary(1.0);
        assert!(
            s.contains("paths: transition=2 fallback=1 fused=1 plan_mismatch=5"),
            "{s}"
        );
    }

    #[test]
    fn fairness_ledger_accumulates_and_surfaces() {
        let mut m = ServeMetrics::new();
        m.fairness = FairnessLedger::new(100);
        m.record_wait("a@1", 40);
        m.record_wait("a@1", 160); // violation
        m.record_wait("b@1", 90);
        m.fairness.record_shed("b@1");
        m.record_wait("", 10); // base key renders as <base>
        let a = m.fairness.rows().find(|(k, _)| *k == "a@1").unwrap().1;
        assert_eq!(a.requests, 2);
        assert_eq!(a.max_wait_us, 160);
        assert_eq!(a.slo_violations, 1);
        assert!((a.mean_wait_us() - 100.0).abs() < 1e-9);
        assert_eq!(m.fairness.total_violations(), 1);
        assert_eq!(m.fairness.total_shed(), 1);
        assert_eq!(m.fairness.max_wait_us(), 160);
        m.record_batch(4, false, 0.0, 100.0);
        let s = m.summary(1.0);
        assert!(s.contains("fairness[a@1]: served=2"), "{s}");
        assert!(s.contains("slo_violations=1"), "{s}");
        assert!(s.contains("fairness[<base>]"), "{s}");
        assert!(s.contains("shed=1"), "{s}");
    }

    #[test]
    fn fairness_rows_sorted_and_zero_slo_disables_violations() {
        let mut l = FairnessLedger::new(0);
        l.record_wait("z", 1_000_000);
        l.record_wait("a", 5);
        assert_eq!(l.total_violations(), 0, "slo 0 = not configured");
        let keys: Vec<&str> = l.rows().map(|(k, _)| k).collect();
        assert_eq!(keys, vec!["a", "z"], "deterministic sorted order");
        // Empty ledger stays out of the serve summary entirely.
        let mut m = ServeMetrics::new();
        m.record_batch(1, false, 0.0, 10.0);
        assert!(!m.summary(1.0).contains("fairness["));
    }

    #[test]
    fn selection_kinds_surface_in_summary() {
        let mut m = ServeMetrics::new();
        m.record_selection(SelectionKind::Base);
        m.record_selection(SelectionKind::Single);
        m.record_selection(SelectionKind::Single);
        m.record_selection(SelectionKind::Set);
        assert_eq!(
            (m.base_requests, m.single_requests, m.set_requests),
            (1, 2, 1)
        );
        m.record_batch(4, false, 0.0, 100.0);
        let s = m.summary(1.0);
        assert!(s.contains("selections: base=1 single=2 set=1"), "{s}");
    }

    #[test]
    fn gated_requests_surface_in_summary() {
        let mut m = ServeMetrics::new();
        // Resolved autos: counted under the resolved kind AND as gated.
        m.record_selection(SelectionKind::Set);
        m.record_gated(1);
        m.record_selection(SelectionKind::Set);
        m.record_gated(1);
        // An auto that reached recording unresolved still counts.
        m.record_selection(SelectionKind::Auto);
        assert_eq!((m.set_requests, m.gated), (2, 3));
        m.record_batch(3, false, 0.0, 100.0);
        let s = m.summary(1.0);
        assert!(s.contains("selections: base=0 single=0 set=2 gated=3"), "{s}");
    }
}
