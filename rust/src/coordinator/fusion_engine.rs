//! Incremental fused-mode multi-adapter serving (DESIGN.md §8).
//!
//! The serial path ([`fuse_shira`]) rebuilds a fused adapter from scratch:
//! fusing k adapters re-walks every delta, and changing one adapter's
//! weight in a fused set costs O(Σ nnz).  This module makes fused-mode
//! serving *incremental*: a precomputed [`FusionPlan`] (per-target union
//! support with per-adapter sub-slices) lets [`FusionEngine::fuse_into`],
//! [`FusionEngine::unfuse_one`] and [`FusionEngine::reweight_one`] touch
//! only the changed adapter's nnz — the "rapid switching directly in fused
//! mode" property that distinguishes SHiRA from LoRA-merge schemes.
//!
//! ## Why incremental updates stay bit-identical
//!
//! The engine never accumulates `+=`/`-=` on live weights (that would
//! drift from a fresh rebuild in float).  Instead every operation
//! *recomputes* each touched union slot from the base snapshot:
//!
//! ```text
//! W[flat] = base[flat] + fold(w_m · Δ_m[flat]  for fused m, roster order)
//! ```
//!
//! The fold is a left-fold in roster order — exactly the order
//! [`fuse_shira`] sums colliding entries when rebuilding from scratch over
//! the scaled members — so after *any* sequence of fuse/unfuse/reweight
//! the resident weights are bit-identical to a serial rebuild (verified by
//! unit and property tests).  Slots with no fused contributor get the base
//! value back, so unfusing everything is an exact revert.
//!
//! ## Parallel dispatch
//!
//! Operations shard the touched adapter's support with the row-aligned
//! [`ShardPlan`](crate::adapter::sparse::ShardPlan) from the switch engine
//! and run as a flat (target × shard) task list under one
//! [`ThreadPool::scoped_for`] region.  Set transitions are always ONE
//! wave: conflict-free touched members scatter member-local (disjoint
//! slots), while colliding members use the same merged-support walk as
//! the switch engine's direct transitions — their union slots are merged
//! (sorted + deduped) per target and every slot is recomputed exactly
//! once, so even a colliding single-member roster swap `"a"` → `"b"`
//! dispatches as one wave instead of serialized per-member waves.  Every
//! parallel path is bit-identical to its serial twin (disjoint writes,
//! same per-slot arithmetic).

use std::sync::Arc;

use super::fault::{FaultInjector, FaultSite};
use super::fusion::{fuse_shira, validate_target_sets, FusionError, PairInterference};
use crate::adapter::kernel;
use crate::adapter::sparse::{shard_sorted, shards_for, SparseDelta};
use crate::adapter::ShiraAdapter;
use crate::model::weights::WeightStore;
use crate::util::threadpool::{SendPtr, ThreadPool};


/// One roster member's view of one plan target: where its local entries
/// land in the union support, and whether it can take the clean
/// (collision-free) scatter path there.
#[derive(Clone, Debug)]
struct MemberSlice {
    /// Index of this target in the member's `tensors` vec.
    tensor_pos: usize,
    /// Local entry `j` of the member's delta lands at union slot
    /// `upos[j]` (strictly increasing).
    upos: Vec<u32>,
    /// True when every slot this member touches has exactly one
    /// contributor (itself) — enables the direct scatter kernel with no
    /// contributor walk.
    clean: bool,
}

/// Per-target piece of a [`FusionPlan`]: the union support plus a CSR of
/// contributors per union slot, stored in roster order so the per-slot
/// fold reproduces [`fuse_shira`]'s left-fold exactly.
#[derive(Clone, Debug)]
struct PlanTarget {
    /// Target tensor name in the weight store.
    name: String,
    rows: usize,
    cols: usize,
    /// Sorted unique union of all members' supports (flat indices).
    union_idx: Vec<u32>,
    /// CSR offsets: contributors of slot `s` are
    /// `contrib_*[off[s]..off[s+1]]`, ordered by roster index.
    contrib_off: Vec<u32>,
    /// Roster index of each contributor.
    contrib_member: Vec<u16>,
    /// The contributor's unscaled delta value at that slot.
    contrib_val: Vec<f32>,
    /// One slice per roster member (identical target sets ⇒ always
    /// present).
    members: Vec<MemberSlice>,
}

/// Precomputed fusion layout over a fixed adapter roster: per-target union
/// support, per-adapter sub-slices into it, contributor lists per slot,
/// and the pairwise-collision matrix used for conflict-free scheduling.
///
/// Building the plan is the only heavy step — linear walks over the
/// roster's supports (union merge + two-cursor pairwise overlap; the
/// quadratic `ata_nnz` diagnostic is deliberately NOT run here).
/// Afterwards every fuse/unfuse/reweight touches one adapter's entries
/// only.
#[derive(Clone, Debug)]
pub struct FusionPlan {
    roster: Vec<Arc<ShiraAdapter>>,
    targets: Vec<PlanTarget>,
    pairs: Vec<PairInterference>,
    /// `collide[i * n + j]` — members i and j share at least one slot.
    collide: Vec<bool>,
}

impl FusionPlan {
    /// Build a plan over `roster`.  All adapters must target the same
    /// tensor names with the same shapes and carry distinct names.
    pub fn build(roster: Vec<Arc<ShiraAdapter>>) -> Result<FusionPlan, FusionError> {
        if roster.is_empty() {
            return Err(FusionError::EmptySet);
        }
        if roster.len() > u16::MAX as usize {
            return Err(FusionError::RosterTooLarge(roster.len()));
        }
        for (i, a) in roster.iter().enumerate() {
            if roster[..i].iter().any(|b| b.name == a.name) {
                return Err(FusionError::DuplicateMember(a.name.clone()));
            }
        }
        let refs: Vec<&ShiraAdapter> = roster.iter().map(|a| a.as_ref()).collect();
        validate_target_sets(&refs)?;
        let n = roster.len();

        // Per-pair collision counts via the cheap two-cursor overlap walk
        // (NOT analyze_shira: its ata_nnz diagnostic is quadratic in
        // per-row support and would stall plan builds on big rosters).
        let mut pairs = Vec::with_capacity(n * (n - 1) / 2);
        for i in 0..n {
            for j in (i + 1)..n {
                let mut collisions = 0usize;
                let mut denom = 0usize;
                for (tname, d) in &refs[i].tensors {
                    if let Some(od) = refs[j].find(tname) {
                        collisions += d.overlap(od);
                        denom += d.nnz().min(od.nnz());
                    }
                }
                pairs.push(PairInterference {
                    i,
                    j,
                    collisions,
                    overlap: if denom == 0 {
                        0.0
                    } else {
                        collisions as f64 / denom as f64
                    },
                    // the §3.2 diagnostic is not computed at build time;
                    // run fusion::analyze_shira for it
                    ata_density: 0.0,
                });
            }
        }

        let mut targets = Vec::with_capacity(roster[0].tensors.len());
        for (tname, d0) in &roster[0].tensors {
            // Union support across all members.
            let mut union: Vec<u32> = d0.idx.clone();
            for a in &roster[1..] {
                let d = a.find(tname).expect("target sets validated identical");
                union = union_sorted(&union, &d.idx);
            }
            // Per-member slot maps + per-slot contributor counts.
            let mut counts = vec![0u32; union.len()];
            let mut members = Vec::with_capacity(n);
            for a in &roster {
                let tensor_pos = a
                    .tensors
                    .iter()
                    .position(|(name, _)| name == tname)
                    .expect("target sets validated identical");
                let d = &a.tensors[tensor_pos].1;
                let mut upos = Vec::with_capacity(d.nnz());
                let mut s = 0usize;
                for &i in &d.idx {
                    while union[s] < i {
                        s += 1;
                    }
                    debug_assert_eq!(union[s], i);
                    upos.push(s as u32);
                    counts[s] += 1;
                    s += 1;
                }
                members.push(MemberSlice {
                    tensor_pos,
                    upos,
                    clean: false,
                });
            }
            // CSR of contributors, filled in roster order (the fold order).
            let mut off = vec![0u32; union.len() + 1];
            for s in 0..union.len() {
                off[s + 1] = off[s] + counts[s];
            }
            let total = off[union.len()] as usize;
            let mut contrib_member = vec![0u16; total];
            let mut contrib_val = vec![0f32; total];
            let mut fill: Vec<u32> = off[..union.len()].to_vec();
            for (mi, a) in roster.iter().enumerate() {
                let ms = &members[mi];
                let d = &a.tensors[ms.tensor_pos].1;
                for (j, &s) in ms.upos.iter().enumerate() {
                    let c = fill[s as usize] as usize;
                    contrib_member[c] = mi as u16;
                    contrib_val[c] = d.delta[j];
                    fill[s as usize] += 1;
                }
            }
            for ms in members.iter_mut() {
                ms.clean = ms.upos.iter().all(|&s| counts[s as usize] == 1);
            }
            targets.push(PlanTarget {
                name: tname.clone(),
                rows: d0.rows,
                cols: d0.cols,
                union_idx: union,
                contrib_off: off,
                contrib_member,
                contrib_val,
                members,
            });
        }

        let mut collide = vec![false; n * n];
        for p in &pairs {
            if p.collisions > 0 {
                collide[p.i * n + p.j] = true;
                collide[p.j * n + p.i] = true;
            }
        }
        Ok(FusionPlan {
            roster,
            targets,
            pairs,
            collide,
        })
    }

    /// The adapters this plan was built over, in roster order.
    pub fn roster(&self) -> &[Arc<ShiraAdapter>] {
        &self.roster
    }

    /// Number of roster members.
    pub fn len(&self) -> usize {
        self.roster.len()
    }

    /// True when the roster is empty (never — `build` rejects it — but
    /// required for the `len`/`is_empty` convention).
    pub fn is_empty(&self) -> bool {
        self.roster.is_empty()
    }

    /// Roster index of the member named `name`.
    pub fn member_index(&self, name: &str) -> Option<usize> {
        self.roster.iter().position(|a| a.name == name)
    }

    /// Per-pair collision entries (`i < j`, roster indices), computed at
    /// build time with the cheap two-cursor overlap walk.  `ata_density`
    /// is left 0.0 here; run
    /// [`analyze_shira`](super::fusion::analyze_shira) over the roster for
    /// the full §3.2 diagnostic.
    pub fn pairs(&self) -> &[PairInterference] {
        &self.pairs
    }

    /// Do members `i` and `j` share at least one weight slot?
    pub fn collides(&self, i: usize, j: usize) -> bool {
        i != j && self.collide[i * self.roster.len() + j]
    }

    /// Total union-support entries across all targets (the cost of a full
    /// set activation; each incremental op costs one member's nnz).
    pub fn union_nnz(&self) -> usize {
        self.targets.iter().map(|t| t.union_idx.len()).sum()
    }

    fn member_delta(&self, t: usize, m: usize) -> &SparseDelta {
        let pt = &self.targets[t];
        &self.roster[m].tensors[pt.members[m].tensor_pos].1
    }
}

/// Counts describing one [`FusionEngine::apply_set`] transition.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SetTransition {
    /// Members newly fused in.
    pub fused: usize,
    /// Members unfused.
    pub unfused: usize,
    /// Members whose weight changed while staying fused.
    pub reweighted: usize,
    /// Scatter waves the transition was dispatched in: 1 when anything
    /// was touched (the merged-support refresh recomputes every touched
    /// union slot exactly once, so colliding members no longer
    /// serialize), 0 for a no-op transition.
    pub waves: usize,
}

/// One shard of refresh work: member `m`'s local entries `[lo, hi)` on
/// plan target `t`.
#[derive(Clone, Copy)]
struct RefreshTask {
    t: usize,
    m: usize,
    lo: usize,
    hi: usize,
}

/// One shard of merged-support refresh work: positions `[lo, hi)` of plan
/// target `t`'s merged (deduped) union-slot list.
#[derive(Clone, Copy)]
struct UnionTask {
    t: usize,
    lo: usize,
    hi: usize,
}

/// Per-target scratch for the merged-support refresh, retained across
/// transitions so steady-state set switching reuses capacity.
#[derive(Default)]
struct UnionScratch {
    /// Merged, sorted, deduped union-slot indices touched this op.
    slots: Vec<u32>,
    /// `union_idx[slot]` per merged slot — the scatter destination, and
    /// the sorted flat-index sequence the row-aligned shards cut.
    flats: Vec<u32>,
}

/// Incremental fused-mode engine over a [`FusionPlan`].
///
/// The engine tracks which roster members are fused at which weight and
/// mutates a caller-owned [`WeightStore`] in place.  `activate` snapshots
/// the base values on the union support once; every subsequent
/// fuse/unfuse/reweight recomputes only the touched adapter's slots from
/// that snapshot, so the cost is O(that adapter's nnz) — not O(Σ nnz) —
/// and the resident weights stay bit-identical to a serial
/// [`fuse_shira`] rebuild of the currently-fused set.
///
/// # Examples
///
/// ```
/// use std::sync::Arc;
/// use shira::adapter::sparse::SparseDelta;
/// use shira::adapter::ShiraAdapter;
/// use shira::coordinator::fusion_engine::{FusionEngine, FusionPlan};
/// use shira::model::tensor::Tensor2;
/// use shira::model::weights::WeightStore;
///
/// let mk = |name: &str, idx: Vec<u32>, val: f32| {
///     let k = idx.len();
///     ShiraAdapter {
///         name: name.into(),
///         strategy: "rand".into(),
///         tensors: vec![("w".into(), SparseDelta::new(4, 4, idx, vec![val; k]))],
///     }
/// };
/// let plan = FusionPlan::build(vec![
///     Arc::new(mk("a", vec![0, 5], 1.0)),
///     Arc::new(mk("b", vec![5, 9], 2.0)),
/// ])
/// .unwrap();
/// let mut store = WeightStore::new();
/// store.insert("w", Tensor2::zeros(4, 4));
///
/// let mut eng = FusionEngine::new(plan);
/// eng.activate(&mut store).unwrap();
/// eng.fuse_into(&mut store, "a", 1.0).unwrap();
/// eng.fuse_into(&mut store, "b", 0.5).unwrap();
/// assert_eq!(store.get("w").data[5], 1.0 + 0.5 * 2.0); // collision sums
/// eng.reweight_one(&mut store, "b", 2.0).unwrap();
/// assert_eq!(store.get("w").data[9], 4.0);
/// eng.unfuse_one(&mut store, "a").unwrap();
/// eng.unfuse_one(&mut store, "b").unwrap();
/// assert!(store.get("w").data.iter().all(|&x| x == 0.0)); // exact revert
/// ```
pub struct FusionEngine {
    plan: FusionPlan,
    pool: Option<Arc<ThreadPool>>,
    /// Current per-member weight (meaningful while `fused[m]`).
    weights: Vec<f32>,
    fused: Vec<bool>,
    /// Base values at the union support, one buffer per plan target;
    /// filled by `activate`.
    base_snap: Vec<Vec<f32>>,
    active: bool,
    /// Incremental operations performed (members refreshed).
    updates: u64,
    /// Reusable shard-task scratch for the parallel path.
    tasks: Vec<RefreshTask>,
    /// Reusable merged-support task scratch (multi-member transitions).
    utasks: Vec<UnionTask>,
    /// Reusable per-target merged-slot scratch.
    union_scratch: Vec<UnionScratch>,
    /// Deterministic fault injector (chaos tests, DESIGN.md §13.2):
    /// when armed, one planned refresh wave panics mid-dispatch.
    fault: Option<Arc<FaultInjector>>,
}

impl FusionEngine {
    /// Engine without a thread pool (all scatters serial).
    pub fn new(plan: FusionPlan) -> Self {
        Self::with_pool(plan, None)
    }

    /// Engine with an attached pool: refresh passes run as a flat
    /// (target × shard) task list under one `scoped_for` region.
    pub fn with_pool(plan: FusionPlan, pool: Option<Arc<ThreadPool>>) -> Self {
        let n = plan.len();
        FusionEngine {
            plan,
            pool,
            weights: vec![0.0; n],
            fused: vec![false; n],
            base_snap: Vec::new(),
            active: false,
            updates: 0,
            tasks: Vec::new(),
            utasks: Vec::new(),
            union_scratch: Vec::new(),
            fault: None,
        }
    }

    /// Arm a deterministic fault injector: planned
    /// [`FaultSite::Wave`] ordinals make the matching refresh wave
    /// panic mid-dispatch (after partial writes), exercising the
    /// router's transactional rollback.
    pub fn set_fault(&mut self, fault: Arc<FaultInjector>) {
        self.fault = Some(fault);
    }

    /// Claim the next wave ordinal; true when this wave must panic.
    fn wave_fault_armed(&self) -> bool {
        match &self.fault {
            Some(f) => f.should_fire(FaultSite::Wave),
            None => false,
        }
    }

    /// Pure-data rollback snapshot: per plan target, the union support
    /// indices and the base values `activate` captured for them.  `None`
    /// until activated.  `base_snap` is written once at activation and
    /// never touched by refresh waves, so it survives a mid-wave panic
    /// intact — the router's transaction scatters it back to restore
    /// base on the whole union.
    pub fn snapshot_parts(&self) -> Option<Vec<(String, Vec<u32>, Vec<f32>)>> {
        if !self.active {
            return None;
        }
        Some(
            self.plan
                .targets
                .iter()
                .enumerate()
                .map(|(t, pt)| {
                    (pt.name.clone(), pt.union_idx.clone(), self.base_snap[t].clone())
                })
                .collect(),
        )
    }

    /// Forget all fused members and deactivate WITHOUT touching the
    /// weights — the rollback path's final step after the router has
    /// restored the resident store itself.  Never call this outside
    /// failure recovery: it desynchronizes the engine from the weights.
    pub fn clear_active(&mut self) {
        self.fused.iter_mut().for_each(|f| *f = false);
        self.weights.iter_mut().for_each(|w| *w = 0.0);
        self.active = false;
    }

    /// The plan this engine operates over.
    pub fn plan(&self) -> &FusionPlan {
        &self.plan
    }

    /// Has `activate` snapshotted a weight store?
    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Incremental operations performed so far (members refreshed).
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Current weight of a fused member (`None` when not fused).
    pub fn fused_weight(&self, name: &str) -> Option<f32> {
        let m = self.plan.member_index(name)?;
        if self.fused[m] {
            Some(self.weights[m])
        } else {
            None
        }
    }

    /// Currently-fused members in roster order.
    pub fn fused_members(&self) -> Vec<(&str, f32)> {
        (0..self.plan.len())
            .filter(|&m| self.fused[m])
            .map(|m| (self.plan.roster[m].name.as_str(), self.weights[m]))
            .collect()
    }

    /// Snapshot the base values on the plan's union support.  The store
    /// must hold every plan target at the plan's shape and currently carry
    /// *base* values there (nothing fused / no other adapter applied).
    pub fn activate(&mut self, store: &mut WeightStore) -> Result<(), FusionError> {
        for pt in &self.plan.targets {
            if !store.names().iter().any(|n| n == &pt.name) {
                return Err(FusionError::MissingTarget(pt.name.clone()));
            }
            let w = store.get(&pt.name);
            if (w.rows, w.cols) != (pt.rows, pt.cols) {
                return Err(FusionError::ShapeMismatch {
                    target: pt.name.clone(),
                    expect: (pt.rows, pt.cols),
                    got: (w.rows, w.cols),
                });
            }
        }
        self.base_snap = self
            .plan
            .targets
            .iter()
            .map(|pt| {
                let w = store.get(&pt.name);
                pt.union_idx.iter().map(|&i| w.data[i as usize]).collect()
            })
            .collect();
        self.weights.iter_mut().for_each(|w| *w = 0.0);
        self.fused.iter_mut().for_each(|f| *f = false);
        self.active = true;
        Ok(())
    }

    /// Unfuse everything and restore the base values exactly, leaving the
    /// engine inactive.
    pub fn deactivate(&mut self, store: &mut WeightStore) {
        if !self.active {
            return;
        }
        for (t, pt) in self.plan.targets.iter().enumerate() {
            let w = store.get_mut(&pt.name);
            for (s, &i) in pt.union_idx.iter().enumerate() {
                w.data[i as usize] = self.base_snap[t][s];
            }
        }
        self.fused.iter_mut().for_each(|f| *f = false);
        self.active = false;
    }

    /// Fuse `name` into the resident weights at `weight`.  O(that
    /// adapter's nnz).  Fusing an already-fused member re-weights it.
    pub fn fuse_into(
        &mut self,
        store: &mut WeightStore,
        name: &str,
        weight: f32,
    ) -> Result<(), FusionError> {
        let m = self.member(name)?;
        self.ensure_active()?;
        self.fused[m] = true;
        self.weights[m] = weight;
        self.refresh_members(store, &[m]);
        Ok(())
    }

    /// Remove `name` from the fused set without touching the other
    /// members' slots (their shared slots are recomputed from the base
    /// snapshot).  O(that adapter's nnz).  Unfusing a non-fused member is
    /// a no-op.
    pub fn unfuse_one(&mut self, store: &mut WeightStore, name: &str) -> Result<(), FusionError> {
        let m = self.member(name)?;
        self.ensure_active()?;
        if !self.fused[m] {
            return Ok(());
        }
        self.fused[m] = false;
        self.refresh_members(store, &[m]);
        Ok(())
    }

    /// Change a fused member's weight in place — no unfuse/refuse of the
    /// rest of the set.  O(that adapter's nnz).  Same operation as
    /// [`Self::fuse_into`] (which fuses the member if it was not).
    pub fn reweight_one(
        &mut self,
        store: &mut WeightStore,
        name: &str,
        weight: f32,
    ) -> Result<(), FusionError> {
        self.fuse_into(store, name, weight)
    }

    /// Transition to exactly the fused set `desired` (members absent from
    /// it are unfused) in ONE parallel wave.  Conflict-free touched sets
    /// scatter member-local (disjoint slots, clean-sub-slice fast path);
    /// colliding touched members — which previously serialized into
    /// per-member waves — have their slots merged into one deduped union
    /// per target and every union slot recomputed exactly once (the
    /// fused-mode twin of the switch engine's direct transitions).  Cost
    /// is the *touched* members' union nnz, so moving between overlapping
    /// sets is far cheaper than a rebuild.
    pub fn apply_set(
        &mut self,
        store: &mut WeightStore,
        desired: &[(String, f32)],
    ) -> Result<SetTransition, FusionError> {
        self.ensure_active()?;
        let n = self.plan.len();
        let mut want: Vec<Option<f32>> = vec![None; n];
        for (name, w) in desired {
            let m = self.member(name)?;
            if want[m].is_some() {
                return Err(FusionError::DuplicateMember(name.clone()));
            }
            want[m] = Some(*w);
        }
        let mut stats = SetTransition::default();
        let mut touched = Vec::new();
        for m in 0..n {
            match (self.fused[m], want[m]) {
                (false, Some(w)) => {
                    self.fused[m] = true;
                    self.weights[m] = w;
                    stats.fused += 1;
                    touched.push(m);
                }
                (true, None) => {
                    self.fused[m] = false;
                    stats.unfused += 1;
                    touched.push(m);
                }
                (true, Some(w)) if w.to_bits() != self.weights[m].to_bits() => {
                    self.weights[m] = w;
                    stats.reweighted += 1;
                    touched.push(m);
                }
                _ => {}
            }
        }
        // ONE wave: flags are already final, so every touched slot's
        // canonical value is computable immediately.  Conflict-free
        // touched sets keep the member-local path (disjoint slots, clean
        // sub-slices skip the contributor walk — already one wave);
        // colliding sets — which used to serialize into per-member waves
        // — refresh the merged union of their slots, each slot exactly
        // once.
        stats.waves = usize::from(!touched.is_empty());
        let colliding = touched.iter().enumerate().any(|(i, &m)| {
            touched[..i].iter().any(|&o| self.plan.collides(o, m))
        });
        if colliding {
            self.refresh_union(store, &touched);
        } else {
            self.refresh_members(store, &touched);
        }
        Ok(stats)
    }

    fn member(&self, name: &str) -> Result<usize, FusionError> {
        self.plan
            .member_index(name)
            .ok_or_else(|| FusionError::UnknownMember(name.to_string()))
    }

    fn ensure_active(&self) -> Result<(), FusionError> {
        if self.active {
            Ok(())
        } else {
            Err(FusionError::NotActive)
        }
    }

    /// Recompute every union slot touched by `members` (which must be
    /// mutually conflict-free so their writes are disjoint).  Flags and
    /// weights must already hold their final values.
    fn refresh_members(&mut self, store: &mut WeightStore, members: &[usize]) {
        if members.is_empty() {
            return;
        }
        self.updates += members.len() as u64;
        // Claim this refresh wave's fault ordinal (chaos injection).
        let boom = self.wave_fault_armed();
        let total_nnz: usize = members
            .iter()
            .map(|&m| self.plan.roster[m].param_count())
            .sum();
        let par = kernel::config().parallel_worthwhile(total_nnz);
        let pool = match &self.pool {
            Some(p) if par && p.threads() > 1 => Some(Arc::clone(p)),
            _ => None,
        };
        // Raw weight cursors per target.  SAFETY: pointers are only used
        // inside this call; tensors are not resized.
        let wptrs: Vec<SendPtr<f32>> = self
            .plan
            .targets
            .iter()
            .map(|pt| SendPtr::new(store.get_mut(&pt.name).data.as_mut_ptr()))
            .collect();
        let threads = pool.as_ref().map(|p| p.threads()).unwrap_or(1);
        self.tasks.clear();
        let n_targets = self.plan.targets.len();
        for &m in members {
            for t in 0..n_targets {
                let d = self.plan.member_delta(t, m);
                if d.nnz() == 0 {
                    continue;
                }
                let sp = d.shard(shards_for(d.nnz(), threads));
                for s in 0..sp.len() {
                    let (lo, hi) = sp.range(s);
                    if lo < hi {
                        self.tasks.push(RefreshTask { t, m, lo, hi });
                    }
                }
            }
        }
        let plan = &self.plan;
        let fused = &self.fused;
        let weights = &self.weights;
        let snaps = &self.base_snap;
        let tasks = &self.tasks;
        let n = tasks.len();
        let run = |i: usize| {
            if boom && i == n / 2 {
                panic!("{}", FaultInjector::WAVE_PANIC_MSG);
            }
            let task = tasks[i];
            // SAFETY: tasks cover disjoint local ranges of each
            // member's unique sorted support; members in one call
            // are conflict-free (no shared slots), so every weight
            // element is written by exactly one task.
            unsafe {
                refresh_range(
                    plan,
                    snaps,
                    fused,
                    weights,
                    wptrs[task.t].get(),
                    task.t,
                    task.m,
                    task.lo,
                    task.hi,
                )
            }
        };
        match pool {
            Some(pool) => {
                if let Err(fault) = pool.try_scoped_for(n, run) {
                    // The pool has fully quiesced: no worker still holds
                    // a cursor into W, so the router's rollback may run.
                    panic!("pool wave failed: {fault}");
                }
            }
            None => (0..n).for_each(run),
        }
        self.tasks.clear();
    }

    /// Recompute every union slot touched by at least one of `members`,
    /// exactly once per slot, in ONE dispatch wave: the members' `upos`
    /// lists are merged (sorted + deduped) per target, the merged list is
    /// cut into row-aligned shards with the same [`shard_sorted`] helper
    /// the switch engine's transitions use, and each shard folds the
    /// contributor CSR into the final canonical value.  Flags and weights
    /// must already hold their final values.  Bit-identical to refreshing
    /// the members one wave at a time (every refresh writes canonical
    /// values), but colliding members no longer serialize.
    fn refresh_union(&mut self, store: &mut WeightStore, members: &[usize]) {
        debug_assert!(members.len() > 1, "single members take refresh_members");
        self.updates += members.len() as u64;
        // Claim this refresh wave's fault ordinal (chaos injection).
        let boom = self.wave_fault_armed();
        let n_targets = self.plan.targets.len();
        if self.union_scratch.len() < n_targets {
            self.union_scratch
                .resize_with(n_targets, UnionScratch::default);
        }
        // Pass 1: merged slot lists per target (capacity reused).
        let mut total = 0usize;
        for (t, pt) in self.plan.targets.iter().enumerate() {
            let sc = &mut self.union_scratch[t];
            sc.slots.clear();
            for &m in members {
                sc.slots.extend_from_slice(&pt.members[m].upos);
            }
            sc.slots.sort_unstable();
            sc.slots.dedup();
            sc.flats.clear();
            sc.flats
                .extend(sc.slots.iter().map(|&s| pt.union_idx[s as usize]));
            total += sc.slots.len();
        }
        let par = kernel::config().parallel_worthwhile(total);
        let pool = match &self.pool {
            Some(p) if par && p.threads() > 1 => Some(Arc::clone(p)),
            _ => None,
        };
        // Raw weight cursors per target.  SAFETY: pointers are only used
        // inside this call; tensors are not resized.
        let wptrs: Vec<SendPtr<f32>> = self
            .plan
            .targets
            .iter()
            .map(|pt| SendPtr::new(store.get_mut(&pt.name).data.as_mut_ptr()))
            .collect();
        let threads = pool.as_ref().map(|p| p.threads()).unwrap_or(1);
        // Pass 2: row-aligned shards over each merged list, flat task list.
        self.utasks.clear();
        for t in 0..n_targets {
            let sc = &self.union_scratch[t];
            if sc.slots.is_empty() {
                continue;
            }
            let sp = shard_sorted(
                &sc.flats,
                self.plan.targets[t].cols,
                shards_for(sc.slots.len(), threads),
            );
            for s in 0..sp.len() {
                let (lo, hi) = sp.range(s);
                if lo < hi {
                    self.utasks.push(UnionTask { t, lo, hi });
                }
            }
        }
        let plan = &self.plan;
        let fused = &self.fused;
        let weights = &self.weights;
        let snaps = &self.base_snap;
        let scratch = &self.union_scratch;
        let tasks = &self.utasks;
        let n = tasks.len();
        let run = |i: usize| {
            if boom && i == n / 2 {
                panic!("{}", FaultInjector::WAVE_PANIC_MSG);
            }
            let task = tasks[i];
            let sc = &scratch[task.t];
            // SAFETY: merged slot lists are deduped and shards cover
            // disjoint ranges, so every union slot — and thus every
            // weight element — is written by exactly one task.
            unsafe {
                refresh_union_range(
                    plan,
                    snaps,
                    fused,
                    weights,
                    wptrs[task.t].get(),
                    task.t,
                    &sc.slots,
                    &sc.flats,
                    task.lo,
                    task.hi,
                )
            }
        };
        match pool {
            Some(pool) => {
                if let Err(fault) = pool.try_scoped_for(n, run) {
                    // Fully quiesced (see refresh_members): rollback-safe.
                    panic!("pool wave failed: {fault}");
                }
            }
            None => (0..n).for_each(run),
        }
        self.utasks.clear();
    }

    /// Rebuild the fused weights for the current set from scratch with the
    /// serial [`fuse_shira`] path (tests / verification — O(Σ nnz)).
    /// Returns `None` when nothing is fused (weights are at base).
    pub fn rebuild_reference(&self, base: &WeightStore) -> Option<WeightStore> {
        let scaled: Vec<ShiraAdapter> = (0..self.plan.len())
            .filter(|&m| self.fused[m])
            .map(|m| {
                let a = &self.plan.roster[m];
                ShiraAdapter {
                    name: a.name.clone(),
                    strategy: a.strategy.clone(),
                    tensors: a
                        .tensors
                        .iter()
                        .map(|(t, d)| (t.clone(), d.scaled(self.weights[m])))
                        .collect(),
                }
            })
            .collect();
        if scaled.is_empty() {
            return None;
        }
        let refs: Vec<&ShiraAdapter> = scaled.iter().collect();
        let merged = fuse_shira(&refs, "reference").expect("roster pre-validated");
        let mut w = base.clone();
        for (t, d) in &merged.tensors {
            d.apply(w.get_mut(t), 1.0);
        }
        Some(w)
    }
}

/// Recompute member `m`'s union slots `[lo, hi)` (local entry indices) on
/// plan target `t`: each slot gets `base + fold(contributions)` — one
/// addition to base, never an increment of a live weight, so the result
/// matches a from-scratch [`fuse_shira`] rebuild bit for bit.
///
/// # Safety
/// `w` must point at target `t`'s weight data; ranges handed to concurrent
/// callers must be disjoint, and no two concurrently-refreshed members may
/// share a slot (enforced by conflict-free wave grouping).
#[allow(clippy::too_many_arguments)]
unsafe fn refresh_range(
    plan: &FusionPlan,
    snaps: &[Vec<f32>],
    fused: &[bool],
    weights: &[f32],
    w: *mut f32,
    t: usize,
    m: usize,
    lo: usize,
    hi: usize,
) {
    let pt = &plan.targets[t];
    let ms = &pt.members[m];
    let d = &plan.roster[m].tensors[ms.tensor_pos].1;
    let snap = &snaps[t];
    if ms.clean {
        // Collision-free sub-slice: single contributor per slot, direct
        // scatter with no contributor walk.
        if fused[m] {
            let wm = weights[m];
            for j in lo..hi {
                let s = *ms.upos.get_unchecked(j) as usize;
                *w.add(*d.idx.get_unchecked(j) as usize) =
                    snap[s] + *d.delta.get_unchecked(j) * wm;
            }
        } else {
            for j in lo..hi {
                let s = *ms.upos.get_unchecked(j) as usize;
                *w.add(*d.idx.get_unchecked(j) as usize) = snap[s];
            }
        }
    } else {
        for j in lo..hi {
            let s = *ms.upos.get_unchecked(j) as usize;
            let mut acc = 0.0f32;
            let mut any = false;
            let c0 = pt.contrib_off[s] as usize;
            let c1 = pt.contrib_off[s + 1] as usize;
            for c in c0..c1 {
                let cm = *pt.contrib_member.get_unchecked(c) as usize;
                if fused[cm] {
                    let v = *pt.contrib_val.get_unchecked(c) * weights[cm];
                    acc = if any { acc + v } else { v };
                    any = true;
                }
            }
            let base = snap[s];
            *w.add(*d.idx.get_unchecked(j) as usize) = if any { base + acc } else { base };
        }
    }
}

/// Recompute merged union slots `[lo, hi)` (positions into the deduped
/// `slots` list) of plan target `t`: each slot gets `base +
/// fold(contributions)` over fused contributors in roster order — the
/// merged-support one-wave twin of [`refresh_range`], writing every
/// touched slot exactly once per transition no matter how many touched
/// members share it, and matching a from-scratch [`fuse_shira`] rebuild
/// bit for bit.
///
/// # Safety
/// `w` must point at target `t`'s weight data; `slots`/`flats` must be
/// deduped, parallel, and in-bounds for the plan; ranges handed to
/// concurrent callers must be disjoint.
#[allow(clippy::too_many_arguments)]
unsafe fn refresh_union_range(
    plan: &FusionPlan,
    snaps: &[Vec<f32>],
    fused: &[bool],
    weights: &[f32],
    w: *mut f32,
    t: usize,
    slots: &[u32],
    flats: &[u32],
    lo: usize,
    hi: usize,
) {
    let pt = &plan.targets[t];
    let snap = &snaps[t];
    for k in lo..hi {
        let s = *slots.get_unchecked(k) as usize;
        let mut acc = 0.0f32;
        let mut any = false;
        let c0 = pt.contrib_off[s] as usize;
        let c1 = pt.contrib_off[s + 1] as usize;
        for c in c0..c1 {
            let cm = *pt.contrib_member.get_unchecked(c) as usize;
            if fused[cm] {
                let v = *pt.contrib_val.get_unchecked(c) * weights[cm];
                acc = if any { acc + v } else { v };
                any = true;
            }
        }
        let base = snap[s];
        *w.add(*flats.get_unchecked(k) as usize) = if any { base + acc } else { base };
    }
}

/// Union of two sorted unique index slices.
fn union_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() || j < b.len() {
        let ia = a.get(i).copied().unwrap_or(u32::MAX);
        let ib = b.get(j).copied().unwrap_or(u32::MAX);
        if ia < ib {
            out.push(ia);
            i += 1;
        } else if ib < ia {
            out.push(ib);
            j += 1;
        } else {
            out.push(ia);
            i += 1;
            j += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    fn delta(rng: &mut Rng, rows: usize, cols: usize, k: usize) -> SparseDelta {
        let idx = rng.sample_indices(rows * cols, k);
        let mut d = vec![0.0; k];
        rng.fill_normal(&mut d, 0.0, 1.0);
        SparseDelta::new(rows, cols, idx, d)
    }

    fn adapter(seed: u64, name: &str, rows: usize, cols: usize, k: usize) -> Arc<ShiraAdapter> {
        let mut rng = Rng::new(seed);
        Arc::new(ShiraAdapter {
            name: name.into(),
            strategy: "rand".into(),
            tensors: vec![
                ("wq".into(), delta(&mut rng, rows, cols, k)),
                ("wk".into(), delta(&mut rng, rows, cols, k)),
            ],
        })
    }

    fn store(rows: usize, cols: usize, seed: u64) -> WeightStore {
        WeightStore::init(
            &[("wq".into(), vec![rows, cols]), ("wk".into(), vec![rows, cols])],
            seed,
        )
    }

    /// Engine state must equal a from-scratch serial rebuild, bit for bit.
    fn assert_matches_rebuild(eng: &FusionEngine, base: &WeightStore, live: &WeightStore) {
        match eng.rebuild_reference(base) {
            Some(reference) => assert!(live.bit_equal(&reference), "live != rebuild"),
            None => assert!(live.bit_equal(base), "empty set should be base"),
        }
    }

    #[test]
    fn plan_build_validates_roster() {
        let a = adapter(1, "a", 8, 8, 6);
        let b = adapter(2, "b", 8, 8, 6);
        assert!(FusionPlan::build(vec![]).is_err());
        assert!(FusionPlan::build(vec![a.clone(), a.clone()]).is_err()); // dup name
        let mut c = (*adapter(3, "c", 8, 8, 6)).clone();
        c.tensors.pop();
        assert!(matches!(
            FusionPlan::build(vec![a.clone(), Arc::new(c)]),
            Err(FusionError::TargetSetMismatch { .. })
        ));
        let plan = FusionPlan::build(vec![a, b]).unwrap();
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.pairs().len(), 1);
    }

    #[test]
    fn plan_union_covers_all_members() {
        let a = adapter(4, "a", 8, 8, 10);
        let b = adapter(5, "b", 8, 8, 10);
        let plan = FusionPlan::build(vec![a.clone(), b.clone()]).unwrap();
        for pt in &plan.targets {
            assert!(pt.union_idx.windows(2).all(|w| w[0] < w[1]));
            for m in [&a, &b] {
                for &i in &m.find(&pt.name).unwrap().idx {
                    assert!(pt.union_idx.binary_search(&i).is_ok());
                }
            }
            // contributor counts sum to member nnz totals
            let total: u32 = *pt.contrib_off.last().unwrap();
            let want: usize = [&a, &b].iter().map(|m| m.find(&pt.name).unwrap().nnz()).sum();
            assert_eq!(total as usize, want);
        }
    }

    #[test]
    fn fuse_reweight_unfuse_bit_identical_to_rebuild() {
        let base = store(16, 16, 7);
        let roster = vec![
            adapter(10, "a", 16, 16, 40),
            adapter(11, "b", 16, 16, 40),
            adapter(12, "c", 16, 16, 40),
        ];
        let plan = FusionPlan::build(roster).unwrap();
        let mut eng = FusionEngine::new(plan);
        let mut w = base.clone();
        eng.activate(&mut w).unwrap();

        eng.fuse_into(&mut w, "a", 1.0).unwrap();
        assert_matches_rebuild(&eng, &base, &w);
        eng.fuse_into(&mut w, "b", 0.5).unwrap();
        assert_matches_rebuild(&eng, &base, &w);
        eng.fuse_into(&mut w, "c", 1.5).unwrap();
        assert_matches_rebuild(&eng, &base, &w);
        eng.reweight_one(&mut w, "b", 2.0).unwrap();
        assert_matches_rebuild(&eng, &base, &w);
        eng.unfuse_one(&mut w, "a").unwrap();
        assert_matches_rebuild(&eng, &base, &w);
        eng.unfuse_one(&mut w, "c").unwrap();
        assert_matches_rebuild(&eng, &base, &w);
        eng.unfuse_one(&mut w, "b").unwrap();
        assert!(w.bit_equal(&base)); // exact revert, the SHiRA claim
        assert_eq!(eng.fused_members().len(), 0);
    }

    #[test]
    fn unknown_member_and_inactive_errors() {
        let plan = FusionPlan::build(vec![adapter(20, "a", 8, 8, 4)]).unwrap();
        let mut eng = FusionEngine::new(plan);
        let mut w = store(8, 8, 1);
        assert_eq!(
            eng.fuse_into(&mut w, "a", 1.0),
            Err(FusionError::NotActive)
        );
        eng.activate(&mut w).unwrap();
        assert!(matches!(
            eng.fuse_into(&mut w, "nope", 1.0),
            Err(FusionError::UnknownMember(_))
        ));
    }

    #[test]
    fn activate_validates_store() {
        let plan = FusionPlan::build(vec![adapter(21, "a", 8, 8, 4)]).unwrap();
        let mut eng = FusionEngine::new(plan.clone());
        let mut missing = WeightStore::new();
        assert!(matches!(
            eng.activate(&mut missing),
            Err(FusionError::MissingTarget(_))
        ));
        let mut wrong = store(4, 4, 1);
        let mut eng2 = FusionEngine::new(plan);
        assert!(matches!(
            eng2.activate(&mut wrong),
            Err(FusionError::ShapeMismatch { .. })
        ));
    }

    #[test]
    fn apply_set_diffs_in_one_wave() {
        let base = store(16, 16, 3);
        // enough support that the members collide with high probability
        let roster = vec![
            adapter(30, "a", 16, 16, 90),
            adapter(31, "b", 16, 16, 90),
            adapter(32, "c", 16, 16, 90),
        ];
        let plan = FusionPlan::build(roster).unwrap();
        assert!(plan.collides(0, 1), "dense supports should collide");
        let mut eng = FusionEngine::new(plan);
        let mut w = base.clone();
        eng.activate(&mut w).unwrap();

        let t = eng
            .apply_set(&mut w, &[("a".into(), 1.0), ("b".into(), 0.5)])
            .unwrap();
        assert_eq!((t.fused, t.unfused, t.reweighted), (2, 0, 0));
        // merged-support refresh: colliding members no longer serialize
        assert_eq!(t.waves, 1, "every transition is one wave");
        assert_matches_rebuild(&eng, &base, &w);

        // b reweighted, a dropped, c added — one transition, one wave
        let t = eng
            .apply_set(&mut w, &[("b".into(), 2.0), ("c".into(), 1.0)])
            .unwrap();
        assert_eq!((t.fused, t.unfused, t.reweighted), (1, 1, 1));
        assert_eq!(t.waves, 1);
        assert_matches_rebuild(&eng, &base, &w);

        // same set again: nothing touched
        let t = eng
            .apply_set(&mut w, &[("b".into(), 2.0), ("c".into(), 1.0)])
            .unwrap();
        assert_eq!(t, SetTransition { waves: 0, ..Default::default() });

        eng.apply_set(&mut w, &[]).unwrap();
        assert!(w.bit_equal(&base));
    }

    #[test]
    fn single_member_roster_swap_is_one_wave_and_exact() {
        // The fused-mode serving case the transition work targets: a
        // request stream moving between one-member sets "a" → "b" where
        // a and b collide.  The swap (unfuse a + fuse b) must be ONE
        // wave and bit-identical to a rebuild, at any thread count.
        let dim = 96usize;
        let k = 4000usize; // crosses the parallel cutoff so pooled runs dispatch
        let base = store(dim, dim, 17);
        let roster = vec![adapter(70, "a", dim, dim, k), adapter(71, "b", dim, dim, k)];
        for threads in [1usize, 2, 4] {
            let plan = FusionPlan::build(roster.clone()).unwrap();
            let pool = Arc::new(ThreadPool::new(threads));
            let mut eng = FusionEngine::with_pool(plan, Some(pool));
            let mut w = base.clone();
            eng.activate(&mut w).unwrap();
            eng.apply_set(&mut w, &[("a".into(), 1.0)]).unwrap();
            assert_matches_rebuild(&eng, &base, &w);
            let t = eng.apply_set(&mut w, &[("b".into(), 0.7)]).unwrap();
            assert_eq!((t.fused, t.unfused, t.waves), (1, 1, 1), "threads={threads}");
            assert_matches_rebuild(&eng, &base, &w);
            // swap back with an alpha change, still one wave
            let t = eng.apply_set(&mut w, &[("a".into(), -0.3)]).unwrap();
            assert_eq!((t.fused, t.unfused, t.waves), (1, 1, 1));
            assert_matches_rebuild(&eng, &base, &w);
            eng.apply_set(&mut w, &[]).unwrap();
            assert!(w.bit_equal(&base), "threads={threads}");
        }
    }

    #[test]
    fn prop_set_transitions_bit_identical_to_rebuild() {
        // Random sequences of apply_set over colliding rosters, serial
        // and pooled: the one-wave merged-support refresh must land on
        // rebuild bytes after every transition.
        let pool = Arc::new(ThreadPool::new(4));
        pt::forall(
            78,
            20,
            |r| {
                let n_members = 2 + r.below(3);
                let sets: Vec<Vec<(usize, f32)>> = (0..2 + r.below(5))
                    .map(|_| {
                        let size = r.below(n_members + 1);
                        (0..size)
                            .map(|_| (r.below(n_members), -2.0 + 4.0 * r.uniform_f32()))
                            .collect()
                    })
                    .collect();
                (r.next_u64(), n_members, sets)
            },
            |&(seed, n_members, ref sets)| {
                let base = store(10, 10, seed);
                let roster: Vec<Arc<ShiraAdapter>> = (0..n_members)
                    .map(|m| adapter(seed ^ (m as u64 + 1), &format!("m{m}"), 10, 10, 30))
                    .collect();
                for pooled in [false, true] {
                    let plan = FusionPlan::build(roster.clone()).unwrap();
                    let mut eng = if pooled {
                        FusionEngine::with_pool(plan, Some(Arc::clone(&pool)))
                    } else {
                        FusionEngine::new(plan)
                    };
                    let mut w = base.clone();
                    eng.activate(&mut w).unwrap();
                    for set in sets {
                        // dedup member indices (apply_set rejects dups)
                        let mut desired: Vec<(String, f32)> = Vec::new();
                        for &(m, alpha) in set {
                            let name = format!("m{m}");
                            if !desired.iter().any(|(n, _)| *n == name) {
                                desired.push((name, alpha));
                            }
                        }
                        let t = eng.apply_set(&mut w, &desired).unwrap();
                        if t.waves > 1 {
                            return false;
                        }
                        let ok = match eng.rebuild_reference(&base) {
                            Some(reference) => w.bit_equal(&reference),
                            None => w.bit_equal(&base),
                        };
                        if !ok {
                            return false;
                        }
                    }
                    eng.apply_set(&mut w, &[]).unwrap();
                    if !w.bit_equal(&base) {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn deactivate_restores_base_exactly() {
        let base = store(16, 16, 9);
        let plan =
            FusionPlan::build(vec![adapter(40, "a", 16, 16, 30), adapter(41, "b", 16, 16, 30)])
                .unwrap();
        let mut eng = FusionEngine::new(plan);
        let mut w = base.clone();
        eng.activate(&mut w).unwrap();
        eng.fuse_into(&mut w, "a", 1.0).unwrap();
        eng.fuse_into(&mut w, "b", -0.7).unwrap();
        assert!(w.max_abs_diff(&base) > 0.0);
        eng.deactivate(&mut w);
        assert!(w.bit_equal(&base));
        assert!(!eng.is_active());
    }

    #[test]
    fn pooled_engine_bit_identical_to_serial_above_threshold() {
        // Big enough to cross the parallel cutoff so the parallel path runs.
        let dim = 96usize;
        let k = 4000usize; // 2 targets × 4000 nnz ≫ the parallel cutoff
        let base = store(dim, dim, 13);
        let roster = vec![
            adapter(50, "a", dim, dim, k),
            adapter(51, "b", dim, dim, k),
            adapter(52, "c", dim, dim, k),
        ];
        for threads in [1usize, 2, 4] {
            let plan = FusionPlan::build(roster.clone()).unwrap();
            let pool = Arc::new(ThreadPool::new(threads));
            let mut eng = FusionEngine::with_pool(plan, Some(pool));
            let mut w = base.clone();
            eng.activate(&mut w).unwrap();
            eng.fuse_into(&mut w, "a", 1.0).unwrap();
            eng.fuse_into(&mut w, "b", 0.3).unwrap();
            eng.fuse_into(&mut w, "c", -1.2).unwrap();
            assert_matches_rebuild(&eng, &base, &w);
            eng.reweight_one(&mut w, "b", 0.9).unwrap();
            assert_matches_rebuild(&eng, &base, &w);
            eng.unfuse_one(&mut w, "a").unwrap();
            assert_matches_rebuild(&eng, &base, &w);
            eng.apply_set(&mut w, &[]).unwrap();
            assert!(w.bit_equal(&base), "threads={threads}");
        }
    }

    #[test]
    fn prop_any_op_sequence_bit_identical_to_rebuild() {
        // The PR's acceptance property: any sequence of
        // fuse_into/unfuse_one/reweight_one leaves the engine state
        // bit-identical to rebuilding from scratch with fuse_shira.
        pt::forall(
            77,
            30,
            |r| {
                let n_members = 2 + r.below(3);
                let ops: Vec<(u8, usize, f32)> = (0..3 + r.below(8))
                    .map(|_| {
                        (
                            r.below(3) as u8,
                            r.below(n_members),
                            -2.0 + 4.0 * r.uniform_f32(),
                        )
                    })
                    .collect();
                (r.next_u64(), n_members, ops)
            },
            |&(seed, n_members, ref ops)| {
                let rows = 10usize;
                let cols = 10usize;
                let base = store(rows, cols, seed);
                let roster: Vec<Arc<ShiraAdapter>> = (0..n_members)
                    .map(|m| {
                        // dense enough (30/100) that collisions are common
                        adapter(seed ^ (m as u64 + 1), &format!("m{m}"), rows, cols, 30)
                    })
                    .collect();
                let plan = FusionPlan::build(roster).unwrap();
                let mut eng = FusionEngine::new(plan);
                let mut w = base.clone();
                eng.activate(&mut w).unwrap();
                for &(op, m, alpha) in ops {
                    let name = format!("m{m}");
                    match op {
                        0 => eng.fuse_into(&mut w, &name, alpha).unwrap(),
                        1 => eng.unfuse_one(&mut w, &name).unwrap(),
                        _ => eng.reweight_one(&mut w, &name, alpha).unwrap(),
                    }
                    let ok = match eng.rebuild_reference(&base) {
                        Some(reference) => w.bit_equal(&reference),
                        None => w.bit_equal(&base),
                    };
                    if !ok {
                        return false;
                    }
                }
                eng.apply_set(&mut w, &[]).unwrap();
                w.bit_equal(&base)
            },
        );
    }
}
