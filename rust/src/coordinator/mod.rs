//! L3 coordinator — the paper's systems contribution: rapid adapter
//! switching (S13), multi-adapter fusion (S14) with an incremental
//! fused-mode engine, request routing + dynamic batching (S15), the
//! adapter lifecycle store (S16: caching, shard-aligned decode, prefetch)
//! and metrics (S17).

pub mod batcher;
pub mod cache;
pub mod fusion;
pub mod fusion_engine;
pub mod metrics;
pub mod server;
pub mod store;
pub mod switch;
