//! L3 coordinator — the paper's systems contribution: rapid adapter
//! switching (§13), multi-adapter fusion (§14) with an incremental
//! fused-mode engine, unified per-request `Selection` routing over
//! trait-based engines (§12), request batching (§15), the adapter
//! lifecycle store (§16: caching, shard-aligned decode, prefetch) and
//! metrics (§17).
//!
//! Public surface map:
//! * [`selection`] — the one request surface (`Base | Single | Set`);
//! * [`error`] — the structured [`error::ServeError`] taxonomy;
//! * [`engine`] — the [`engine::AdapterEngine`] trait and the
//!   per-request [`engine::Router`];
//! * [`server`] — [`server::ServerBuilder`] / [`server::Server`];
//! * [`fault`] — deterministic fault injection ([`fault::FaultPlan`])
//!   for chaos-testing every recovery path;
//! * [`fleet`] — N-replica fleet serving (DESIGN.md §14): affinity
//!   routing over a shared store, admission control, and the seeded
//!   determinism harness with its bit-identity oracle;
//! * [`gate`] — the learned top-k [`gate::Gate`] that resolves
//!   [`selection::Selection::Auto`] requests into weighted sets
//!   (DESIGN.md §17);
//! * [`pool`] — the [`pool::ExpertPool`] roster the gate selects over:
//!   register/retire lifecycle, capacity caps, utilization counters.

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod error;
pub mod fault;
pub mod fleet;
pub mod fusion;
pub mod fusion_engine;
pub mod gate;
pub mod metrics;
pub mod pool;
pub mod selection;
pub mod server;
pub mod store;
pub mod switch;
