//! L3 coordinator — the paper's systems contribution: rapid adapter
//! switching (S13), multi-adapter fusion (S14) with an incremental
//! fused-mode engine, request routing + dynamic batching (S15), adapter
//! caching (S16) and metrics (S17).

pub mod batcher;
pub mod cache;
pub mod fusion;
pub mod fusion_engine;
pub mod metrics;
pub mod server;
pub mod switch;
