//! The switch engine — the paper's rapid-switching contribution (§3.2,
//! Appendix A/B) applied to a caller-owned resident [`WeightStore`].
//!
//! Since the `Selection` routing redesign the engine no longer owns the
//! weights: the server (or any caller) holds ONE resident copy of the
//! base model and passes it to every operation, so the switch engine and
//! the fused-mode [`FusionEngine`](super::fusion_engine::FusionEngine)
//! mutate the *same* store and both sit behind the
//! [`AdapterEngine`](super::engine::AdapterEngine) trait.  Requests pick
//! their path per-request via
//! [`Selection`](super::selection::Selection) — there is no
//! construction-time policy fork.
//!
//! Mechanisms (unchanged from PRs 1–4):
//!
//! * **SHiRA scatter** — snapshot the k base values on the adapter's
//!   support, scatter the adapter in, infer, scatter the snapshot back.
//!   O(k) work, exact revert.
//! * **Direct transitions** — [`SwitchEngine::transition_to`] walks the
//!   A∪B support union once and dispatches ONE pool wave instead of
//!   revert+apply's two passes and two waves.
//! * **LoRA fuse** — the HF load→fuse→infer→unfuse pipeline baseline:
//!   dense `W += s·AB` / `W -= s·AB` over every target.  Revert
//!   accumulates float drift.
//!
//! ## Steady-state allocation & parallelism (DESIGN.md §4)
//!
//! Snapshots live in a per-target **arena** of reusable buffers: after the
//! first visit to a target tensor the switch path performs no O(nnz)
//! allocations — buffers are resized within retained capacity.  (Parallel
//! dispatch itself costs one small O(threads) control block per region —
//! bounded and nnz-independent.)  When a
//! [`ThreadPool`] is attached, scatter-apply and snapshot-restore run as a
//! flat list of row-aligned shard tasks spanning *all* target tensors, so
//! switch work overlaps across tensors and across shards of one tensor.
//! Parallel results are bit-identical to the serial path (each element is
//! written by exactly one shard; per-element arithmetic unchanged).
//!
//! The engine's snapshot arena is keyed by target-tensor name: callers
//! must pass the *same* weight store (or a bit-identical clone at base)
//! across an apply/revert pair, exactly as they previously had to leave
//! the engine-owned store untouched between the two calls.
//!
//! Since PR 8 every scatter bottoms out in the dispatch-selected span
//! kernels of [`crate::adapter::kernel`] (DESIGN.md §15): store-built
//! [`TensorPlan`]s hand each shard its precomputed run cuts so the SIMD
//! execution sweeps contiguous runs, and f16-resident adapters
//! ([`ShiraF16Adapter`]) are applied by dequantizing lane-wise inside the
//! kernel — both bit-identical to the scalar / f32 reference paths.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::adapter::kernel::{self, F16Src, F32Src, KernelDispatch, Runs};
use crate::adapter::sparse::{shard_sorted, shards_for, TensorPlan};
use super::fault::{FaultInjector, FaultSite};
use crate::adapter::{AdapterTransition, LoraAdapter, ShiraAdapter, ShiraF16Adapter};
use crate::model::weights::WeightStore;
use crate::util::threadpool::ThreadPool;

/// Which path one adapter application took (recorded per switch in
/// `ServeMetrics`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchPath {
    /// One-pass direct transition over the A∪B support union (one pool
    /// dispatch wave) via a precomputed
    /// [`AdapterTransition`](crate::adapter::AdapterTransition) plan.
    Transition,
    /// Classic revert-then-apply (no usable transition plan: cold pair,
    /// no previous adapter, or a plan/adapter mismatch).
    Fallback,
    /// Served by the incremental fused-mode engine: the set (or
    /// one-member-set single) transition recomputed only the touched
    /// members' union slots in one wave.
    Fused,
}

impl SwitchPath {
    /// Stable report name of the path.
    pub fn name(&self) -> &'static str {
        match self {
            SwitchPath::Transition => "transition",
            SwitchPath::Fallback => "fallback",
            SwitchPath::Fused => "fused",
        }
    }
}

/// Per-stage timings of one switch, mirroring paper Table 5.
#[derive(Clone, Copy, Debug, Default)]
pub struct SwitchTiming {
    /// Deserialization time (flash → decoded adapter), microseconds.
    pub load_us: f64,
    /// Weight-mutation time: scatter-apply for SHiRA, dense fuse for LoRA.
    pub fuse_us: f64,
    /// Revert time: snapshot-restore for SHiRA, dense unfuse for LoRA.
    pub unfuse_us: f64,
    /// Drop/unload time, microseconds.
    pub unload_us: f64,
}

impl SwitchTiming {
    /// Sum of all four stages, microseconds.
    pub fn total_us(&self) -> f64 {
        self.load_us + self.fuse_us + self.unfuse_us + self.unload_us
    }
}

/// What is currently applied to the resident weights.  Adapters are held
/// by `Arc`, so activating a cached adapter copies no tensor data.  An
/// active SHiRA adapter may carry store-built per-tensor [`TensorPlan`]s
/// (shard-aligned decode + precomputed run cuts) so revert reuses them
/// too.
#[derive(Debug)]
enum Active {
    None,
    Shira {
        adapter: Arc<ShiraAdapter>,
        plans: Option<Arc<Vec<TensorPlan>>>,
    },
    /// f16-resident SHiRA adapter (raw binary16 delta bits, dequantized
    /// lane-wise in the kernel on apply — DESIGN.md §15).
    ShiraF16 {
        adapter: Arc<ShiraF16Adapter>,
        plans: Option<Arc<Vec<TensorPlan>>>,
    },
    Lora {
        adapter: Arc<LoraAdapter>,
    },
}

/// Where a task's delta values live — mirrors the kernel layer's
/// `DeltaSource` at the task level, so one task list serves f32- and
/// f16-resident adapters through the same wave dispatch.
#[derive(Clone, Copy)]
enum TaskDelta {
    /// f32-resident delta values.
    F32(*const f32),
    /// f16-resident delta bits, dequantized lane-wise in the kernel.
    F16(*const u16),
}

/// One shard's worth of scatter work: raw cursors into a target tensor,
/// its snapshot arena buffer, and the adapter's idx/delta arrays, plus
/// the shard's precomputed run cuts when a [`TensorPlan`] is in hand.
///
/// Pointers are only dereferenced inside the `scoped_for` region of the
/// switch call that built them; the task list is cleared afterwards.
/// Run-cut pointers point into plan storage (`Arc`-held) that the same
/// call keeps alive across the wave.
#[derive(Clone, Copy)]
struct ShardTask {
    w: *mut f32,
    snap: *mut f32,
    idx: *const u32,
    delta: TaskDelta,
    lo: usize,
    hi: usize,
    runs: Runs,
}

unsafe impl Send for ShardTask {}
unsafe impl Sync for ShardTask {}

impl ShardTask {
    /// Fused snapshot + scatter-apply over this shard's range — delegates
    /// to the span kernels in `adapter::kernel`.
    ///
    /// # Safety
    /// Tasks must cover disjoint idx ranges; all pointers must be live.
    unsafe fn snapshot_apply(&self, dispatch: KernelDispatch, alpha: f32) {
        match self.delta {
            TaskDelta::F32(d) => kernel::snapshot_apply_span(
                dispatch,
                self.idx,
                F32Src(d),
                self.w,
                self.snap,
                alpha,
                self.lo,
                self.hi,
                self.runs,
            ),
            TaskDelta::F16(d) => kernel::snapshot_apply_span(
                dispatch,
                self.idx,
                F16Src(d),
                self.w,
                self.snap,
                alpha,
                self.lo,
                self.hi,
                self.runs,
            ),
        }
    }

    /// Snapshot-restore over this shard's range.
    ///
    /// # Safety
    /// Same contract as [`Self::snapshot_apply`].
    unsafe fn restore(&self, dispatch: KernelDispatch) {
        kernel::restore_span(dispatch, self.idx, self.w, self.snap, self.lo, self.hi, self.runs)
    }
}

/// One shard of direct-transition work: raw cursors into the union-walk
/// arrays of one tensor's [`TransitionPlan`](crate::adapter::sparse::TransitionPlan),
/// the outgoing adapter's snapshot (read), the incoming adapter's
/// snapshot buffer (written) and the target tensor.
///
/// Pointers are only dereferenced inside the `scoped_for` region of the
/// transition call that built them; the task list is cleared afterwards.
#[derive(Clone, Copy)]
struct TransitionTask {
    idx: *const u32,
    a_pos: *const u32,
    b_pos: *const u32,
    delta: *const f32,
    w: *mut f32,
    snap_a: *const f32,
    snap_b: *mut f32,
    lo: usize,
    hi: usize,
    runs: Runs,
}

unsafe impl Send for TransitionTask {}
unsafe impl Sync for TransitionTask {}

impl TransitionTask {
    /// One-pass union transition over this shard's range — delegates to
    /// the transition span kernel in `adapter::kernel`.
    ///
    /// # Safety
    /// Tasks must cover disjoint union ranges; all pointers must be live.
    unsafe fn run(&self, dispatch: KernelDispatch, alpha: f32) {
        kernel::transition_span(
            dispatch,
            self.idx,
            self.a_pos,
            self.b_pos,
            F32Src(self.delta),
            self.w,
            self.snap_a,
            self.snap_b,
            alpha,
            self.lo,
            self.hi,
            self.runs,
        )
    }
}

/// Applies and reverts adapters on a caller-owned resident weight store.
///
/// The engine tracks what is applied (Arc-held), keeps the per-target
/// snapshot arena, and dispatches scatter work on an optional pool; the
/// weights themselves belong to the caller and are passed into every
/// operation — the same store the fused-mode engine mutates, so one
/// server can route singles and sets onto one resident copy.
pub struct SwitchEngine {
    active: Active,
    /// Number of adapter activations performed.
    pub switches: u64,
    pool: Option<Arc<ThreadPool>>,
    /// Kernel dispatch mode for the engine's sharded waves, captured from
    /// [`kernel::active_dispatch`] at construction.  (Serial one-shots go
    /// through the `SparseDelta` methods, which read the process-wide mode
    /// at call time — both modes are bit-identical for f32 deltas, so the
    /// split is invisible in bytes.)
    dispatch: KernelDispatch,
    /// Reusable per-target snapshot buffers: allocation-free steady state.
    arena: HashMap<String, Vec<f32>>,
    /// Back buffers for direct transitions: the incoming adapter's
    /// snapshot is written here while the outgoing adapter's snapshot is
    /// still being read from `arena`, then the two are swapped per target.
    /// Retained like the arena, so transitions stay allocation-free too.
    spare: HashMap<String, Vec<f32>>,
    /// Reusable shard-task scratch for the parallel path.
    tasks: Vec<ShardTask>,
    /// Reusable transition-task scratch for the one-wave direct path.
    ttasks: Vec<TransitionTask>,
    /// Direct one-pass transitions performed (subset of `switches`).
    pub transitions: u64,
    /// Store-built shard-plan sets ignored because they did not match the
    /// adapter (wrong tensor count or per-tensor nnz — typically a
    /// mis-sized pool width at decode time).  Dispatch silently fell back
    /// to freshly computed plans; this counter makes that visible.
    pub plan_mismatches: u64,
    /// Deterministic fault injector (chaos tests, DESIGN.md §13.2):
    /// when armed, one planned mutation wave panics mid-dispatch.
    fault: Option<Arc<FaultInjector>>,
}

impl Default for SwitchEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl SwitchEngine {
    /// Engine without a thread pool (all scatters serial).
    pub fn new() -> Self {
        Self::with_pool(None)
    }

    /// Engine with an attached thread pool: scatter/restore and the LoRA
    /// fuse baseline run shard-parallel across all target tensors.
    pub fn with_pool(pool: Option<Arc<ThreadPool>>) -> Self {
        SwitchEngine {
            active: Active::None,
            switches: 0,
            pool,
            dispatch: kernel::active_dispatch(),
            arena: HashMap::new(),
            spare: HashMap::new(),
            tasks: Vec::new(),
            ttasks: Vec::new(),
            transitions: 0,
            plan_mismatches: 0,
            fault: None,
        }
    }

    /// Arm a deterministic fault injector: planned
    /// [`FaultSite::Wave`] ordinals make the matching mutation wave
    /// panic mid-dispatch (after partial writes), exercising the
    /// router's transactional rollback.
    pub fn set_fault(&mut self, fault: Arc<FaultInjector>) {
        self.fault = Some(fault);
    }

    /// Claim the next wave ordinal; true when this wave must panic.
    fn wave_fault_armed(&self) -> bool {
        match &self.fault {
            Some(f) => f.should_fire(FaultSite::Wave),
            None => false,
        }
    }

    /// Attach (or detach) the thread pool used for parallel dispatch.
    pub fn set_pool(&mut self, pool: Option<Arc<ThreadPool>>) {
        self.pool = pool;
    }

    /// The attached thread pool, if any.
    pub fn pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    /// Override the kernel dispatch mode used by this engine's sharded
    /// waves (the scalar/SIMD bit-identity harness hook; production
    /// engines inherit the process-wide mode at construction).
    pub fn set_dispatch(&mut self, d: KernelDispatch) {
        self.dispatch = d;
    }

    /// The engine's kernel dispatch mode.
    pub fn dispatch(&self) -> KernelDispatch {
        self.dispatch
    }

    /// Name of the adapter currently applied to the weights.
    pub fn active_name(&self) -> Option<&str> {
        match &self.active {
            Active::None => None,
            Active::Shira { adapter, .. } => Some(adapter.name.as_str()),
            Active::ShiraF16 { adapter, .. } => Some(adapter.name.as_str()),
            Active::Lora { adapter } => Some(adapter.name.as_str()),
        }
    }

    /// Pure-data rollback snapshot of the active SHiRA adapter: per
    /// target tensor, the support indices and the arena's base values
    /// for them.  `None` unless a SHiRA adapter is active.  Reads only
    /// engine state untouched by a mid-wave panic (the arena is only
    /// overwritten by *apply* waves, which the router pre-captures
    /// around), so the router can use this to restore base after a
    /// failed transition or revert wave.
    pub fn shira_rollback(&self) -> Option<Vec<(String, Vec<u32>, Vec<f32>)>> {
        // Rollback data is residency-agnostic: support indices plus the
        // arena's f32 base snapshot — so f16-resident singles are covered
        // by the exact same transaction machinery.
        let supports: Vec<(&String, &Vec<u32>)> = match &self.active {
            Active::Shira { adapter, .. } => adapter
                .tensors
                .iter()
                .map(|(target, delta)| (target, &delta.idx))
                .collect(),
            Active::ShiraF16 { adapter, .. } => adapter
                .tensors
                .iter()
                .map(|(target, delta)| (target, &delta.idx))
                .collect(),
            _ => return None,
        };
        Some(
            supports
                .into_iter()
                .map(|(target, idx)| {
                    let snap = self
                        .arena
                        .get(target.as_str())
                        .expect("snapshot exists for active adapter");
                    (target.clone(), idx.clone(), snap.clone())
                })
                .collect(),
        )
    }

    /// The active LoRA adapter, if one is fused in (`None` otherwise).
    /// The router's rollback replays a dense unfuse from it.
    pub fn lora_rollback(&self) -> Option<Arc<LoraAdapter>> {
        match &self.active {
            Active::Lora { adapter } => Some(Arc::clone(adapter)),
            _ => None,
        }
    }

    /// Forget the active adapter WITHOUT touching the weights — the
    /// rollback path's final step after the router has restored the
    /// resident store itself.  Never call this outside failure
    /// recovery: it desynchronizes the engine from the weights.
    pub fn clear_active(&mut self) {
        self.active = Active::None;
    }

    /// Ensure the arena buffer for `target` exists and has length `len`,
    /// returning it (allocates only on first growth; steady state reuses
    /// capacity).  No clear(): stale contents are fine — the fused
    /// snapshot+apply pass overwrites every slot, so only genuinely new
    /// capacity is zero-filled by `resize`.
    fn arena_buf_prepare<'a>(
        arena: &'a mut HashMap<String, Vec<f32>>,
        target: &str,
        len: usize,
    ) -> &'a mut Vec<f32> {
        if !arena.contains_key(target) {
            arena.insert(target.to_string(), Vec::new());
        }
        let Some(buf) = arena.get_mut(target) else {
            unreachable!("inserted above");
        };
        buf.resize(len, 0.0);
        buf
    }

    /// Apply a SHiRA adapter to `w` at strength `alpha` (reverting
    /// whatever was active first).  Returns stage timings.
    ///
    /// Convenience wrapper that deep-clones the adapter into an `Arc`
    /// (outside the timed fuse stage).  Hot paths — the server request
    /// loop, switch benchmarks — should hold adapters in `Arc`s and use
    /// [`Self::switch_to_shira_shared`], which copies nothing.
    pub fn switch_to_shira(
        &mut self,
        w: &mut WeightStore,
        a: &ShiraAdapter,
        alpha: f32,
    ) -> SwitchTiming {
        self.switch_to_shira_shared(w, Arc::new(a.clone()), alpha)
    }

    /// Zero-copy variant: the engine keeps the `Arc` (no tensor clone), so
    /// activating a cache-resident adapter performs no O(nnz) allocation
    /// in steady state — only first-visit arena growth, plus one
    /// O(threads) dispatch control block per parallel region.
    pub fn switch_to_shira_shared(
        &mut self,
        w: &mut WeightStore,
        a: Arc<ShiraAdapter>,
        alpha: f32,
    ) -> SwitchTiming {
        self.switch_to_shira_planned(w, a, None, alpha)
    }

    /// [`Self::switch_to_shira_shared`] with store-built per-tensor
    /// [`TensorPlan`]s (shard-aligned decode, DESIGN.md §10/§15): the
    /// parallel dispatch reuses `plans` — both the row-aligned shard
    /// partition and the precomputed run cuts the SIMD kernels sweep — so
    /// the first switch through a store-decoded adapter skips plan
    /// construction AND run detection.  Plans are positional with
    /// `a.tensors`; a plan set that does not match (wrong length or
    /// totals) is ignored and the engine falls back to computing its own
    /// shards (runs detected on the fly) — the result is bit-identical
    /// either way, plans only affect dispatch.
    pub fn switch_to_shira_planned(
        &mut self,
        w: &mut WeightStore,
        a: Arc<ShiraAdapter>,
        plans: Option<Arc<Vec<TensorPlan>>>,
        alpha: f32,
    ) -> SwitchTiming {
        let mut t = self.revert_timing(w);
        let t0 = Instant::now();
        // Claim this apply wave's fault ordinal (chaos injection): when it
        // fires, the wave panics after partial writes to W and the arena.
        let boom = self.wave_fault_armed();
        let par = kernel::config().parallel_worthwhile(a.param_count());
        let pool = match &self.pool {
            Some(p) if par && p.threads() > 1 => Some(Arc::clone(p)),
            _ => None,
        };
        match pool {
            Some(pool) => {
                self.build_shira_tasks(w, &a, plans.as_deref(), pool.threads(), true);
                let dispatch = self.dispatch;
                let tasks = &self.tasks;
                let n = tasks.len();
                if let Err(fault) = pool.try_scoped_for(n, |i| {
                    if boom && i == n / 2 {
                        panic!("{}", FaultInjector::WAVE_PANIC_MSG);
                    }
                    // SAFETY: tasks cover disjoint idx ranges (row-aligned
                    // shard plans over unique sorted indices, one plan per
                    // distinct target tensor with its own arena buffer).
                    unsafe { tasks[i].snapshot_apply(dispatch, alpha) }
                }) {
                    // The pool has fully quiesced: no worker still holds a
                    // cursor into W, so the router's rollback may run.
                    panic!("pool wave failed: {fault}");
                }
                self.tasks.clear();
            }
            None => {
                for (ti, (target, delta)) in a.tensors.iter().enumerate() {
                    let buf = Self::arena_buf_prepare(&mut self.arena, target, delta.nnz());
                    let wt = w.get_mut(target);
                    delta.snapshot_apply(wt, alpha, buf);
                    if boom && ti == 0 {
                        panic!("{}", FaultInjector::WAVE_PANIC_MSG);
                    }
                }
            }
        }
        t.fuse_us += t0.elapsed().as_secs_f64() * 1e6;
        self.active = Active::Shira { adapter: a, plans };
        self.switches += 1;
        t
    }

    /// Apply an f16-resident SHiRA adapter (reverting whatever was active
    /// first).  Delta bits stay binary16 end-to-end: the wave dequantizes
    /// lane-wise inside the kernel, so no f32 materialization of the
    /// delta ever exists.  Because the widening is exact, serving this is
    /// bit-identical to [`Self::switch_to_shira_planned`] on the f32
    /// decode of the same `v2-f16` file (property-tested).
    ///
    /// f16 singles always take this revert+apply path — direct
    /// transitions ([`Self::transition_to`]) remain f32-only.
    pub fn switch_to_shira_f16(
        &mut self,
        w: &mut WeightStore,
        a: Arc<ShiraF16Adapter>,
        plans: Option<Arc<Vec<TensorPlan>>>,
        alpha: f32,
    ) -> SwitchTiming {
        let mut t = self.revert_timing(w);
        let t0 = Instant::now();
        let boom = self.wave_fault_armed();
        let par = kernel::config().parallel_worthwhile(a.param_count());
        let pool = match &self.pool {
            Some(p) if par && p.threads() > 1 => Some(Arc::clone(p)),
            _ => None,
        };
        match pool {
            Some(pool) => {
                self.build_shira_tasks_f16(w, &a, plans.as_deref(), pool.threads(), true);
                let dispatch = self.dispatch;
                let tasks = &self.tasks;
                let n = tasks.len();
                if let Err(fault) = pool.try_scoped_for(n, |i| {
                    if boom && i == n / 2 {
                        panic!("{}", FaultInjector::WAVE_PANIC_MSG);
                    }
                    // SAFETY: same disjointness contract as the f32 path.
                    unsafe { tasks[i].snapshot_apply(dispatch, alpha) }
                }) {
                    panic!("pool wave failed: {fault}");
                }
                self.tasks.clear();
            }
            None => {
                for (ti, (target, delta)) in a.tensors.iter().enumerate() {
                    let buf = Self::arena_buf_prepare(&mut self.arena, target, delta.nnz());
                    let wt = w.get_mut(target);
                    delta.snapshot_apply(wt, alpha, buf);
                    if boom && ti == 0 {
                        panic!("{}", FaultInjector::WAVE_PANIC_MSG);
                    }
                }
            }
        }
        t.fuse_us += t0.elapsed().as_secs_f64() * 1e6;
        self.active = Active::ShiraF16 { adapter: a, plans };
        self.switches += 1;
        t
    }

    /// Direct adapter-to-adapter switch: one pass over the A∪B support
    /// union instead of revert+apply's two, dispatched as ONE pool wave.
    ///
    /// `tp` is a precomputed [`AdapterTransition`] for (currently-active →
    /// `b`); `plans` carries `b`'s store-built shard plans for the later
    /// revert, exactly as in [`Self::switch_to_shira_planned`].  Per union
    /// slot the kernel restores A's snapshot (A-only), snapshots the base
    /// and applies B (B-only), or forwards A's snapshot value as B's base
    /// while applying B (overlap) — leaving the weights AND the snapshot
    /// arena bit-identical to a `revert` followed by a fresh
    /// snapshot+apply of `b` (property-tested).
    ///
    /// When `tp` does not describe the (active, `b`) pair — no SHiRA
    /// adapter active, or a name/shape/nnz mismatch — the engine falls
    /// back to revert+apply and reports [`SwitchPath::Fallback`]; the
    /// resulting bytes are identical either way.
    pub fn transition_to(
        &mut self,
        w: &mut WeightStore,
        b: Arc<ShiraAdapter>,
        plans: Option<Arc<Vec<TensorPlan>>>,
        tp: &AdapterTransition,
        alpha: f32,
    ) -> (SwitchTiming, SwitchPath) {
        let valid = match &self.active {
            Active::Shira { adapter, .. } => tp.matches(adapter, &b),
            _ => false,
        };
        if !valid {
            let t = self.switch_to_shira_planned(w, b, plans, alpha);
            return (t, SwitchPath::Fallback);
        }
        let mut t = SwitchTiming::default();
        let t0 = Instant::now();
        // Claim this transition wave's fault ordinal (chaos injection).  A
        // mid-wave panic here leaves the OUTGOING adapter still active
        // (the swap below never ran), with W partially transitioned.
        let boom = self.wave_fault_armed();
        let par = kernel::config().parallel_worthwhile(tp.union_nnz());
        let pool = match &self.pool {
            Some(p) if par && p.threads() > 1 => Some(Arc::clone(p)),
            _ => None,
        };
        match pool {
            Some(pool) => {
                self.build_transition_tasks(w, &b, tp);
                let dispatch = self.dispatch;
                let tasks = &self.ttasks;
                let n = tasks.len();
                if let Err(fault) = pool.try_scoped_for(n, |i| {
                    if boom && i == n / 2 {
                        panic!("{}", FaultInjector::WAVE_PANIC_MSG);
                    }
                    // SAFETY: tasks cover disjoint union ranges (row-
                    // aligned shards over unique sorted union indices, one
                    // plan per distinct target tensor), so every W element
                    // and every incoming-snapshot slot is written by
                    // exactly one task; outgoing snapshots are read-only.
                    unsafe { tasks[i].run(dispatch, alpha) }
                }) {
                    panic!("pool wave failed: {fault}");
                }
                self.ttasks.clear();
            }
            None => {
                for (ti, (target, d_b)) in b.tensors.iter().enumerate() {
                    let snap_b = Self::arena_buf_prepare(&mut self.spare, target, d_b.nnz());
                    let snap_a = self
                        .arena
                        .get(target.as_str())
                        .expect("snapshot exists for active adapter");
                    let wt = w.get_mut(target);
                    tp.plans()[ti].transition(wt, snap_a, snap_b, d_b, alpha);
                    if boom && ti == 0 {
                        panic!("{}", FaultInjector::WAVE_PANIC_MSG);
                    }
                }
            }
        }
        // The incoming adapter's base snapshot landed in the spare
        // buffers; swap them live.  The outgoing buffers become the next
        // transition's spares — capacity retained, so steady-state
        // transitions allocate nothing.
        for (target, _) in &b.tensors {
            let live = self
                .arena
                .get_mut(target.as_str())
                .expect("snapshot exists for active adapter");
            let fresh = self
                .spare
                .get_mut(target.as_str())
                .expect("spare buffer prepared above");
            std::mem::swap(live, fresh);
        }
        t.fuse_us = t0.elapsed().as_secs_f64() * 1e6;
        self.active = Active::Shira { adapter: b, plans };
        self.switches += 1;
        self.transitions += 1;
        (t, SwitchPath::Transition)
    }

    /// Build the flat transition-task list spanning every target tensor:
    /// each task is one row-aligned shard of one tensor's union walk, so
    /// the whole A→B switch runs under a single `scoped_for` region.
    fn build_transition_tasks(
        &mut self,
        w: &mut WeightStore,
        b: &ShiraAdapter,
        tp: &AdapterTransition,
    ) {
        self.ttasks.clear();
        for (ti, (target, d_b)) in b.tensors.iter().enumerate() {
            let snap_b = Self::arena_buf_prepare(&mut self.spare, target, d_b.nnz());
            let snap_a = self
                .arena
                .get(target.as_str())
                .expect("snapshot exists for active adapter");
            let wt = w.get_mut(target);
            let plan = &tp.plans()[ti];
            debug_assert_eq!((wt.rows, wt.cols), (plan.rows(), plan.cols()));
            debug_assert_eq!(snap_a.len(), plan.a_nnz());
            debug_assert_eq!(snap_b.len(), plan.b_nnz());
            let (idx, a_pos, b_pos) = plan.raw_parts();
            let sp = plan.shards();
            let runs = plan.runs();
            for s in 0..sp.len() {
                let (lo, hi) = sp.range(s);
                if lo == hi {
                    continue;
                }
                // Precomputed union run cuts for this shard: the SIMD
                // execution sweeps them without a detection pass.
                let (ptr, len) = runs.span(lo, hi);
                self.ttasks.push(TransitionTask {
                    idx,
                    a_pos,
                    b_pos,
                    delta: d_b.delta.as_ptr(),
                    w: wt.data.as_mut_ptr(),
                    snap_a: snap_a.as_ptr(),
                    snap_b: snap_b.as_mut_ptr(),
                    lo,
                    hi,
                    runs: Runs::Cuts { ptr, len },
                });
            }
        }
    }

    /// Append one tensor's shard tasks.  A prebuilt [`TensorPlan`]
    /// contributes its shard ranges AND its run cuts ([`Runs::Cuts`] — no
    /// detection pass inside the wave); the fallback computes a fresh
    /// row-aligned shard split and lets the kernel detect runs on the fly
    /// (a freshly built `RunPlan` would be a temporary the tasks cannot
    /// borrow).
    #[allow(clippy::too_many_arguments)]
    fn push_tensor_tasks(
        tasks: &mut Vec<ShardTask>,
        plan: Option<&TensorPlan>,
        idx: &[u32],
        delta: TaskDelta,
        cols: usize,
        w: *mut f32,
        snap: *mut f32,
        threads: usize,
    ) {
        match plan {
            Some(p) => {
                for s in 0..p.shards.len() {
                    let (lo, hi) = p.shards.range(s);
                    if lo == hi {
                        continue;
                    }
                    let (ptr, len) = p.runs.span(lo, hi);
                    tasks.push(ShardTask {
                        w,
                        snap,
                        idx: idx.as_ptr(),
                        delta,
                        lo,
                        hi,
                        runs: Runs::Cuts { ptr, len },
                    });
                }
            }
            None => {
                let sp = shard_sorted(idx, cols, shards_for(idx.len(), threads));
                for s in 0..sp.len() {
                    let (lo, hi) = sp.range(s);
                    if lo == hi {
                        continue;
                    }
                    tasks.push(ShardTask {
                        w,
                        snap,
                        idx: idx.as_ptr(),
                        delta,
                        lo,
                        hi,
                        runs: Runs::Detect,
                    });
                }
            }
        }
    }

    /// Build the flat shard-task list spanning every target tensor.
    /// `fresh` resizes arena buffers for a new snapshot; revert reuses the
    /// buffers exactly as the preceding apply left them.  `plans` carries
    /// store-built per-tensor [`TensorPlan`]s; any mismatch falls back to
    /// a freshly computed row-aligned shard split.
    fn build_shira_tasks(
        &mut self,
        w: &mut WeightStore,
        a: &ShiraAdapter,
        plans: Option<&Vec<TensorPlan>>,
        threads: usize,
        fresh: bool,
    ) {
        self.tasks.clear();
        let prebuilt = plans.filter(|p| p.len() == a.tensors.len());
        let mut mismatches = u64::from(plans.is_some() && prebuilt.is_none());
        for (ti, (target, delta)) in a.tensors.iter().enumerate() {
            let buf = if fresh {
                Self::arena_buf_prepare(&mut self.arena, target, delta.nnz())
            } else {
                let Some(buf) = self.arena.get_mut(target.as_str()) else {
                    unreachable!("arena buffer exists for active target");
                };
                buf
            };
            debug_assert_eq!(buf.len(), delta.nnz());
            let wt = w.get_mut(target);
            debug_assert_eq!((wt.rows, wt.cols), (delta.rows, delta.cols));
            let plan = match prebuilt {
                Some(p) if p[ti].total() == delta.nnz() => Some(&p[ti]),
                Some(_) => {
                    mismatches += 1;
                    None
                }
                None => None,
            };
            Self::push_tensor_tasks(
                &mut self.tasks,
                plan,
                &delta.idx,
                TaskDelta::F32(delta.delta.as_ptr()),
                delta.cols,
                wt.data.as_mut_ptr(),
                buf.as_mut_ptr(),
                threads,
            );
        }
        if mismatches > 0 {
            self.record_plan_mismatch(mismatches);
        }
    }

    /// f16-resident twin of [`Self::build_shira_tasks`]: identical shard
    /// and run layout (plans are built from the idx array alone), with
    /// tasks carrying [`TaskDelta::F16`] so the kernel dequantizes
    /// lane-wise on apply.
    fn build_shira_tasks_f16(
        &mut self,
        w: &mut WeightStore,
        a: &ShiraF16Adapter,
        plans: Option<&Vec<TensorPlan>>,
        threads: usize,
        fresh: bool,
    ) {
        self.tasks.clear();
        let prebuilt = plans.filter(|p| p.len() == a.tensors.len());
        let mut mismatches = u64::from(plans.is_some() && prebuilt.is_none());
        for (ti, (target, delta)) in a.tensors.iter().enumerate() {
            let buf = if fresh {
                Self::arena_buf_prepare(&mut self.arena, target, delta.nnz())
            } else {
                let Some(buf) = self.arena.get_mut(target.as_str()) else {
                    unreachable!("arena buffer exists for active target");
                };
                buf
            };
            debug_assert_eq!(buf.len(), delta.nnz());
            let wt = w.get_mut(target);
            debug_assert_eq!((wt.rows, wt.cols), (delta.rows, delta.cols));
            let plan = match prebuilt {
                Some(p) if p[ti].total() == delta.nnz() => Some(&p[ti]),
                Some(_) => {
                    mismatches += 1;
                    None
                }
                None => None,
            };
            Self::push_tensor_tasks(
                &mut self.tasks,
                plan,
                &delta.idx,
                TaskDelta::F16(delta.bits.as_ptr()),
                delta.cols,
                wt.data.as_mut_ptr(),
                buf.as_mut_ptr(),
                threads,
            );
        }
        if mismatches > 0 {
            self.record_plan_mismatch(mismatches);
        }
    }

    /// Count ignored store-built plans (and warn once, so a mis-sized
    /// pool width is not invisible — bytes are unaffected either way).
    fn record_plan_mismatch(&mut self, n: u64) {
        if self.plan_mismatches == 0 {
            crate::log_warn!(
                "store-built shard plans did not match the adapter \
                 (pool-width/nnz mismatch); recomputing row-aligned plans"
            );
        }
        self.plan_mismatches += n;
    }

    /// Fuse a LoRA adapter into `w` (HF pipeline's fuse stage).
    /// Convenience wrapper that deep-clones; prefer
    /// [`Self::switch_to_lora_shared`] on hot paths.
    pub fn switch_to_lora(&mut self, w: &mut WeightStore, a: &LoraAdapter) -> SwitchTiming {
        self.switch_to_lora_shared(w, Arc::new(a.clone()))
    }

    /// Zero-copy LoRA fuse: the engine keeps the `Arc` (no tensor clone).
    pub fn switch_to_lora_shared(
        &mut self,
        w: &mut WeightStore,
        a: Arc<LoraAdapter>,
    ) -> SwitchTiming {
        let mut t = self.revert_timing(w);
        let t0 = Instant::now();
        let pool = self.pool.clone();
        let cfg = kernel::config();
        for lt in &a.tensors {
            let wt = w.get_mut(&lt.target);
            match &pool {
                Some(p) if cfg.parallel_worthwhile(wt.numel()) && p.threads() > 1 => {
                    wt.add_outer_product_par(&lt.a, &lt.b, a.scale, p);
                }
                _ => wt.add_outer_product(&lt.a, &lt.b, a.scale),
            }
        }
        t.fuse_us += t0.elapsed().as_secs_f64() * 1e6;
        self.active = Active::Lora { adapter: a };
        self.switches += 1;
        t
    }

    /// Revert `w` to base values for whatever is applied; returns the
    /// time spent (unfuse stage).
    pub fn revert(&mut self, w: &mut WeightStore) -> SwitchTiming {
        self.revert_timing(w)
    }

    /// Dispatch the prepared restore wave over the task list, then clear
    /// it.  Shared by the f32- and f16-resident revert paths (restore
    /// only reads indices and the snapshot — residency never matters).
    fn run_restore_wave(&mut self, pool: &ThreadPool, boom: bool) {
        let dispatch = self.dispatch;
        let tasks = &self.tasks;
        let n = tasks.len();
        if let Err(fault) = pool.try_scoped_for(n, |i| {
            if boom && i == n / 2 {
                panic!("{}", FaultInjector::WAVE_PANIC_MSG);
            }
            // SAFETY: same disjointness contract as apply.
            unsafe { tasks[i].restore(dispatch) }
        }) {
            panic!("pool wave failed: {fault}");
        }
        self.tasks.clear();
    }

    fn revert_timing(&mut self, w: &mut WeightStore) -> SwitchTiming {
        let mut t = SwitchTiming::default();
        let t0 = Instant::now();
        match std::mem::replace(&mut self.active, Active::None) {
            Active::None => {}
            Active::Shira { adapter, plans } => {
                // Claim this revert wave's fault ordinal (chaos
                // injection).  A mid-wave panic leaves W partially
                // restored with `active` already taken (None) — the
                // router's pre-captured transaction restores base.
                let boom = self.wave_fault_armed();
                let par = kernel::config().parallel_worthwhile(adapter.param_count());
                let pool = match &self.pool {
                    Some(p) if par && p.threads() > 1 => Some(Arc::clone(p)),
                    _ => None,
                };
                match pool {
                    Some(pool) => {
                        let threads = pool.threads();
                        self.build_shira_tasks(w, &adapter, plans.as_deref(), threads, false);
                        self.run_restore_wave(&pool, boom);
                    }
                    None => {
                        for (ti, (target, delta)) in adapter.tensors.iter().enumerate() {
                            let snap = self
                                .arena
                                .get(target.as_str())
                                .expect("snapshot exists for active adapter");
                            delta.restore(w.get_mut(target), snap);
                            if boom && ti == 0 {
                                panic!("{}", FaultInjector::WAVE_PANIC_MSG);
                            }
                        }
                    }
                }
            }
            Active::ShiraF16 { adapter, plans } => {
                let boom = self.wave_fault_armed();
                let par = kernel::config().parallel_worthwhile(adapter.param_count());
                let pool = match &self.pool {
                    Some(p) if par && p.threads() > 1 => Some(Arc::clone(p)),
                    _ => None,
                };
                match pool {
                    Some(pool) => {
                        let threads = pool.threads();
                        self.build_shira_tasks_f16(w, &adapter, plans.as_deref(), threads, false);
                        self.run_restore_wave(&pool, boom);
                    }
                    None => {
                        for (ti, (target, delta)) in adapter.tensors.iter().enumerate() {
                            let Some(snap) = self.arena.get(target.as_str()) else {
                                unreachable!("snapshot exists for active adapter");
                            };
                            delta.restore(w.get_mut(target), snap);
                            if boom && ti == 0 {
                                panic!("{}", FaultInjector::WAVE_PANIC_MSG);
                            }
                        }
                    }
                }
            }
            Active::Lora { adapter } => {
                let pool = self.pool.clone();
                let cfg = kernel::config();
                for lt in &adapter.tensors {
                    let wt = w.get_mut(&lt.target);
                    match &pool {
                        Some(p) if cfg.parallel_worthwhile(wt.numel()) && p.threads() > 1 => {
                            wt.sub_outer_product_par(&lt.a, &lt.b, adapter.scale, p);
                        }
                        _ => wt.sub_outer_product(&lt.a, &lt.b, adapter.scale),
                    }
                }
            }
        }
        t.unfuse_us = t0.elapsed().as_secs_f64() * 1e6;
        t
    }

    /// Full HF-style pipeline for one adapter visit, with per-stage timers
    /// (paper Table 5): load (deserialize) → fuse → [caller infers] is
    /// simulated by apply/revert around a no-op → unfuse → unload (drop).
    pub fn hf_pipeline_shira(
        &mut self,
        w: &mut WeightStore,
        bytes: &[u8],
        alpha: f32,
    ) -> SwitchTiming {
        let t0 = Instant::now();
        let adapter = crate::adapter::io::decode_shira(bytes).expect("valid adapter");
        let load_us = t0.elapsed().as_secs_f64() * 1e6;
        let mut t = self.switch_to_shira_shared(w, Arc::new(adapter), alpha);
        t.load_us = load_us;
        let mut t2 = self.revert(w);
        let t1 = Instant::now();
        t2.unload_us = t1.elapsed().as_secs_f64() * 1e6;
        t.unfuse_us = t2.unfuse_us;
        t.unload_us = t2.unload_us;
        t
    }

    /// LoRA version of [`Self::hf_pipeline_shira`]: load → dense fuse →
    /// unfuse → unload, with per-stage timers.
    pub fn hf_pipeline_lora(&mut self, w: &mut WeightStore, bytes: &[u8]) -> SwitchTiming {
        let t0 = Instant::now();
        let adapter = crate::adapter::io::decode_lora(bytes).expect("valid adapter");
        let load_us = t0.elapsed().as_secs_f64() * 1e6;
        let mut t = self.switch_to_lora_shared(w, Arc::new(adapter));
        t.load_us = load_us;
        let mut t2 = self.revert(w);
        let t1 = Instant::now();
        t2.unload_us = t1.elapsed().as_secs_f64() * 1e6;
        t.unfuse_us = t2.unfuse_us;
        t.unload_us = t2.unload_us;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::sparse::SparseDelta;
    use crate::adapter::{io, LoraTensor};
    use crate::model::tensor::Tensor2;
    use crate::util::rng::Rng;

    fn weights() -> WeightStore {
        WeightStore::init(
            &[
                ("l0.wq".into(), vec![32, 32]),
                ("l0.wk".into(), vec![32, 32]),
            ],
            1,
        )
    }

    fn shira(rng: &mut Rng, name: &str) -> ShiraAdapter {
        let mk = |rng: &mut Rng| {
            let idx = rng.sample_indices(1024, 20);
            let mut d = vec![0.0; 20];
            rng.fill_normal(&mut d, 0.0, 0.5);
            SparseDelta::new(32, 32, idx, d)
        };
        ShiraAdapter {
            name: name.into(),
            strategy: "rand".into(),
            tensors: vec![("l0.wq".into(), mk(rng)), ("l0.wk".into(), mk(rng))],
        }
    }

    fn lora(rng: &mut Rng, name: &str) -> LoraAdapter {
        let mut a = Tensor2::zeros(32, 4);
        let mut b = Tensor2::zeros(4, 32);
        rng.fill_normal(&mut a.data, 0.0, 0.1);
        rng.fill_normal(&mut b.data, 0.0, 0.1);
        LoraAdapter {
            name: name.into(),
            scale: 2.0,
            tensors: vec![LoraTensor {
                target: "l0.wq".into(),
                a,
                b,
            }],
        }
    }

    /// A weight store + adapter big enough to cross the parallel threshold.
    fn big_weights_and_adapter(seed: u64) -> (WeightStore, ShiraAdapter) {
        let dim = 128usize;
        let k = 6000usize; // 2 tensors * 6000 nnz > the parallel cutoff
        let store = WeightStore::init(
            &[
                ("big.wq".into(), vec![dim, dim]),
                ("big.wk".into(), vec![dim, dim]),
            ],
            seed,
        );
        let mut rng = Rng::new(seed ^ 0xABCD);
        let mk = |rng: &mut Rng| {
            let idx = rng.sample_indices(dim * dim, k);
            let mut d = vec![0.0; k];
            rng.fill_normal(&mut d, 0.0, 0.5);
            SparseDelta::new(dim, dim, idx, d)
        };
        let a = ShiraAdapter {
            name: "big".into(),
            strategy: "rand".into(),
            tensors: vec![("big.wq".into(), mk(&mut rng)), ("big.wk".into(), mk(&mut rng))],
        };
        (store, a)
    }

    #[test]
    fn shira_switch_and_revert_is_bit_exact() {
        let mut rng = Rng::new(1);
        let base = weights();
        let mut w = base.clone();
        let mut eng = SwitchEngine::new();
        let a = shira(&mut rng, "a");
        eng.switch_to_shira(&mut w, &a, 1.0);
        assert_eq!(eng.active_name(), Some("a"));
        assert!(w.max_abs_diff(&base) > 0.0);
        eng.revert(&mut w);
        assert!(w.bit_equal(&base)); // the SHiRA exactness claim
        assert_eq!(eng.active_name(), None);
    }

    #[test]
    fn parallel_engine_bit_identical_to_serial_for_any_thread_count() {
        let (base, a) = big_weights_and_adapter(11);
        // Serial reference.
        let mut ws = base.clone();
        let mut serial = SwitchEngine::new();
        serial.switch_to_shira(&mut ws, &a, 0.9);
        let applied = ws.clone();
        serial.revert(&mut ws);
        assert!(ws.bit_equal(&base));
        for threads in [1usize, 2, 4] {
            let pool = Arc::new(ThreadPool::new(threads));
            let mut w = base.clone();
            let mut eng = SwitchEngine::with_pool(Some(pool));
            eng.switch_to_shira(&mut w, &a, 0.9);
            assert!(
                w.bit_equal(&applied),
                "apply differs at threads={threads}"
            );
            eng.revert(&mut w);
            assert!(
                w.bit_equal(&base),
                "revert differs at threads={threads}"
            );
        }
    }

    #[test]
    fn arena_is_reused_across_switches() {
        let (base, a) = big_weights_and_adapter(12);
        let (_, b) = big_weights_and_adapter(13);
        let b = ShiraAdapter { name: "b".into(), ..b };
        let pool = Arc::new(ThreadPool::new(4));
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(pool));
        // Many switches through the same targets: snapshots stay correct.
        for round in 0..6 {
            let (adapter, alpha) = if round % 2 == 0 { (&a, 1.0) } else { (&b, 0.7) };
            eng.switch_to_shira(&mut w, adapter, alpha);
            assert_eq!(eng.active_name(), Some(adapter.name.as_str()));
        }
        eng.revert(&mut w);
        assert!(w.bit_equal(&base));
        assert_eq!(eng.switches, 6);
    }

    #[test]
    fn planned_switch_bit_identical_to_unplanned() {
        // Store-built tensor plans (shard-aligned decode + run cuts) only
        // change dispatch, never bytes — including revert, which reuses
        // them.
        let (base, a) = big_weights_and_adapter(14);
        let a = Arc::new(a);
        let plans: Arc<Vec<TensorPlan>> = Arc::new(
            a.tensors
                .iter()
                .map(|(_, d)| TensorPlan::build(d, shards_for(d.nnz(), 4)))
                .collect(),
        );
        let mut wr = base.clone();
        let mut reference = SwitchEngine::new();
        reference.switch_to_shira_shared(&mut wr, Arc::clone(&a), 0.8);
        let applied = wr.clone();
        for threads in [2usize, 4] {
            let pool = Arc::new(ThreadPool::new(threads));
            let mut w = base.clone();
            let mut eng = SwitchEngine::with_pool(Some(pool));
            eng.switch_to_shira_planned(&mut w, Arc::clone(&a), Some(Arc::clone(&plans)), 0.8);
            assert!(w.bit_equal(&applied), "threads={threads}");
            eng.revert(&mut w);
            assert!(w.bit_equal(&base), "revert threads={threads}");
        }
        // A mismatched plan set is ignored, not trusted.
        let bogus: Arc<Vec<TensorPlan>> = Arc::new(Vec::new());
        let pool = Arc::new(ThreadPool::new(2));
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(pool));
        eng.switch_to_shira_planned(&mut w, Arc::clone(&a), Some(bogus), 0.8);
        assert!(w.bit_equal(&applied));
        eng.revert(&mut w);
        assert!(w.bit_equal(&base));
    }

    /// Adapter with the same targets as [`big_weights_and_adapter`]'s but
    /// a support overlapping `base_of`'s by roughly `overlap` fraction.
    fn overlapping_adapter(
        base_of: &ShiraAdapter,
        name: &str,
        overlap: f64,
        seed: u64,
    ) -> ShiraAdapter {
        let mut rng = Rng::new(seed);
        let tensors = base_of
            .tensors
            .iter()
            .map(|(target, d)| {
                let k = d.nnz();
                let shared = (k as f64 * overlap) as usize;
                let mut seen: std::collections::HashSet<u32> =
                    d.idx[..shared].iter().copied().collect();
                while seen.len() < k {
                    seen.insert(rng.below(d.numel()) as u32);
                }
                let mut idx: Vec<u32> = seen.into_iter().collect();
                idx.sort_unstable();
                let mut delta = vec![0.0; k];
                rng.fill_normal(&mut delta, 0.0, 0.5);
                (target.clone(), SparseDelta::new(d.rows, d.cols, idx, delta))
            })
            .collect();
        ShiraAdapter {
            name: name.into(),
            strategy: "rand".into(),
            tensors,
        }
    }

    #[test]
    fn transition_bit_identical_to_revert_apply_sequences() {
        // The PR-4 acceptance property at the engine level: arbitrary
        // switch sequences via `transition_to` — including alpha changes,
        // a self-transition, and disjoint / heavy-overlap supports —
        // produce bit-identical weights to revert+apply, at 1 and 4
        // threads, and leave the arena able to revert to base exactly.
        let (base, a) = big_weights_and_adapter(21);
        let b = overlapping_adapter(&a, "b", 0.0, 22); // disjoint-ish
        let c = overlapping_adapter(&a, "c", 0.95, 23); // heavy overlap
        let seq: Vec<(&ShiraAdapter, f32)> = vec![
            (&a, 1.0),
            (&b, 0.7),
            (&c, 1.3),
            (&a, 0.5),
            (&a, 1.1), // self-transition with an alpha change
            (&c, -0.4),
        ];
        for threads in [1usize, 4] {
            let pool = Arc::new(ThreadPool::new(threads));
            let mut wd = base.clone();
            let mut wr = base.clone();
            let mut direct = SwitchEngine::with_pool(Some(Arc::clone(&pool)));
            let mut reference = SwitchEngine::with_pool(Some(pool));
            for (step, &(adapter, alpha)) in seq.iter().enumerate() {
                let shared = Arc::new(adapter.clone());
                reference.switch_to_shira_shared(&mut wr, Arc::clone(&shared), alpha);
                if step == 0 {
                    direct.switch_to_shira_shared(&mut wd, Arc::clone(&shared), alpha);
                } else {
                    let prev = seq[step - 1].0;
                    let tp = AdapterTransition::build(prev, adapter, threads)
                        .expect("same target sets");
                    let (_t, path) = direct.transition_to(&mut wd, shared, None, &tp, alpha);
                    assert_eq!(path, SwitchPath::Transition, "step {step}");
                }
                assert!(
                    wd.bit_equal(&wr),
                    "step {step} threads={threads}"
                );
            }
            assert_eq!(direct.transitions, (seq.len() - 1) as u64);
            assert_eq!(direct.switches, seq.len() as u64);
            // The arena must hold the last adapter's true base snapshot.
            direct.revert(&mut wd);
            assert!(wd.bit_equal(&base), "threads={threads}");
        }
    }

    #[test]
    fn transition_falls_back_on_mismatched_plan() {
        let (base, a) = big_weights_and_adapter(24);
        let b = overlapping_adapter(&a, "b", 0.5, 25);
        let c = overlapping_adapter(&a, "c", 0.5, 26);
        let wrong = AdapterTransition::build(&c, &b, 2).unwrap(); // c→b, not a→b
        let pool = Arc::new(ThreadPool::new(2));
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(pool));
        eng.switch_to_shira(&mut w, &a, 1.0);
        let (_t, path) = eng.transition_to(&mut w, Arc::new(b.clone()), None, &wrong, 1.0);
        assert_eq!(path, SwitchPath::Fallback);
        assert_eq!(eng.transitions, 0);
        // Fallback still produced the correct state.
        let mut wr = base.clone();
        let mut reference = SwitchEngine::new();
        reference.switch_to_shira(&mut wr, &a, 1.0);
        reference.switch_to_shira(&mut wr, &b, 1.0);
        assert!(w.bit_equal(&wr));
        // No active adapter at all → fallback too.
        let mut wc = base.clone();
        let mut cold = SwitchEngine::new();
        let tp = AdapterTransition::build(&a, &b, 1).unwrap();
        let (_t, path) = cold.transition_to(&mut wc, Arc::new(b), None, &tp, 1.0);
        assert_eq!(path, SwitchPath::Fallback);
    }

    #[test]
    fn mismatched_store_plans_are_counted() {
        // Silently-ignored TensorPlan sets increment a visible counter
        // (bytes are unaffected either way).
        let (base, a) = big_weights_and_adapter(27);
        let a = Arc::new(a);
        let pool = Arc::new(ThreadPool::new(2));
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(pool));
        let bogus: Arc<Vec<TensorPlan>> = Arc::new(Vec::new());
        eng.switch_to_shira_planned(&mut w, Arc::clone(&a), Some(bogus), 1.0);
        assert!(eng.plan_mismatches >= 1, "wrong-length plan set counted");
        let before = eng.plan_mismatches;
        // A matching plan set adds nothing.
        let good: Arc<Vec<TensorPlan>> = Arc::new(
            a.tensors
                .iter()
                .map(|(_, d)| TensorPlan::build(d, shards_for(d.nnz(), 2)))
                .collect(),
        );
        eng.switch_to_shira_planned(&mut w, Arc::clone(&a), Some(good), 1.0);
        eng.revert(&mut w);
        assert!(w.bit_equal(&base));
        // the mismatched-plan revert already happened inside the second
        // switch; only the first (bogus) dispatch should have counted
        assert_eq!(eng.plan_mismatches, before + 1, "revert of bogus-planned switch");
    }

    #[test]
    fn forced_dispatch_engines_bit_identical_across_paths() {
        // The tentpole acceptance property at the engine level: scalar and
        // SIMD engines produce identical bytes on apply, direct
        // transitions and revert — with and without prebuilt TensorPlans,
        // at 1 and 4 threads, across scattered and fully-contiguous
        // supports (long runs are the SIMD sweet spot).
        let (base, a) = big_weights_and_adapter(31);
        let b = overlapping_adapter(&a, "b", 0.6, 32);
        // Fully-contiguous support: one solid block per tensor.
        let c = ShiraAdapter {
            name: "c".into(),
            strategy: "rand".into(),
            tensors: a
                .tensors
                .iter()
                .map(|(t, d)| {
                    let k = d.nnz();
                    let idx: Vec<u32> = (100..100 + k as u32).collect();
                    let mut delta = vec![0.0; k];
                    Rng::new(33).fill_normal(&mut delta, 0.0, 0.5);
                    (t.clone(), SparseDelta::new(d.rows, d.cols, idx, delta))
                })
                .collect(),
        };
        let plans: Arc<Vec<TensorPlan>> = Arc::new(
            a.tensors
                .iter()
                .map(|(_, d)| TensorPlan::build(d, shards_for(d.nnz(), 4)))
                .collect(),
        );
        // Serial reference (no pool).
        let mut wr = base.clone();
        let mut reference = SwitchEngine::new();
        reference.switch_to_shira(&mut wr, &a, 0.8);
        let applied_a = wr.clone();
        reference.switch_to_shira(&mut wr, &b, 1.2);
        let applied_b = wr.clone();
        reference.switch_to_shira(&mut wr, &c, -0.6);
        let applied_c = wr.clone();
        reference.revert(&mut wr);
        assert!(wr.bit_equal(&base));
        for threads in [1usize, 4] {
            for disp in [KernelDispatch::Scalar, KernelDispatch::Simd] {
                let pool = Arc::new(ThreadPool::new(threads));
                let mut w = base.clone();
                let mut eng = SwitchEngine::with_pool(Some(pool));
                eng.set_dispatch(disp);
                assert_eq!(eng.dispatch(), disp);
                eng.switch_to_shira_planned(
                    &mut w,
                    Arc::new(a.clone()),
                    Some(Arc::clone(&plans)),
                    0.8,
                );
                assert!(w.bit_equal(&applied_a), "{} t={threads} a", disp.name());
                let tab = AdapterTransition::build(&a, &b, threads).expect("same targets");
                let (_t, path) = eng.transition_to(&mut w, Arc::new(b.clone()), None, &tab, 1.2);
                assert_eq!(path, SwitchPath::Transition);
                assert!(w.bit_equal(&applied_b), "{} t={threads} b", disp.name());
                let tbc = AdapterTransition::build(&b, &c, threads).expect("same targets");
                let (_t, path) = eng.transition_to(&mut w, Arc::new(c.clone()), None, &tbc, -0.6);
                assert_eq!(path, SwitchPath::Transition);
                assert!(w.bit_equal(&applied_c), "{} t={threads} c", disp.name());
                eng.revert(&mut w);
                assert!(w.bit_equal(&base), "{} t={threads} revert", disp.name());
            }
        }
    }

    #[test]
    fn f16_resident_switch_bit_identical_to_f32_of_decoded_values() {
        use crate::adapter::sparse::SparseDeltaF16;
        // Narrow a random adapter to binary16 and serve the f16-resident
        // form; the reference is its EXACT f32 widening (what an f32
        // decode of the same v2-f16 file yields).  Bytes must match under
        // both dispatches at 1 and 4 threads, and revert to base exactly.
        let (base, a32) = big_weights_and_adapter(33);
        let f16 = ShiraF16Adapter {
            name: a32.name.clone(),
            strategy: a32.strategy.clone(),
            tensors: a32
                .tensors
                .iter()
                .map(|(t, d)| (t.clone(), SparseDeltaF16::from_f32(d)))
                .collect(),
        };
        let decoded = f16.to_shira(); // exact widening — the f32 oracle
        let mut wr = base.clone();
        let mut reference = SwitchEngine::new();
        reference.switch_to_shira(&mut wr, &decoded, 0.9);
        let applied = wr.clone();
        let f16 = Arc::new(f16);
        for threads in [1usize, 4] {
            for disp in [KernelDispatch::Scalar, KernelDispatch::Simd] {
                let pool = Arc::new(ThreadPool::new(threads));
                let mut w = base.clone();
                let mut eng = SwitchEngine::with_pool(Some(pool));
                eng.set_dispatch(disp);
                eng.switch_to_shira_f16(&mut w, Arc::clone(&f16), None, 0.9);
                assert_eq!(eng.active_name(), Some("big"));
                // Rollback data is available for f16 singles too.
                assert!(eng.shira_rollback().is_some());
                assert!(w.bit_equal(&applied), "{} t={threads}", disp.name());
                eng.revert(&mut w);
                assert!(w.bit_equal(&base), "{} t={threads} revert", disp.name());
            }
        }
        // With store-built plans (the f16-resident decode path builds
        // TensorPlans from the idx array alone).
        let plans: Arc<Vec<TensorPlan>> = Arc::new(
            f16.tensors
                .iter()
                .map(|(_, d)| TensorPlan::from_idx(&d.idx, d.cols, shards_for(d.nnz(), 4)))
                .collect(),
        );
        let pool = Arc::new(ThreadPool::new(4));
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(pool));
        eng.switch_to_shira_f16(&mut w, Arc::clone(&f16), Some(plans), 0.9);
        assert!(w.bit_equal(&applied));
        eng.revert(&mut w);
        assert!(w.bit_equal(&base));
    }

    #[test]
    fn transition_from_f16_active_falls_back() {
        use crate::adapter::sparse::SparseDeltaF16;
        // Direct transitions are f32-only: with an f16-resident adapter
        // active, transition_to must take the (bit-identical) fallback —
        // which exercises the f16 revert inside a switch.
        let (base, a32) = big_weights_and_adapter(34);
        let f16 = Arc::new(ShiraF16Adapter {
            name: a32.name.clone(),
            strategy: a32.strategy.clone(),
            tensors: a32
                .tensors
                .iter()
                .map(|(t, d)| (t.clone(), SparseDeltaF16::from_f32(d)))
                .collect(),
        });
        let decoded = f16.to_shira();
        let b = overlapping_adapter(&decoded, "b", 0.5, 35);
        let pool = Arc::new(ThreadPool::new(2));
        let mut w = base.clone();
        let mut eng = SwitchEngine::with_pool(Some(pool));
        eng.switch_to_shira_f16(&mut w, Arc::clone(&f16), None, 1.0);
        let tp = AdapterTransition::build(&decoded, &b, 2).expect("same targets");
        let (_t, path) = eng.transition_to(&mut w, Arc::new(b.clone()), None, &tp, 1.0);
        assert_eq!(path, SwitchPath::Fallback);
        assert_eq!(eng.transitions, 0);
        // Fallback still produced the correct state.
        let mut wr = base.clone();
        let mut reference = SwitchEngine::new();
        reference.switch_to_shira(&mut wr, &decoded, 1.0);
        reference.switch_to_shira(&mut wr, &b, 1.0);
        assert!(w.bit_equal(&wr));
        eng.revert(&mut w);
        assert!(w.bit_equal(&base));
    }

    #[test]
    fn lora_fuse_unfuse_has_float_drift_but_small() {
        let mut rng = Rng::new(2);
        let base = weights();
        let mut w = base.clone();
        let mut eng = SwitchEngine::new();
        let l = lora(&mut rng, "l");
        eng.switch_to_lora(&mut w, &l);
        eng.revert(&mut w);
        let drift = w.max_abs_diff(&base);
        assert!(drift < 1e-4, "drift={drift}");
    }

    #[test]
    fn parallel_lora_fuse_bit_identical_to_serial() {
        let dim = 96usize;
        let base = WeightStore::init(&[("w".into(), vec![dim, dim])], 5);
        let mut rng = Rng::new(6);
        let mut a = Tensor2::zeros(dim, 8);
        let mut b = Tensor2::zeros(8, dim);
        rng.fill_normal(&mut a.data, 0.0, 0.1);
        rng.fill_normal(&mut b.data, 0.0, 0.1);
        let l = LoraAdapter {
            name: "l".into(),
            scale: 1.5,
            tensors: vec![LoraTensor { target: "w".into(), a, b }],
        };
        let mut ws = base.clone();
        let mut serial = SwitchEngine::new();
        serial.switch_to_lora(&mut ws, &l);
        for threads in [2usize, 4] {
            let pool = Arc::new(ThreadPool::new(threads));
            let mut w = base.clone();
            let mut eng = SwitchEngine::with_pool(Some(pool));
            eng.switch_to_lora(&mut w, &l);
            assert!(w.bit_equal(&ws), "threads={threads}");
            eng.revert(&mut w);
        }
    }

    #[test]
    fn switching_between_adapters_reverts_previous() {
        let mut rng = Rng::new(3);
        let base = weights();
        let mut w = base.clone();
        let mut eng = SwitchEngine::new();
        let a = shira(&mut rng, "a");
        let b = shira(&mut rng, "b");
        eng.switch_to_shira(&mut w, &a, 1.0);
        eng.switch_to_shira(&mut w, &b, 1.0);
        assert_eq!(eng.active_name(), Some("b"));
        // reverting b restores base exactly (a was reverted on switch)
        eng.revert(&mut w);
        assert!(w.bit_equal(&base));
        assert_eq!(eng.switches, 2);
    }

    #[test]
    fn cross_family_switch_shira_then_lora() {
        let mut rng = Rng::new(4);
        let base = weights();
        let mut w = base.clone();
        let mut eng = SwitchEngine::new();
        eng.switch_to_shira(&mut w, &shira(&mut rng, "s"), 0.5);
        eng.switch_to_lora(&mut w, &lora(&mut rng, "l"));
        eng.revert(&mut w);
        assert!(w.max_abs_diff(&base) < 1e-4);
    }

    #[test]
    fn alpha_scales_the_applied_delta() {
        let mut rng = Rng::new(5);
        let base = weights();
        let a = shira(&mut rng, "a");
        let mut w1 = base.clone();
        let mut w2 = base.clone();
        let mut e1 = SwitchEngine::new();
        let mut e2 = SwitchEngine::new();
        e1.switch_to_shira(&mut w1, &a, 1.0);
        e2.switch_to_shira(&mut w2, &a, 0.5);
        let d1 = w1.max_abs_diff(&base);
        let d2 = w2.max_abs_diff(&base);
        assert!((d2 - d1 * 0.5).abs() < 1e-5, "{d1} {d2}");
    }

    #[test]
    fn hf_pipeline_timings_populated() {
        let mut rng = Rng::new(6);
        let base = weights();
        let mut w = base.clone();
        let mut eng = SwitchEngine::new();
        let sa = shira(&mut rng, "s");
        let sbytes = io::encode_shira(&sa);
        let t = eng.hf_pipeline_shira(&mut w, &sbytes, 1.0);
        assert!(t.load_us > 0.0);
        assert!(t.fuse_us > 0.0);
        assert!(w.bit_equal(&base));
        let lbytes = io::encode_lora(&lora(&mut rng, "l"));
        let t2 = eng.hf_pipeline_lora(&mut w, &lbytes);
        assert!(t2.fuse_us > 0.0);
        assert!(t2.total_us() >= t2.fuse_us);
    }

    #[test]
    fn switch_path_names() {
        assert_eq!(SwitchPath::Transition.name(), "transition");
        assert_eq!(SwitchPath::Fallback.name(), "fallback");
        assert_eq!(SwitchPath::Fused.name(), "fused");
    }
}
