//! The switch engine — the paper's rapid-switching contribution (§3.2,
//! Appendix A/B) implemented over the resident weight store.
//!
//! Three serving policies are implemented and benchmarked:
//!
//! * `ShiraScatter` — snapshot the k base values on the adapter's support,
//!   scatter the adapter in, infer, scatter the snapshot back.  O(k) work,
//!   exact revert.
//! * `LoraFuse` — the HF load→fuse→infer→unfuse→unload pipeline: dense
//!   `W += s·AB` / `W -= s·AB` over every target tensor.  O(n·m·r) work,
//!   revert accumulates float drift.
//! * `LoraUnfused` — leave branches on the forward path (handled by the
//!   server via the `llama_fwd_unfused_lora` artifact; no weight mutation).

use std::time::Instant;

use crate::adapter::{LoraAdapter, ShiraAdapter};
use crate::model::weights::WeightStore;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    ShiraScatter,
    LoraFuse,
    LoraUnfused,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::ShiraScatter => "shira-scatter",
            Policy::LoraFuse => "lora-fuse",
            Policy::LoraUnfused => "lora-unfused",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        Some(match s {
            "shira-scatter" | "shira" => Policy::ShiraScatter,
            "lora-fuse" | "lora" => Policy::LoraFuse,
            "lora-unfused" | "unfused" => Policy::LoraUnfused,
            _ => return None,
        })
    }
}

/// Per-stage timings of one switch, mirroring paper Table 5.
#[derive(Clone, Copy, Debug, Default)]
pub struct SwitchTiming {
    pub load_us: f64,
    pub fuse_us: f64,   // scatter-apply for SHiRA
    pub unfuse_us: f64, // snapshot-restore for SHiRA
    pub unload_us: f64,
}

impl SwitchTiming {
    pub fn total_us(&self) -> f64 {
        self.load_us + self.fuse_us + self.unfuse_us + self.unload_us
    }
}

/// What is currently applied to the resident weights.
#[derive(Debug)]
enum Active {
    None,
    Shira {
        name: String,
        /// (target, snapshot of base values on the adapter's support)
        snapshots: Vec<(String, Vec<f32>)>,
        /// the adapter's supports, needed to restore
        adapter: ShiraAdapter,
    },
    Lora {
        name: String,
        adapter: LoraAdapter,
    },
}

/// Owns the resident base weights and mutates them per adapter.
pub struct SwitchEngine {
    pub weights: WeightStore,
    active: Active,
    pub switches: u64,
}

impl SwitchEngine {
    pub fn new(weights: WeightStore) -> Self {
        SwitchEngine {
            weights,
            active: Active::None,
            switches: 0,
        }
    }

    pub fn active_name(&self) -> Option<&str> {
        match &self.active {
            Active::None => None,
            Active::Shira { name, .. } | Active::Lora { name, .. } => Some(name),
        }
    }

    /// Apply a SHiRA adapter at strength `alpha` (reverting whatever was
    /// active first).  Returns stage timings.
    pub fn switch_to_shira(&mut self, a: &ShiraAdapter, alpha: f32) -> SwitchTiming {
        let mut t = self.revert_timing();
        let t0 = Instant::now();
        let mut snapshots = Vec::with_capacity(a.tensors.len());
        for (target, delta) in &a.tensors {
            let w = self.weights.get_mut(target);
            snapshots.push((target.clone(), delta.snapshot(w)));
            delta.apply(w, alpha);
        }
        t.fuse_us += t0.elapsed().as_secs_f64() * 1e6;
        self.active = Active::Shira {
            name: a.name.clone(),
            snapshots,
            adapter: a.clone(),
        };
        self.switches += 1;
        t
    }

    /// Fuse a LoRA adapter (HF pipeline's fuse stage).
    pub fn switch_to_lora(&mut self, a: &LoraAdapter) -> SwitchTiming {
        let mut t = self.revert_timing();
        let t0 = Instant::now();
        for lt in &a.tensors {
            let w = self.weights.get_mut(&lt.target);
            w.add_outer_product(&lt.a, &lt.b, a.scale);
        }
        t.fuse_us += t0.elapsed().as_secs_f64() * 1e6;
        self.active = Active::Lora {
            name: a.name.clone(),
            adapter: a.clone(),
        };
        self.switches += 1;
        t
    }

    /// Revert to base weights; returns the time spent (unfuse stage).
    pub fn revert(&mut self) -> SwitchTiming {
        self.revert_timing()
    }

    fn revert_timing(&mut self) -> SwitchTiming {
        let mut t = SwitchTiming::default();
        let t0 = Instant::now();
        match std::mem::replace(&mut self.active, Active::None) {
            Active::None => {}
            Active::Shira {
                snapshots, adapter, ..
            } => {
                for (target, snap) in &snapshots {
                    let delta = adapter.find(target).expect("active adapter target");
                    delta.restore(self.weights.get_mut(target), snap);
                }
            }
            Active::Lora { adapter, .. } => {
                for lt in &adapter.tensors {
                    let w = self.weights.get_mut(&lt.target);
                    w.sub_outer_product(&lt.a, &lt.b, adapter.scale);
                }
            }
        }
        t.unfuse_us = t0.elapsed().as_secs_f64() * 1e6;
        t
    }

    /// Full HF-style pipeline for one adapter visit, with per-stage timers
    /// (paper Table 5): load (deserialize) → fuse → [caller infers] is
    /// simulated by apply/revert around a no-op → unfuse → unload (drop).
    pub fn hf_pipeline_shira(&mut self, bytes: &[u8], alpha: f32) -> SwitchTiming {
        let t0 = Instant::now();
        let adapter = crate::adapter::io::decode_shira(bytes).expect("valid adapter");
        let load_us = t0.elapsed().as_secs_f64() * 1e6;
        let mut t = self.switch_to_shira(&adapter, alpha);
        t.load_us = load_us;
        let mut t2 = self.revert();
        let t1 = Instant::now();
        drop(adapter);
        t2.unload_us = t1.elapsed().as_secs_f64() * 1e6;
        t.unfuse_us = t2.unfuse_us;
        t.unload_us = t2.unload_us;
        t
    }

    pub fn hf_pipeline_lora(&mut self, bytes: &[u8]) -> SwitchTiming {
        let t0 = Instant::now();
        let adapter = crate::adapter::io::decode_lora(bytes).expect("valid adapter");
        let load_us = t0.elapsed().as_secs_f64() * 1e6;
        let mut t = self.switch_to_lora(&adapter);
        t.load_us = load_us;
        let mut t2 = self.revert();
        let t1 = Instant::now();
        drop(adapter);
        t2.unload_us = t1.elapsed().as_secs_f64() * 1e6;
        t.unfuse_us = t2.unfuse_us;
        t.unload_us = t2.unload_us;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::sparse::SparseDelta;
    use crate::adapter::{io, LoraTensor};
    use crate::model::tensor::Tensor2;
    use crate::util::rng::Rng;

    fn weights() -> WeightStore {
        WeightStore::init(
            &[
                ("l0.wq".into(), vec![32, 32]),
                ("l0.wk".into(), vec![32, 32]),
            ],
            1,
        )
    }

    fn shira(rng: &mut Rng, name: &str) -> ShiraAdapter {
        let mk = |rng: &mut Rng| {
            let idx = rng.sample_indices(1024, 20);
            let mut d = vec![0.0; 20];
            rng.fill_normal(&mut d, 0.0, 0.5);
            SparseDelta::new(32, 32, idx, d)
        };
        ShiraAdapter {
            name: name.into(),
            strategy: "rand".into(),
            tensors: vec![("l0.wq".into(), mk(rng)), ("l0.wk".into(), mk(rng))],
        }
    }

    fn lora(rng: &mut Rng, name: &str) -> LoraAdapter {
        let mut a = Tensor2::zeros(32, 4);
        let mut b = Tensor2::zeros(4, 32);
        rng.fill_normal(&mut a.data, 0.0, 0.1);
        rng.fill_normal(&mut b.data, 0.0, 0.1);
        LoraAdapter {
            name: name.into(),
            scale: 2.0,
            tensors: vec![LoraTensor {
                target: "l0.wq".into(),
                a,
                b,
            }],
        }
    }

    #[test]
    fn shira_switch_and_revert_is_bit_exact() {
        let mut rng = Rng::new(1);
        let base = weights();
        let mut eng = SwitchEngine::new(base.clone());
        let a = shira(&mut rng, "a");
        eng.switch_to_shira(&a, 1.0);
        assert_eq!(eng.active_name(), Some("a"));
        assert!(eng.weights.max_abs_diff(&base) > 0.0);
        eng.revert();
        assert!(eng.weights.bit_equal(&base)); // the SHiRA exactness claim
        assert_eq!(eng.active_name(), None);
    }

    #[test]
    fn lora_fuse_unfuse_has_float_drift_but_small() {
        let mut rng = Rng::new(2);
        let base = weights();
        let mut eng = SwitchEngine::new(base.clone());
        let l = lora(&mut rng, "l");
        eng.switch_to_lora(&l);
        eng.revert();
        let drift = eng.weights.max_abs_diff(&base);
        assert!(drift < 1e-4, "drift={drift}");
    }

    #[test]
    fn switching_between_adapters_reverts_previous() {
        let mut rng = Rng::new(3);
        let base = weights();
        let mut eng = SwitchEngine::new(base.clone());
        let a = shira(&mut rng, "a");
        let b = shira(&mut rng, "b");
        eng.switch_to_shira(&a, 1.0);
        eng.switch_to_shira(&b, 1.0);
        assert_eq!(eng.active_name(), Some("b"));
        // reverting b restores base exactly (a was reverted on switch)
        eng.revert();
        assert!(eng.weights.bit_equal(&base));
        assert_eq!(eng.switches, 2);
    }

    #[test]
    fn cross_family_switch_shira_then_lora() {
        let mut rng = Rng::new(4);
        let base = weights();
        let mut eng = SwitchEngine::new(base.clone());
        eng.switch_to_shira(&shira(&mut rng, "s"), 0.5);
        eng.switch_to_lora(&lora(&mut rng, "l"));
        eng.revert();
        assert!(eng.weights.max_abs_diff(&base) < 1e-4);
    }

    #[test]
    fn alpha_scales_the_applied_delta() {
        let mut rng = Rng::new(5);
        let base = weights();
        let a = shira(&mut rng, "a");
        let mut e1 = SwitchEngine::new(base.clone());
        let mut e2 = SwitchEngine::new(base.clone());
        e1.switch_to_shira(&a, 1.0);
        e2.switch_to_shira(&a, 0.5);
        let d1 = e1.weights.max_abs_diff(&base);
        let d2 = e2.weights.max_abs_diff(&base);
        assert!((d2 - d1 * 0.5).abs() < 1e-5, "{d1} {d2}");
    }

    #[test]
    fn hf_pipeline_timings_populated() {
        let mut rng = Rng::new(6);
        let base = weights();
        let mut eng = SwitchEngine::new(base.clone());
        let sa = shira(&mut rng, "s");
        let sbytes = io::encode_shira(&sa);
        let t = eng.hf_pipeline_shira(&sbytes, 1.0);
        assert!(t.load_us > 0.0);
        assert!(t.fuse_us > 0.0);
        assert!(eng.weights.bit_equal(&base));
        let lbytes = io::encode_lora(&lora(&mut rng, "l"));
        let t2 = eng.hf_pipeline_lora(&lbytes);
        assert!(t2.fuse_us > 0.0);
        assert!(t2.total_us() >= t2.fuse_us);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(Policy::parse("shira"), Some(Policy::ShiraScatter));
        assert_eq!(Policy::parse("lora-fuse"), Some(Policy::LoraFuse));
        assert_eq!(Policy::parse("unfused"), Some(Policy::LoraUnfused));
        assert_eq!(Policy::parse("x"), None);
    }
}
