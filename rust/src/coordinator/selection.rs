//! The unified request surface: every [`Request`](crate::data::trace::Request)
//! carries a [`Selection`] saying what should be resident on the weights
//! when its batch executes — the base model, one adapter at a strength, or
//! a weighted adapter *set*.
//!
//! This is the API form of the paper's core claim: SHiRA makes
//! single-adapter switching and multi-adapter fusion the *same* cheap
//! fused-mode operation, so a serving request should be able to name
//! either without the server forking into per-policy code paths at
//! construction time.  A single adapter is just a one-member set; related
//! sparse-expert work (Arnob et al.) treats every deployment that way.
//!
//! ## Spec grammar
//!
//! [`Selection::parse`] subsumes the old `SetSpec` grammar:
//!
//! ```text
//! ""                  -> Base
//! "name"              -> Single { name, alpha: 1.0 }
//! "name@0.5"          -> Single { name, alpha: 0.5 }
//! "a@0.5+b"           -> Set { [("a", 0.5), ("b", 1.0)] }   (sorted by name)
//! "a@0.5+"            -> Set { [("a", 0.5)] }               (one-member set)
//! "@auto"             -> Auto                                (gate decides)
//! ```
//!
//! `+` is the *set marker*: any spec containing one is a `Set`, and a
//! trailing `+` spells a one-member set — distinct from the `Single` of
//! the same name and strength, because the two route through different
//! engines (scatter vs fused mode) even though the bytes agree.  `+`
//! and `@` are metacharacters: adapter names containing them are
//! rejected (such an adapter could never be addressed by a spec), the
//! guard the fused-mode roster has enforced since PR 2.
//!
//! `"@auto"` is [`Selection::Auto`] — the request delegates the choice to
//! the serving front end's [`Gate`](super::gate::Gate), which resolves it
//! to a concrete weighted `Set` over the expert pool *before* routing, so
//! batcher affinity and prefetch see an ordinary selection.  The spelling
//! starts with `@` precisely because no valid adapter name can (it is a
//! metacharacter), so `Auto` can never collide with a real adapter.
//!
//! ## Canonical identity
//!
//! [`Selection::key`] (also the `Display` form) is a canonical string:
//! set members sort by name and equal sets share one key regardless of
//! input order, so the batcher's affinity policy — and the store's
//! prefetch lookahead — key on *selection identity* instead of raw
//! request strings.  `"b+a@0.5"` and `"a@0.5+b@1"` batch together.

use super::error::ServeError;

/// What one request wants resident on the weights: the base model, a
/// single adapter at a strength, or a weighted adapter set.
///
/// # Examples
///
/// ```
/// use shira::coordinator::selection::Selection;
///
/// assert_eq!(Selection::parse("").unwrap(), Selection::Base);
/// let s = Selection::parse("style@0.5").unwrap();
/// assert_eq!(s, Selection::Single { name: "style".into(), alpha: 0.5 });
/// let set = Selection::parse("b+a@0.5").unwrap();
/// assert_eq!(set.key(), "a@0.5+b@1"); // canonical: sorted, equal sets share a key
/// assert_eq!(set.key(), Selection::parse("a@0.5+b@1").unwrap().key());
/// ```
#[derive(Clone, Debug, PartialEq)]
pub enum Selection {
    /// Serve the unmodified base weights.
    Base,
    /// Serve one adapter applied at strength `alpha` (SHiRA scatter or
    /// LoRA fuse, by the adapter's family; `alpha` is ignored for LoRA,
    /// whose strength is baked into its own scale).
    Single {
        /// Adapter name in the store.
        name: String,
        /// Application strength (SHiRA: the Fig. 6 α knob; default 1.0).
        alpha: f32,
    },
    /// Serve a weighted adapter set through the incremental fused-mode
    /// engine.  All members must be SHiRA adapters.
    Set {
        /// (adapter name, weight) members.  Canonical form is sorted by
        /// name with no duplicates; [`Selection::set`] and
        /// [`Selection::parse`] produce that form.
        members: Vec<(String, f32)>,
    },
    /// Let the configured gate pick: resolved by the server/fleet front
    /// end into a weighted [`Selection::Set`] over the expert pool before
    /// any routing happens.  Reaching a [`Router`](super::engine::Router)
    /// unresolved is an error — engines never see this variant.
    Auto,
}

/// The canonical spec spelling of [`Selection::Auto`].  Starts with the
/// `@` metacharacter so it can never collide with an adapter name.
pub const AUTO_SPEC: &str = "@auto";

/// Which arm of [`Selection`] a value is — the per-request routing label
/// surfaced in serve reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionKind {
    /// [`Selection::Base`].
    Base,
    /// [`Selection::Single`].
    Single,
    /// [`Selection::Set`].
    Set,
    /// [`Selection::Auto`] — gate-resolved before routing.
    Auto,
}

impl SelectionKind {
    /// Stable report name of the kind.
    pub fn name(&self) -> &'static str {
        match self {
            SelectionKind::Base => "base",
            SelectionKind::Single => "single",
            SelectionKind::Set => "set",
            SelectionKind::Auto => "auto",
        }
    }
}

fn bad(spec: &str, reason: impl Into<String>) -> ServeError {
    ServeError::InvalidSelection {
        spec: spec.to_string(),
        reason: reason.into(),
    }
}

fn parse_member(spec: &str, part: &str) -> Result<(String, f32), ServeError> {
    let part = part.trim();
    if part.is_empty() {
        return Err(bad(spec, "empty member"));
    }
    match part.split_once('@') {
        Some((n, w)) => {
            let n = n.trim();
            let w: f32 = w
                .trim()
                .parse()
                .map_err(|_| bad(spec, format!("bad weight in {part:?}")))?;
            if n.is_empty() {
                return Err(bad(spec, "empty adapter name"));
            }
            if !w.is_finite() {
                return Err(bad(spec, format!("non-finite weight in {part:?}")));
            }
            if n.contains('@') {
                return Err(bad(spec, format!("'@' in adapter name {n:?}")));
            }
            Ok((n.to_string(), w))
        }
        None => Ok((part.to_string(), 1.0)),
    }
}

impl Selection {
    /// Parse a selection spec (see the module docs for the grammar).
    /// Empty / whitespace-only specs are [`Selection::Base`]; a spec with
    /// no `+` is a [`Selection::Single`]; anything else is a canonicalized
    /// [`Selection::Set`] — a trailing `+` spells a one-member set.
    ///
    /// # Examples
    ///
    /// ```
    /// use shira::coordinator::selection::Selection;
    ///
    /// assert!(Selection::parse("a++b").is_err());   // empty member
    /// assert!(Selection::parse("a@x").is_err());    // bad weight
    /// assert!(Selection::parse("a+a@2").is_err());  // duplicate member
    /// assert_eq!(
    ///     Selection::parse(" a @ 0.5 ").unwrap(),
    ///     Selection::Single { name: "a".into(), alpha: 0.5 },
    /// );
    /// assert_eq!(
    ///     Selection::parse("a@0.5+").unwrap(),      // one-member set
    ///     Selection::set(&[("a", 0.5)]),
    /// );
    /// ```
    pub fn parse(spec: &str) -> Result<Selection, ServeError> {
        let trimmed = spec.trim();
        if trimmed.is_empty() {
            return Ok(Selection::Base);
        }
        if trimmed == AUTO_SPEC {
            return Ok(Selection::Auto);
        }
        if !trimmed.contains('+') {
            let (name, alpha) = parse_member(spec, trimmed)?;
            return Ok(Selection::Single { name, alpha });
        }
        let mut parts: Vec<&str> = trimmed.split('+').collect();
        // A trailing '+' is the explicit set marker ("a@0.5+" is a
        // one-member set); any other empty member is malformed.
        if parts.len() >= 2 && parts.last().map(|p| p.trim().is_empty()) == Some(true) {
            parts.pop();
        }
        let mut members = Vec::new();
        for part in parts {
            members.push(parse_member(spec, part)?);
        }
        members.sort_by(|a, b| a.0.cmp(&b.0));
        if let Some(w) = members.windows(2).find(|w| w[0].0 == w[1].0) {
            return Err(ServeError::DuplicateMember(w[0].0.clone()));
        }
        Ok(Selection::Set { members })
    }

    /// A single-adapter selection at strength 1.0.
    ///
    /// # Examples
    ///
    /// ```
    /// use shira::coordinator::selection::Selection;
    /// assert_eq!(Selection::single("a").key(), "a");
    /// ```
    pub fn single(name: &str) -> Selection {
        Selection::Single {
            name: name.to_string(),
            alpha: 1.0,
        }
    }

    /// A single-adapter selection at an explicit strength.
    pub fn single_at(name: &str, alpha: f32) -> Selection {
        Selection::Single {
            name: name.to_string(),
            alpha,
        }
    }

    /// A set selection over `(name, weight)` members, canonicalized
    /// (sorted by name).  Duplicates are caught by [`Self::validate`] /
    /// the server, not here.
    ///
    /// # Examples
    ///
    /// ```
    /// use shira::coordinator::selection::Selection;
    /// let s = Selection::set(&[("b", 1.0), ("a", 0.5)]);
    /// assert_eq!(s.key(), "a@0.5+b@1");
    /// ```
    pub fn set(members: &[(&str, f32)]) -> Selection {
        let mut members: Vec<(String, f32)> = members
            .iter()
            .map(|(n, w)| (n.to_string(), *w))
            .collect();
        members.sort_by(|a, b| a.0.cmp(&b.0));
        Selection::Set { members }
    }

    /// Strength-1 [`Selection::single`]s for a list of adapter names —
    /// the common shape trace generators and tests want.
    pub fn singles(names: &[String]) -> Vec<Selection> {
        names.iter().map(|n| Selection::single(n)).collect()
    }

    /// Which arm this selection is.
    pub fn kind(&self) -> SelectionKind {
        match self {
            Selection::Base => SelectionKind::Base,
            Selection::Single { .. } => SelectionKind::Single,
            Selection::Set { .. } => SelectionKind::Set,
            Selection::Auto => SelectionKind::Auto,
        }
    }

    /// Every adapter name this selection references (empty for `Base`
    /// and for `Auto`, whose names exist only after gate resolution).
    pub fn names(&self) -> Vec<&str> {
        match self {
            Selection::Base | Selection::Auto => Vec::new(),
            Selection::Single { name, .. } => vec![name.as_str()],
            Selection::Set { members } => members.iter().map(|(n, _)| n.as_str()).collect(),
        }
    }

    /// Canonical identity string (the `Display` form): `""` for base,
    /// `name[@alpha]` for singles (the `@alpha` suffix only when
    /// `alpha != 1`), and sorted `name@weight` members joined by `+` for
    /// sets — one-member sets carry a trailing `+` so they can never
    /// collide with the `Single` of the same name and strength (the two
    /// route through different engines).  Equal sets share one key
    /// regardless of member order — the affinity batcher and prefetch
    /// lookahead key on this.
    pub fn key(&self) -> String {
        match self {
            Selection::Base => String::new(),
            Selection::Auto => AUTO_SPEC.to_string(),
            Selection::Single { name, alpha } => {
                if *alpha == 1.0 {
                    name.clone()
                } else {
                    format!("{name}@{alpha}")
                }
            }
            Selection::Set { members } => {
                let mut sorted: Vec<&(String, f32)> = members.iter().collect();
                sorted.sort_by(|a, b| a.0.cmp(&b.0));
                let joined = sorted
                    .iter()
                    .map(|(n, w)| format!("{n}@{w}"))
                    .collect::<Vec<_>>()
                    .join("+");
                if sorted.len() == 1 {
                    format!("{joined}+")
                } else {
                    joined
                }
            }
        }
    }

    /// Check a (possibly hand-constructed) selection for the invariants
    /// `parse` guarantees: non-empty metacharacter-free names, finite
    /// weights, non-empty sets with no duplicate members.  The server
    /// validates every request selection on entry.
    ///
    /// # Examples
    ///
    /// ```
    /// use shira::coordinator::selection::Selection;
    /// assert!(Selection::single("a").validate().is_ok());
    /// assert!(Selection::single("a+b").validate().is_err()); // metacharacter
    /// assert!(Selection::Set { members: vec![] }.validate().is_err());
    /// ```
    pub fn validate(&self) -> Result<(), ServeError> {
        let spec = self.key();
        let check_name = |name: &str| -> Result<(), ServeError> {
            if name.is_empty() {
                return Err(bad(&spec, "empty adapter name"));
            }
            if name.contains('+') || name.contains('@') {
                return Err(bad(
                    &spec,
                    format!("adapter name {name:?} contains a spec metacharacter ('+' or '@')"),
                ));
            }
            Ok(())
        };
        match self {
            Selection::Base | Selection::Auto => Ok(()),
            Selection::Single { name, alpha } => {
                check_name(name)?;
                if !alpha.is_finite() {
                    return Err(bad(&spec, "non-finite strength"));
                }
                Ok(())
            }
            Selection::Set { members } => {
                if members.is_empty() {
                    return Err(bad(&spec, "empty adapter set"));
                }
                for (i, (name, w)) in members.iter().enumerate() {
                    check_name(name)?;
                    if !w.is_finite() {
                        return Err(bad(&spec, format!("non-finite weight for {name:?}")));
                    }
                    if members[..i].iter().any(|(o, _)| o == name) {
                        return Err(ServeError::DuplicateMember(name.clone()));
                    }
                }
                Ok(())
            }
        }
    }
}

impl std::fmt::Display for Selection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.key())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_base_single_set() {
        assert_eq!(Selection::parse("").unwrap(), Selection::Base);
        assert_eq!(Selection::parse("   ").unwrap(), Selection::Base);
        assert_eq!(
            Selection::parse("a").unwrap(),
            Selection::Single { name: "a".into(), alpha: 1.0 }
        );
        assert_eq!(
            Selection::parse("a@0.5").unwrap(),
            Selection::Single { name: "a".into(), alpha: 0.5 }
        );
        assert_eq!(
            Selection::parse("b + a@0.5").unwrap(),
            Selection::Set {
                members: vec![("a".into(), 0.5), ("b".into(), 1.0)]
            }
        );
        // Trailing '+' is the explicit one-member-set spelling.
        assert_eq!(
            Selection::parse("a+").unwrap(),
            Selection::Set {
                members: vec![("a".into(), 1.0)]
            }
        );
        assert_eq!(
            Selection::parse("a@0.5+").unwrap(),
            Selection::set(&[("a", 0.5)])
        );
    }

    #[test]
    fn keys_are_canonical_and_roundtrip() {
        let set = Selection::parse("b+a@0.5").unwrap();
        assert_eq!(set.key(), "a@0.5+b@1");
        assert_eq!(Selection::parse(&set.key()).unwrap().key(), set.key());
        let single = Selection::parse("x@2").unwrap();
        assert_eq!(single.key(), "x@2");
        assert_eq!(Selection::parse(&single.key()).unwrap(), single);
        assert_eq!(Selection::single("x").key(), "x");
        assert_eq!(Selection::Base.key(), "");
        // Display mirrors key()
        assert_eq!(format!("{set}"), set.key());
        // Singles and one-member sets route differently (scatter vs the
        // fused engine), so their keys must differ at EVERY strength —
        // the one-member set carries the trailing set marker.
        assert_eq!(Selection::set(&[("x", 1.0)]).key(), "x@1+");
        assert_eq!(Selection::set(&[("x", 0.5)]).key(), "x@0.5+");
        assert_ne!(Selection::set(&[("x", 1.0)]).key(), Selection::single("x").key());
        assert_ne!(
            Selection::set(&[("x", 0.5)]).key(),
            Selection::single_at("x", 0.5).key()
        );
        // One-member-set keys roundtrip through parse.
        let one = Selection::set(&[("x", 0.5)]);
        assert_eq!(Selection::parse(&one.key()).unwrap(), one);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for spec in ["a++b", "+", "@1", "a@", "a@x", "a@inf", "a@@2+b", "a+ +b"] {
            assert!(
                matches!(
                    Selection::parse(spec),
                    Err(ServeError::InvalidSelection { .. })
                ),
                "{spec:?} should be InvalidSelection"
            );
        }
        assert!(matches!(
            Selection::parse("a+a@2"),
            Err(ServeError::DuplicateMember(n)) if n == "a"
        ));
    }

    #[test]
    fn validate_guards_hand_built_selections() {
        assert!(Selection::Base.validate().is_ok());
        assert!(Selection::single_at("a", 0.5).validate().is_ok());
        assert!(Selection::set(&[("a", 1.0), ("b", 2.0)]).validate().is_ok());
        assert!(Selection::single("a+b").validate().is_err());
        assert!(Selection::single("a@b").validate().is_err());
        assert!(Selection::single_at("a", f32::NAN).validate().is_err());
        assert!(Selection::Set { members: vec![] }.validate().is_err());
        assert!(matches!(
            Selection::Set {
                members: vec![("a".into(), 1.0), ("a".into(), 2.0)]
            }
            .validate(),
            Err(ServeError::DuplicateMember(_))
        ));
    }

    #[test]
    fn auto_parses_roundtrips_and_never_collides_with_names() {
        assert_eq!(Selection::parse("@auto").unwrap(), Selection::Auto);
        assert_eq!(Selection::parse("  @auto  ").unwrap(), Selection::Auto);
        assert_eq!(Selection::Auto.key(), AUTO_SPEC);
        assert_eq!(format!("{}", Selection::Auto), "@auto");
        assert_eq!(
            Selection::parse(&Selection::Auto.key()).unwrap(),
            Selection::Auto
        );
        assert!(Selection::Auto.validate().is_ok());
        assert!(Selection::Auto.names().is_empty());
        assert_eq!(Selection::Auto.kind(), SelectionKind::Auto);
        assert_eq!(Selection::Auto.kind().name(), "auto");
        // The spelling is reserved by the metacharacter guard: no valid
        // adapter could ever be named "@auto" (or anything '@'-prefixed),
        // and near-miss spellings stay errors rather than aliasing Auto.
        assert!(Selection::single("@auto").validate().is_err());
        for spec in ["@aut", "@auto2", "@ auto", "@auto+b", "x@auto"] {
            assert!(
                !matches!(Selection::parse(spec), Ok(Selection::Auto)),
                "{spec:?} must not parse as Auto"
            );
        }
        assert!(Selection::parse("@auto+b").is_err());
        assert!(Selection::parse("@aut").is_err());
    }

    #[test]
    fn names_and_kinds() {
        assert!(Selection::Base.names().is_empty());
        assert_eq!(Selection::single("a").names(), vec!["a"]);
        assert_eq!(
            Selection::set(&[("b", 1.0), ("a", 0.5)]).names(),
            vec!["a", "b"]
        );
        assert_eq!(Selection::Base.kind().name(), "base");
        assert_eq!(Selection::single("a").kind().name(), "single");
        assert_eq!(Selection::set(&[("a", 1.0)]).kind().name(), "set");
    }
}
