//! Adapter lifecycle subsystem (DESIGN.md §10): flash-encoded adapters, a
//! byte-budgeted pinned decode cache, shard-aligned decode, and background
//! prefetch on the serving thread pool.
//!
//! This is the storage half of the paper's deployment story (Fig. 3a):
//! many adapters live on "flash" as compact encoded bytes (format v2 by
//! default — varint delta-coded indices, see [`io::Format`]); a bounded
//! RAM cache holds decoded [`AdapterHandle`]s.  Decode is *shard-aligned*:
//! the store materializes each SHiRA tensor's row-aligned
//! [`ShardPlan`] alongside the [`SparseDelta`](crate::adapter::sparse::SparseDelta),
//! so the first switch or fuse through an adapter skips plan construction
//! entirely.
//!
//! **Pinning.**  [`AdapterStore::pin`] adds a refcount under which the
//! cache never evicts the entry — the server pins the active adapter and
//! every fusion-roster member, so an adapter in an in-flight switch or an
//! active fused set cannot be evicted mid-apply no matter the cache
//! pressure.  (`Arc`s make eviction memory-safe regardless; pinning is the
//! residency guarantee.)
//!
//! **Prefetch.**  [`AdapterStore::prefetch`] submits decode jobs for
//! upcoming adapters (the batcher's affinity lookahead) to the shared
//! [`ThreadPool`]; results land in a staging area.  A later
//! [`AdapterStore::fetch`] that finds its adapter staged pays no decode on
//! the switch path — and if the decode is still in flight it waits for it
//! rather than decoding twice.  Decoded bytes are identical on every path
//! (cold miss, cache hit, prefetch), so serving output is unaffected.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::cache::LruCache;
use super::error::ServeError;
use super::fault::{FaultInjector, FaultSite};
use crate::adapter::io::{self, AdapterFamily, Format, IoError};
use crate::adapter::sparse::{shards_for, TensorPlan};
use crate::adapter::{AdapterTransition, LoraAdapter, ShiraAdapter, ShiraF16Adapter};
use crate::util::threadpool::ThreadPool;

/// A decoded adapter of either family.  Variants hold `Arc`s so a cache
/// hit can be activated on the switch engine without copying tensor data.
#[derive(Clone, Debug)]
pub enum AnyAdapter {
    /// A sparse high-rank adapter.
    Shira(Arc<ShiraAdapter>),
    /// A sparse high-rank adapter kept f16-resident: delta values stay
    /// raw binary16 bits in cache (half the resident bytes) and are
    /// dequantized lane-wise inside the kernel on apply (DESIGN.md §15).
    ShiraF16(Arc<ShiraF16Adapter>),
    /// A low-rank (LoRA) adapter.
    Lora(Arc<LoraAdapter>),
}

impl AnyAdapter {
    /// The adapter's name (unique within a store).
    pub fn name(&self) -> &str {
        match self {
            AnyAdapter::Shira(a) => &a.name,
            AnyAdapter::ShiraF16(a) => &a.name,
            AnyAdapter::Lora(a) => &a.name,
        }
    }

    /// Decoded in-memory size in bytes (the cache accounting unit).
    pub fn nbytes(&self) -> usize {
        match self {
            AnyAdapter::Shira(a) => a.nbytes(),
            AnyAdapter::ShiraF16(a) => a.nbytes(),
            AnyAdapter::Lora(a) => a.nbytes(),
        }
    }
}

/// A decoded adapter plus its shard-aligned layout: one [`TensorPlan`]
/// (row-aligned shard bounds + row-run cuts, DESIGN.md §15) per SHiRA
/// tensor, built once at decode time for the store's pool width so the
/// switch engine's first apply skips both plan and run construction
/// (empty for LoRA).
#[derive(Clone, Debug)]
pub struct AdapterHandle {
    /// The decoded adapter.
    pub adapter: AnyAdapter,
    /// Per-tensor shard/run plans in `tensors` order (SHiRA only).
    pub plans: Arc<Vec<TensorPlan>>,
}

impl AdapterHandle {
    fn decode(
        bytes: &[u8],
        plan_threads: usize,
        f16_resident: bool,
    ) -> Result<AdapterHandle, io::IoError> {
        match io::sniff_family(bytes) {
            Some(AdapterFamily::Shira) => {
                // f16 residency only applies to v2-f16 flash images: for
                // any other format the resident bits would be a lossy
                // re-quantization, so those decode to f32 as before.
                if f16_resident && io::is_v2_f16(bytes) {
                    let a = io::decode_shira_f16(bytes)?;
                    let plans = a
                        .tensors
                        .iter()
                        .map(|(_, d)| {
                            TensorPlan::from_idx(&d.idx, d.cols, shards_for(d.nnz(), plan_threads))
                        })
                        .collect();
                    return Ok(AdapterHandle {
                        adapter: AnyAdapter::ShiraF16(Arc::new(a)),
                        plans: Arc::new(plans),
                    });
                }
                let a = io::decode_shira(bytes)?;
                let plans = a
                    .tensors
                    .iter()
                    .map(|(_, d)| TensorPlan::build(d, shards_for(d.nnz(), plan_threads)))
                    .collect();
                Ok(AdapterHandle {
                    adapter: AnyAdapter::Shira(Arc::new(a)),
                    plans: Arc::new(plans),
                })
            }
            Some(AdapterFamily::Lora) => Ok(AdapterHandle {
                adapter: AnyAdapter::Lora(Arc::new(io::decode_lora(bytes)?)),
                plans: Arc::new(Vec::new()),
            }),
            None => Err(io::IoError::Format("unknown adapter magic".into())),
        }
    }

    /// Cache byte cost of this handle (the decoded adapter's size).
    pub fn nbytes(&self) -> usize {
        self.adapter.nbytes()
    }
}

/// Store tunables: decode-cache budget, on-flash format, prefetch depth,
/// transition-plan cache budget.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Byte budget of the decoded-adapter cache.
    pub cache_bytes: usize,
    /// On-flash encoding for adapters added to the store.
    pub format: Format,
    /// How many upcoming adapters one [`AdapterStore::prefetch`] call may
    /// submit for background decode (0 disables prefetch; the same depth
    /// bounds [`AdapterStore::prefetch_transitions`]).
    pub prefetch_depth: usize,
    /// Byte budget of the pairwise transition-plan cache (0 disables
    /// direct transitions: every switch falls back to revert+apply).
    pub plan_cache_bytes: usize,
    /// Retries after a transient I/O failure on the inline fetch path
    /// (0 disables retry; permanent failures never retry).
    pub retry_max: u32,
    /// Base backoff between retries, microseconds; doubles per attempt
    /// (0 retries immediately — what tests use).
    pub retry_backoff_us: u64,
    /// Upper bound on how long one flash read may stall before the fetch
    /// is failed with a transient timeout (microseconds; 0 disables the
    /// bound).  Without it an injected [`FaultSite::SlowFetch`] stall
    /// inflates latency unobserved; with it the stall trips the same
    /// retry/quarantine machinery as any other transient fault.
    pub fetch_deadline_us: u64,
    /// Consecutive terminal fetch failures (post-retry) before an adapter
    /// is quarantined and refused with [`ServeError::Quarantined`].
    pub quarantine_threshold: u32,
    /// How long a quarantine refuses fetches before letting one re-probe
    /// through, milliseconds (0 re-probes immediately).
    pub quarantine_ttl_ms: u64,
    /// Keep SHiRA deltas decoded from `v2-f16` flash images resident as
    /// raw binary16 bits (half the cache bytes); the kernel dequantizes
    /// lane-wise on apply, bit-identical to serving the f32 decode of the
    /// same file (DESIGN.md §15).  Non-f16 flash images are unaffected.
    pub f16_resident: bool,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            cache_bytes: 8 << 20,
            format: Format::V2,
            prefetch_depth: 2,
            plan_cache_bytes: 4 << 20,
            retry_max: 2,
            retry_backoff_us: 100,
            fetch_deadline_us: 100_000,
            quarantine_threshold: 3,
            quarantine_ttl_ms: 250,
            f16_resident: false,
        }
    }
}

/// Lifecycle counters for the end-of-run serving summary.
#[derive(Clone, Debug, Default)]
pub struct StoreStats {
    /// Decoded-cache lookups that found a resident entry.
    pub hits: u64,
    /// Decoded-cache lookups that missed.
    pub misses: u64,
    /// Entries evicted to fit the cache byte budget.
    pub evictions: u64,
    /// Background decode jobs submitted.
    pub prefetch_issued: u64,
    /// Fetches satisfied from the prefetch staging area (instead of
    /// decoding inline).
    pub prefetch_hits: u64,
    /// Subset of `prefetch_hits` whose decode was still in flight at
    /// fetch time — the fetch waited it out, so part of the decode cost
    /// landed on the request path after all (raise `--prefetch-depth` or
    /// issue prefetch earlier when this is high).
    pub prefetch_waits: u64,
    /// Fetches of adapters larger than the whole cache budget, served as
    /// uncached `Arc`s without flushing resident entries.
    pub oversized_serves: u64,
    /// Bytes of decoded adapters currently resident in the cache.
    pub resident_bytes: usize,
    /// Subset of `resident_bytes` held by f16-resident adapters (raw
    /// binary16 deltas; roughly half what the same adapters would cost
    /// decoded to f32).  Zero unless [`StoreConfig::f16_resident`] is on
    /// and v2-f16 flash images were fetched.
    pub f16_resident_bytes: usize,
    /// Decoded adapters currently resident in the cache.
    pub resident_entries: usize,
    /// Transition-plan lookups ([`AdapterStore::begin_transition`]) that
    /// found a resident plan — these switches take the one-pass direct
    /// path.
    pub plan_hits: u64,
    /// Transition-plan lookups that missed — these switches fall back to
    /// revert+apply.
    pub plan_misses: u64,
    /// Transition plans evicted to fit the plan-cache byte budget.
    pub plan_evictions: u64,
    /// Background transition-plan builds submitted to the pool.
    pub plan_builds: u64,
    /// Bytes of transition plans currently resident in the plan cache.
    pub plan_resident_bytes: usize,
    /// Transition plans currently resident in the plan cache.
    pub plan_resident_entries: usize,
    /// Flash reads failed because an injected stall exceeded
    /// [`StoreConfig::fetch_deadline_us`] (each surfaces as a transient
    /// timeout and rides the retry path).
    pub fetch_timeouts: u64,
    /// Transient-I/O fetch attempts retried (DESIGN.md §13.3).
    pub retries: u64,
    /// Quarantine trips: an adapter crossed the consecutive-failure
    /// threshold and was refused until its TTL re-probe.
    pub quarantines: u64,
}

impl StoreStats {
    /// hits / (hits + misses), 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// What a background decode job has produced for a name so far.
enum Staged {
    /// A job is submitted or running; a fetch waits instead of re-decoding.
    Pending,
    /// Decode finished; the handle moves into the cache on first fetch.
    Ready(AdapterHandle),
    /// Decode failed (corrupt flash bytes); the fetch surfaces the error.
    Failed(IoError),
}

struct PrefetchShared {
    slots: Mutex<HashMap<String, Staged>>,
    ready: Condvar,
}

/// What a background transition-plan build has produced for a pair key.
/// Unlike decode staging there is no waiting: a switch that finds its
/// plan `Pending` simply falls back to revert+apply — blocking the
/// request path on an optimization would defeat it.
enum PlanStaged {
    /// A build job is submitted or running.
    Pending,
    /// The plan is built; it moves into the plan cache on the next drain.
    Ready(AdapterTransition),
    /// The pair has mismatched target sets and can never be planned;
    /// kept as a tombstone so the pair is not re-submitted every batch.
    Unplannable,
}

struct PlanShared {
    slots: Mutex<HashMap<String, PlanStaged>>,
}

/// Per-adapter fetch-failure bookkeeping (DESIGN.md §13.3): consecutive
/// terminal failures, and when the quarantine (if any) was tripped.
#[derive(Default)]
struct Health {
    consecutive: u32,
    quarantined_at: Option<Instant>,
}

/// Flash-resident encoded adapters + pinned RAM cache of decoded ones,
/// with shard-aligned decode and background prefetch (module docs).
pub struct AdapterStore {
    flash: HashMap<String, Arc<Vec<u8>>>,
    cache: LruCache<AdapterHandle>,
    /// Pairwise A→B transition plans, keyed by [`Self::pair_key`],
    /// byte-budgeted like the decode cache.
    plans: LruCache<AdapterTransition>,
    format: Format,
    prefetch_depth: usize,
    /// Shard-plan width for decode (the serving pool's thread count).
    plan_threads: usize,
    pool: Option<Arc<ThreadPool>>,
    staging: Arc<PrefetchShared>,
    plan_staging: Arc<PlanShared>,
    prefetch_issued: u64,
    prefetch_hits: u64,
    prefetch_waits: u64,
    plan_builds: u64,
    /// Retry/quarantine tunables (see [`StoreConfig`]).
    retry_max: u32,
    retry_backoff_us: u64,
    fetch_deadline_us: u64,
    quarantine_threshold: u32,
    quarantine_ttl_ms: u64,
    /// Per-adapter consecutive-failure / quarantine state.
    health: HashMap<String, Health>,
    retries: u64,
    quarantines: u64,
    fetch_timeouts: u64,
    /// Decode v2-f16 flash images to f16-resident handles.
    f16_resident: bool,
    /// Cache cost of every f16-resident handle admitted so far, by name;
    /// `stats()` sums the still-resident subset into
    /// [`StoreStats::f16_resident_bytes`].
    f16_costs: HashMap<String, usize>,
    /// Optional deterministic fault injector (chaos tests only).
    fault: Option<Arc<FaultInjector>>,
}

impl AdapterStore {
    /// Store with a decoded-adapter cache budget of `cache_bytes` and
    /// default format/prefetch settings (no pool: prefetch disabled).
    pub fn new(cache_bytes: usize) -> Self {
        Self::with_config(
            StoreConfig {
                cache_bytes,
                ..StoreConfig::default()
            },
            None,
        )
    }

    /// Store with explicit tunables and an optional shared thread pool
    /// (used for background prefetch decode and as the shard-plan width).
    pub fn with_config(cfg: StoreConfig, pool: Option<Arc<ThreadPool>>) -> Self {
        let plan_threads = pool.as_ref().map(|p| p.threads()).unwrap_or(1);
        AdapterStore {
            flash: HashMap::new(),
            cache: LruCache::new(cfg.cache_bytes),
            plans: LruCache::new(cfg.plan_cache_bytes),
            format: cfg.format,
            prefetch_depth: cfg.prefetch_depth,
            plan_threads,
            pool,
            staging: Arc::new(PrefetchShared {
                slots: Mutex::new(HashMap::new()),
                ready: Condvar::new(),
            }),
            plan_staging: Arc::new(PlanShared {
                slots: Mutex::new(HashMap::new()),
            }),
            prefetch_issued: 0,
            prefetch_hits: 0,
            prefetch_waits: 0,
            plan_builds: 0,
            retry_max: cfg.retry_max,
            retry_backoff_us: cfg.retry_backoff_us,
            fetch_deadline_us: cfg.fetch_deadline_us,
            quarantine_threshold: cfg.quarantine_threshold.max(1),
            quarantine_ttl_ms: cfg.quarantine_ttl_ms,
            health: HashMap::new(),
            retries: 0,
            quarantines: 0,
            fetch_timeouts: 0,
            f16_resident: cfg.f16_resident,
            f16_costs: HashMap::new(),
            fault: None,
        }
    }

    /// Install a deterministic fault injector (chaos tests).  Production
    /// never calls this; every hook is a no-op without one.
    pub fn set_fault(&mut self, fault: Arc<FaultInjector>) {
        self.fault = Some(fault);
    }

    /// The on-flash encoding this store writes.
    pub fn format(&self) -> Format {
        self.format
    }

    /// Adapters one [`Self::prefetch`] call may submit.
    pub fn prefetch_depth(&self) -> usize {
        self.prefetch_depth
    }

    /// Encode a SHiRA adapter onto "flash" in the store's format.
    pub fn add_shira(&mut self, a: &ShiraAdapter) {
        self.flash
            .insert(a.name.clone(), Arc::new(io::encode_shira_as(a, self.format)));
    }

    /// Encode a LoRA adapter onto "flash" in the store's format.
    pub fn add_lora(&mut self, a: &LoraAdapter) {
        self.flash
            .insert(a.name.clone(), Arc::new(io::encode_lora_as(a, self.format)));
    }

    /// Store pre-encoded bytes under `name` (validated lazily at fetch).
    pub fn add_encoded(&mut self, name: &str, bytes: Vec<u8>) {
        self.flash.insert(name.to_string(), Arc::new(bytes));
    }

    /// Sorted names of every stored adapter.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.flash.keys().cloned().collect();
        v.sort();
        v
    }

    /// On-flash encoded size of `name`, if stored.
    pub fn encoded_len(&self, name: &str) -> Option<usize> {
        self.flash.get(name).map(|b| b.len())
    }

    /// Fetch a decoded handle: cache hit → prefetch staging → inline
    /// decode (with transient-I/O retry), in that order.  An adapter whose
    /// decoded size exceeds the whole cache budget is served as an
    /// uncached `Arc` without flushing resident entries.
    ///
    /// Errors are structured: a name the store has never seen is
    /// [`ServeError::UnknownAdapter`]; corrupt flash bytes surface as
    /// [`ServeError::Io`]; a quarantined adapter is refused with
    /// [`ServeError::Quarantined`] — callers branch on the variant
    /// instead of string-matching.
    ///
    /// Resilience (DESIGN.md §13.3): transient I/O failures retry with
    /// exponential backoff before counting as terminal; terminal failures
    /// feed a per-adapter consecutive-failure streak that quarantines the
    /// adapter at the threshold, with a TTL that lets one re-probe
    /// through.  A failed *background* decode no longer poisons the
    /// adapter: the fetch records the failure and falls through to an
    /// inline decode of the current flash bytes, so transient staging
    /// failures are retryable.
    pub fn fetch(&mut self, name: &str) -> Result<Arc<AdapterHandle>, ServeError> {
        if let Some(h) = self.cache.get(name) {
            return Ok(h);
        }
        if let Some(refused) = self.quarantine_gate(name) {
            return Err(refused);
        }
        match self.take_staged(name) {
            Ok(Some((handle, waited))) => {
                self.prefetch_hits += 1;
                if waited {
                    self.prefetch_waits += 1;
                }
                self.note_success(name);
                return Ok(self.admit(name, handle));
            }
            Ok(None) => {}
            Err(_stale) => {
                // Regression fix: a `Staged::Failed` entry used to
                // surface here as the fetch's terminal error, poisoning
                // the adapter even after its flash bytes were replaced.
                // The stale background failure is dropped (the inline
                // decode below gives ground truth on the CURRENT bytes);
                // only the inline outcome feeds the failure streak, so
                // one fetch never counts twice.
            }
        }
        let bytes = Arc::clone(
            self.flash
                .get(name)
                .ok_or_else(|| ServeError::UnknownAdapter(name.to_string()))?,
        );
        match self.read_and_decode(&bytes) {
            Ok(handle) => {
                self.note_success(name);
                Ok(self.admit(name, handle))
            }
            Err(e) => {
                if let Some(refused) = self.note_failure(name) {
                    return Err(refused);
                }
                Err(ServeError::Io(e))
            }
        }
    }

    /// Inline read+decode with transient-I/O retry: up to `retry_max`
    /// retries with exponential backoff (base `retry_backoff_us`,
    /// doubling); permanent failures (bad magic, CRC) never retry.
    fn read_and_decode(&mut self, bytes: &[u8]) -> Result<AdapterHandle, IoError> {
        let mut attempt = 0u32;
        loop {
            match self.try_read_decode(bytes) {
                Ok(h) => return Ok(h),
                Err(e) if e.is_transient() && attempt < self.retry_max => {
                    attempt += 1;
                    self.retries += 1;
                    let backoff = self.retry_backoff_us << (attempt - 1).min(16);
                    if backoff > 0 {
                        std::thread::sleep(Duration::from_micros(backoff));
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One read+decode attempt, applying any planned faults: a slow-fetch
    /// stall (bounded by the fetch deadline), a transient read error, or
    /// a one-byte decode corruption.
    fn try_read_decode(&mut self, bytes: &[u8]) -> Result<AdapterHandle, IoError> {
        if let Some(f) = self.fault.clone() {
            if f.should_fire(FaultSite::SlowFetch) {
                let stall = f.slow_stall_us();
                let timed_out =
                    self.fetch_deadline_us > 0 && stall > self.fetch_deadline_us;
                let bound = if timed_out { self.fetch_deadline_us } else { stall };
                std::thread::sleep(Duration::from_micros(bound));
                if timed_out {
                    self.fetch_timeouts += 1;
                    return Err(IoError::Io(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        "injected stall exceeded the fetch deadline",
                    )));
                }
            }
            if f.should_fire(FaultSite::Fetch) {
                return Err(IoError::Io(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "injected fault: transient flash read",
                )));
            }
        }
        decode_with_fault(
            bytes,
            self.plan_threads,
            self.f16_resident,
            self.fault.as_deref(),
        )
    }

    /// Refuse fetches of a quarantined adapter until the TTL lets one
    /// re-probe through (the probe's outcome then re-trips or clears it).
    fn quarantine_gate(&mut self, name: &str) -> Option<ServeError> {
        let ttl = Duration::from_millis(self.quarantine_ttl_ms);
        let h = self.health.get_mut(name)?;
        let since = h.quarantined_at?.elapsed();
        if since < ttl {
            return Some(ServeError::Quarantined {
                name: name.to_string(),
                failures: h.consecutive,
                retry_in_ms: ((ttl - since).as_millis() as u64).max(1),
            });
        }
        h.quarantined_at = None; // TTL expired: let this probe through
        None
    }

    /// Record a terminal fetch failure for `name`; returns the quarantine
    /// refusal when this failure crossed the consecutive-failure
    /// threshold (re-probe failures re-trip immediately).
    fn note_failure(&mut self, name: &str) -> Option<ServeError> {
        let threshold = self.quarantine_threshold;
        let ttl_ms = self.quarantine_ttl_ms;
        let h = self.health.entry(name.to_string()).or_default();
        h.consecutive += 1;
        if h.consecutive >= threshold {
            h.quarantined_at = Some(Instant::now());
            self.quarantines += 1;
            return Some(ServeError::Quarantined {
                name: name.to_string(),
                failures: h.consecutive,
                retry_in_ms: ttl_ms.max(1),
            });
        }
        None
    }

    /// A successful fetch clears the failure streak and any quarantine.
    fn note_success(&mut self, name: &str) {
        self.health.remove(name);
    }

    fn quarantine_active(&self, name: &str) -> bool {
        let ttl = Duration::from_millis(self.quarantine_ttl_ms);
        match self.health.get(name).and_then(|h| h.quarantined_at) {
            Some(t0) => t0.elapsed() < ttl,
            None => false,
        }
    }

    /// True when `name` is currently refused by quarantine.
    pub fn is_quarantined(&self, name: &str) -> bool {
        self.quarantine_active(name)
    }

    /// True when `name` is decoded and resident in the cache, without
    /// touching recency or counters — the warm-cache rung of the fleet's
    /// affinity cost ladder (`coordinator::fleet`).
    pub fn is_resident(&self, name: &str) -> bool {
        self.cache.peek(name).is_some()
    }

    /// A fault-free serial fork of this store for a bit-identity oracle:
    /// it shares the same `Arc`'d flash bytes (no copy of the encoded
    /// adapters) but starts with a fresh decode/plan cache, no pool, no
    /// prefetch, no fault injector, and default retry/quarantine
    /// tunables.  Serving a selection through a router backed by the
    /// fork yields the fault-free reference bytes the fleet's replicas
    /// are checked against.
    pub fn fork_reference(&self) -> AdapterStore {
        let mut fork = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: self.cache.capacity_bytes(),
                format: self.format,
                prefetch_depth: 0,
                plan_cache_bytes: 0,
                ..StoreConfig::default()
            },
            None,
        );
        for (name, bytes) in &self.flash {
            fork.flash.insert(name.clone(), Arc::clone(bytes));
        }
        fork
    }

    /// Submit background decode jobs for up to `prefetch_depth` of
    /// `names` (skipping resident, already-staged and unknown names).
    /// No-op without a pool.  Results are picked up by later fetches.
    ///
    /// The depth bounds *submissions*, not names examined: skipped names
    /// (already resident, already staged by this or another replica
    /// sharing the store, quarantined, unknown) do not consume the
    /// budget, so a lookahead whose head is warm still prefetches the
    /// cold tail — and N fleet replicas prefetching overlapping
    /// lookaheads submit one decode per adapter, not N.
    pub fn prefetch(&mut self, names: &[String]) {
        let Some(pool) = self.pool.clone() else {
            return;
        };
        let mut submitted = 0usize;
        for name in names {
            if submitted == self.prefetch_depth {
                break;
            }
            if self.cache.peek(name).is_some() {
                continue;
            }
            if self.quarantine_active(name) {
                continue; // don't burn pool time on a refused adapter
            }
            let Some(bytes) = self.flash.get(name) else {
                continue;
            };
            let bytes = Arc::clone(bytes);
            {
                let mut slots = self.staging.slots.lock().unwrap();
                if slots.contains_key(name.as_str()) {
                    continue;
                }
                slots.insert(name.clone(), Staged::Pending);
            }
            self.prefetch_issued += 1;
            submitted += 1;
            let shared = Arc::clone(&self.staging);
            let plan_threads = self.plan_threads;
            let f16_resident = self.f16_resident;
            let job_name = name.clone();
            let fault = self.fault.clone();
            pool.execute(move || {
                let res = decode_with_fault(&bytes, plan_threads, f16_resident, fault.as_deref());
                let mut slots = shared.slots.lock().unwrap();
                slots.insert(
                    job_name,
                    match res {
                        Ok(h) => Staged::Ready(h),
                        Err(e) => Staged::Failed(e),
                    },
                );
                shared.ready.notify_all();
            });
        }
    }

    // -- pairwise transition plans ---------------------------------------

    /// Plan-cache key for the ordered pair `from` → `to` (transitions are
    /// directional: A→B restores A and applies B).
    fn pair_key(from: &str, to: &str) -> String {
        format!("{from}\u{1f}{to}")
    }

    /// Move finished background plan builds into the byte-budgeted plan
    /// cache (leaving in-flight builds and unplannable tombstones staged).
    fn drain_plans(&mut self) {
        let ready: Vec<(String, AdapterTransition)> = {
            let mut slots = self.plan_staging.slots.lock().unwrap();
            let keys: Vec<String> = slots
                .iter()
                .filter(|(_, s)| matches!(s, PlanStaged::Ready(_)))
                .map(|(k, _)| k.clone())
                .collect();
            keys.into_iter()
                .map(|k| match slots.remove(&k) {
                    Some(PlanStaged::Ready(t)) => (k, t),
                    _ => unreachable!("key filtered as Ready above"),
                })
                .collect()
        };
        for (key, plan) in ready {
            let cost = plan.nbytes();
            if cost > self.plans.capacity_bytes() {
                // The plan could never be cached (oversized for the whole
                // budget).  Tombstone the pair instead of discarding the
                // build, or prefetch would re-submit the identical build
                // every batch forever while the pair still falls back.
                self.plans.oversized += 1;
                self.plan_staging
                    .slots
                    .lock()
                    .unwrap()
                    .insert(key, PlanStaged::Unplannable);
                continue;
            }
            self.plans.put(&key, plan, cost);
        }
    }

    /// Submit background builds of `from`→`to` transition plans for up to
    /// `prefetch_depth` of `tos` (skipping self-pairs, already-resident or
    /// already-staged pairs, unplannable tombstones, and pairs whose
    /// adapters are not both decoded SHiRA residents yet — the decode
    /// prefetch fills those in and a later call picks them up).  No-op
    /// without a pool.  Built plans are admitted to the plan cache by the
    /// next [`Self::begin_transition`] / `prefetch_transitions` call.
    pub fn prefetch_transitions(&mut self, from: &str, tos: &[String]) {
        let Some(pool) = self.pool.clone() else {
            return;
        };
        if self.plans.capacity_bytes() == 0 {
            return;
        }
        self.drain_plans();
        let Some(from_handle) = self.cache.peek(from) else {
            return;
        };
        let AnyAdapter::Shira(from_arc) = &from_handle.adapter else {
            return;
        };
        // Like decode prefetch: the depth bounds build *submissions*;
        // self-pairs, resident plans, staged builds and tombstones do not
        // consume the budget.
        let mut submitted = 0usize;
        for to in tos {
            if submitted == self.prefetch_depth {
                break;
            }
            if to == from {
                continue;
            }
            let key = Self::pair_key(from, to);
            if self.plans.peek(&key).is_some() {
                continue;
            }
            let Some(to_handle) = self.cache.peek(to) else {
                continue;
            };
            let AnyAdapter::Shira(to_arc) = &to_handle.adapter else {
                continue;
            };
            {
                let mut slots = self.plan_staging.slots.lock().unwrap();
                if slots.contains_key(&key) {
                    continue; // pending build or unplannable tombstone
                }
                slots.insert(key.clone(), PlanStaged::Pending);
            }
            self.plan_builds += 1;
            submitted += 1;
            let shared = Arc::clone(&self.plan_staging);
            let plan_threads = self.plan_threads;
            let a = Arc::clone(from_arc);
            let b = Arc::clone(to_arc);
            pool.execute(move || {
                let built = AdapterTransition::build(&a, &b, plan_threads);
                let mut slots = shared.slots.lock().unwrap();
                slots.insert(
                    key,
                    match built {
                        Some(t) => PlanStaged::Ready(t),
                        None => PlanStaged::Unplannable,
                    },
                );
            });
        }
    }

    /// Look up the cached `from`→`to` transition plan for an imminent
    /// switch.  On a hit the entry is **pinned** until
    /// [`Self::end_transition`], so plan-cache eviction can never drop the
    /// plan of the in-flight transition.  A miss (cold pair, build still
    /// in flight, or unplannable pair) returns `None` and the switch
    /// falls back to revert+apply.
    pub fn begin_transition(&mut self, from: &str, to: &str) -> Option<Arc<AdapterTransition>> {
        self.drain_plans();
        let key = Self::pair_key(from, to);
        let plan = self.plans.get(&key)?;
        self.plans.pin(&key);
        Some(plan)
    }

    /// Release the in-flight pin taken by [`Self::begin_transition`].
    pub fn end_transition(&mut self, from: &str, to: &str) {
        self.plans.unpin(&Self::pair_key(from, to));
    }

    /// True when a `from`→`to` plan is resident (no recency or counter
    /// touch).
    pub fn has_transition_plan(&self, from: &str, to: &str) -> bool {
        self.plans.peek(&Self::pair_key(from, to)).is_some()
    }

    /// Names with a resident `from`→X transition plan — the exclusion set
    /// for the batcher's `upcoming` lookahead, so plan prefetch is not
    /// re-suggested pairs it already holds.
    pub fn planned_to_names(&mut self, from: &str) -> Vec<String> {
        self.drain_plans();
        let prefix = Self::pair_key(from, "");
        self.plans
            .keys_lru_order()
            .into_iter()
            .filter_map(|k| k.strip_prefix(prefix.as_str()))
            .map(str::to_string)
            .collect()
    }

    /// Pin `name` in the decode cache (refcounted): pinned entries are
    /// never evicted.  Returns false when the adapter is not resident.
    pub fn pin(&mut self, name: &str) -> bool {
        self.cache.pin(name)
    }

    /// Drop one pin from `name`.
    pub fn unpin(&mut self, name: &str) -> bool {
        self.cache.unpin(name)
    }

    /// True when `name` is resident with at least one pin.
    pub fn is_pinned(&self, name: &str) -> bool {
        self.cache.is_pinned(name)
    }

    /// Resident decoded adapters currently holding at least one pin — the
    /// pin-leak audit probe: after any failed request this must return to
    /// its pre-request baseline.
    pub fn pinned_count(&self) -> usize {
        self.cache.pinned_entries()
    }

    /// Resident transition plans currently holding at least one pin (the
    /// matching probe for [`Self::begin_transition`] pins).
    pub fn pinned_plan_count(&self) -> usize {
        self.plans.pinned_entries()
    }

    /// Lifecycle counters so far.
    pub fn stats(&self) -> StoreStats {
        StoreStats {
            hits: self.cache.hits,
            misses: self.cache.misses,
            evictions: self.cache.evictions,
            prefetch_issued: self.prefetch_issued,
            prefetch_hits: self.prefetch_hits,
            prefetch_waits: self.prefetch_waits,
            oversized_serves: self.cache.oversized,
            resident_bytes: self.cache.used_bytes(),
            f16_resident_bytes: self
                .f16_costs
                .iter()
                .filter(|(n, _)| self.cache.peek(n).is_some())
                .map(|(_, c)| c)
                .sum(),
            resident_entries: self.cache.len(),
            plan_hits: self.plans.hits,
            plan_misses: self.plans.misses,
            plan_evictions: self.plans.evictions,
            plan_builds: self.plan_builds,
            plan_resident_bytes: self.plans.used_bytes(),
            plan_resident_entries: self.plans.len(),
            fetch_timeouts: self.fetch_timeouts,
            retries: self.retries,
            quarantines: self.quarantines,
        }
    }

    /// (cache hits, cache misses) so far.
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits, self.cache.misses)
    }

    /// Move a decoded handle into the cache; the cache serves it uncached
    /// when it could never fit the budget (and counts it as oversized).
    fn admit(&mut self, name: &str, handle: AdapterHandle) -> Arc<AdapterHandle> {
        let cost = handle.nbytes();
        if matches!(handle.adapter, AnyAdapter::ShiraF16(_)) {
            self.f16_costs.insert(name.to_string(), cost);
        } else {
            self.f16_costs.remove(name);
        }
        self.cache.put(name, handle, cost)
    }

    /// Remove `name` from staging, waiting out an in-flight decode.
    /// Returns the handle plus whether the fetch had to wait (the decode
    /// was still in flight — part of its cost landed on the request path).
    /// A staged failure is returned as the raw [`IoError`] so the fetch
    /// can record it and still retry inline.
    fn take_staged(&mut self, name: &str) -> Result<Option<(AdapterHandle, bool)>, IoError> {
        let mut slots = self.staging.slots.lock().unwrap();
        let mut waited = false;
        loop {
            let pending = match slots.get(name) {
                None => return Ok(None),
                Some(Staged::Pending) => true,
                Some(_) => false,
            };
            if !pending {
                break;
            }
            waited = true;
            slots = self.staging.ready.wait(slots).unwrap();
        }
        match slots.remove(name) {
            Some(Staged::Ready(h)) => Ok(Some((h, waited))),
            Some(Staged::Failed(e)) => Err(e),
            _ => unreachable!("loop exits only on Ready/Failed"),
        }
    }
}

/// Decode `bytes`, flipping one byte first when a decode fault is
/// planned — the CRC check then genuinely fails, so corruption detection
/// is exercised by the real verifier, not simulated.
fn decode_with_fault(
    bytes: &[u8],
    plan_threads: usize,
    f16_resident: bool,
    fault: Option<&FaultInjector>,
) -> Result<AdapterHandle, IoError> {
    if let Some(f) = fault {
        if f.should_fire(FaultSite::Decode) {
            let mut corrupted = bytes.to_vec();
            f.corrupt(&mut corrupted);
            return AdapterHandle::decode(&corrupted, plan_threads, f16_resident);
        }
    }
    AdapterHandle::decode(bytes, plan_threads, f16_resident)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::sparse::SparseDelta;
    use crate::util::rng::Rng;

    fn shira(rng: &mut Rng, name: &str, dim: usize, k: usize) -> ShiraAdapter {
        let idx = rng.sample_indices(dim * dim, k);
        let mut d = vec![0.0; k];
        rng.fill_normal(&mut d, 0.0, 0.5);
        ShiraAdapter {
            name: name.into(),
            strategy: "rand".into(),
            tensors: vec![("w".into(), SparseDelta::new(dim, dim, idx, d))],
        }
    }

    #[test]
    fn fetch_decodes_and_caches() {
        let mut rng = Rng::new(1);
        let a = shira(&mut rng, "a", 16, 20);
        let mut store = AdapterStore::new(1 << 20);
        store.add_shira(&a);
        let h = store.fetch("a").unwrap();
        match &h.adapter {
            AnyAdapter::Shira(s) => assert_eq!(**s, a),
            _ => panic!("family"),
        }
        assert_eq!(h.plans.len(), 1);
        assert_eq!(h.plans[0].total(), 20);
        let (hits, misses) = store.cache_stats();
        assert_eq!((hits, misses), (0, 1));
        store.fetch("a").unwrap();
        assert_eq!(store.cache_stats(), (1, 1));
        assert!(matches!(
            store.fetch("ghost"),
            Err(ServeError::UnknownAdapter(n)) if n == "ghost"
        ));
    }

    #[test]
    fn v1_and_v2_flash_decode_identically() {
        let mut rng = Rng::new(2);
        let a = shira(&mut rng, "a", 32, 64);
        for format in [Format::V1, Format::V2] {
            let mut store = AdapterStore::with_config(
                StoreConfig {
                    cache_bytes: 1 << 20,
                    format,
                    prefetch_depth: 0,
                    ..StoreConfig::default()
                },
                None,
            );
            store.add_shira(&a);
            match &store.fetch("a").unwrap().adapter {
                AnyAdapter::Shira(s) => assert_eq!(**s, a, "{}", format.name()),
                _ => panic!("family"),
            }
        }
    }

    #[test]
    fn v2_flash_bytes_smaller_than_v1() {
        let mut rng = Rng::new(3);
        let a = shira(&mut rng, "a", 128, (128 * 128) / 50); // 2% sparse
        let mk = |format| {
            let mut s = AdapterStore::with_config(
                StoreConfig {
                    cache_bytes: 1 << 20,
                    format,
                    prefetch_depth: 0,
                    ..StoreConfig::default()
                },
                None,
            );
            s.add_shira(&a);
            s.encoded_len("a").unwrap()
        };
        assert!(mk(Format::V2) < mk(Format::V1));
    }

    #[test]
    fn f16_resident_fetch_keeps_bits_and_counts_bytes() {
        let mut rng = Rng::new(40);
        let a = shira(&mut rng, "a", 32, 100);
        let mk = |f16_resident| {
            AdapterStore::with_config(
                StoreConfig {
                    cache_bytes: 1 << 20,
                    format: Format::V2F16,
                    prefetch_depth: 0,
                    f16_resident,
                    ..StoreConfig::default()
                },
                None,
            )
        };
        let mut store = mk(true);
        store.add_shira(&a);
        let h = store.fetch("a").unwrap();
        let AnyAdapter::ShiraF16(f) = &h.adapter else {
            panic!("expected an f16-resident handle");
        };
        assert_eq!(h.plans.len(), 1);
        assert_eq!(h.plans[0].total(), 100);
        // Materializing the resident bits gives exactly the f32 decode of
        // the same flash bytes (the bit-identity invariant).
        let mut oracle = mk(false);
        oracle.add_shira(&a);
        let oh = oracle.fetch("a").unwrap();
        let AnyAdapter::Shira(g) = &oh.adapter else {
            panic!("oracle must decode to f32");
        };
        assert_eq!(f.to_shira(), **g);
        // f16 residency roughly halves the cache bytes and is counted
        // separately in the stats.
        assert!(h.nbytes() < oh.nbytes());
        let stats = store.stats();
        assert_eq!(stats.f16_resident_bytes, h.nbytes());
        assert!(stats.f16_resident_bytes <= stats.resident_bytes);
        assert_eq!(oracle.stats().f16_resident_bytes, 0);
    }

    #[test]
    fn f16_residency_ignores_non_f16_flash() {
        // f16_resident on, but the flash image stores f32 values: the
        // resident bits would be a lossy re-quantization, so the decode
        // falls back to f32.
        let mut rng = Rng::new(41);
        let a = shira(&mut rng, "a", 16, 20);
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 1 << 20,
                format: Format::V2,
                prefetch_depth: 0,
                f16_resident: true,
                ..StoreConfig::default()
            },
            None,
        );
        store.add_shira(&a);
        match &store.fetch("a").unwrap().adapter {
            AnyAdapter::Shira(s) => assert_eq!(**s, a),
            _ => panic!("v2 (f32) flash must decode to f32"),
        }
        assert_eq!(store.stats().f16_resident_bytes, 0);
    }

    #[test]
    fn oversized_adapter_served_uncached_without_flushing() {
        // Satellite regression: a fetch whose decoded size exceeds the
        // whole budget must serve an uncached Arc and leave residents.
        let mut rng = Rng::new(4);
        let small = shira(&mut rng, "small", 16, 10); // 80 bytes decoded
        let big = shira(&mut rng, "big", 64, 1000); // 8000 bytes decoded
        let mut store = AdapterStore::new(500);
        store.add_shira(&small);
        store.add_shira(&big);
        store.fetch("small").unwrap();
        let h = store.fetch("big").unwrap();
        assert_eq!(h.adapter.name(), "big");
        let stats = store.stats();
        assert_eq!(stats.oversized_serves, 1);
        assert_eq!(stats.evictions, 0);
        assert_eq!(stats.resident_entries, 1); // "small" survived
        store.fetch("small").unwrap();
        assert_eq!(store.stats().hits, 1);
    }

    #[test]
    fn pinned_adapters_survive_cache_pressure() {
        let mut rng = Rng::new(5);
        let mut store = AdapterStore::new(200); // fits ~2 adapters of 80 B
        for name in ["a", "b", "c", "d"] {
            store.add_shira(&shira(&mut rng, name, 16, 10));
        }
        store.fetch("a").unwrap();
        assert!(store.pin("a"));
        for name in ["b", "c", "d"] {
            store.fetch(name).unwrap();
        }
        assert!(store.stats().evictions > 0);
        assert!(store.is_pinned("a"));
        store.fetch("a").unwrap();
        assert_eq!(store.stats().hits, 1, "pinned adapter stayed resident");
        assert!(store.unpin("a"));
        assert!(!store.is_pinned("a"));
    }

    #[test]
    fn prefetch_stages_decode_off_the_fetch_path() {
        let mut rng = Rng::new(6);
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 1 << 20,
                format: Format::V2,
                prefetch_depth: 2,
                ..StoreConfig::default()
            },
            Some(Arc::new(ThreadPool::new(2))),
        );
        let a = shira(&mut rng, "a", 32, 100);
        store.add_shira(&a);
        store.add_shira(&shira(&mut rng, "b", 32, 100));
        store.prefetch(&["a".to_string(), "b".to_string(), "zz".to_string()]);
        let stats = store.stats();
        assert_eq!(stats.prefetch_issued, 2); // depth 2; "zz" unknown anyway
        let h = store.fetch("a").unwrap();
        match &h.adapter {
            AnyAdapter::Shira(s) => assert_eq!(**s, a),
            _ => panic!("family"),
        }
        let stats = store.stats();
        assert_eq!(stats.prefetch_hits, 1);
        // re-prefetching a resident adapter is a no-op
        store.prefetch(&["a".to_string()]);
        assert_eq!(store.stats().prefetch_issued, 2);
    }

    #[test]
    fn prefetch_without_pool_is_a_noop() {
        let mut rng = Rng::new(7);
        let mut store = AdapterStore::new(1 << 20);
        store.add_shira(&shira(&mut rng, "a", 16, 10));
        store.prefetch(&["a".to_string()]);
        assert_eq!(store.stats().prefetch_issued, 0);
        store.fetch("a").unwrap();
        assert_eq!(store.stats().prefetch_hits, 0);
    }

    /// Store + pool wired for transition-plan tests, with the named
    /// adapters added and fetched resident.
    fn plan_store(
        plan_cache_bytes: usize,
        names: &[&str],
        rng: &mut Rng,
    ) -> (AdapterStore, Arc<ThreadPool>) {
        let pool = Arc::new(ThreadPool::new(2));
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 1 << 20,
                format: Format::V2,
                prefetch_depth: 8,
                plan_cache_bytes,
                ..StoreConfig::default()
            },
            Some(Arc::clone(&pool)),
        );
        for name in names {
            store.add_shira(&shira(rng, name, 32, 64));
            store.fetch(name).unwrap();
        }
        (store, pool)
    }

    #[test]
    fn transition_plans_build_in_background_and_hit() {
        let mut rng = Rng::new(10);
        let (mut store, pool) = plan_store(1 << 20, &["a", "b", "c"], &mut rng);
        // Cold pair: miss, fallback.
        assert!(store.begin_transition("a", "b").is_none());
        assert_eq!(store.stats().plan_misses, 1);
        store.prefetch_transitions("a", &["b".to_string(), "c".to_string()]);
        assert_eq!(store.stats().plan_builds, 2);
        pool.join(); // deterministic: wait out the background builds
        let plan = store.begin_transition("a", "b").expect("plan built");
        assert_eq!((plan.from.as_str(), plan.to.as_str()), ("a", "b"));
        store.end_transition("a", "b");
        assert!(store.has_transition_plan("a", "c"));
        assert!(!store.has_transition_plan("b", "a"), "plans are directional");
        let stats = store.stats();
        assert_eq!(stats.plan_hits, 1);
        assert_eq!(stats.plan_resident_entries, 2);
        assert!(stats.plan_resident_bytes > 0);
        // planned pairs are reported for the upcoming() exclusion set
        let mut planned = store.planned_to_names("a");
        planned.sort();
        assert_eq!(planned, vec!["b".to_string(), "c".to_string()]);
        // re-prefetching a resident pair (or a self-pair) is a no-op
        store.prefetch_transitions("a", &["b".to_string(), "a".to_string()]);
        assert_eq!(store.stats().plan_builds, 2);
    }

    #[test]
    fn unplannable_pairs_tombstone_instead_of_respawning() {
        let mut rng = Rng::new(11);
        let (mut store, pool) = plan_store(1 << 20, &["a"], &mut rng);
        // "odd" targets a different tensor set — unplannable with "a".
        let mut odd = shira(&mut rng, "odd", 32, 64);
        odd.tensors[0].0 = "other".into();
        store.add_shira(&odd);
        store.fetch("odd").unwrap();
        store.prefetch_transitions("a", &["odd".to_string()]);
        pool.join();
        assert!(store.begin_transition("a", "odd").is_none());
        assert_eq!(store.stats().plan_builds, 1);
        // the tombstone stops the pair from being re-submitted every batch
        store.prefetch_transitions("a", &["odd".to_string()]);
        assert_eq!(store.stats().plan_builds, 1);
    }

    #[test]
    fn oversized_plan_tombstones_instead_of_rebuilding_forever() {
        // A plan bigger than the whole plan budget can never be cached:
        // it must tombstone like an unplannable pair, not be rebuilt on
        // the pool every batch while silently never serving a hit.
        let mut rng = Rng::new(13);
        let (mut store, pool) = plan_store(256, &["a", "b"], &mut rng); // plan ~2.2 KB > 256 B
        store.prefetch_transitions("a", &["b".to_string()]);
        pool.join();
        assert!(store.begin_transition("a", "b").is_none());
        assert_eq!(store.stats().plan_builds, 1);
        assert_eq!(store.stats().plan_resident_entries, 0);
        // the tombstone stops the pair from being re-submitted
        store.prefetch_transitions("a", &["b".to_string()]);
        pool.join();
        assert_eq!(store.stats().plan_builds, 1, "oversized pair rebuilt");
    }

    #[test]
    fn plan_cache_eviction_never_evicts_inflight_plan() {
        // Satellite: the plan taken by begin_transition is pinned until
        // end_transition, so cache pressure cannot drop it mid-switch.
        let mut rng = Rng::new(12);
        let names = ["a", "b", "c", "d", "e"];
        // One plan for these adapters costs ~2.2 KB, so a 4 KB budget
        // cannot hold two: every later build pressures the cache.
        let (mut store, pool) = plan_store(4096, &names, &mut rng);
        store.prefetch_transitions("a", &["b".to_string()]);
        pool.join();
        let inflight = store.begin_transition("a", "b").expect("plan built");
        assert_eq!((inflight.from.as_str(), inflight.to.as_str()), ("a", "b"));
        for other in ["c", "d", "e"] {
            store.prefetch_transitions(other, &["b".to_string(), "a".to_string()]);
        }
        pool.join();
        store.drain_plans();
        assert!(store.stats().plan_evictions > 0, "pressure evicted something");
        assert!(
            store.has_transition_plan("a", "b"),
            "in-flight plan survived eviction pressure"
        );
        store.end_transition("a", "b");
    }

    #[test]
    fn corrupt_flash_bytes_error_on_fetch_and_prefetch() {
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 1 << 20,
                format: Format::V2,
                prefetch_depth: 1,
                ..StoreConfig::default()
            },
            Some(Arc::new(ThreadPool::new(1))),
        );
        store.add_encoded("junk", vec![0xAB; 64]);
        assert!(matches!(store.fetch("junk"), Err(ServeError::Io(_))));
        store.prefetch(&["junk".to_string()]);
        assert!(matches!(store.fetch("junk"), Err(ServeError::Io(_))));
    }

    /// Store with retry/quarantine tunables for resilience tests (no
    /// backoff sleeps; quarantine trips at 2 consecutive failures).
    fn resilient_store(
        quarantine_ttl_ms: u64,
        pool: Option<Arc<ThreadPool>>,
    ) -> AdapterStore {
        AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 1 << 20,
                prefetch_depth: 2,
                retry_max: 2,
                retry_backoff_us: 0,
                quarantine_threshold: 2,
                quarantine_ttl_ms,
                ..StoreConfig::default()
            },
            pool,
        )
    }

    #[test]
    fn stale_staged_failure_does_not_poison_the_adapter() {
        // Satellite regression: a failed background decode used to
        // surface as every later fetch's terminal error — the adapter
        // was poisoned even after its flash bytes were replaced.
        let pool = Arc::new(ThreadPool::new(1));
        let mut store = resilient_store(60_000, Some(Arc::clone(&pool)));
        store.add_encoded("a", vec![0xAB; 64]); // corrupt flash image
        store.prefetch(&["a".to_string()]);
        pool.join(); // background decode has failed and staged the error
        let mut rng = Rng::new(21);
        store.add_shira(&shira(&mut rng, "a", 16, 10)); // flash repaired
        let h = store.fetch("a").expect("repaired adapter must fetch");
        assert_eq!(h.adapter.name(), "a");
        let stats = store.stats();
        assert_eq!(stats.quarantines, 0);
        assert!(!store.is_quarantined("a"));
    }

    #[test]
    fn transient_fetch_faults_are_retried_and_counted() {
        use crate::coordinator::fault::FaultPlan;
        let mut rng = Rng::new(22);
        let mut store = resilient_store(60_000, None);
        store.add_shira(&shira(&mut rng, "a", 16, 10));
        // Attempt 1 fails transiently and stalls; the retry succeeds.
        store.set_fault(
            FaultPlan::new().fail_fetch_at(1).slow_fetch_at(1).slow_us(1).injector(),
        );
        let h = store.fetch("a").expect("retry must recover");
        assert_eq!(h.adapter.name(), "a");
        let stats = store.stats();
        assert_eq!(stats.retries, 1);
        assert_eq!(stats.quarantines, 0, "a recovered fetch is not a failure");
    }

    #[test]
    fn exhausted_retries_surface_io_then_quarantine() {
        use crate::coordinator::fault::FaultPlan;
        let mut rng = Rng::new(23);
        let mut store = resilient_store(60_000, None);
        store.add_shira(&shira(&mut rng, "a", 16, 10));
        // 6 consecutive read attempts fail: fetch #1 burns attempts 1-3
        // (2 retries) and is terminal; fetch #2 burns 4-6, terminal too,
        // crossing the threshold of 2 → quarantine.
        let mut plan = FaultPlan::new();
        for n in 1..=6 {
            plan = plan.fail_fetch_at(n);
        }
        store.set_fault(plan.injector());
        assert!(matches!(store.fetch("a"), Err(ServeError::Io(_))));
        assert!(matches!(
            store.fetch("a"),
            Err(ServeError::Quarantined { failures: 2, .. })
        ));
        assert!(store.is_quarantined("a"));
        let stats = store.stats();
        assert_eq!(stats.retries, 4);
        assert_eq!(stats.quarantines, 1);
        // While quarantined: refused without touching flash, and
        // prefetch skips the adapter entirely.
        assert!(matches!(
            store.fetch("a"),
            Err(ServeError::Quarantined { .. })
        ));
        store.prefetch(&["a".to_string()]);
        assert_eq!(store.stats().prefetch_issued, 0);
    }

    #[test]
    fn quarantine_ttl_reprobe_recovers_a_healthy_adapter() {
        use crate::coordinator::fault::FaultPlan;
        let mut rng = Rng::new(24);
        // TTL 0: the re-probe is allowed immediately after the trip.
        let mut store = resilient_store(0, None);
        store.add_shira(&shira(&mut rng, "a", 16, 10));
        store.set_fault(
            FaultPlan::new().corrupt_decode_at(1).corrupt_decode_at(2).injector(),
        );
        assert!(matches!(store.fetch("a"), Err(ServeError::Io(_))));
        assert!(matches!(
            store.fetch("a"),
            Err(ServeError::Quarantined { .. })
        ));
        // The fault plan is exhausted: the TTL-expired re-probe decodes
        // the (healthy) bytes and clears the streak.
        let h = store.fetch("a").expect("re-probe must recover");
        assert_eq!(h.adapter.name(), "a");
        assert!(!store.is_quarantined("a"));
        assert_eq!(store.stats().quarantines, 1);
    }

    #[test]
    fn prefetch_depth_bounds_submissions_not_names() {
        // Regression (fleet satellite): resident/staged names at the head
        // of the lookahead used to consume the depth budget, so a warm
        // head starved the cold tail of any prefetch at all.
        let mut rng = Rng::new(30);
        let pool = Arc::new(ThreadPool::new(2));
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 1 << 20,
                prefetch_depth: 2,
                ..StoreConfig::default()
            },
            Some(pool),
        );
        for name in ["warm0", "warm1", "cold0", "cold1", "cold2"] {
            store.add_shira(&shira(&mut rng, name, 16, 10));
        }
        store.fetch("warm0").unwrap();
        store.fetch("warm1").unwrap();
        let names: Vec<String> = ["warm0", "warm1", "cold0", "cold1", "cold2"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        store.prefetch(&names);
        // Two submissions land on the cold tail; the third cold name is
        // beyond the depth and the warm head burned nothing.
        assert_eq!(store.stats().prefetch_issued, 2);
        store.fetch("cold0").unwrap();
        store.fetch("cold1").unwrap();
        assert_eq!(store.stats().prefetch_hits, 2);
        // Re-prefetching the same list re-submits nothing for the now
        // resident names but still has budget for the last cold one.
        store.prefetch(&names);
        assert_eq!(store.stats().prefetch_issued, 3);
        store.fetch("cold2").unwrap();
        assert_eq!(store.stats().prefetch_hits, 3);
    }

    #[test]
    fn shared_store_decodes_each_adapter_once_across_replicas() {
        // Fleet dedupe regression: N replicas sharing one AdapterStore
        // behind a Mutex must decode each adapter once fleet-wide — the
        // staging table dedupes overlapping prefetch lookaheads and the
        // cache serves every later fetch.
        let mut rng = Rng::new(31);
        let pool = Arc::new(ThreadPool::new(4));
        let mut store = AdapterStore::with_config(
            StoreConfig {
                cache_bytes: 1 << 20,
                prefetch_depth: 4,
                ..StoreConfig::default()
            },
            Some(pool),
        );
        let names: Vec<String> = (0..4).map(|i| format!("ad{i}")).collect();
        for n in &names {
            store.add_shira(&shira(&mut rng, n, 16, 10));
        }
        let shared = Arc::new(Mutex::new(store));
        let n_replicas = 8;
        std::thread::scope(|s| {
            for _ in 0..n_replicas {
                let shared = Arc::clone(&shared);
                let names = names.clone();
                s.spawn(move || {
                    // Every replica prefetches the same lookahead, then
                    // fetches every adapter — the concurrent-fetch shape
                    // of a fleet serving one hot selection mix.
                    shared.lock().unwrap().prefetch(&names);
                    for n in &names {
                        shared.lock().unwrap().fetch(n).unwrap();
                    }
                });
            }
        });
        let store = shared.lock().unwrap();
        let stats = store.stats();
        // One decode per adapter fleet-wide: every background submission
        // is deduped by the staging table (at most one per name), and no
        // inline fetch re-decoded a staged or resident adapter.
        assert!(
            stats.prefetch_issued <= names.len() as u64,
            "staging dedupe failed: {} decode submissions for {} adapters",
            stats.prefetch_issued,
            names.len()
        );
        // Total decodes = prefetch submissions + inline decodes.  Inline
        // decodes happen only when a fetch misses both cache and staging:
        // misses counts those *plus* staged pickups, so subtract them.
        let inline_decodes = stats.misses - stats.prefetch_hits;
        assert_eq!(
            stats.prefetch_issued + inline_decodes,
            names.len() as u64,
            "each adapter decoded exactly once (stats: {stats:?})"
        );
        assert_eq!(
            stats.hits + stats.misses,
            (n_replicas * names.len()) as u64,
            "every fetch accounted for"
        );
    }
}
