//! Deterministic fault injection for the serving core (DESIGN.md §13).
//!
//! A [`FaultPlan`] names *which* events fail — "the 3rd flash read errors",
//! "the 2nd decode sees a flipped byte", "the 5th dispatch wave panics
//! mid-flight" — and a shared [`FaultInjector`] counts events at each hook
//! site and fires exactly at the planned ordinals.  Plans are either built
//! explicitly (one method per fault kind) or generated from a seed
//! ([`FaultPlan::seeded`]), so a chaos run is reproducible from a single
//! `u64` and every recovery path in the store, the engines, and the router
//! is property-testable.
//!
//! The injector is plain runtime state (not `cfg(test)`-gated) so
//! integration tests and the chaos suite can thread it through the public
//! builders; production simply never installs one, and every hook is a
//! no-op behind an `Option` that defaults to `None`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::rng::Rng;

/// A hook site where a planned fault can fire.  Each site keeps its own
/// event counter; ordinals in a [`FaultSpec`] are 1-based per site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// A flash read in `AdapterStore::fetch`/`prefetch` — fires as a
    /// transient I/O error (exercises retry-with-backoff).
    Fetch,
    /// An adapter decode — the encoded bytes get one byte flipped before
    /// decoding, so the CRC genuinely fails (exercises quarantine).
    Decode,
    /// An engine dispatch wave — one task panics mid-wave, leaving the
    /// resident weights partially mutated (exercises rollback).
    Wave,
    /// A flash read that completes but slowly (exercises latency paths;
    /// never an error).
    SlowFetch,
    /// A replica apply stalls and the replica is declared crashed before
    /// the mutation lands — fired by the fleet just before
    /// `Router::apply`, so the failure looks like a dead worker, not a
    /// torn mutation (exercises quarantine → probe → recover).
    Apply,
    /// A gate resolution of a `Selection::Auto` request fails — fired by
    /// the front end while rewriting autos into explicit sets, before
    /// any placement happens (exercises `FailurePolicy` degradation to
    /// base / skip; DESIGN.md §17.4).
    Gate,
}

const N_SITES: usize = 6;

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::Fetch => 0,
            FaultSite::Decode => 1,
            FaultSite::Wave => 2,
            FaultSite::SlowFetch => 3,
            FaultSite::Apply => 4,
            FaultSite::Gate => 5,
        }
    }

    /// Stable label for logs and test output.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Fetch => "fetch",
            FaultSite::Decode => "decode",
            FaultSite::Wave => "wave",
            FaultSite::SlowFetch => "slow-fetch",
            FaultSite::Apply => "apply",
            FaultSite::Gate => "gate",
        }
    }
}

/// One planned fault: fire at the `at`-th event (1-based) on `site`.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    /// Which hook site fails.
    pub site: FaultSite,
    /// 1-based event ordinal at that site.
    pub at: u64,
}

/// A reproducible set of planned faults plus injection parameters.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
    /// Per-replica crash plans: `(replica, nth_apply_on_that_replica)`,
    /// 1-based like [`FaultSpec::at`] but counted per replica so a plan
    /// can deterministically crash *every* replica regardless of how the
    /// scheduler spreads applies across them.
    replica_crashes: Vec<(usize, u64)>,
    /// Injected latency for [`FaultSite::SlowFetch`] hits, microseconds.
    pub slow_us: u64,
}

impl FaultPlan {
    /// Empty plan (no faults ever fire).
    pub fn new() -> Self {
        FaultPlan {
            specs: Vec::new(),
            replica_crashes: Vec::new(),
            slow_us: 200,
        }
    }

    /// A random plan: `n_faults` faults spread over the first `horizon`
    /// events of uniformly chosen sites.  Same seed, same plan.
    pub fn seeded(seed: u64, n_faults: usize, horizon: u64) -> Self {
        let mut rng = Rng::new(seed);
        // Deliberately the original five sites: adding `Gate` here would
        // shift every existing seeded chaos schedule.  Gate faults are
        // planned explicitly via [`FaultPlan::fail_gate_at`].
        let sites = [
            FaultSite::Fetch,
            FaultSite::Decode,
            FaultSite::Wave,
            FaultSite::SlowFetch,
            FaultSite::Apply,
        ];
        let mut plan = FaultPlan::new();
        for _ in 0..n_faults {
            let site = *rng.choose(&sites);
            let at = 1 + rng.next_u64() % horizon.max(1);
            plan.specs.push(FaultSpec { site, at });
        }
        plan
    }

    /// Plan a transient I/O error on the `n`-th flash read.
    pub fn fail_fetch_at(mut self, n: u64) -> Self {
        self.specs.push(FaultSpec { site: FaultSite::Fetch, at: n });
        self
    }

    /// Plan a one-byte corruption on the `n`-th decode.
    pub fn corrupt_decode_at(mut self, n: u64) -> Self {
        self.specs.push(FaultSpec { site: FaultSite::Decode, at: n });
        self
    }

    /// Plan a mid-wave panic on the `n`-th engine dispatch wave.
    pub fn panic_wave_at(mut self, n: u64) -> Self {
        self.specs.push(FaultSpec { site: FaultSite::Wave, at: n });
        self
    }

    /// Plan an injected latency stall on the `n`-th flash read.
    pub fn slow_fetch_at(mut self, n: u64) -> Self {
        self.specs.push(FaultSpec { site: FaultSite::SlowFetch, at: n });
        self
    }

    /// Plan a replica crash on the `n`-th apply *globally* (any replica).
    pub fn crash_apply_at(mut self, n: u64) -> Self {
        self.specs.push(FaultSpec { site: FaultSite::Apply, at: n });
        self
    }

    /// Plan a gate-resolution failure on the `n`-th auto request.
    pub fn fail_gate_at(mut self, n: u64) -> Self {
        self.specs.push(FaultSpec { site: FaultSite::Gate, at: n });
        self
    }

    /// Plan a replica crash on the `n`-th apply *on replica `replica`*
    /// (per-replica ordinal).  Global [`FaultSite::Apply`] ordinals
    /// cannot guarantee a specific replica faults — which one claims the
    /// n-th global apply depends on placement — so recovery tests that
    /// must quarantine every replica use this instead.
    pub fn crash_replica_at(mut self, replica: usize, n: u64) -> Self {
        self.replica_crashes.push((replica, n));
        self
    }

    /// Override the [`FaultSite::SlowFetch`] stall duration.
    pub fn slow_us(mut self, us: u64) -> Self {
        self.slow_us = us;
        self
    }

    /// Planned faults (site, ordinal) in insertion order.
    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }

    /// Freeze the plan into a shareable injector.
    pub fn injector(self) -> Arc<FaultInjector> {
        Arc::new(FaultInjector {
            plan: self,
            counts: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
            fired: AtomicU64::new(0),
            apply_counts: Mutex::new(Vec::new()),
        })
    }
}

/// Shared event counter that fires the faults a [`FaultPlan`] names.
/// Cloned (via `Arc`) into the store and both engines so ordinals count
/// global events, not per-component ones.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    counts: [AtomicU64; N_SITES],
    fired: AtomicU64,
    /// Per-replica apply ordinals for [`FaultPlan::crash_replica_at`],
    /// indexed by replica id (grown on demand).
    apply_counts: Mutex<Vec<u64>>,
}

impl FaultInjector {
    /// Count one event at `site`; true when the plan says this ordinal
    /// fails.  Thread-safe: ordinals are claimed atomically, so exactly
    /// one caller observes each planned fault.
    pub fn should_fire(&self, site: FaultSite) -> bool {
        let n = self.counts[site.index()].fetch_add(1, Ordering::SeqCst) + 1;
        let hit = self
            .plan
            .specs
            .iter()
            .any(|s| s.site == site && s.at == n);
        if hit {
            self.fired.fetch_add(1, Ordering::SeqCst);
        }
        hit
    }

    /// Count one apply on `replica`; true when either the global
    /// [`FaultSite::Apply`] plan or a per-replica crash plan says this
    /// apply dies.  The global site is counted on every call so seeded
    /// plans fire here with the same ordinal discipline as other sites.
    pub fn should_crash_apply(&self, replica: usize) -> bool {
        let global = self.should_fire(FaultSite::Apply);
        let per_replica = {
            let mut counts =
                self.apply_counts.lock().unwrap_or_else(|p| p.into_inner());
            if counts.len() <= replica {
                counts.resize(replica + 1, 0);
            }
            counts[replica] += 1;
            let n = counts[replica];
            self.plan
                .replica_crashes
                .iter()
                .any(|&(r, at)| r == replica && at == n)
        };
        if per_replica {
            self.fired.fetch_add(1, Ordering::SeqCst);
        }
        global || per_replica
    }

    /// Events counted so far at `site`.
    pub fn count(&self, site: FaultSite) -> u64 {
        self.counts[site.index()].load(Ordering::SeqCst)
    }

    /// Total faults that actually fired.
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::SeqCst)
    }

    /// Flip one byte of an encoded adapter image so its CRC check fails.
    /// Deterministic: always the middle byte, XORed with a fixed mask.
    pub fn corrupt(&self, bytes: &mut [u8]) {
        if let Some(b) = bytes.len().checked_sub(1).map(|n| n / 2) {
            bytes[b] ^= 0x5A;
        }
    }

    /// The configured slow-fetch stall, microseconds.
    pub fn slow_stall_us(&self) -> u64 {
        self.plan.slow_us
    }

    /// Panic message used by injected wave faults (tests match on it).
    pub const WAVE_PANIC_MSG: &'static str = "injected fault: wave panic";

    /// Error message used by injected apply crashes (tests match on it).
    pub const APPLY_CRASH_MSG: &'static str =
        "injected fault: replica apply crash";

    /// Error message used by injected gate faults (tests match on it).
    pub const GATE_FAULT_MSG: &'static str =
        "injected fault: gate resolution failure";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_exactly_at_planned_ordinals() {
        let inj = FaultPlan::new()
            .fail_fetch_at(3)
            .corrupt_decode_at(1)
            .injector();
        assert!(!inj.should_fire(FaultSite::Fetch)); // 1
        assert!(!inj.should_fire(FaultSite::Fetch)); // 2
        assert!(inj.should_fire(FaultSite::Fetch)); // 3 — planned
        assert!(!inj.should_fire(FaultSite::Fetch)); // 4
        assert!(inj.should_fire(FaultSite::Decode)); // 1 — planned
        assert!(!inj.should_fire(FaultSite::Decode)); // 2
        assert_eq!(inj.fired(), 2);
        assert_eq!(inj.count(FaultSite::Fetch), 4);
        assert_eq!(inj.count(FaultSite::Wave), 0);
    }

    #[test]
    fn sites_count_independently() {
        let inj = FaultPlan::new().panic_wave_at(2).injector();
        assert!(!inj.should_fire(FaultSite::Fetch));
        assert!(!inj.should_fire(FaultSite::Wave)); // wave 1
        assert!(!inj.should_fire(FaultSite::Fetch));
        assert!(inj.should_fire(FaultSite::Wave)); // wave 2 — planned
    }

    #[test]
    fn seeded_plans_are_reproducible() {
        let a = FaultPlan::seeded(42, 8, 20);
        let b = FaultPlan::seeded(42, 8, 20);
        let c = FaultPlan::seeded(43, 8, 20);
        let key = |p: &FaultPlan| {
            p.specs().iter().map(|s| (s.site.name(), s.at)).collect::<Vec<_>>()
        };
        assert_eq!(key(&a), key(&b));
        assert_ne!(key(&a), key(&c));
        assert_eq!(a.specs().len(), 8);
        assert!(a.specs().iter().all(|s| s.at >= 1 && s.at <= 20));
    }

    #[test]
    fn corruption_flips_one_byte_deterministically() {
        let inj = FaultPlan::new().injector();
        let orig: Vec<u8> = (0u8..64).collect();
        let mut a = orig.clone();
        let mut b = orig.clone();
        inj.corrupt(&mut a);
        inj.corrupt(&mut b);
        assert_eq!(a, b);
        let diffs: Vec<usize> =
            (0..64).filter(|&i| a[i] != orig[i]).collect();
        assert_eq!(diffs.len(), 1);
        inj.corrupt(&mut []); // empty image: no-op, no panic
    }

    #[test]
    fn per_replica_crash_plans_count_independently_of_global_ordinals() {
        let inj = FaultPlan::new()
            .crash_replica_at(1, 2)
            .crash_apply_at(5)
            .injector();
        // Replica 0 applies three times: never crashes (no plan for it,
        // and the global ordinal 5 is not reached yet).
        assert!(!inj.should_crash_apply(0)); // global 1, r0 #1
        assert!(!inj.should_crash_apply(0)); // global 2, r0 #2
        assert!(!inj.should_crash_apply(0)); // global 3, r0 #3
        // Replica 1's 2nd apply crashes per plan even though the global
        // ordinal (5) has not fired.
        assert!(!inj.should_crash_apply(1)); // global 4, r1 #1
        assert!(inj.should_crash_apply(1)); // global 5 fires AND r1 #2
        assert!(!inj.should_crash_apply(1)); // global 6, r1 #3
        assert_eq!(inj.count(FaultSite::Apply), 6);
        assert!(inj.fired() >= 1);
    }

    #[test]
    fn concurrent_ordinal_claims_fire_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let inj = FaultPlan::new().fail_fetch_at(50).injector();
        let hits = AtomicUsize::new(0);
        let pool = crate::util::threadpool::ThreadPool::new(4);
        pool.scoped_for(100, |_| {
            if inj.should_fire(FaultSite::Fetch) {
                hits.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        assert_eq!(inj.count(FaultSite::Fetch), 100);
    }
}
