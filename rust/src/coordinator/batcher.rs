//! Dynamic batching with selection affinity — the scheduling half of the
//! rapid-switching story.
//!
//! Requests are queued per [`Selection`] identity.  The scheduler picks
//! the next batch with an affinity-plus-aging policy: stay on the active
//! selection while it has work (switches are never free, even for
//! SHiRA), but never let another queue's head request age beyond
//! `max_wait` picks (starvation freedom, verified by property test).
//!
//! Queues key on [`Selection::key`] — the canonical identity — so the
//! affinity policy covers base, single-adapter and fused-set traffic
//! uniformly: two spellings of one set share a queue and never force a
//! transition, and a single adapter at two strengths batches separately
//! (they are different resident states).

use std::collections::{HashMap, VecDeque};

use super::selection::Selection;
use crate::data::trace::Request;

/// Tunables for [`DynamicBatcher`].
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per batch (the compiled artifact's batch dim).
    pub max_batch: usize,
    /// Aging bound: a queue whose head has waited this many scheduling
    /// rounds preempts affinity.
    pub max_wait_rounds: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait_rounds: 4,
        }
    }
}

struct Queue {
    /// The selection every request in this queue carries (one clone kept
    /// so `next_batch`/`upcoming` can hand selections back without
    /// re-parsing keys).
    selection: Selection,
    requests: VecDeque<Request>,
    /// Scheduling round when the current head arrived in the queue.
    head_since_round: u64,
}

/// Per-selection request queues with affinity-plus-aging batch selection.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    queues: HashMap<String, Queue>,
    round: u64,
    pending: usize,
}

impl DynamicBatcher {
    /// Empty batcher with the given tunables.
    pub fn new(cfg: BatcherConfig) -> Self {
        DynamicBatcher {
            cfg,
            queues: HashMap::new(),
            round: 0,
            pending: 0,
        }
    }

    /// Enqueue a request on its selection's queue.
    pub fn push(&mut self, req: Request) {
        let round = self.round;
        let key = req.selection.key();
        let q = self.queues.entry(key).or_insert_with(|| Queue {
            selection: req.selection.clone(),
            requests: VecDeque::new(),
            head_since_round: round,
        });
        if q.requests.is_empty() {
            q.head_since_round = round;
        }
        q.requests.push_back(req);
        self.pending += 1;
    }

    /// Requests enqueued but not yet batched.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// True when no requests are pending.
    pub fn is_empty(&self) -> bool {
        self.pending == 0
    }

    /// Drop every pending request (the server drains the batcher when a
    /// trace aborts mid-run, so a later trace cannot replay the failed
    /// one's tail).
    pub fn clear(&mut self) {
        self.queues.clear();
        self.pending = 0;
    }

    /// Pick the next (selection, batch).  `active` is the key of the
    /// selection currently resident on the weights (affinity target).
    ///
    /// Invariants (property-tested):
    /// * every batch is single-selection;
    /// * FIFO within a selection;
    /// * no queue head waits more than max_wait_rounds once other queues
    ///   are being served.
    pub fn next_batch(&mut self, active: Option<&str>) -> Option<(Selection, Vec<Request>)> {
        if self.pending == 0 {
            return None;
        }
        self.round += 1;
        // 1. starvation guard: oldest head beyond the aging bound wins.
        let mut starving: Option<(&String, u64)> = None;
        for (key, q) in &self.queues {
            if q.requests.is_empty() {
                continue;
            }
            let waited = self.round.saturating_sub(q.head_since_round);
            if waited >= self.cfg.max_wait_rounds {
                match starving {
                    Some((_, w)) if w >= waited => {}
                    _ => starving = Some((key, waited)),
                }
            }
        }
        let chosen: String = if let Some((key, _)) = starving {
            key.clone()
        } else if let Some(a) = active {
            // 2. affinity: stay on the active selection while it has work.
            if self.queues.get(a).map(|q| !q.requests.is_empty()).unwrap_or(false) {
                a.to_string()
            } else {
                self.longest_queue()?
            }
        } else {
            self.longest_queue()?
        };
        let q = self.queues.get_mut(&chosen).unwrap();
        let take = q.requests.len().min(self.cfg.max_batch);
        let batch: Vec<Request> = q.requests.drain(..take).collect();
        q.head_since_round = self.round;
        self.pending -= batch.len();
        Some((q.selection.clone(), batch))
    }

    /// Up to `k` selections likely to be scheduled soon, in scheduling
    /// priority order (aging first — a starving head preempts affinity —
    /// then queue length, then key for determinism), excluding every key
    /// in `exclude` — typically the selection the current batch is
    /// already switching to, plus (for transition-plan prefetch) the
    /// adapters whose pairwise plan is already resident, so the lookahead
    /// never re-suggests pairs the plan cache holds.  This is the store's
    /// prefetch lookahead: decoding these selections' adapters (and
    /// planning transitions to them) in the background turns upcoming
    /// cold misses into hits.
    pub fn upcoming(&self, k: usize, exclude: &[&str]) -> Vec<Selection> {
        let mut cands: Vec<(&str, &Selection, u64, usize)> = self
            .queues
            .iter()
            .filter(|(key, q)| {
                !q.requests.is_empty() && !exclude.contains(&key.as_str())
            })
            .map(|(key, q)| {
                (
                    key.as_str(),
                    &q.selection,
                    self.round.saturating_sub(q.head_since_round),
                    q.requests.len(),
                )
            })
            .collect();
        cands.sort_by(|a, b| b.2.cmp(&a.2).then(b.3.cmp(&a.3)).then(a.0.cmp(b.0)));
        cands
            .into_iter()
            .take(k)
            .map(|(_, sel, _, _)| sel.clone())
            .collect()
    }

    fn longest_queue(&self) -> Option<String> {
        self.queues
            .iter()
            .filter(|(_, q)| !q.requests.is_empty())
            .max_by_key(|(key, q)| (q.requests.len(), std::cmp::Reverse(key.as_str())))
            .map(|(key, _)| key.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    fn req(id: u64, spec: &str) -> Request {
        Request {
            id,
            selection: Selection::parse(spec).unwrap(),
            arrival_us: id,
            payload_seed: id,
        }
    }

    #[test]
    fn batches_are_single_selection_and_fifo() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 4,
            max_wait_rounds: 100,
        });
        for i in 0..10 {
            b.push(req(i, if i % 2 == 0 { "a" } else { "b" }));
        }
        let mut seen: HashMap<String, u64> = HashMap::new();
        while let Some((sel, batch)) = b.next_batch(None) {
            assert!(batch.len() <= 4);
            for r in &batch {
                assert_eq!(r.selection, sel);
                let key = sel.key();
                if let Some(&prev) = seen.get(&key) {
                    assert!(r.id > prev, "FIFO violated in {key}");
                }
                seen.insert(key, r.id);
            }
        }
        assert!(b.is_empty());
    }

    #[test]
    fn affinity_prefers_active_selection() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_wait_rounds: 100,
        });
        for i in 0..4 {
            b.push(req(i, "a"));
        }
        for i in 4..12 {
            b.push(req(i, "b")); // longer queue
        }
        let (sel, _) = b.next_batch(Some("a")).unwrap();
        assert_eq!(sel.key(), "a"); // affinity beats queue length
        let (sel, _) = b.next_batch(Some("a")).unwrap();
        assert_eq!(sel.key(), "a");
        let (sel, _) = b.next_batch(Some("a")).unwrap();
        assert_eq!(sel.key(), "b"); // a drained
    }

    #[test]
    fn aging_preempts_affinity() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 1,
            max_wait_rounds: 3,
        });
        for i in 0..10 {
            b.push(req(i, "hot"));
        }
        b.push(req(100, "cold"));
        let mut served_cold_at = None;
        for round in 0..8 {
            let (sel, _) = b.next_batch(Some("hot")).unwrap();
            if sel.key() == "cold" {
                served_cold_at = Some(round);
                break;
            }
        }
        assert!(
            served_cold_at.is_some() && served_cold_at.unwrap() <= 4,
            "cold starved: {served_cold_at:?}"
        );
    }

    #[test]
    fn affinity_extends_to_selection_identity() {
        // Mixed base / single / set traffic: base requests get their own
        // queue (empty key), two spellings of one set share a queue, and
        // affinity prefers the resident set exactly like a single.
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_wait_rounds: 100,
        });
        for i in 0..2 {
            b.push(req(i, "b+a")); // canonicalizes with "a+b@1"
        }
        for i in 2..4 {
            b.push(req(i, "a@1+b"));
        }
        for i in 4..10 {
            b.push(req(i, "c")); // longer queue
        }
        b.push(req(10, ""));
        let set_key = Selection::parse("b+a").unwrap().key();
        let (sel, batch) = b.next_batch(Some(&set_key)).unwrap();
        assert_eq!(sel.key(), set_key); // set affinity beats queue length
        assert_eq!(batch.len(), 2);
        let (sel, _) = b.next_batch(Some(&set_key)).unwrap();
        assert_eq!(sel.key(), set_key); // both spellings shared the queue
        let (sel, _) = b.next_batch(Some(&set_key)).unwrap();
        assert_eq!(sel.key(), "c"); // the fused set drained
        // base requests are schedulable like any other selection
        while let Some((sel, _)) = b.next_batch(None) {
            if sel == Selection::Base {
                return;
            }
        }
        panic!("base request never scheduled");
    }

    #[test]
    fn upcoming_orders_by_priority_and_excludes_active() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_wait_rounds: 100,
        });
        for i in 0..2 {
            b.push(req(i, "a"));
        }
        for i in 2..8 {
            b.push(req(i, "b"));
        }
        for i in 8..12 {
            b.push(req(i, "c"));
        }
        let keys = |v: Vec<Selection>| -> Vec<String> { v.iter().map(|s| s.key()).collect() };
        // No aging yet: longest queue first, active excluded.
        assert_eq!(keys(b.upcoming(2, &["b"])), vec!["c", "a"]);
        assert_eq!(keys(b.upcoming(10, &[])), vec!["b", "c", "a"]);
        assert!(b.upcoming(0, &[]).is_empty());
        // A multi-key exclusion set (the transition-plan prefetch case:
        // active selection + already-planned pairs) filters them all.
        assert_eq!(keys(b.upcoming(10, &["b", "c"])), vec!["a"]);
        assert!(b.upcoming(10, &["a", "b", "c"]).is_empty());
        // Serve "b" for a while: the waiting queues age ahead of it.
        for _ in 0..3 {
            let (sel, _) = b.next_batch(Some("b")).unwrap();
            assert_eq!(sel.key(), "b");
        }
        let ahead = keys(b.upcoming(3, &["b"]));
        assert_eq!(ahead.len(), 2);
        assert!(ahead.contains(&"a".to_string()) && ahead.contains(&"c".to_string()));
        // Drained queues disappear from the lookahead.
        while b.next_batch(None).is_some() {}
        assert!(b.upcoming(4, &[]).is_empty());
    }

    #[test]
    fn empty_batcher_returns_none() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        assert!(b.next_batch(None).is_none());
        assert!(b.next_batch(Some("x")).is_none());
    }

    #[test]
    fn prop_all_requests_served_exactly_once() {
        pt::forall(
            13,
            30,
            |r: &mut Rng| {
                let n = 1 + r.below(60);
                (0..n as u64)
                    .map(|i| (i, r.below(4)))
                    .collect::<Vec<(u64, usize)>>()
            },
            |reqs| {
                let mut b = DynamicBatcher::new(BatcherConfig {
                    max_batch: 3,
                    max_wait_rounds: 2,
                });
                for &(id, a) in reqs {
                    b.push(req(id, &format!("a{a}")));
                }
                let mut served = Vec::new();
                let mut active: Option<String> = None;
                let mut guard = 0;
                while let Some((sel, batch)) = b.next_batch(active.as_deref()) {
                    served.extend(batch.iter().map(|r| r.id));
                    active = Some(sel.key());
                    guard += 1;
                    if guard > 500 {
                        return false;
                    }
                }
                let mut ids: Vec<u64> = served;
                ids.sort_unstable();
                ids == reqs.iter().map(|&(id, _)| id).collect::<Vec<_>>()
            },
        );
    }

    #[test]
    fn prop_no_head_waits_past_bound_plus_slack() {
        // Once scheduling begins, a nonempty queue's head is served within
        // max_wait_rounds + (number of selections) rounds.
        pt::forall(
            17,
            20,
            |r: &mut Rng| (0..40u64).map(|i| (i, r.below(3))).collect::<Vec<_>>(),
            |reqs| {
                let max_wait = 3u64;
                let mut b = DynamicBatcher::new(BatcherConfig {
                    max_batch: 2,
                    max_wait_rounds: max_wait,
                });
                for &(id, a) in reqs {
                    b.push(req(id, &format!("a{a}")));
                }
                let mut active: Option<String> = None;
                let mut rounds_since: HashMap<String, u64> = HashMap::new();
                while let Some((sel, _batch)) = b.next_batch(active.as_deref()) {
                    let key = sel.key();
                    for (k, v) in rounds_since.iter_mut() {
                        if k != &key {
                            *v += 1;
                        }
                    }
                    rounds_since.insert(key.clone(), 0);
                    active = Some(key);
                    // drop drained queues from the wait ledger
                    rounds_since.retain(|k, _| {
                        b.queues
                            .get(k)
                            .map(|q| !q.requests.is_empty())
                            .unwrap_or(false)
                    });
                    // no other nonempty queue may exceed the bound + slack
                    if rounds_since.values().any(|&v| v > max_wait + 4) {
                        return false;
                    }
                }
                true
            },
        );
    }
}
