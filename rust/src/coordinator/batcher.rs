//! Dynamic batching with adapter affinity — the scheduling half of the
//! rapid-switching story.
//!
//! Requests are queued per adapter.  The scheduler picks the next batch
//! with an affinity-plus-aging policy: stay on the active adapter while it
//! has work (switches are never free, even for SHiRA), but never let
//! another adapter's head request age beyond `max_wait` picks (starvation
//! freedom, verified by property test).
//!
//! The batcher keys queues by the request's adapter *string*, so the
//! affinity policy extends unchanged to fused-mode serving: the server
//! canonicalizes adapter-set specs
//! ([`SetSpec::id`](super::fusion_engine::SetSpec::id)) before pushing,
//! and affinity then keeps consecutive batches on the currently-fused
//! *set* — two spellings of one set never force a transition.

use std::collections::{HashMap, VecDeque};

use crate::data::trace::Request;

/// Tunables for [`DynamicBatcher`].
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per batch (the compiled artifact's batch dim).
    pub max_batch: usize,
    /// Aging bound: a queue whose head has waited this many scheduling
    /// rounds preempts affinity.
    pub max_wait_rounds: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait_rounds: 4,
        }
    }
}

struct Queue {
    requests: VecDeque<Request>,
    /// Scheduling round when the current head arrived in the queue.
    head_since_round: u64,
}

/// Per-adapter request queues with affinity-plus-aging batch selection.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    queues: HashMap<String, Queue>,
    round: u64,
    pending: usize,
}

impl DynamicBatcher {
    /// Empty batcher with the given tunables.
    pub fn new(cfg: BatcherConfig) -> Self {
        DynamicBatcher {
            cfg,
            queues: HashMap::new(),
            round: 0,
            pending: 0,
        }
    }

    /// Enqueue a request on its adapter's queue.
    pub fn push(&mut self, req: Request) {
        let round = self.round;
        let q = self
            .queues
            .entry(req.adapter.clone())
            .or_insert_with(|| Queue {
                requests: VecDeque::new(),
                head_since_round: round,
            });
        if q.requests.is_empty() {
            q.head_since_round = round;
        }
        q.requests.push_back(req);
        self.pending += 1;
    }

    /// Requests enqueued but not yet batched.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// True when no requests are pending.
    pub fn is_empty(&self) -> bool {
        self.pending == 0
    }

    /// Pick the next (adapter, batch).  `active` is the adapter currently
    /// applied to the weights (affinity target).
    ///
    /// Invariants (property-tested):
    /// * every batch is single-adapter;
    /// * FIFO within an adapter;
    /// * no queue head waits more than max_wait_rounds once other queues
    ///   are being served.
    pub fn next_batch(&mut self, active: Option<&str>) -> Option<(String, Vec<Request>)> {
        if self.pending == 0 {
            return None;
        }
        self.round += 1;
        // 1. starvation guard: oldest head beyond the aging bound wins.
        let mut starving: Option<(&String, u64)> = None;
        for (name, q) in &self.queues {
            if q.requests.is_empty() {
                continue;
            }
            let waited = self.round.saturating_sub(q.head_since_round);
            if waited >= self.cfg.max_wait_rounds {
                match starving {
                    Some((_, w)) if w >= waited => {}
                    _ => starving = Some((name, waited)),
                }
            }
        }
        let chosen: String = if let Some((name, _)) = starving {
            name.clone()
        } else if let Some(a) = active {
            // 2. affinity: stay on the active adapter while it has work.
            if self.queues.get(a).map(|q| !q.requests.is_empty()).unwrap_or(false) {
                a.to_string()
            } else {
                self.longest_queue()?
            }
        } else {
            self.longest_queue()?
        };
        let q = self.queues.get_mut(&chosen).unwrap();
        let take = q.requests.len().min(self.cfg.max_batch);
        let batch: Vec<Request> = q.requests.drain(..take).collect();
        q.head_since_round = self.round;
        self.pending -= batch.len();
        Some((chosen, batch))
    }

    /// Up to `k` adapters likely to be scheduled soon, in scheduling
    /// priority order (aging first — a starving head preempts affinity —
    /// then queue length, then name for determinism), excluding every name
    /// in `exclude` — typically the adapter the current batch is already
    /// switching to, plus (for transition-plan prefetch) the adapters
    /// whose pairwise plan is already resident, so the lookahead never
    /// re-suggests pairs the plan cache holds.  This is the store's
    /// prefetch lookahead: decoding these (and planning transitions to
    /// them) in the background turns upcoming cold misses into hits.
    pub fn upcoming(&self, k: usize, exclude: &[&str]) -> Vec<String> {
        let mut cands: Vec<(&str, u64, usize)> = self
            .queues
            .iter()
            .filter(|(name, q)| {
                !q.requests.is_empty() && !exclude.contains(&name.as_str())
            })
            .map(|(name, q)| {
                (
                    name.as_str(),
                    self.round.saturating_sub(q.head_since_round),
                    q.requests.len(),
                )
            })
            .collect();
        cands.sort_by(|a, b| b.1.cmp(&a.1).then(b.2.cmp(&a.2)).then(a.0.cmp(b.0)));
        cands.into_iter().take(k).map(|(n, _, _)| n.to_string()).collect()
    }

    fn longest_queue(&self) -> Option<String> {
        self.queues
            .iter()
            .filter(|(_, q)| !q.requests.is_empty())
            .max_by_key(|(name, q)| (q.requests.len(), std::cmp::Reverse(name.as_str())))
            .map(|(name, _)| name.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;
    use crate::util::rng::Rng;

    fn req(id: u64, adapter: &str) -> Request {
        Request {
            id,
            adapter: adapter.to_string(),
            arrival_us: id,
            payload_seed: id,
        }
    }

    #[test]
    fn batches_are_single_adapter_and_fifo() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 4,
            max_wait_rounds: 100,
        });
        for i in 0..10 {
            b.push(req(i, if i % 2 == 0 { "a" } else { "b" }));
        }
        let mut seen: HashMap<String, u64> = HashMap::new();
        while let Some((name, batch)) = b.next_batch(None) {
            assert!(batch.len() <= 4);
            for r in &batch {
                assert_eq!(r.adapter, name);
                if let Some(&prev) = seen.get(&name) {
                    assert!(r.id > prev, "FIFO violated in {name}");
                }
                seen.insert(name.clone(), r.id);
            }
        }
        assert!(b.is_empty());
    }

    #[test]
    fn affinity_prefers_active_adapter() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_wait_rounds: 100,
        });
        for i in 0..4 {
            b.push(req(i, "a"));
        }
        for i in 4..12 {
            b.push(req(i, "b")); // longer queue
        }
        let (name, _) = b.next_batch(Some("a")).unwrap();
        assert_eq!(name, "a"); // affinity beats queue length
        let (name, _) = b.next_batch(Some("a")).unwrap();
        assert_eq!(name, "a");
        let (name, _) = b.next_batch(Some("a")).unwrap();
        assert_eq!(name, "b"); // a drained
    }

    #[test]
    fn aging_preempts_affinity() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 1,
            max_wait_rounds: 3,
        });
        for i in 0..10 {
            b.push(req(i, "hot"));
        }
        b.push(req(100, "cold"));
        let mut served_cold_at = None;
        for round in 0..8 {
            let (name, _) = b.next_batch(Some("hot")).unwrap();
            if name == "cold" {
                served_cold_at = Some(round);
                break;
            }
        }
        assert!(
            served_cold_at.is_some() && served_cold_at.unwrap() <= 4,
            "cold starved: {served_cold_at:?}"
        );
    }

    #[test]
    fn affinity_extends_to_set_identity() {
        // Fused-mode serving pushes canonical set ids as the adapter key;
        // affinity then prefers the currently-fused set exactly like a
        // single adapter.
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_wait_rounds: 100,
        });
        for i in 0..4 {
            b.push(req(i, "a@1+b@0.5"));
        }
        for i in 4..10 {
            b.push(req(i, "b@1+c@1")); // longer queue
        }
        let (name, _) = b.next_batch(Some("a@1+b@0.5")).unwrap();
        assert_eq!(name, "a@1+b@0.5"); // set affinity beats queue length
        let (name, _) = b.next_batch(Some("a@1+b@0.5")).unwrap();
        assert_eq!(name, "a@1+b@0.5");
        let (name, _) = b.next_batch(Some("a@1+b@0.5")).unwrap();
        assert_eq!(name, "b@1+c@1"); // the fused set drained
    }

    #[test]
    fn upcoming_orders_by_priority_and_excludes_active() {
        let mut b = DynamicBatcher::new(BatcherConfig {
            max_batch: 2,
            max_wait_rounds: 100,
        });
        for i in 0..2 {
            b.push(req(i, "a"));
        }
        for i in 2..8 {
            b.push(req(i, "b"));
        }
        for i in 8..12 {
            b.push(req(i, "c"));
        }
        // No aging yet: longest queue first, active excluded.
        assert_eq!(b.upcoming(2, &["b"]), vec!["c", "a"]);
        assert_eq!(b.upcoming(10, &[]), vec!["b", "c", "a"]);
        assert_eq!(b.upcoming(0, &[]), Vec::<String>::new());
        // A multi-name exclusion set (the transition-plan prefetch case:
        // active adapter + already-planned pairs) filters them all.
        assert_eq!(b.upcoming(10, &["b", "c"]), vec!["a"]);
        assert!(b.upcoming(10, &["a", "b", "c"]).is_empty());
        // Serve "b" for a while: the waiting queues age ahead of it.
        for _ in 0..3 {
            let (name, _) = b.next_batch(Some("b")).unwrap();
            assert_eq!(name, "b");
        }
        let ahead = b.upcoming(3, &["b"]);
        assert_eq!(ahead.len(), 2);
        assert!(ahead.contains(&"a".to_string()) && ahead.contains(&"c".to_string()));
        // Drained queues disappear from the lookahead.
        while b.next_batch(None).is_some() {}
        assert!(b.upcoming(4, &[]).is_empty());
    }

    #[test]
    fn empty_batcher_returns_none() {
        let mut b = DynamicBatcher::new(BatcherConfig::default());
        assert!(b.next_batch(None).is_none());
        assert!(b.next_batch(Some("x")).is_none());
    }

    #[test]
    fn prop_all_requests_served_exactly_once() {
        pt::forall(
            13,
            30,
            |r: &mut Rng| {
                let n = 1 + r.below(60);
                (0..n as u64)
                    .map(|i| (i, r.below(4)))
                    .collect::<Vec<(u64, usize)>>()
            },
            |reqs| {
                let mut b = DynamicBatcher::new(BatcherConfig {
                    max_batch: 3,
                    max_wait_rounds: 2,
                });
                for &(id, a) in reqs {
                    b.push(req(id, &format!("a{a}")));
                }
                let mut served = Vec::new();
                let mut active: Option<String> = None;
                let mut guard = 0;
                while let Some((name, batch)) = b.next_batch(active.as_deref()) {
                    served.extend(batch.iter().map(|r| r.id));
                    active = Some(name);
                    guard += 1;
                    if guard > 500 {
                        return false;
                    }
                }
                let mut ids: Vec<u64> = served;
                ids.sort_unstable();
                ids == reqs.iter().map(|&(id, _)| id).collect::<Vec<_>>()
            },
        );
    }

    #[test]
    fn prop_no_head_waits_past_bound_plus_slack() {
        // Once scheduling begins, a nonempty queue's head is served within
        // max_wait_rounds + (number of adapters) rounds.
        pt::forall(
            17,
            20,
            |r: &mut Rng| (0..40u64).map(|i| (i, r.below(3))).collect::<Vec<_>>(),
            |reqs| {
                let max_wait = 3u64;
                let mut b = DynamicBatcher::new(BatcherConfig {
                    max_batch: 2,
                    max_wait_rounds: max_wait,
                });
                for &(id, a) in reqs {
                    b.push(req(id, &format!("a{a}")));
                }
                let mut active: Option<String> = None;
                let mut rounds_since: HashMap<String, u64> = HashMap::new();
                while let Some((name, _batch)) = b.next_batch(active.as_deref()) {
                    for (k, v) in rounds_since.iter_mut() {
                        if k != &name {
                            *v += 1;
                        }
                    }
                    rounds_since.insert(name.clone(), 0);
                    active = Some(name);
                    // drop drained queues from the wait ledger
                    rounds_since.retain(|k, _| {
                        b.queues
                            .get(k)
                            .map(|q| !q.requests.is_empty())
                            .unwrap_or(false)
                    });
                    // no other nonempty queue may exceed the bound + slack
                    if rounds_since.values().any(|&v| v > max_wait + 4) {
                        return false;
                    }
                }
                true
            },
        );
    }
}
