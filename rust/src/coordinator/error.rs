//! Structured serving errors: the one failure taxonomy of the public
//! coordinator API.
//!
//! Every fallible coordinator call ([`Server`](super::server::Server),
//! [`Router`](super::engine::Router),
//! [`AdapterStore`](super::store::AdapterStore), the
//! [`AdapterEngine`](super::engine::AdapterEngine) trait) returns
//! [`ServeError`] so callers can *branch on the variant* — retry on a
//! transient [`ServeError::Runtime`], surface an
//! [`ServeError::UnknownAdapter`] as HTTP 404, reject an
//! [`ServeError::InvalidSelection`] as 400 — instead of string-matching
//! an opaque `anyhow` chain, which is what the coordinator exposed
//! before this redesign.

use crate::adapter::io::IoError;
use super::fusion::FusionError;

/// Why a serving operation failed.  See the module docs for the intent;
/// DESIGN.md §12.4 maps variants to the requests that produce them.
#[derive(Debug)]
pub enum ServeError {
    /// A selection named an adapter the store has never seen.
    UnknownAdapter(String),
    /// The manifest has no model under this name.
    UnknownModel(String),
    /// A fused-set member (or fusion-roster candidate) is not a SHiRA
    /// adapter — only sparse adapters compose in fused mode.
    NotShira(String),
    /// Two shapes that must agree (an adapter delta and the resident
    /// tensor, or two set members' deltas) do not.
    ShapeMismatch {
        /// Target tensor name.
        target: String,
        /// (rows, cols) the reference side carries.
        expect: (usize, usize),
        /// (rows, cols) the mismatching side carries.
        got: (usize, usize),
    },
    /// A selection spec failed to parse, or a hand-built [`Selection`]
    /// violated its invariants (metacharacters, non-finite weights,
    /// empty sets).
    ///
    /// [`Selection`]: super::selection::Selection
    InvalidSelection {
        /// The offending spec (canonical form for hand-built selections).
        spec: String,
        /// What was wrong with it.
        reason: String,
    },
    /// The same adapter appears more than once in a set or roster.
    DuplicateMember(String),
    /// Flash bytes failed to decode (corruption, bad magic, checksum).
    Io(IoError),
    /// A fusion-engine failure not covered by a more specific variant
    /// (mismatched target sets, inactive engine, oversized roster).
    Fusion(FusionError),
    /// The adapter is quarantined: it failed decode/CRC too many times in
    /// a row and the store refuses to serve it until the re-probe TTL
    /// expires (DESIGN.md §13.3).
    Quarantined {
        /// The quarantined adapter.
        name: String,
        /// Consecutive failures that tripped the quarantine.
        failures: u32,
        /// Milliseconds until the store re-probes the adapter.
        retry_in_ms: u64,
    },
    /// A weight mutation failed mid-flight (a pool wave panicked or an
    /// engine errored after dispatch) and the transactional guard rolled
    /// the resident weights back to base bit-exactly.  The router is
    /// serving base and stays serviceable (DESIGN.md §13.1).
    MutationRolledBack {
        /// What the router was applying when the fault hit.
        selection: String,
        /// First panic/error message captured from the failed wave.
        cause: String,
    },
    /// Fleet admission control shed the request: every eligible replica's
    /// bounded queue was full (or every replica was quarantined), and the
    /// fleet's failure policy was `FailFast` (`coordinator::fleet`;
    /// DESIGN.md §14.3).  Under the other policies shedding degrades or
    /// skips instead of surfacing this.
    Overloaded {
        /// Canonical key of the selection that was shed.
        selection: String,
        /// Worker replicas in the fleet.
        replicas: usize,
        /// Per-replica queue bound that was exhausted.
        queue_depth: usize,
    },
    /// The request's end-to-end deadline elapsed before any replica
    /// served it: admission and failover kept re-dispatching (bounded by
    /// the per-request retry budget) but the deadline ran out first
    /// (`coordinator::fleet`; DESIGN.md §16.3).  Requests that end here
    /// are accounted, not silently lost.
    DeadlineExceeded {
        /// Canonical key of the selection whose request timed out.
        selection: String,
        /// Configured end-to-end deadline, microseconds.
        deadline_us: u64,
        /// How long the request had waited when it was declared dead.
        waited_us: u64,
        /// Re-dispatch attempts it consumed before timing out.
        attempts: u32,
    },
    /// Gate resolution of a [`Selection::Auto`] request failed: no gate
    /// is configured, the expert pool has no active expert the gate can
    /// score, or an injected gate fault fired
    /// (`coordinator::gate`; DESIGN.md §17).  Under
    /// `FailurePolicy::DegradeToBase`/`SkipRequest` the front end
    /// degrades or skips instead of surfacing this.
    ///
    /// [`Selection::Auto`]: super::selection::Selection::Auto
    Gate {
        /// What went wrong resolving the selection.
        reason: String,
    },
    /// The PJRT runtime failed (artifact missing, compile or execute
    /// error).  Stringly: runtime errors originate outside the
    /// coordinator and carry no stable structure.
    Runtime(String),
}

impl ServeError {
    /// Wrap a runtime-layer error (anything `Display`) as
    /// [`ServeError::Runtime`].
    pub fn runtime(e: impl std::fmt::Display) -> ServeError {
        ServeError::Runtime(e.to_string())
    }

    /// Short stable label of the variant (for logs and counters).
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::UnknownAdapter(_) => "unknown-adapter",
            ServeError::UnknownModel(_) => "unknown-model",
            ServeError::NotShira(_) => "not-shira",
            ServeError::ShapeMismatch { .. } => "shape-mismatch",
            ServeError::InvalidSelection { .. } => "invalid-selection",
            ServeError::DuplicateMember(_) => "duplicate-member",
            ServeError::Io(_) => "io",
            ServeError::Fusion(_) => "fusion",
            ServeError::Quarantined { .. } => "quarantined",
            ServeError::MutationRolledBack { .. } => "mutation-rolled-back",
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::DeadlineExceeded { .. } => "deadline-exceeded",
            ServeError::Gate { .. } => "gate",
            ServeError::Runtime(_) => "runtime",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownAdapter(n) => write!(f, "unknown adapter {n:?}"),
            ServeError::UnknownModel(n) => write!(f, "unknown model {n:?}"),
            ServeError::NotShira(n) => {
                write!(f, "adapter {n:?} is not a SHiRA adapter (fused sets are SHiRA-only)")
            }
            ServeError::ShapeMismatch { target, expect, got } => write!(
                f,
                "target {target:?}: adapter shape {got:?} does not match resident {expect:?}"
            ),
            ServeError::InvalidSelection { spec, reason } => {
                write!(f, "invalid selection {spec:?}: {reason}")
            }
            ServeError::DuplicateMember(n) => {
                write!(f, "adapter {n:?} appears more than once")
            }
            ServeError::Io(e) => write!(f, "{e}"),
            ServeError::Fusion(e) => write!(f, "{e}"),
            ServeError::Quarantined { name, failures, retry_in_ms } => write!(
                f,
                "adapter {name:?} quarantined after {failures} consecutive \
                 failures (re-probe in {retry_in_ms}ms)"
            ),
            ServeError::MutationRolledBack { selection, cause } => write!(
                f,
                "mutation for {selection:?} failed and was rolled back to \
                 base weights: {cause}"
            ),
            ServeError::Overloaded { selection, replicas, queue_depth } => write!(
                f,
                "fleet overloaded: request for {selection:?} shed — all \
                 {replicas} replica queue(s) full (depth {queue_depth})"
            ),
            ServeError::DeadlineExceeded { selection, deadline_us, waited_us, attempts } => {
                write!(
                    f,
                    "request for {selection:?} exceeded its {deadline_us}us \
                     deadline (waited {waited_us}us, {attempts} re-dispatch \
                     attempt(s))"
                )
            }
            ServeError::Gate { reason } => {
                write!(f, "gate resolution failed: {reason}")
            }
            ServeError::Runtime(m) => write!(f, "runtime: {m}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::Io(e) => Some(e),
            ServeError::Fusion(e) => Some(e),
            _ => None,
        }
    }
}

impl From<IoError> for ServeError {
    fn from(e: IoError) -> Self {
        ServeError::Io(e)
    }
}

impl From<FusionError> for ServeError {
    /// Fusion failures with a direct serving meaning map onto the
    /// matching [`ServeError`] variant; the rest ride along as
    /// [`ServeError::Fusion`].
    fn from(e: FusionError) -> Self {
        match e {
            FusionError::ShapeMismatch { target, expect, got } => {
                ServeError::ShapeMismatch { target, expect, got }
            }
            FusionError::DuplicateMember(n) => ServeError::DuplicateMember(n),
            FusionError::UnknownMember(n) => ServeError::UnknownAdapter(n),
            other => ServeError::Fusion(other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_specific() {
        let e = ServeError::UnknownAdapter("ghost".into());
        assert!(e.to_string().contains("ghost"));
        assert_eq!(e.kind(), "unknown-adapter");
        let e = ServeError::ShapeMismatch {
            target: "wq".into(),
            expect: (4, 4),
            got: (2, 2),
        };
        assert!(e.to_string().contains("wq"));
        assert_eq!(e.kind(), "shape-mismatch");
    }

    #[test]
    fn fusion_errors_map_to_matching_variants() {
        assert!(matches!(
            ServeError::from(FusionError::UnknownMember("x".into())),
            ServeError::UnknownAdapter(n) if n == "x"
        ));
        assert!(matches!(
            ServeError::from(FusionError::DuplicateMember("x".into())),
            ServeError::DuplicateMember(_)
        ));
        assert!(matches!(
            ServeError::from(FusionError::ShapeMismatch {
                target: "w".into(),
                expect: (1, 1),
                got: (2, 2)
            }),
            ServeError::ShapeMismatch { .. }
        ));
        assert!(matches!(
            ServeError::from(FusionError::NotActive),
            ServeError::Fusion(FusionError::NotActive)
        ));
    }

    #[test]
    fn robustness_variants_have_stable_kinds() {
        let q = ServeError::Quarantined {
            name: "bad".into(),
            failures: 3,
            retry_in_ms: 250,
        };
        assert_eq!(q.kind(), "quarantined");
        assert!(q.to_string().contains("bad"));
        assert!(q.to_string().contains("3 consecutive"));
        let r = ServeError::MutationRolledBack {
            selection: "a+b@2".into(),
            cause: "injected fault: wave panic".into(),
        };
        assert_eq!(r.kind(), "mutation-rolled-back");
        assert!(r.to_string().contains("a+b@2"));
        assert!(r.to_string().contains("wave panic"));
        let o = ServeError::Overloaded {
            selection: "hot@1".into(),
            replicas: 4,
            queue_depth: 8,
        };
        assert_eq!(o.kind(), "overloaded");
        assert!(o.to_string().contains("hot@1"));
        assert!(o.to_string().contains("4 replica"));
        let d = ServeError::DeadlineExceeded {
            selection: "slow@1".into(),
            deadline_us: 5_000,
            waited_us: 7_250,
            attempts: 3,
        };
        assert_eq!(d.kind(), "deadline-exceeded");
        assert!(d.to_string().contains("slow@1"));
        assert!(d.to_string().contains("5000us"));
        assert!(d.to_string().contains("7250us"));
        let g = ServeError::Gate {
            reason: "no active expert to gate over".into(),
        };
        assert_eq!(g.kind(), "gate");
        assert!(g.to_string().contains("no active expert"));
    }

    #[test]
    fn io_errors_wrap_with_source() {
        use std::error::Error;
        let e = ServeError::from(IoError::Format("bad magic".into()));
        assert!(e.source().is_some());
        assert!(e.to_string().contains("bad magic"));
    }
}
