//! Learned top-k gating over the expert pool (DESIGN.md §17): resolves
//! [`Selection::Auto`] requests into weighted [`Selection::Set`]s that
//! ride the existing fused-mode machinery unchanged.
//!
//! SHiRA's serving claim is that highly sparse adapters merge with
//! little concept loss, which makes *serving a merged set chosen per
//! request* viable — but a request had to name its set explicitly.
//! Following SiRA (arXiv 2311.09179) and sparse-expert merging work
//! (arXiv 2507.07140), the missing piece is a small learned gate: score
//! the expert pool on per-request features, keep the top-k experts,
//! softmax their scores into fusion weights, and emit the set.  The
//! emitted `Set` flows through the [`Router`](super::engine::Router) /
//! fusion engine exactly like a hand-written one, so every bit-identity
//! and rollback guarantee downstream applies to gated traffic for free.
//!
//! ## Determinism
//!
//! A [`Gate`] is a *pure function* of `(gate parameters, features,
//! roster)` — no clocks, no global RNG, no interior mutability.  The
//! serving front ends resolve every `Auto` request **up front**, before
//! any placement or batching decision, so:
//!
//! * the same `(trace, gate)` pair resolves to the same explicit trace
//!   on every replay, at any thread or replica count;
//! * a gated trace is *indistinguishable* downstream from the same trace
//!   with the emitted sets spelled explicitly — the acceptance
//!   bit-identity criterion reduces to ordinary fleet determinism.
//!
//! Per-request features are derived from [`Rng`] streams keyed by the
//! request's `payload_seed`, mirroring how payload tokens are drawn at
//! execute time — deterministic per request, varied across requests.

use super::error::ServeError;
use super::selection::Selection;
use crate::util::rng::Rng;

/// Number of gate input features: one occupancy bin per synthetic task
/// dialect ([`crate::data::tasks`], 8 families) plus one bin for tokens
/// outside every dialect (PAD/control/unused vocab).
pub const N_FEATURES: usize = 9;

/// First token of the task-dialect region (mirrors `data::tasks`).
const DIALECT_BASE: i32 = 16;
/// Tokens per task dialect (mirrors `data::tasks`).
const DIALECT_SIZE: i32 = 28;
/// Task families covered by the dialect region.
const N_DIALECTS: usize = 8;
/// Pseudo-token window length used for per-request features.
const REQUEST_WINDOW: usize = 32;

/// Histogram a token window into the gate's feature vector: per-dialect
/// occupancy fractions plus an "other" bin, normalized to sum to 1 (all
/// zeros for an empty window).  Shared by training (real task examples)
/// and serving (per-request pseudo-token windows), so the gate sees one
/// feature space end to end.
pub fn features_from_tokens(tokens: &[i32]) -> [f32; N_FEATURES] {
    let mut f = [0.0f32; N_FEATURES];
    if tokens.is_empty() {
        return f;
    }
    for &t in tokens {
        let d = (t - DIALECT_BASE).div_euclid(DIALECT_SIZE);
        if t >= DIALECT_BASE && (d as usize) < N_DIALECTS {
            f[d as usize] += 1.0;
        } else {
            f[N_FEATURES - 1] += 1.0;
        }
    }
    let n = tokens.len() as f32;
    for v in &mut f {
        *v /= n;
    }
    f
}

/// Deterministic per-request features: a pseudo-token window derived
/// from the request's `payload_seed` — the same seed that drives the
/// payload tokens at execute time — histogrammed through
/// [`features_from_tokens`].  Each request leans toward one task
/// dialect (seeded), so gated traffic spreads across experts instead of
/// collapsing onto one, while staying exactly replayable.
pub fn request_features(payload_seed: u64) -> [f32; N_FEATURES] {
    let mut rng = Rng::new(payload_seed).stream("gate/features");
    let lean = rng.below(N_DIALECTS) as i32;
    let mut tokens = [0i32; REQUEST_WINDOW];
    for t in tokens.iter_mut() {
        // 3:1 leaned-dialect to anywhere — enough signal for a linear
        // gate, enough noise that top-k weights differ across requests.
        *t = if rng.below(4) < 3 {
            DIALECT_BASE + lean * DIALECT_SIZE + rng.below(DIALECT_SIZE as usize) as i32
        } else {
            rng.below(256) as i32
        };
    }
    features_from_tokens(&tokens)
}

/// A deterministic per-request expert selector.  `select` must be a pure
/// function of its inputs (see the module docs — the fleet's replay and
/// bit-identity guarantees depend on it); implementations carry their
/// own parameters and are seedable at construction.
pub trait Gate: Send + Sync {
    /// Stable short name for reports ("linear", ...).
    fn kind(&self) -> &'static str;

    /// Resolve one request's features into a concrete selection over
    /// `roster` (the expert pool's currently-active experts, sorted).
    /// Returns a canonical weighted [`Selection::Set`]; errors with
    /// [`ServeError::Gate`] when no scorable expert is active.
    fn select(
        &self,
        features: &[f32; N_FEATURES],
        roster: &[String],
    ) -> Result<Selection, ServeError>;
}

/// Linear/softmax top-k scorer: `scores = W·features + b`, softmax over
/// the roster-active experts, keep the top-k by probability (name-ordered
/// on ties), renormalize to fusion weights.  Parameters come from
/// [`crate::train::gate::train_gate`] or a seeded random init.
#[derive(Clone, Debug)]
pub struct LinearGate {
    experts: Vec<String>,
    /// Row-major `experts.len() x N_FEATURES` score matrix.
    w: Vec<f32>,
    b: Vec<f32>,
    top_k: usize,
}

impl LinearGate {
    /// Gate over `experts` with explicit parameters (the trainer's exit
    /// path).  `w` is row-major `experts.len() x N_FEATURES`; `top_k` is
    /// clamped to at least 1.
    pub fn new(experts: &[String], top_k: usize, w: Vec<f32>, b: Vec<f32>) -> LinearGate {
        debug_assert_eq!(w.len(), experts.len() * N_FEATURES);
        debug_assert_eq!(b.len(), experts.len());
        LinearGate {
            experts: experts.to_vec(),
            w,
            b,
            top_k: top_k.max(1),
        }
    }

    /// Untrained gate with small seeded-random parameters — deterministic
    /// per seed, useful for plumbing tests that don't care about routing
    /// quality.
    pub fn seeded(experts: &[String], top_k: usize, seed: u64) -> LinearGate {
        let mut rng = Rng::new(seed).stream("gate/init");
        let mut w = vec![0.0f32; experts.len() * N_FEATURES];
        rng.fill_normal(&mut w, 0.0, 0.5);
        let mut b = vec![0.0f32; experts.len()];
        rng.fill_normal(&mut b, 0.0, 0.1);
        LinearGate::new(experts, top_k, w, b)
    }

    /// The experts this gate scores, in parameter order.
    pub fn experts(&self) -> &[String] {
        &self.experts
    }

    /// Experts kept per selection.
    pub fn top_k(&self) -> usize {
        self.top_k
    }

    /// Raw linear score of expert row `i` on `features`.
    fn score(&self, i: usize, features: &[f32; N_FEATURES]) -> f32 {
        let row = &self.w[i * N_FEATURES..(i + 1) * N_FEATURES];
        let mut s = self.b[i];
        for (w, f) in row.iter().zip(features.iter()) {
            s += w * f;
        }
        s
    }
}

impl Gate for LinearGate {
    fn kind(&self) -> &'static str {
        "linear"
    }

    fn select(
        &self,
        features: &[f32; N_FEATURES],
        roster: &[String],
    ) -> Result<Selection, ServeError> {
        // Score only experts the pool currently serves: a retired expert
        // drops out of gating the moment it leaves the roster, with no
        // retraining (its probability mass redistributes in the softmax).
        let mut scored: Vec<(f32, &str)> = self
            .experts
            .iter()
            .enumerate()
            .filter(|(_, n)| roster.iter().any(|r| r == *n))
            .map(|(i, n)| (self.score(i, features), n.as_str()))
            .collect();
        if scored.is_empty() {
            return Err(ServeError::Gate {
                reason: format!(
                    "no active expert to gate over (gate knows {}, roster has {})",
                    self.experts.len(),
                    roster.len()
                ),
            });
        }
        // Stable softmax over the active scores.
        let mut max = f32::NEG_INFINITY;
        for &(s, _) in &scored {
            if s > max {
                max = s;
            }
        }
        let mut z = 0.0f32;
        for (s, _) in scored.iter_mut() {
            *s = (*s - max).exp();
            z += *s;
        }
        for (s, _) in scored.iter_mut() {
            *s /= z;
        }
        // Top-k by probability, name-ascending on exact ties so equal
        // scores cannot make the selection order-dependent.
        scored.sort_by(|a, b| {
            b.0.partial_cmp(&a.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.1.cmp(b.1))
        });
        scored.truncate(self.top_k.min(scored.len()));
        let kept: f32 = scored.iter().map(|(p, _)| p).sum();
        // Canonical set form: members sorted by name, weights summing
        // to 1 over the kept experts.
        let mut members: Vec<(String, f32)> = scored
            .into_iter()
            .map(|(p, n)| (n.to_string(), p / kept))
            .collect();
        members.sort_by(|a, b| a.0.cmp(&b.0));
        let sel = Selection::Set { members };
        sel.validate()?;
        Ok(sel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("adapter{i}")).collect()
    }

    #[test]
    fn features_histogram_dialects_and_normalize() {
        // 16 is dialect 0's first token; 16+28 dialect 1's; 0 is PAD.
        let f = features_from_tokens(&[16, 16, 44, 0]);
        assert_eq!(f[0], 0.5);
        assert_eq!(f[1], 0.25);
        assert_eq!(f[N_FEATURES - 1], 0.25);
        assert!((f.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert_eq!(features_from_tokens(&[]), [0.0; N_FEATURES]);
        // Tokens below the dialect base and above the last dialect both
        // land in the "other" bin (no negative-index panic).
        let f = features_from_tokens(&[0, 15, 16 + 8 * 28, 255]);
        assert_eq!(f[N_FEATURES - 1], 1.0);
    }

    #[test]
    fn request_features_are_deterministic_and_varied() {
        assert_eq!(request_features(7), request_features(7));
        // Across many seeds, different requests lean different ways.
        let leads: std::collections::HashSet<usize> = (0..64u64)
            .map(|s| {
                let f = request_features(s);
                (0..N_FEATURES)
                    .max_by(|&a, &b| f[a].partial_cmp(&f[b]).unwrap())
                    .unwrap()
            })
            .collect();
        assert!(leads.len() >= 4, "request features collapsed: {leads:?}");
    }

    #[test]
    fn seeded_gate_is_deterministic_and_emits_canonical_sets() {
        let ex = names(6);
        let g1 = LinearGate::seeded(&ex, 2, 42);
        let g2 = LinearGate::seeded(&ex, 2, 42);
        let g3 = LinearGate::seeded(&ex, 2, 43);
        let f = request_features(11);
        let s1 = g1.select(&f, &ex).unwrap();
        assert_eq!(s1, g2.select(&f, &ex).unwrap());
        assert_ne!(
            (0..32u64)
                .map(|s| g1.select(&request_features(s), &ex).unwrap().key())
                .collect::<Vec<_>>(),
            (0..32u64)
                .map(|s| g3.select(&request_features(s), &ex).unwrap().key())
                .collect::<Vec<_>>(),
            "different gate seeds should route at least one request differently"
        );
        match &s1 {
            Selection::Set { members } => {
                assert_eq!(members.len(), 2);
                assert!(members.windows(2).all(|w| w[0].0 < w[1].0), "sorted");
                let sum: f32 = members.iter().map(|(_, w)| w).sum();
                assert!((sum - 1.0).abs() < 1e-5, "weights renormalized: {sum}");
                assert!(members.iter().all(|(_, w)| *w > 0.0));
            }
            other => panic!("expected a set, got {other}"),
        }
        s1.validate().unwrap();
        assert_eq!(g1.kind(), "linear");
    }

    #[test]
    fn roster_restricts_and_empty_roster_errors() {
        let ex = names(4);
        let g = LinearGate::seeded(&ex, 2, 1);
        let f = request_features(3);
        // Only one active expert: the set has exactly that member at 1.0.
        let roster = vec!["adapter2".to_string()];
        match g.select(&f, &roster).unwrap() {
            Selection::Set { members } => {
                assert_eq!(members.len(), 1);
                assert_eq!(members[0].0, "adapter2");
                assert!((members[0].1 - 1.0).abs() < 1e-6);
            }
            other => panic!("expected one-member set, got {other}"),
        }
        // Retiring an expert removes it from every future selection.
        let full = g.select(&f, &ex).unwrap();
        let without: Vec<String> =
            ex.iter().filter(|n| *n != "adapter0").cloned().collect();
        let restricted = g.select(&f, &without).unwrap();
        assert!(!restricted.names().contains(&"adapter0"));
        let _ = full;
        // No overlap between gate and roster: a structured Gate error.
        let err = g.select(&f, &["stranger".to_string()]).unwrap_err();
        assert_eq!(err.kind(), "gate");
        let err = g.select(&f, &[]).unwrap_err();
        assert_eq!(err.kind(), "gate");
    }

    #[test]
    fn top_k_clamps_to_roster_and_one() {
        let ex = names(3);
        // top_k 0 clamps to 1; top_k beyond the roster clamps down.
        let g = LinearGate::seeded(&ex, 0, 5);
        let f = request_features(9);
        assert_eq!(g.select(&f, &ex).unwrap().names().len(), 1);
        let g = LinearGate::seeded(&ex, 10, 5);
        assert_eq!(g.select(&f, &ex).unwrap().names().len(), 3);
    }
}
