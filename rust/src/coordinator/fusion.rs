//! Multi-adapter fusion (paper §3.2, Fig. 3b, Table 4) and the
//! orthogonality/interference analysis behind the concept-loss claim.

use crate::adapter::{LoraAdapter, ShiraAdapter};

/// Interference diagnostics between a set of adapters.
#[derive(Clone, Debug)]
pub struct InterferenceReport {
    /// Mean pairwise support-overlap fraction (0 = perfectly disjoint).
    pub mean_overlap: f64,
    /// Mean pairwise density of `AᵢᵀAⱼ` (paper §3.2's diagnostic);
    /// LoRA's fused AB products make this 1.0 by construction.
    pub mean_ata_density: f64,
    /// Total colliding entries across all pairs and targets.
    pub collisions: usize,
    pub n_adapters: usize,
}

/// Fuse SHiRA adapters by naive sparse addition (the paper's method: no
/// post-processing, no retraining).
pub fn fuse_shira(adapters: &[&ShiraAdapter], name: &str) -> ShiraAdapter {
    assert!(!adapters.is_empty());
    let mut acc = adapters[0].clone();
    for other in &adapters[1..] {
        acc = acc.fuse_with(other, name);
    }
    acc.name = name.to_string();
    acc
}

/// Interference analysis for SHiRA adapters.
pub fn analyze_shira(adapters: &[&ShiraAdapter]) -> InterferenceReport {
    let n = adapters.len();
    let mut overlap_sum = 0.0;
    let mut ata_sum = 0.0;
    let mut pairs = 0usize;
    let mut collisions = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            overlap_sum += adapters[i].overlap_fraction(adapters[j]);
            let mut pair_ata = 0.0;
            let mut targets = 0usize;
            for (tname, d) in &adapters[i].tensors {
                if let Some(od) = adapters[j].find(tname) {
                    let (nnz, total) = d.ata_nnz(od);
                    pair_ata += nnz as f64 / total as f64;
                    targets += 1;
                    collisions += d.overlap(od);
                }
            }
            if targets > 0 {
                ata_sum += pair_ata / targets as f64;
            }
            pairs += 1;
        }
    }
    InterferenceReport {
        mean_overlap: if pairs > 0 { overlap_sum / pairs as f64 } else { 0.0 },
        mean_ata_density: if pairs > 0 { ata_sum / pairs as f64 } else { 0.0 },
        collisions,
        n_adapters: n,
    }
}

/// LoRA multi-adapter "fusion" = fusing every adapter's AB into the base
/// (what the paper's LoRA baseline does).  The interference diagnostic is
/// structural: fused LoRA products are dense, so `A1ᵀA2` density is ~1.
pub fn analyze_lora(adapters: &[&LoraAdapter]) -> InterferenceReport {
    let n = adapters.len();
    let mut collisions = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            for t in &adapters[i].tensors {
                if adapters[j].find(&t.target).is_some() {
                    // every entry of the shared target collides
                    collisions += t.a.rows * t.b.cols;
                }
            }
        }
    }
    InterferenceReport {
        mean_overlap: if n > 1 { 1.0 } else { 0.0 },
        mean_ata_density: if n > 1 { 1.0 } else { 0.0 },
        collisions,
        n_adapters: n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::sparse::SparseDelta;
    use crate::adapter::LoraTensor;
    use crate::model::tensor::Tensor2;
    use crate::util::rng::Rng;

    fn shira(seed: u64, frac: f64) -> ShiraAdapter {
        let mut rng = Rng::new(seed);
        let n = 64;
        let k = ((n * n) as f64 * frac) as usize;
        let mk = |rng: &mut Rng| {
            let idx = rng.sample_indices(n * n, k);
            let mut d = vec![0.0; k];
            rng.fill_normal(&mut d, 0.0, 0.1);
            SparseDelta::new(n, n, idx, d)
        };
        ShiraAdapter {
            name: format!("a{seed}"),
            strategy: "rand".into(),
            tensors: vec![("wq".into(), mk(&mut rng)), ("wk".into(), mk(&mut rng))],
        }
    }

    #[test]
    fn fuse_preserves_disjoint_deltas() {
        let a = shira(1, 0.01);
        let b = shira(2, 0.01);
        let f = fuse_shira(&[&a, &b], "ab");
        // every entry of a survives in f (possibly summed on collision)
        for (tname, d) in &a.tensors {
            let fd = f.find(tname).unwrap();
            for (j, &i) in d.idx.iter().enumerate() {
                let pos = fd.idx.binary_search(&i).expect("index present");
                let other = b.find(tname).and_then(|od| {
                    od.idx.binary_search(&i).ok().map(|p| od.delta[p])
                });
                let want = d.delta[j] + other.unwrap_or(0.0);
                assert!((fd.delta[pos] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn sparse_adapters_interfere_far_less_than_lora() {
        // The §3.2 claim, quantitatively.
        let a = shira(3, 0.01);
        let b = shira(4, 0.01);
        let rep = analyze_shira(&[&a, &b]);
        assert!(rep.mean_ata_density < 0.05, "{rep:?}");
        assert!(rep.mean_overlap < 0.05, "{rep:?}");

        let mk_lora = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut a = Tensor2::zeros(64, 4);
            let mut b = Tensor2::zeros(4, 64);
            rng.fill_normal(&mut a.data, 0.0, 0.1);
            rng.fill_normal(&mut b.data, 0.0, 0.1);
            LoraAdapter {
                name: format!("l{seed}"),
                scale: 1.0,
                tensors: vec![LoraTensor {
                    target: "wq".into(),
                    a,
                    b,
                }],
            }
        };
        let l1 = mk_lora(5);
        let l2 = mk_lora(6);
        let lrep = analyze_lora(&[&l1, &l2]);
        assert_eq!(lrep.mean_ata_density, 1.0);
        assert!(lrep.collisions > rep.collisions * 100);
    }

    #[test]
    fn denser_masks_collide_more() {
        let a1 = shira(7, 0.01);
        let b1 = shira(8, 0.01);
        let a2 = shira(7, 0.10);
        let b2 = shira(8, 0.10);
        let sparse = analyze_shira(&[&a1, &b1]);
        let dense = analyze_shira(&[&a2, &b2]);
        assert!(dense.collisions > sparse.collisions);
        assert!(dense.mean_ata_density > sparse.mean_ata_density);
    }

    #[test]
    fn three_way_fusion() {
        let a = shira(9, 0.01);
        let b = shira(10, 0.01);
        let c = shira(11, 0.01);
        let f = fuse_shira(&[&a, &b, &c], "abc");
        assert_eq!(f.name, "abc");
        let rep = analyze_shira(&[&a, &b, &c]);
        assert_eq!(rep.n_adapters, 3);
        // fused nnz <= sum of parts
        assert!(f.param_count() <= a.param_count() + b.param_count() + c.param_count());
        assert!(f.param_count() >= a.param_count());
    }
}
