//! Multi-adapter fusion (paper §3.2, Fig. 3b, Table 4) and the
//! orthogonality/interference analysis behind the concept-loss claim.
//!
//! This module owns the *serial* fusion reference ([`fuse_shira`]) and the
//! interference diagnostics ([`analyze_shira`] / [`analyze_lora`]).  The
//! incremental fused-mode engine in [`super::fusion_engine`] is verified
//! bit-identical against [`fuse_shira`] and reuses the per-pair collision
//! breakdown ([`InterferenceReport::pairs`]) to pick a conflict-free
//! scatter order.

use crate::adapter::{LoraAdapter, ShiraAdapter};

/// Errors from fusion construction and the incremental fusion engine.
#[derive(Clone, Debug, PartialEq)]
pub enum FusionError {
    /// A fusion was requested over zero adapters.
    EmptySet,
    /// Two adapters in the set do not target the same tensor names.
    TargetSetMismatch {
        /// Name of the reference adapter (first in the set).
        first: String,
        /// Name of the adapter whose target set differs.
        other: String,
    },
    /// Two adapters target the same tensor with different shapes.
    ShapeMismatch {
        /// Target tensor name.
        target: String,
        /// (rows, cols) of the reference adapter's delta.
        expect: (usize, usize),
        /// (rows, cols) of the mismatching adapter's delta.
        got: (usize, usize),
    },
    /// The same adapter name appears twice in a roster or set spec.
    DuplicateMember(String),
    /// A set operation named an adapter outside the plan's roster.
    UnknownMember(String),
    /// The roster exceeds the engine's member-index width.
    RosterTooLarge(usize),
    /// An engine operation was issued before [`activate`] snapshotted the
    /// base weights.
    ///
    /// [`activate`]: super::fusion_engine::FusionEngine::activate
    NotActive,
    /// The weight store is missing a tensor the plan targets.
    MissingTarget(String),
}

impl std::fmt::Display for FusionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FusionError::EmptySet => write!(f, "fusion over an empty adapter set"),
            FusionError::TargetSetMismatch { first, other } => write!(
                f,
                "adapters {first:?} and {other:?} target different tensor sets"
            ),
            FusionError::ShapeMismatch {
                target,
                expect,
                got,
            } => write!(
                f,
                "target {target:?}: shape {got:?} does not match {expect:?}"
            ),
            FusionError::DuplicateMember(n) => {
                write!(f, "adapter {n:?} appears more than once")
            }
            FusionError::UnknownMember(n) => {
                write!(f, "adapter {n:?} is not in the fusion roster")
            }
            FusionError::RosterTooLarge(n) => {
                write!(f, "fusion roster of {n} adapters exceeds the engine limit")
            }
            FusionError::NotActive => {
                write!(f, "fusion engine not activated on a weight store")
            }
            FusionError::MissingTarget(t) => {
                write!(f, "weight store has no tensor {t:?}")
            }
        }
    }
}

impl std::error::Error for FusionError {}

/// Validate that every adapter targets the same tensor names with the same
/// shapes as the first one.  Shared by [`fuse_shira`] and
/// [`super::fusion_engine::FusionPlan::build`].
pub(crate) fn validate_target_sets(adapters: &[&ShiraAdapter]) -> Result<(), FusionError> {
    let first = adapters[0];
    let mut names: Vec<&str> = first.tensors.iter().map(|(n, _)| n.as_str()).collect();
    names.sort_unstable();
    for a in &adapters[1..] {
        let mut an: Vec<&str> = a.tensors.iter().map(|(n, _)| n.as_str()).collect();
        an.sort_unstable();
        if an != names {
            return Err(FusionError::TargetSetMismatch {
                first: first.name.clone(),
                other: a.name.clone(),
            });
        }
        for (tname, d) in &a.tensors {
            let d0 = first.find(tname).expect("name set already matched");
            if (d.rows, d.cols) != (d0.rows, d0.cols) {
                return Err(FusionError::ShapeMismatch {
                    target: tname.clone(),
                    expect: (d0.rows, d0.cols),
                    got: (d.rows, d.cols),
                });
            }
        }
    }
    Ok(())
}

/// Interference between one pair of adapters — the per-pair breakdown of
/// [`InterferenceReport`].  The fusion engine reads `collisions` to decide
/// which adapters may scatter concurrently (zero collisions ⇒ disjoint
/// writes ⇒ same parallel wave).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PairInterference {
    /// Index of the first adapter in the analyzed slice.
    pub i: usize,
    /// Index of the second adapter (`i < j`).
    pub j: usize,
    /// Entries where both supports hit the same weight element, summed
    /// over shared target tensors.
    pub collisions: usize,
    /// Support-overlap fraction for this pair (0 = disjoint).
    pub overlap: f64,
    /// Density of `AᵢᵀAⱼ` for this pair (paper §3.2's diagnostic).
    pub ata_density: f64,
}

/// Interference diagnostics between a set of adapters.
#[derive(Clone, Debug)]
pub struct InterferenceReport {
    /// Mean pairwise support-overlap fraction (0 = perfectly disjoint).
    pub mean_overlap: f64,
    /// Mean pairwise density of `AᵢᵀAⱼ` (paper §3.2's diagnostic);
    /// LoRA's fused AB products make this 1.0 by construction.
    pub mean_ata_density: f64,
    /// Total colliding entries across all pairs and targets.
    pub collisions: usize,
    /// Number of adapters analyzed.
    pub n_adapters: usize,
    /// Per-pair breakdown (one entry per unordered pair `i < j`) — the
    /// same shape the incremental fusion engine computes at plan-build
    /// time as its collision diagnostic.
    pub pairs: Vec<PairInterference>,
}

/// Fuse SHiRA adapters by naive sparse addition (the paper's method: no
/// post-processing, no retraining).
///
/// The adapters must all target the same tensor names with the same
/// shapes; a mismatched set returns [`FusionError::TargetSetMismatch`] or
/// [`FusionError::ShapeMismatch`] instead of silently producing a partial
/// fusion.  This left-fold merge is the bit-exact reference the
/// incremental [`super::fusion_engine::FusionEngine`] is verified against.
///
/// # Examples
///
/// ```
/// use shira::adapter::sparse::SparseDelta;
/// use shira::adapter::ShiraAdapter;
/// use shira::coordinator::fusion::fuse_shira;
///
/// let mk = |name: &str, idx: Vec<u32>, val: f32| {
///     let k = idx.len();
///     ShiraAdapter {
///         name: name.into(),
///         strategy: "rand".into(),
///         tensors: vec![("w".into(), SparseDelta::new(2, 4, idx, vec![val; k]))],
///     }
/// };
/// let a = mk("a", vec![0, 3], 1.0);
/// let b = mk("b", vec![3, 6], 2.0);
/// let fused = fuse_shira(&[&a, &b], "a+b").unwrap();
/// let d = fused.find("w").unwrap();
/// assert_eq!(d.idx, vec![0, 3, 6]);   // union support
/// assert_eq!(d.delta[1], 3.0);        // collision sums
/// ```
pub fn fuse_shira(adapters: &[&ShiraAdapter], name: &str) -> Result<ShiraAdapter, FusionError> {
    if adapters.is_empty() {
        return Err(FusionError::EmptySet);
    }
    validate_target_sets(adapters)?;
    let mut acc = adapters[0].clone();
    for other in &adapters[1..] {
        acc = acc.fuse_with(other, name);
    }
    acc.name = name.to_string();
    Ok(acc)
}

/// Interference analysis for SHiRA adapters, including the per-pair
/// collision breakdown the fusion engine schedules by.
pub fn analyze_shira(adapters: &[&ShiraAdapter]) -> InterferenceReport {
    let n = adapters.len();
    let mut overlap_sum = 0.0;
    let mut ata_sum = 0.0;
    let mut pairs_n = 0usize;
    let mut collisions = 0usize;
    let mut pairs = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let overlap = adapters[i].overlap_fraction(adapters[j]);
            overlap_sum += overlap;
            let mut pair_ata = 0.0;
            let mut targets = 0usize;
            let mut pair_coll = 0usize;
            for (tname, d) in &adapters[i].tensors {
                if let Some(od) = adapters[j].find(tname) {
                    let (nnz, total) = d.ata_nnz(od);
                    pair_ata += nnz as f64 / total as f64;
                    targets += 1;
                    pair_coll += d.overlap(od);
                }
            }
            let ata_density = if targets > 0 {
                pair_ata / targets as f64
            } else {
                0.0
            };
            if targets > 0 {
                ata_sum += ata_density;
            }
            collisions += pair_coll;
            pairs.push(PairInterference {
                i,
                j,
                collisions: pair_coll,
                overlap,
                ata_density,
            });
            pairs_n += 1;
        }
    }
    InterferenceReport {
        mean_overlap: if pairs_n > 0 {
            overlap_sum / pairs_n as f64
        } else {
            0.0
        },
        mean_ata_density: if pairs_n > 0 {
            ata_sum / pairs_n as f64
        } else {
            0.0
        },
        collisions,
        n_adapters: n,
        pairs,
    }
}

/// LoRA multi-adapter "fusion" = fusing every adapter's AB into the base
/// (what the paper's LoRA baseline does).  The interference diagnostic is
/// structural: fused LoRA products are dense, so `A1ᵀA2` density is ~1.
pub fn analyze_lora(adapters: &[&LoraAdapter]) -> InterferenceReport {
    let n = adapters.len();
    let mut collisions = 0usize;
    let mut pairs = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let mut pair_coll = 0usize;
            let mut shared = 0usize;
            for t in &adapters[i].tensors {
                if adapters[j].find(&t.target).is_some() {
                    // every entry of the shared target collides
                    pair_coll += t.a.rows * t.b.cols;
                    shared += 1;
                }
            }
            collisions += pair_coll;
            // dense products interfere totally — but only where the two
            // adapters actually share a target tensor
            let structural = if shared > 0 { 1.0 } else { 0.0 };
            pairs.push(PairInterference {
                i,
                j,
                collisions: pair_coll,
                overlap: structural,
                ata_density: structural,
            });
        }
    }
    InterferenceReport {
        mean_overlap: if n > 1 { 1.0 } else { 0.0 },
        mean_ata_density: if n > 1 { 1.0 } else { 0.0 },
        collisions,
        n_adapters: n,
        pairs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::sparse::SparseDelta;
    use crate::adapter::LoraTensor;
    use crate::model::tensor::Tensor2;
    use crate::util::rng::Rng;

    fn shira(seed: u64, frac: f64) -> ShiraAdapter {
        let mut rng = Rng::new(seed);
        let n = 64;
        let k = ((n * n) as f64 * frac) as usize;
        let mk = |rng: &mut Rng| {
            let idx = rng.sample_indices(n * n, k);
            let mut d = vec![0.0; k];
            rng.fill_normal(&mut d, 0.0, 0.1);
            SparseDelta::new(n, n, idx, d)
        };
        ShiraAdapter {
            name: format!("a{seed}"),
            strategy: "rand".into(),
            tensors: vec![("wq".into(), mk(&mut rng)), ("wk".into(), mk(&mut rng))],
        }
    }

    #[test]
    fn fuse_preserves_disjoint_deltas() {
        let a = shira(1, 0.01);
        let b = shira(2, 0.01);
        let f = fuse_shira(&[&a, &b], "ab").unwrap();
        // every entry of a survives in f (possibly summed on collision)
        for (tname, d) in &a.tensors {
            let fd = f.find(tname).unwrap();
            for (j, &i) in d.idx.iter().enumerate() {
                let pos = fd.idx.binary_search(&i).expect("index present");
                let other = b.find(tname).and_then(|od| {
                    od.idx.binary_search(&i).ok().map(|p| od.delta[p])
                });
                let want = d.delta[j] + other.unwrap_or(0.0);
                assert!((fd.delta[pos] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn empty_set_is_an_error() {
        assert_eq!(fuse_shira(&[], "none"), Err(FusionError::EmptySet));
    }

    #[test]
    fn mismatched_target_sets_are_an_error() {
        let a = shira(20, 0.01);
        let mut b = shira(21, 0.01);
        b.tensors.push(("wv".into(), SparseDelta::new(64, 64, vec![1], vec![1.0])));
        match fuse_shira(&[&a, &b], "bad") {
            Err(FusionError::TargetSetMismatch { first, other }) => {
                assert_eq!(first, a.name);
                assert_eq!(other, b.name);
            }
            other => panic!("expected TargetSetMismatch, got {other:?}"),
        }
    }

    #[test]
    fn mismatched_shapes_are_an_error() {
        let a = shira(22, 0.01);
        let mut b = shira(23, 0.01);
        b.tensors[0].1 = SparseDelta::new(32, 32, vec![0], vec![1.0]);
        match fuse_shira(&[&a, &b], "bad") {
            Err(FusionError::ShapeMismatch { target, expect, got }) => {
                assert_eq!(target, "wq");
                assert_eq!(expect, (64, 64));
                assert_eq!(got, (32, 32));
            }
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
    }

    #[test]
    fn sparse_adapters_interfere_far_less_than_lora() {
        // The §3.2 claim, quantitatively.
        let a = shira(3, 0.01);
        let b = shira(4, 0.01);
        let rep = analyze_shira(&[&a, &b]);
        assert!(rep.mean_ata_density < 0.05, "{rep:?}");
        assert!(rep.mean_overlap < 0.05, "{rep:?}");

        let mk_lora = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut a = Tensor2::zeros(64, 4);
            let mut b = Tensor2::zeros(4, 64);
            rng.fill_normal(&mut a.data, 0.0, 0.1);
            rng.fill_normal(&mut b.data, 0.0, 0.1);
            LoraAdapter {
                name: format!("l{seed}"),
                scale: 1.0,
                tensors: vec![LoraTensor {
                    target: "wq".into(),
                    a,
                    b,
                }],
            }
        };
        let l1 = mk_lora(5);
        let l2 = mk_lora(6);
        let lrep = analyze_lora(&[&l1, &l2]);
        assert_eq!(lrep.mean_ata_density, 1.0);
        assert!(lrep.collisions > rep.collisions * 100);
        assert_eq!(lrep.pairs.len(), 1);
        assert_eq!(lrep.pairs[0].collisions, lrep.collisions);
    }

    #[test]
    fn pair_breakdown_sums_to_totals() {
        let a = shira(30, 0.05);
        let b = shira(31, 0.05);
        let c = shira(32, 0.05);
        let rep = analyze_shira(&[&a, &b, &c]);
        assert_eq!(rep.pairs.len(), 3);
        let sum: usize = rep.pairs.iter().map(|p| p.collisions).sum();
        assert_eq!(sum, rep.collisions);
        for p in &rep.pairs {
            assert!(p.i < p.j && p.j < 3);
        }
        // self-consistency with a direct pairwise count
        let direct: usize = a
            .tensors
            .iter()
            .map(|(t, d)| d.overlap(b.find(t).unwrap()))
            .sum();
        assert_eq!(rep.pairs[0].collisions, direct);
    }

    #[test]
    fn denser_masks_collide_more() {
        let a1 = shira(7, 0.01);
        let b1 = shira(8, 0.01);
        let a2 = shira(7, 0.10);
        let b2 = shira(8, 0.10);
        let sparse = analyze_shira(&[&a1, &b1]);
        let dense = analyze_shira(&[&a2, &b2]);
        assert!(dense.collisions > sparse.collisions);
        assert!(dense.mean_ata_density > sparse.mean_ata_density);
    }

    #[test]
    fn three_way_fusion() {
        let a = shira(9, 0.01);
        let b = shira(10, 0.01);
        let c = shira(11, 0.01);
        let f = fuse_shira(&[&a, &b, &c], "abc").unwrap();
        assert_eq!(f.name, "abc");
        let rep = analyze_shira(&[&a, &b, &c]);
        assert_eq!(rep.n_adapters, 3);
        // fused nnz <= sum of parts
        assert!(f.param_count() <= a.param_count() + b.param_count() + c.param_count());
        assert!(f.param_count() >= a.param_count());
    }
}
