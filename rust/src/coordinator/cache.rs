//! Byte-budgeted LRU adapter cache — on-device adapter storage management
//! for the rapid-switching serving loop (the paper's mobile deployment
//! story: many adapters on flash, few resident in RAM).

use std::collections::HashMap;
use std::sync::Arc;

/// Cached entry: the decoded adapter plus its resident byte cost.
pub struct CacheEntry<T> {
    pub value: Arc<T>,
    pub bytes: usize,
}

pub struct LruCache<T> {
    capacity_bytes: usize,
    used_bytes: usize,
    map: HashMap<String, CacheEntry<T>>,
    /// LRU order: front = coldest.
    order: Vec<String>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl<T> LruCache<T> {
    pub fn new(capacity_bytes: usize) -> Self {
        LruCache {
            capacity_bytes,
            used_bytes: 0,
            map: HashMap::new(),
            order: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    fn touch(&mut self, key: &str) {
        if let Some(pos) = self.order.iter().position(|k| k == key) {
            let k = self.order.remove(pos);
            self.order.push(k);
        }
    }

    pub fn get(&mut self, key: &str) -> Option<Arc<T>> {
        if self.map.contains_key(key) {
            self.hits += 1;
            self.touch(key);
            Some(Arc::clone(&self.map[key].value))
        } else {
            self.misses += 1;
            None
        }
    }

    /// Insert (evicting LRU entries until the budget fits).  Entries larger
    /// than the whole budget are admitted alone (budget temporarily
    /// exceeded is a policy choice: serving must not fail).
    pub fn put(&mut self, key: &str, value: T, bytes: usize) -> Arc<T> {
        if let Some(old) = self.map.remove(key) {
            self.used_bytes -= old.bytes;
            self.order.retain(|k| k != key);
        }
        while !self.order.is_empty() && self.used_bytes + bytes > self.capacity_bytes {
            let coldest = self.order.remove(0);
            if let Some(e) = self.map.remove(&coldest) {
                self.used_bytes -= e.bytes;
                self.evictions += 1;
            }
        }
        let arc = Arc::new(value);
        self.map.insert(
            key.to_string(),
            CacheEntry {
                value: Arc::clone(&arc),
                bytes,
            },
        );
        self.used_bytes += bytes;
        self.order.push(key.to_string());
        arc
    }

    /// Fetch or build-and-insert.
    pub fn get_or_insert_with(
        &mut self,
        key: &str,
        build: impl FnOnce() -> (T, usize),
    ) -> Arc<T> {
        if let Some(v) = self.get(key) {
            return v;
        }
        let (value, bytes) = build();
        self.put(key, value, bytes)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;

    #[test]
    fn hit_and_miss_counting() {
        let mut c: LruCache<u32> = LruCache::new(1000);
        assert!(c.get("a").is_none());
        c.put("a", 1, 100);
        assert_eq!(*c.get("a").unwrap(), 1);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert_eq!(c.hit_rate(), 0.5);
    }

    #[test]
    fn evicts_lru_when_over_budget() {
        let mut c: LruCache<u32> = LruCache::new(250);
        c.put("a", 1, 100);
        c.put("b", 2, 100);
        let _ = c.get("a"); // a becomes hottest
        c.put("c", 3, 100); // must evict b
        assert!(c.get("b").is_none());
        assert!(c.get("a").is_some());
        assert!(c.get("c").is_some());
        assert_eq!(c.evictions, 1);
        assert!(c.used_bytes() <= 250);
    }

    #[test]
    fn oversized_entry_admitted_alone() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("big", 1, 500);
        assert!(c.get("big").is_some());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn replace_updates_bytes() {
        let mut c: LruCache<u32> = LruCache::new(300);
        c.put("a", 1, 100);
        c.put("a", 2, 200);
        assert_eq!(c.used_bytes(), 200);
        assert_eq!(*c.get("a").unwrap(), 2);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn get_or_insert_builds_once() {
        let mut c: LruCache<u32> = LruCache::new(300);
        let mut builds = 0;
        for _ in 0..3 {
            let v = c.get_or_insert_with("k", || {
                builds += 1;
                (7, 10)
            });
            assert_eq!(*v, 7);
        }
        assert_eq!(builds, 1);
    }

    #[test]
    fn prop_used_bytes_invariant() {
        // After any operation sequence, used_bytes == sum of live entries
        // and (when >1 entry) stays within budget.
        pt::forall(
            11,
            40,
            |r| {
                let n = 1 + r.below(30);
                (0..n)
                    .map(|_| (r.below(6), 1 + r.below(120)))
                    .collect::<Vec<(usize, usize)>>()
            },
            |ops| {
                let mut c: LruCache<usize> = LruCache::new(256);
                for &(key, bytes) in ops {
                    c.put(&format!("k{key}"), key, bytes);
                }
                let sum: usize = c
                    .order
                    .iter()
                    .map(|k| c.map.get(k).map(|e| e.bytes).unwrap_or(0))
                    .sum();
                sum == c.used_bytes && c.map.len() == c.order.len()
            },
        );
    }
}
