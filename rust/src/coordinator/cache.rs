//! Byte-budgeted LRU adapter cache — on-device adapter storage management
//! for the rapid-switching serving loop (the paper's mobile deployment
//! story: many adapters on flash, few resident in RAM).
//!
//! Recency is an intrusive doubly-linked list threaded through a slab of
//! nodes, with a name→slot map: `get`/`put`/evict are O(1) per entry (the
//! previous implementation kept a `Vec<String>` order list whose touch and
//! evict were O(n) scans with O(n) shifts — measurable at serving rates
//! with many resident adapters).

use std::collections::HashMap;
use std::sync::Arc;

const NIL: usize = usize::MAX;

struct Node<T> {
    key: String,
    value: Arc<T>,
    bytes: usize,
    /// Pin refcount: while nonzero this entry is never evicted.
    pins: u32,
    prev: usize,
    next: usize,
}

/// Byte-budgeted LRU cache keyed by name, with O(1) get/put/evict,
/// refcounted pinning ([`Self::pin`]: pinned entries are never evicted),
/// and an uncached-passthrough policy for oversized entries: a `put`
/// whose byte cost exceeds the whole budget returns its `Arc` without
/// inserting — and without flushing resident entries to make room for a
/// value that could never fit.
///
/// # Examples
///
/// ```
/// use shira::coordinator::cache::LruCache;
///
/// let mut c: LruCache<u32> = LruCache::new(200);
/// c.put("a", 1, 100);
/// c.put("b", 2, 100);
/// assert_eq!(*c.get("a").unwrap(), 1);    // touches "a"
/// c.put("c", 3, 100);                     // evicts coldest ("b")
/// assert!(c.get("b").is_none());
/// assert_eq!(c.used_bytes(), 200);
/// let big = c.put("big", 9, 500);         // oversized: served uncached
/// assert_eq!(*big, 9);
/// assert!(c.get("big").is_none());        // not resident...
/// assert!(c.get("a").is_some());          // ...and nothing was flushed
/// ```
pub struct LruCache<T> {
    capacity_bytes: usize,
    used_bytes: usize,
    map: HashMap<String, usize>,
    /// Slab of nodes; freed slots are recycled via `free`.
    slab: Vec<Option<Node<T>>>,
    free: Vec<usize>,
    /// Intrusive list: head = coldest, tail = hottest.
    head: usize,
    tail: usize,
    /// Lookups that found a resident entry.
    pub hits: u64,
    /// Lookups that missed.
    pub misses: u64,
    /// Entries evicted to fit the byte budget.
    pub evictions: u64,
    /// Oversized puts served uncached (byte cost > whole budget).
    pub oversized: u64,
}

impl<T> LruCache<T> {
    /// Empty cache with the given byte budget.
    pub fn new(capacity_bytes: usize) -> Self {
        LruCache {
            capacity_bytes,
            used_bytes: 0,
            map: HashMap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
            evictions: 0,
            oversized: 0,
        }
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// The byte budget this cache evicts to fit.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    /// True when no entries are resident.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Sum of the byte costs of resident entries.
    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    fn node(&self, i: usize) -> &Node<T> {
        self.slab[i].as_ref().expect("live slot")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node<T> {
        self.slab[i].as_mut().expect("live slot")
    }

    /// Detach slot `i` from the recency list.
    fn unlink(&mut self, i: usize) {
        let (prev, next) = {
            let n = self.node(i);
            (n.prev, n.next)
        };
        if prev != NIL {
            self.node_mut(prev).next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.node_mut(next).prev = prev;
        } else {
            self.tail = prev;
        }
    }

    /// Append slot `i` as hottest.
    fn push_tail(&mut self, i: usize) {
        let old_tail = self.tail;
        {
            let n = self.node_mut(i);
            n.prev = old_tail;
            n.next = NIL;
        }
        if old_tail != NIL {
            self.node_mut(old_tail).next = i;
        } else {
            self.head = i;
        }
        self.tail = i;
    }

    /// Remove slot `i` entirely, returning its byte cost.
    fn remove_slot(&mut self, i: usize) -> usize {
        self.unlink(i);
        let node = self.slab[i].take().expect("live slot");
        self.free.push(i);
        self.map.remove(&node.key);
        node.bytes
    }

    /// Fetch by name, marking the entry hottest on a hit.
    pub fn get(&mut self, key: &str) -> Option<Arc<T>> {
        if let Some(&i) = self.map.get(key) {
            self.hits += 1;
            self.unlink(i);
            self.push_tail(i);
            Some(Arc::clone(&self.node(i).value))
        } else {
            self.misses += 1;
            None
        }
    }

    /// Fetch by name without touching recency or the hit/miss counters
    /// (for residency probes such as prefetch planning).
    pub fn peek(&self, key: &str) -> Option<Arc<T>> {
        self.map
            .get(key)
            .map(|&i| Arc::clone(&self.node(i).value))
    }

    /// Add a pin to `key` (refcounted): pinned entries are skipped by
    /// eviction, so an adapter in an active fusion roster or an in-flight
    /// switch stays resident under any cache pressure.  Returns false when
    /// `key` is not resident (nothing to pin — callers holding an `Arc`
    /// keep the value alive regardless).
    pub fn pin(&mut self, key: &str) -> bool {
        match self.map.get(key).copied() {
            Some(i) => {
                self.node_mut(i).pins += 1;
                true
            }
            None => false,
        }
    }

    /// Drop one pin from `key`.  Returns false when `key` is not resident
    /// or not pinned.
    pub fn unpin(&mut self, key: &str) -> bool {
        match self.map.get(key).copied() {
            Some(i) if self.node(i).pins > 0 => {
                self.node_mut(i).pins -= 1;
                true
            }
            _ => false,
        }
    }

    /// True when `key` is resident with at least one pin.
    pub fn is_pinned(&self, key: &str) -> bool {
        self.map
            .get(key)
            .map(|&i| self.node(i).pins > 0)
            .unwrap_or(false)
    }

    /// Number of resident entries holding at least one pin — the leak
    /// audit probe: after any failed request this must return to its
    /// pre-request baseline.
    pub fn pinned_entries(&self) -> usize {
        self.map
            .values()
            .filter(|&&i| self.node(i).pins > 0)
            .count()
    }

    /// Insert, evicting unpinned LRU entries until the budget fits.
    ///
    /// * **Oversized** (`bytes > capacity`): the value is returned as an
    ///   uncached `Arc` — resident entries are NOT flushed for a value
    ///   that could never fit (serving must not fail, and the rest of the
    ///   working set must not pay for it).  Replacing a resident key with
    ///   an oversized value drops the old entry (and its pins).
    /// * **Pinned** entries are skipped by the eviction scan; when only
    ///   pinned entries remain the budget is temporarily exceeded.
    pub fn put(&mut self, key: &str, value: T, bytes: usize) -> Arc<T> {
        let mut inherited_pins = 0u32;
        if let Some(&i) = self.map.get(key) {
            inherited_pins = self.node(i).pins;
            self.used_bytes -= self.remove_slot(i);
        }
        if bytes > self.capacity_bytes {
            self.oversized += 1;
            return Arc::new(value);
        }
        let mut cur = self.head;
        while cur != NIL && self.used_bytes + bytes > self.capacity_bytes {
            let next = self.node(cur).next;
            if self.node(cur).pins == 0 {
                self.used_bytes -= self.remove_slot(cur);
                self.evictions += 1;
            }
            cur = next;
        }
        let arc = Arc::new(value);
        let node = Node {
            key: key.to_string(),
            value: Arc::clone(&arc),
            bytes,
            pins: inherited_pins,
            prev: NIL,
            next: NIL,
        };
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s] = Some(node);
                s
            }
            None => {
                self.slab.push(Some(node));
                self.slab.len() - 1
            }
        };
        self.push_tail(slot);
        self.map.insert(key.to_string(), slot);
        self.used_bytes += bytes;
        arc
    }

    /// Fetch or build-and-insert.
    pub fn get_or_insert_with(
        &mut self,
        key: &str,
        build: impl FnOnce() -> (T, usize),
    ) -> Arc<T> {
        if let Some(v) = self.get(key) {
            return v;
        }
        let (value, bytes) = build();
        self.put(key, value, bytes)
    }

    /// hits / (hits + misses), 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Keys coldest-first — the recency order (diagnostics / tests).
    pub fn keys_lru_order(&self) -> Vec<&str> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut cur = self.head;
        while cur != NIL {
            let n = self.node(cur);
            out.push(n.key.as_str());
            cur = n.next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;

    #[test]
    fn hit_and_miss_counting() {
        let mut c: LruCache<u32> = LruCache::new(1000);
        assert!(c.get("a").is_none());
        c.put("a", 1, 100);
        assert_eq!(*c.get("a").unwrap(), 1);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert_eq!(c.hit_rate(), 0.5);
    }

    #[test]
    fn evicts_lru_when_over_budget() {
        let mut c: LruCache<u32> = LruCache::new(250);
        c.put("a", 1, 100);
        c.put("b", 2, 100);
        let _ = c.get("a"); // a becomes hottest
        c.put("c", 3, 100); // must evict b
        assert!(c.get("b").is_none());
        assert!(c.get("a").is_some());
        assert!(c.get("c").is_some());
        assert_eq!(c.evictions, 1);
        assert!(c.used_bytes() <= 250);
    }

    #[test]
    fn oversized_entry_served_uncached_without_flush() {
        // Regression (was: evict everything, then admit over budget): an
        // oversized put serves its Arc uncached and leaves residents alone.
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("a", 1, 40);
        c.put("b", 2, 40);
        let big = c.put("big", 9, 500);
        assert_eq!(*big, 9);
        assert!(c.get("big").is_none());
        assert!(c.get("a").is_some());
        assert!(c.get("b").is_some());
        assert_eq!(c.evictions, 0);
        assert_eq!(c.oversized, 1);
        assert_eq!(c.used_bytes(), 80);
    }

    #[test]
    fn oversized_replace_drops_old_entry() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("a", 1, 40);
        let big = c.put("a", 2, 500);
        assert_eq!(*big, 2);
        assert!(c.get("a").is_none());
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn pinned_entries_survive_eviction_pressure() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("hot", 1, 60);
        assert!(c.pin("hot"));
        for i in 0..5 {
            c.put(&format!("k{i}"), i, 60);
        }
        // "hot" is the coldest entry but pinned: never evicted.
        assert!(c.get("hot").is_some());
        assert!(c.is_pinned("hot"));
        assert!(c.evictions > 0);
        assert!(c.unpin("hot"));
        assert!(!c.is_pinned("hot"));
        c.put("k9", 9, 60);
        // unpinned now — "hot" was touched by get above, so the coldest
        // unpinned entry goes first; flood until "hot" must go too.
        c.put("k10", 10, 60);
        assert!(c.peek("hot").is_none());
    }

    #[test]
    fn pin_on_absent_key_is_refused() {
        let mut c: LruCache<u32> = LruCache::new(100);
        assert!(!c.pin("ghost"));
        assert!(!c.unpin("ghost"));
        c.put("a", 1, 10);
        assert!(c.pin("a"));
        assert!(c.pin("a")); // refcounted
        assert!(c.unpin("a"));
        assert!(c.is_pinned("a")); // one pin still held
        assert!(c.unpin("a"));
        assert!(!c.unpin("a"));
    }

    #[test]
    fn pinned_entries_counts_distinct_pinned_keys() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("a", 1, 10);
        c.put("b", 2, 10);
        assert_eq!(c.pinned_entries(), 0);
        c.pin("a");
        c.pin("a"); // refcount, same entry
        c.pin("b");
        assert_eq!(c.pinned_entries(), 2);
        c.unpin("a");
        assert_eq!(c.pinned_entries(), 2); // "a" still holds one pin
        c.unpin("a");
        c.unpin("b");
        assert_eq!(c.pinned_entries(), 0);
    }

    #[test]
    fn peek_does_not_touch_recency_or_counters() {
        let mut c: LruCache<u32> = LruCache::new(1000);
        c.put("a", 1, 10);
        c.put("b", 2, 10);
        assert_eq!(*c.peek("a").unwrap(), 1);
        assert!(c.peek("x").is_none());
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 0);
        assert_eq!(c.keys_lru_order(), vec!["a", "b"]);
    }

    #[test]
    fn replace_updates_bytes() {
        let mut c: LruCache<u32> = LruCache::new(300);
        c.put("a", 1, 100);
        c.put("a", 2, 200);
        assert_eq!(c.used_bytes(), 200);
        assert_eq!(*c.get("a").unwrap(), 2);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn get_or_insert_builds_once() {
        let mut c: LruCache<u32> = LruCache::new(300);
        let mut builds = 0;
        for _ in 0..3 {
            let v = c.get_or_insert_with("k", || {
                builds += 1;
                (7, 10)
            });
            assert_eq!(*v, 7);
        }
        assert_eq!(builds, 1);
    }

    #[test]
    fn recency_order_tracks_gets_and_puts() {
        let mut c: LruCache<u32> = LruCache::new(10_000);
        for (k, v) in [("a", 1), ("b", 2), ("c", 3)] {
            c.put(k, v, 10);
        }
        assert_eq!(c.keys_lru_order(), vec!["a", "b", "c"]);
        let _ = c.get("a");
        assert_eq!(c.keys_lru_order(), vec!["b", "c", "a"]);
        c.put("b", 9, 10); // replace re-inserts as hottest
        assert_eq!(c.keys_lru_order(), vec!["c", "a", "b"]);
    }

    /// Reference model: a Vec-order implementation of the full policy
    /// (recency, oversized passthrough, pins), kept as the behavioral
    /// oracle for the O(1) list version.
    struct ModelCache {
        cap: usize,
        used: usize,
        entries: Vec<(String, u32, usize, u32)>, // coldest-first; .3 = pins
        hits: u64,
        misses: u64,
        evictions: u64,
        oversized: u64,
    }

    impl ModelCache {
        fn new(cap: usize) -> Self {
            ModelCache {
                cap,
                used: 0,
                entries: Vec::new(),
                hits: 0,
                misses: 0,
                evictions: 0,
                oversized: 0,
            }
        }

        fn get(&mut self, key: &str) -> Option<u32> {
            if let Some(pos) = self.entries.iter().position(|(k, ..)| k == key) {
                self.hits += 1;
                let e = self.entries.remove(pos);
                let v = e.1;
                self.entries.push(e);
                Some(v)
            } else {
                self.misses += 1;
                None
            }
        }

        fn put(&mut self, key: &str, value: u32, bytes: usize) {
            let mut pins = 0u32;
            if let Some(pos) = self.entries.iter().position(|(k, ..)| k == key) {
                let e = self.entries.remove(pos);
                self.used -= e.2;
                pins = e.3;
            }
            if bytes > self.cap {
                self.oversized += 1;
                return;
            }
            // evict unpinned entries coldest-first until the budget fits
            let mut pos = 0usize;
            while pos < self.entries.len() && self.used + bytes > self.cap {
                if self.entries[pos].3 == 0 {
                    let e = self.entries.remove(pos);
                    self.used -= e.2;
                    self.evictions += 1;
                } else {
                    pos += 1;
                }
            }
            self.entries.push((key.to_string(), value, bytes, pins));
            self.used += bytes;
        }

        fn pin(&mut self, key: &str) {
            if let Some(e) = self.entries.iter_mut().find(|(k, ..)| k == key) {
                e.3 += 1;
            }
        }

        fn unpin(&mut self, key: &str) {
            if let Some(e) = self.entries.iter_mut().find(|(k, ..)| k == key) {
                e.3 = e.3.saturating_sub(1);
            }
        }
    }

    #[test]
    fn prop_matches_reference_model() {
        // Any op sequence (get/put/pin/unpin, byte costs up to oversized):
        // identical hits/misses/evictions/oversized, identical recency
        // order, identical byte accounting.
        pt::forall(
            11,
            60,
            |r| {
                let n = 1 + r.below(80);
                (0..n)
                    .map(|_| (r.below(4), r.below(6), 1 + r.below(300)))
                    .collect::<Vec<(usize, usize, usize)>>()
            },
            |ops| {
                let mut real: LruCache<u32> = LruCache::new(256);
                let mut model = ModelCache::new(256);
                for &(op, key, bytes) in ops {
                    let k = format!("k{key}");
                    match op {
                        0 => {
                            let got = real.get(&k).map(|v| *v);
                            let want = model.get(&k);
                            if got != want {
                                return false;
                            }
                        }
                        1 => {
                            real.put(&k, key as u32, bytes);
                            model.put(&k, key as u32, bytes);
                        }
                        2 => {
                            real.pin(&k);
                            model.pin(&k);
                        }
                        _ => {
                            real.unpin(&k);
                            model.unpin(&k);
                        }
                    }
                }
                let order: Vec<String> =
                    real.keys_lru_order().iter().map(|s| s.to_string()).collect();
                let model_order: Vec<String> =
                    model.entries.iter().map(|(k, ..)| k.clone()).collect();
                order == model_order
                    && real.used_bytes() == model.used
                    && real.hits == model.hits
                    && real.misses == model.misses
                    && real.evictions == model.evictions
                    && real.oversized == model.oversized
                    && real.len() == model.entries.len()
            },
        );
    }
}
