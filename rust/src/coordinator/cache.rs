//! Byte-budgeted LRU adapter cache — on-device adapter storage management
//! for the rapid-switching serving loop (the paper's mobile deployment
//! story: many adapters on flash, few resident in RAM).
//!
//! Recency is an intrusive doubly-linked list threaded through a slab of
//! nodes, with a name→slot map: `get`/`put`/evict are O(1) per entry (the
//! previous implementation kept a `Vec<String>` order list whose touch and
//! evict were O(n) scans with O(n) shifts — measurable at serving rates
//! with many resident adapters).

use std::collections::HashMap;
use std::sync::Arc;

const NIL: usize = usize::MAX;

struct Node<T> {
    key: String,
    value: Arc<T>,
    bytes: usize,
    prev: usize,
    next: usize,
}

/// Byte-budgeted LRU cache keyed by name, with O(1) get/put/evict.
///
/// # Examples
///
/// ```
/// use shira::coordinator::cache::LruCache;
///
/// let mut c: LruCache<u32> = LruCache::new(200);
/// c.put("a", 1, 100);
/// c.put("b", 2, 100);
/// assert_eq!(*c.get("a").unwrap(), 1);    // touches "a"
/// c.put("c", 3, 100);                     // evicts coldest ("b")
/// assert!(c.get("b").is_none());
/// assert_eq!(c.used_bytes(), 200);
/// ```
pub struct LruCache<T> {
    capacity_bytes: usize,
    used_bytes: usize,
    map: HashMap<String, usize>,
    /// Slab of nodes; freed slots are recycled via `free`.
    slab: Vec<Option<Node<T>>>,
    free: Vec<usize>,
    /// Intrusive list: head = coldest, tail = hottest.
    head: usize,
    tail: usize,
    /// Lookups that found a resident entry.
    pub hits: u64,
    /// Lookups that missed.
    pub misses: u64,
    /// Entries evicted to fit the byte budget.
    pub evictions: u64,
}

impl<T> LruCache<T> {
    /// Empty cache with the given byte budget.
    pub fn new(capacity_bytes: usize) -> Self {
        LruCache {
            capacity_bytes,
            used_bytes: 0,
            map: HashMap::new(),
            slab: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no entries are resident.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Sum of the byte costs of resident entries.
    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    fn node(&self, i: usize) -> &Node<T> {
        self.slab[i].as_ref().expect("live slot")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node<T> {
        self.slab[i].as_mut().expect("live slot")
    }

    /// Detach slot `i` from the recency list.
    fn unlink(&mut self, i: usize) {
        let (prev, next) = {
            let n = self.node(i);
            (n.prev, n.next)
        };
        if prev != NIL {
            self.node_mut(prev).next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.node_mut(next).prev = prev;
        } else {
            self.tail = prev;
        }
    }

    /// Append slot `i` as hottest.
    fn push_tail(&mut self, i: usize) {
        let old_tail = self.tail;
        {
            let n = self.node_mut(i);
            n.prev = old_tail;
            n.next = NIL;
        }
        if old_tail != NIL {
            self.node_mut(old_tail).next = i;
        } else {
            self.head = i;
        }
        self.tail = i;
    }

    /// Remove slot `i` entirely, returning its byte cost.
    fn remove_slot(&mut self, i: usize) -> usize {
        self.unlink(i);
        let node = self.slab[i].take().expect("live slot");
        self.free.push(i);
        self.map.remove(&node.key);
        node.bytes
    }

    /// Fetch by name, marking the entry hottest on a hit.
    pub fn get(&mut self, key: &str) -> Option<Arc<T>> {
        if let Some(&i) = self.map.get(key) {
            self.hits += 1;
            self.unlink(i);
            self.push_tail(i);
            Some(Arc::clone(&self.node(i).value))
        } else {
            self.misses += 1;
            None
        }
    }

    /// Insert (evicting LRU entries until the budget fits).  Entries larger
    /// than the whole budget are admitted alone (budget temporarily
    /// exceeded is a policy choice: serving must not fail).
    pub fn put(&mut self, key: &str, value: T, bytes: usize) -> Arc<T> {
        if let Some(&i) = self.map.get(key) {
            self.used_bytes -= self.remove_slot(i);
        }
        while self.head != NIL && self.used_bytes + bytes > self.capacity_bytes {
            let coldest = self.head;
            self.used_bytes -= self.remove_slot(coldest);
            self.evictions += 1;
        }
        let arc = Arc::new(value);
        let node = Node {
            key: key.to_string(),
            value: Arc::clone(&arc),
            bytes,
            prev: NIL,
            next: NIL,
        };
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s] = Some(node);
                s
            }
            None => {
                self.slab.push(Some(node));
                self.slab.len() - 1
            }
        };
        self.push_tail(slot);
        self.map.insert(key.to_string(), slot);
        self.used_bytes += bytes;
        arc
    }

    /// Fetch or build-and-insert.
    pub fn get_or_insert_with(
        &mut self,
        key: &str,
        build: impl FnOnce() -> (T, usize),
    ) -> Arc<T> {
        if let Some(v) = self.get(key) {
            return v;
        }
        let (value, bytes) = build();
        self.put(key, value, bytes)
    }

    /// hits / (hits + misses), 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Keys coldest-first — the recency order (diagnostics / tests).
    pub fn keys_lru_order(&self) -> Vec<&str> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut cur = self.head;
        while cur != NIL {
            let n = self.node(cur);
            out.push(n.key.as_str());
            cur = n.next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest as pt;

    #[test]
    fn hit_and_miss_counting() {
        let mut c: LruCache<u32> = LruCache::new(1000);
        assert!(c.get("a").is_none());
        c.put("a", 1, 100);
        assert_eq!(*c.get("a").unwrap(), 1);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert_eq!(c.hit_rate(), 0.5);
    }

    #[test]
    fn evicts_lru_when_over_budget() {
        let mut c: LruCache<u32> = LruCache::new(250);
        c.put("a", 1, 100);
        c.put("b", 2, 100);
        let _ = c.get("a"); // a becomes hottest
        c.put("c", 3, 100); // must evict b
        assert!(c.get("b").is_none());
        assert!(c.get("a").is_some());
        assert!(c.get("c").is_some());
        assert_eq!(c.evictions, 1);
        assert!(c.used_bytes() <= 250);
    }

    #[test]
    fn oversized_entry_admitted_alone() {
        let mut c: LruCache<u32> = LruCache::new(100);
        c.put("big", 1, 500);
        assert!(c.get("big").is_some());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn replace_updates_bytes() {
        let mut c: LruCache<u32> = LruCache::new(300);
        c.put("a", 1, 100);
        c.put("a", 2, 200);
        assert_eq!(c.used_bytes(), 200);
        assert_eq!(*c.get("a").unwrap(), 2);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn get_or_insert_builds_once() {
        let mut c: LruCache<u32> = LruCache::new(300);
        let mut builds = 0;
        for _ in 0..3 {
            let v = c.get_or_insert_with("k", || {
                builds += 1;
                (7, 10)
            });
            assert_eq!(*v, 7);
        }
        assert_eq!(builds, 1);
    }

    #[test]
    fn recency_order_tracks_gets_and_puts() {
        let mut c: LruCache<u32> = LruCache::new(10_000);
        for (k, v) in [("a", 1), ("b", 2), ("c", 3)] {
            c.put(k, v, 10);
        }
        assert_eq!(c.keys_lru_order(), vec!["a", "b", "c"]);
        let _ = c.get("a");
        assert_eq!(c.keys_lru_order(), vec!["b", "c", "a"]);
        c.put("b", 9, 10); // replace re-inserts as hottest
        assert_eq!(c.keys_lru_order(), vec!["c", "a", "b"]);
    }

    /// Reference model: the original Vec-order implementation, kept as the
    /// behavioral oracle for the O(1) list version.
    struct ModelCache {
        cap: usize,
        used: usize,
        entries: Vec<(String, u32, usize)>, // coldest-first
        hits: u64,
        misses: u64,
        evictions: u64,
    }

    impl ModelCache {
        fn new(cap: usize) -> Self {
            ModelCache {
                cap,
                used: 0,
                entries: Vec::new(),
                hits: 0,
                misses: 0,
                evictions: 0,
            }
        }

        fn get(&mut self, key: &str) -> Option<u32> {
            if let Some(pos) = self.entries.iter().position(|(k, _, _)| k == key) {
                self.hits += 1;
                let e = self.entries.remove(pos);
                let v = e.1;
                self.entries.push(e);
                Some(v)
            } else {
                self.misses += 1;
                None
            }
        }

        fn put(&mut self, key: &str, value: u32, bytes: usize) {
            if let Some(pos) = self.entries.iter().position(|(k, _, _)| k == key) {
                let e = self.entries.remove(pos);
                self.used -= e.2;
            }
            while !self.entries.is_empty() && self.used + bytes > self.cap {
                let e = self.entries.remove(0);
                self.used -= e.2;
                self.evictions += 1;
            }
            self.entries.push((key.to_string(), value, bytes));
            self.used += bytes;
        }
    }

    #[test]
    fn prop_matches_reference_model() {
        // Any op sequence: identical hits/misses/evictions, identical
        // recency order, identical byte accounting.
        pt::forall(
            11,
            60,
            |r| {
                let n = 1 + r.below(60);
                (0..n)
                    .map(|_| (r.below(2), r.below(6), 1 + r.below(120)))
                    .collect::<Vec<(usize, usize, usize)>>()
            },
            |ops| {
                let mut real: LruCache<u32> = LruCache::new(256);
                let mut model = ModelCache::new(256);
                for &(op, key, bytes) in ops {
                    let k = format!("k{key}");
                    if op == 0 {
                        let got = real.get(&k).map(|v| *v);
                        let want = model.get(&k);
                        if got != want {
                            return false;
                        }
                    } else {
                        real.put(&k, key as u32, bytes);
                        model.put(&k, key as u32, bytes);
                    }
                }
                let order: Vec<String> =
                    real.keys_lru_order().iter().map(|s| s.to_string()).collect();
                let model_order: Vec<String> =
                    model.entries.iter().map(|(k, _, _)| k.clone()).collect();
                order == model_order
                    && real.used_bytes() == model.used
                    && real.hits == model.hits
                    && real.misses == model.misses
                    && real.evictions == model.evictions
                    && real.len() == model.entries.len()
            },
        );
    }
}
